//! The replicate lever (hot-window read replication + power-of-two-choices
//! routing, the fifth rung of the fleet ladder) end to end — hermetic (no
//! `pjrt` feature, no artifacts):
//!
//! * **Live replication**: under zipf(1.1) the fleet ladder escalates past
//!   migration and publishes a replica set mid-serving with pipelined
//!   tickets in flight — every response stays row-identical, every replica
//!   view aliases the one shared table slab (`Arc` pointer identity — no
//!   row is copied), and the replica set passes its invariants against the
//!   plan.
//! * **P2C routing**: with replicas live, the hot shard's traffic spreads
//!   over owner + replicas (every replica actually serves rows), sampled
//!   in-flight queue depths stay within 2x of the mean, and the depth
//!   gauges drain to zero once every ticket is redeemed.
//! * **Uniform floor**: flat traffic never clears the hot-share gate, so
//!   no replica is ever created.
//! * **De-replication**: when the hotspot subsides the exit-share check
//!   retires every replica (no drain — a ticket submitted before the drop
//!   pins its generation and merges correctly), witnessed in the decision
//!   trace, and the counter identity
//!   `generations == redeal + resplit + migrate + repack + replicate`
//!   holds throughout.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

use a100win::coordinator::{
    AdaptiveConfig, BatcherConfig, CardSpec, ControlPlaneConfig, Lever, ReplicateConfig, Table,
};
use a100win::probe::TopologyMap;
use a100win::service::{
    FleetConfig, FleetService, FleetTicket, HedgeConfig, RebalanceConfig, ResilienceConfig,
    SimTiming,
};
use a100win::sim::{FaultPlan, StallKind};
use a100win::workload::{synth::Distribution, RequestGen, WorkloadSpec};

const CARDS: usize = 3;
const D: usize = 4;
const TOTAL_ROWS: u64 = 8_192;
const ROW_BYTES: u64 = (D * 4) as u64;

fn map(card: usize) -> TopologyMap {
    TopologyMap {
        groups: vec![vec![0, 1], vec![2, 3]],
        reach_bytes: 64 << 30,
        solo_gbps: vec![100.0, 100.0],
        independent: true,
        card_id: format!("replicate-card{card}"),
    }
}

/// Every card can host a whole-table replica on top of its own shard.
fn card(i: usize) -> CardSpec {
    CardSpec {
        map: map(i),
        memory_bytes: TOTAL_ROWS * ROW_BYTES,
    }
}

fn quick_batcher() -> BatcherConfig {
    BatcherConfig {
        max_batch_rows: 4096,
        max_wait: Duration::from_millis(1),
        max_pending: 512,
    }
}

/// A replication-armed fleet with an eager ladder: act on the first
/// failing epoch, no cooldown (manual epochs are already rate-limited by
/// the request loop), so the ladder walks redeal -> resplit -> migrate ->
/// repack -> replicate in a handful of failing epochs.
fn build_fleet(table: &Table, replicate: bool) -> FleetService {
    FleetService::build_sim_with(
        (0..CARDS).map(|i| (card(i), SimTiming::Probed)).collect(),
        table,
        FleetConfig {
            batcher: quick_batcher(),
            seed: 5,
            adaptive: Some(AdaptiveConfig::default()),
            rebalance: RebalanceConfig {
                min_imbalance: 0.15,
                min_epoch_rows: 512,
                min_move_rows: 16,
            },
            control: ControlPlaneConfig {
                min_imbalance: 0.10,
                patience: 1,
                cooldown: 0,
                max_lever: Lever::Migrate, // raised to Replicate when armed
                trace_len: 512,
            },
            // capacity_fraction 0: the demand gate compares wall-clock
            // demand against *simulated* bandwidth, which no test loop can
            // meet; the hot-share gate alone decides.
            replicate: replicate.then(|| ReplicateConfig {
                capacity_fraction: 0.0,
                ..ReplicateConfig::default()
            }),
            ..FleetConfig::default()
        },
    )
    .unwrap()
}

fn spec(distribution: Distribution, seed: u64) -> WorkloadSpec {
    WorkloadSpec {
        total_rows: TOTAL_ROWS,
        distribution,
        request_rows: (512, 512),
        seed,
    }
}

fn verify(out: &[f32], rows: &[u64], table: &Table) {
    assert_eq!(out.len(), rows.len() * D);
    for (k, &row) in rows.iter().enumerate() {
        for j in 0..D {
            assert_eq!(
                out[k * D + j],
                table.expected(row, j),
                "row {row} column {j}"
            );
        }
    }
}

/// Zero-copy discipline for the whole fleet: every owner card *and* every
/// replica unit serves a view whose backing store is the one shared table
/// slab, and each replica's view covers exactly its shard's row range.
fn check_zero_copy(fleet: &FleetService, table: &Table) {
    for svc in fleet.cards() {
        let view = svc.backend().view().expect("sim backends expose views");
        assert!(
            Arc::ptr_eq(view.storage(), &table.data),
            "owner card view does not alias the shared table slab"
        );
    }
    let plan = fleet.plan();
    for (shard, card, svc) in fleet.replica_cards() {
        let view = svc.backend().view().expect("sim backends expose views");
        assert!(
            Arc::ptr_eq(view.storage(), &table.data),
            "replica of shard {shard} on card {card} copied table data"
        );
        assert_eq!(view.start_row(), plan.shards[shard].start_row);
        assert_eq!(view.rows(), plan.shards[shard].rows);
    }
    fleet
        .replica_set()
        .check(&plan, CARDS)
        .expect("published replica set violates invariants");
}

/// `generations == redeal + resplit + migrate + repack + replicate` at
/// fleet scope.
fn check_counters(fleet: &FleetService) {
    let m = fleet.fleet_metrics();
    assert_eq!(
        m.generations_published,
        m.redeal_epochs + m.resplit_epochs + m.migrate_epochs + m.repack_epochs
            + m.replicate_epochs,
        "generation counters inconsistent"
    );
}

/// Drive pipelined zipf traffic with a control epoch per submit until the
/// replicate lever has published, verifying every drained response.
/// Returns the in-flight queue at the moment replication went live.
fn escalate_to_replication(
    fleet: &FleetService,
    table: &Table,
    gen: &mut RequestGen,
) -> VecDeque<(FleetTicket, Arc<Vec<u64>>)> {
    let mut inflight: VecDeque<(FleetTicket, Arc<Vec<u64>>)> = VecDeque::new();
    for _ in 0..60 {
        let rows = Arc::new(gen.next_request());
        let ticket = fleet.submit(Arc::clone(&rows), None).unwrap();
        inflight.push_back((ticket, rows));
        fleet.control_epoch();
        if inflight.len() >= 8 {
            let (t, rows) = inflight.pop_front().unwrap();
            verify(&t.wait().unwrap(), &rows, table);
        }
        if fleet.fleet_metrics().replicas_created > 0 {
            return inflight;
        }
    }
    panic!("zipf(1.1) never escalated to a replication in 60 epochs");
}

// ---------------------------------------------------------------------------
// 1. Live replication: zero-copy, ticket-safe, P2C-routed.
// ---------------------------------------------------------------------------

#[test]
fn replication_is_live_zero_copy_and_p2c_routed() {
    let table = Table::synthetic(TOTAL_ROWS, D);
    let fleet = build_fleet(&table, true);
    let mut gen = RequestGen::new(spec(Distribution::Zipf { theta: 1.1 }, 31));

    // Publication lands while old-generation tickets are in flight —
    // exactly the swap generation pinning must make safe.
    let mut inflight = escalate_to_replication(&fleet, &table, &mut gen);

    let set = fleet.replica_set();
    assert!(!set.is_empty(), "counter says created but set is empty");
    assert!(set.generation > 0);
    assert_eq!(
        set.count(),
        fleet.replica_cards().len(),
        "replica units not position-matched to the set"
    );
    check_zero_copy(&fleet, &table);

    // Tickets split before the publication merge correctly after it.
    for (t, rows) in inflight.drain(..) {
        verify(&t.wait().unwrap(), &rows, &table);
    }

    // P2C phase: keep a depth-8 pipeline and sample the live queue depths
    // once the pipeline is full.
    let mut depth_sum = vec![0u64; CARDS];
    let mut samples = 0u64;
    for _ in 0..120 {
        let rows = Arc::new(gen.next_request());
        let ticket = fleet.submit(Arc::clone(&rows), None).unwrap();
        inflight.push_back((ticket, rows));
        if inflight.len() >= 8 {
            let depths = fleet.queue_depths();
            assert_eq!(depths.len(), CARDS);
            for (s, d) in depth_sum.iter_mut().zip(&depths) {
                *s += d;
            }
            samples += 1;
            let (t, rows) = inflight.pop_front().unwrap();
            verify(&t.wait().unwrap(), &rows, &table);
        }
    }
    for (t, rows) in inflight.drain(..) {
        verify(&t.wait().unwrap(), &rows, &table);
    }

    // Every replica actually served rows — the hot shard's traffic really
    // spread over the candidates (without P2C the owner serves it all).
    for (shard, card, svc) in fleet.replica_cards() {
        assert!(
            svc.metrics().rows > 0,
            "replica of shard {shard} on card {card} never served a row"
        );
    }

    // Depth skew under zipf(1.1): sampled in-flight depth per card stays
    // within 2x of the fleet mean.
    assert!(samples > 0);
    let means: Vec<f64> = depth_sum.iter().map(|&s| s as f64 / samples as f64).collect();
    let mean = means.iter().sum::<f64>() / CARDS as f64;
    let max = means.iter().fold(0.0f64, |a, &b| a.max(b));
    assert!(mean > 0.0, "no in-flight depth was ever observed");
    assert!(
        max / mean <= 2.0,
        "queue-depth skew: per-card means {means:?} (max/mean {:.2} > 2.0)",
        max / mean
    );

    // Every guard released: the gauges drain to zero with nothing in
    // flight.
    assert_eq!(fleet.queue_depths(), vec![0; CARDS], "depth gauge leaked");

    // Full-table row-content identity through the replicated map.
    let all: Arc<Vec<u64>> = Arc::new((0..TOTAL_ROWS).step_by(37).collect());
    verify(&fleet.lookup(Arc::clone(&all)).unwrap(), &all, &table);

    check_counters(&fleet);
    let m = fleet.fleet_metrics();
    assert!(m.replicate_epochs >= 1);
    assert!(m.replicas_created >= 1);
    fleet.shutdown();
}

// ---------------------------------------------------------------------------
// 2. Uniform traffic never clears the hot-share gate.
// ---------------------------------------------------------------------------

#[test]
fn uniform_traffic_never_replicates() {
    let table = Table::synthetic(TOTAL_ROWS, D);
    let fleet = build_fleet(&table, true);
    let mut gen = RequestGen::new(spec(Distribution::Uniform, 3));
    for i in 0..60 {
        let rows = Arc::new(gen.next_request());
        let out = fleet.lookup(Arc::clone(&rows)).unwrap();
        if i % 20 == 0 {
            verify(&out, &rows, &table);
        }
        fleet.control_epoch();
    }
    let m = fleet.fleet_metrics();
    assert_eq!(m.replicas_created, 0, "uniform load must not replicate");
    assert_eq!(m.replicate_epochs, 0);
    assert!(fleet.replica_set().is_empty());
    assert!(fleet.replica_cards().is_empty());
    check_counters(&fleet);
    fleet.shutdown();
}

// ---------------------------------------------------------------------------
// 3. De-replication when the hotspot subsides: no drain, trace-audited.
// ---------------------------------------------------------------------------

#[test]
fn replicas_retire_when_the_hotspot_subsides() {
    let table = Table::synthetic(TOTAL_ROWS, D);
    let fleet = build_fleet(&table, true);
    let mut gen = RequestGen::new(spec(Distribution::Zipf { theta: 1.1 }, 31));
    let inflight = escalate_to_replication(&fleet, &table, &mut gen);
    for (t, rows) in inflight {
        verify(&t.wait().unwrap(), &rows, &table);
    }
    let set = fleet.replica_set();
    assert!(!set.is_empty());

    // A ticket submitted under the replicated generation, redeemed only
    // *after* the drop below: its pinned generation keeps the retired
    // replica backends alive (no drain barrier).
    let pinned_rows: Arc<Vec<u64>> =
        Arc::new((0..1_000u64).map(|i| (i * 7) % TOTAL_ROWS).collect());
    let pinned = fleet.submit(Arc::clone(&pinned_rows), None).unwrap();

    // Flat traffic collapses the hot shard's combined share under the
    // exit floor; the drop is judged every epoch (de-escalation is not
    // ladder-gated).
    let mut uni = RequestGen::new(spec(Distribution::Uniform, 4242));
    let mut retired_at = None;
    for i in 0..80 {
        let rows = Arc::new(uni.next_request());
        verify(&fleet.lookup(Arc::clone(&rows)).unwrap(), &rows, &table);
        fleet.control_epoch();
        if fleet.replica_set().is_empty() {
            retired_at = Some(i);
            break;
        }
    }
    let retired_at = retired_at.expect("uniform load never retired the replicas in 80 epochs");
    assert!(fleet.replica_cards().is_empty(), "units outlived the set");
    assert!(
        fleet.replica_set().generation > set.generation,
        "the empty set must publish a new replica generation"
    );

    // The pinned ticket still merges row-identically through the retired
    // generation (epoch {retired_at} dropped it).
    verify(&pinned.wait().unwrap(), &pinned_rows, &table);
    assert_eq!(fleet.queue_depths(), vec![0; CARDS], "depth gauge leaked");

    // Audited: the decision trace carries the drop, and the counters
    // balance.
    let dropped = fleet
        .control_decisions()
        .iter()
        .any(|d| d.acted == Some(Lever::Replicate) && d.why.contains("dropped"));
    assert!(dropped, "no drop decision in the trace (retired at {retired_at})");
    let m = fleet.fleet_metrics();
    assert!(m.replicas_dropped >= 1);
    assert!(m.replicate_epochs >= 2, "one create + one drop at minimum");
    check_counters(&fleet);

    // Serving stays correct after the retirement.
    let all: Arc<Vec<u64>> = Arc::new((0..TOTAL_ROWS).step_by(41).collect());
    verify(&fleet.lookup(Arc::clone(&all)).unwrap(), &all, &table);
    fleet.shutdown();
}

// ---------------------------------------------------------------------------
// 4. An unarmed fleet never replicates, whatever the skew.
// ---------------------------------------------------------------------------

#[test]
fn unarmed_fleet_never_replicates() {
    let table = Table::synthetic(TOTAL_ROWS, D);
    let fleet = build_fleet(&table, false);
    let mut gen = RequestGen::new(spec(Distribution::Zipf { theta: 1.1 }, 31));
    for _ in 0..30 {
        let rows = Arc::new(gen.next_request());
        verify(&fleet.lookup(Arc::clone(&rows)).unwrap(), &rows, &table);
        fleet.control_epoch();
    }
    let m = fleet.fleet_metrics();
    assert_eq!(m.replicas_created, 0);
    assert_eq!(m.replicate_epochs, 0);
    assert!(fleet.replica_set().is_empty());
    check_counters(&fleet);
    fleet.shutdown();
}

// ---------------------------------------------------------------------------
// 5. Resilience composes with replication: hedged sub-batches on a
//    replicated fleet stay row-identical and release the P2C depth
//    gauges exactly once.
// ---------------------------------------------------------------------------

#[test]
fn hedging_composes_with_replication_and_balances_depth_gauges() {
    // Every card's group 0 stalls 20_000x forever; pacing (timescale 50)
    // makes that real wall time (~14 ms per stalled sub-batch vs ~30 us
    // healthy), so the per-card monitor hedges each straggler to the
    // sibling group past the 2 ms floor.  Meanwhile zipf(1.1) load walks
    // the fleet ladder up to replication.  The composition must hold:
    // every response row-identical, at least one hedge won by the
    // speculative copy, and the fleet's P2C depth gauges back at zero
    // once the pipeline drains — the guard releases exactly once per
    // ticket even when the winner was the hedge, not the original.
    let table = Table::synthetic(TOTAL_ROWS, D);
    let fleet = FleetService::build_sim_with(
        (0..CARDS).map(|i| (card(i), SimTiming::Probed)).collect(),
        &table,
        FleetConfig {
            batcher: quick_batcher(),
            seed: 5,
            adaptive: Some(AdaptiveConfig::default()),
            rebalance: RebalanceConfig {
                min_imbalance: 0.15,
                min_epoch_rows: 512,
                min_move_rows: 16,
            },
            control: ControlPlaneConfig {
                min_imbalance: 0.10,
                patience: 1,
                cooldown: 0,
                max_lever: Lever::Migrate, // raised to Replicate when armed
                trace_len: 512,
            },
            replicate: Some(ReplicateConfig {
                capacity_fraction: 0.0,
                ..ReplicateConfig::default()
            }),
            sim_timescale: 50.0,
            fault: Some(FaultPlan::new(9).stall(0, 0, u64::MAX, StallKind::Fixed(20_000.0))),
            resilience: ResilienceConfig {
                hedge: Some(HedgeConfig {
                    min_after: Duration::from_millis(2),
                    quantile: 0.99,
                }),
                ..ResilienceConfig::default()
            },
            ..FleetConfig::default()
        },
    )
    .unwrap();
    let mut gen = RequestGen::new(spec(Distribution::Zipf { theta: 1.1 }, 31));

    // Escalate to a live replica set with hedges already firing.
    let mut inflight = escalate_to_replication(&fleet, &table, &mut gen);
    check_zero_copy(&fleet, &table);

    // Keep a depth-8 pipeline through the replicated map until a hedge
    // wins somewhere in the fleet (owners or replica units).
    let hedge_wins = |fleet: &FleetService| -> u64 {
        let owners: u64 = fleet.cards().iter().map(|s| s.metrics().hedge_wins).sum();
        let replicas: u64 = fleet
            .replica_cards()
            .iter()
            .map(|(_, _, s)| s.metrics().hedge_wins)
            .sum();
        owners + replicas
    };
    let mut wins = 0u64;
    for _ in 0..40 {
        let rows = Arc::new(gen.next_request());
        let ticket = fleet.submit(Arc::clone(&rows), None).unwrap();
        inflight.push_back((ticket, rows));
        if inflight.len() >= 8 {
            let (t, rows) = inflight.pop_front().unwrap();
            verify(&t.wait().unwrap(), &rows, &table);
        }
        wins = hedge_wins(&fleet);
        if wins >= 1 {
            break;
        }
    }
    for (t, rows) in inflight.drain(..) {
        verify(&t.wait().unwrap(), &rows, &table);
    }
    wins = wins.max(hedge_wins(&fleet));
    assert!(wins >= 1, "no hedge ever won on the replicated fleet");

    // The critical gauge identity: hedged tickets (winner = speculative
    // copy) must still release their card's P2C depth exactly once.
    assert_eq!(
        fleet.queue_depths(),
        vec![0; CARDS],
        "depth gauge leaked under hedging"
    );

    // Replication really happened, the counters balance, and a
    // full-table sweep through the replicated + hedged map stays exact.
    let m = fleet.fleet_metrics();
    assert!(m.replicas_created >= 1);
    check_counters(&fleet);
    let all: Arc<Vec<u64>> = Arc::new((0..TOTAL_ROWS).step_by(43).collect());
    verify(&fleet.lookup(Arc::clone(&all)).unwrap(), &all, &table);
    fleet.shutdown();
}

//! The serving facade end to end — hermetic (default build, no `pjrt`
//! feature, no artifacts): every scenario runs against the sim backend.
//!
//! Covers the acceptance surface of the facade redesign:
//! * sim-backend lookup correctness vs `Table::expected` under all three
//!   placement policies (>= 10k rows),
//! * ticketed async submission (out-of-order redemption),
//! * ticket deadline expiry (wait-side and dispatcher-side culling),
//! * admission-control rejection and queue-mode backpressure under
//!   overload, surfaced in `Metrics`,
//! * fleet sharding: merged rows in request order + per-card metrics.

use std::sync::Arc;
use std::time::Duration;

use a100win::config::MachineConfig;
use a100win::coordinator::{BatcherConfig, CardSpec, Table, WindowPlan};
use a100win::probe::TopologyMap;
use a100win::service::{
    Backend, FleetService, OverloadPolicy, Service, SessionConfig, SimBackend, SimBackendConfig,
    SimTiming, TicketState,
};
use a100win::sim::Machine;
use a100win::util::rng::Rng;
use a100win::workload::{RequestGen, WorkloadSpec};

fn tiny_machine() -> Machine {
    Machine::new(MachineConfig::tiny_test()).unwrap()
}

/// A hand-rolled 4-group map (no machine behind it: Probed timing).
fn map4() -> TopologyMap {
    TopologyMap {
        groups: (0..4).map(|g| vec![g * 2, g * 2 + 1]).collect(),
        reach_bytes: 64 << 30,
        solo_gbps: vec![120.0, 119.0, 91.0, 90.0],
        independent: true,
        card_id: "facade-test".into(),
    }
}

fn start_service(
    policy: a100win::coordinator::PlacementPolicy,
    rows: u64,
    d: usize,
    windows: usize,
    timing: SimTiming,
    batcher: BatcherConfig,
) -> (Service, Table) {
    let map = match &timing {
        SimTiming::Machine(m) => TopologyMap::ground_truth(m),
        SimTiming::Probed => map4(),
    };
    let table = Table::synthetic(rows, d);
    let plan = WindowPlan::split(rows, (d * 4) as u64, windows);
    let mut cfg = SimBackendConfig::new(policy);
    cfg.batcher = batcher;
    cfg.calib_accesses_per_sm = 600; // keep DES calibration quick in tests
    let backend = SimBackend::start(cfg, &map, plan, table.view(), timing).unwrap();
    (Service::new(Arc::new(backend)), table)
}

fn quick_batcher() -> BatcherConfig {
    BatcherConfig {
        max_batch_rows: 4096,
        max_wait: Duration::from_millis(1),
        max_pending: 512,
    }
}

fn verify(out: &[f32], rows: &[u64], table: &Table) {
    assert_eq!(out.len(), rows.len() * table.d);
    for (k, &row) in rows.iter().enumerate() {
        for j in 0..table.d {
            assert_eq!(
                out[k * table.d + j],
                table.expected(row, j),
                "row {row} column {j}"
            );
        }
    }
}

#[test]
fn sim_backend_correct_under_all_policies() {
    use a100win::coordinator::PlacementPolicy::*;
    // >= 10k rows end-to-end per policy; GroupToChunk exercises the real
    // DES calibration path, the other two use probed rates.
    for policy in [Naive, SmToChunk, GroupToChunk] {
        let timing = if policy == GroupToChunk {
            SimTiming::machine(tiny_machine())
        } else {
            SimTiming::Probed
        };
        let (service, table) = start_service(policy, 10_000, 8, 3, timing, quick_batcher());
        let mut gen = RequestGen::new(WorkloadSpec::uniform(table.rows, 350, 11));
        let mut served = 0u64;
        for _ in 0..30 {
            let rows = Arc::new(gen.next_request());
            let out = service.lookup(Arc::clone(&rows)).unwrap();
            verify(&out, &rows, &table);
            served += rows.len() as u64;
        }
        assert!(served >= 10_000, "only {served} rows under {policy}");
        let m = service.metrics();
        assert_eq!(m.requests, 30);
        assert_eq!(m.rows, served);
        assert_eq!(m.errors, 0);
        service.shutdown();
    }
}

#[test]
fn tickets_redeem_out_of_order() {
    let (service, table) = start_service(
        a100win::coordinator::PlacementPolicy::GroupToChunk,
        4_096,
        4,
        2,
        SimTiming::Probed,
        quick_batcher(),
    );
    let mut rng = Rng::seed_from_u64(3);
    let requests: Vec<Arc<Vec<u64>>> = (0..16)
        .map(|_| Arc::new((0..64).map(|_| rng.gen_range(table.rows)).collect::<Vec<u64>>()))
        .collect();
    let mut tickets: Vec<_> = requests
        .iter()
        .map(|r| service.submit(Arc::clone(r), None).unwrap())
        .collect();
    // Redeem back to front: order of waits must not matter.
    while let Some(t) = tickets.pop() {
        let rows = &requests[tickets.len()];
        verify(&t.wait().unwrap(), rows, &table);
    }
    service.shutdown();
}

#[test]
fn ticket_poll_transitions_to_ready() {
    let (service, table) = start_service(
        a100win::coordinator::PlacementPolicy::GroupToChunk,
        1_024,
        4,
        1,
        SimTiming::Probed,
        quick_batcher(),
    );
    let rows = Arc::new(vec![1u64, 2, 3]);
    let mut ticket = service.submit(Arc::clone(&rows), None).unwrap();
    // Spin until ready (the 1 ms batch deadline bounds this).
    let t0 = std::time::Instant::now();
    while ticket.poll() != TicketState::Ready {
        assert!(t0.elapsed() < Duration::from_secs(5), "never became ready");
        std::thread::sleep(Duration::from_micros(200));
    }
    verify(&ticket.wait().unwrap(), &rows, &table);
    service.shutdown();
}

#[test]
fn ticket_deadline_expires_while_batched() {
    // A batcher that holds requests far longer than the ticket deadline:
    // wait() must fail with an expiry, counted in Metrics::expired, and
    // the dispatcher must also cull the request when the batch finally
    // fires (second expired increment).
    let slow = BatcherConfig {
        max_batch_rows: 1 << 20,
        max_wait: Duration::from_millis(150),
        max_pending: 64,
    };
    let (service, _table) = start_service(
        a100win::coordinator::PlacementPolicy::GroupToChunk,
        1_024,
        4,
        1,
        SimTiming::Probed,
        slow,
    );
    let ticket = service
        .submit(Arc::new(vec![5, 6, 7]), Some(Duration::from_millis(20)))
        .unwrap();
    let err = ticket.wait().unwrap_err();
    assert!(err.to_string().contains("deadline expired"), "{err}");
    assert!(service.metrics().expired >= 1);
    // Let the batch fire and the dispatcher cull the dead request too.
    std::thread::sleep(Duration::from_millis(250));
    assert_eq!(service.metrics().expired, 2);
    service.shutdown();
}

#[test]
fn unexpired_deadline_still_serves() {
    let (service, table) = start_service(
        a100win::coordinator::PlacementPolicy::GroupToChunk,
        1_024,
        4,
        1,
        SimTiming::Probed,
        quick_batcher(),
    );
    let rows = Arc::new(vec![9u64, 99, 999]);
    let out = service
        .submit(Arc::clone(&rows), Some(Duration::from_secs(10)))
        .unwrap()
        .wait()
        .unwrap();
    verify(&out, &rows, &table);
    assert_eq!(service.metrics().expired, 0);
    service.shutdown();
}

#[test]
fn out_of_range_rows_rejected() {
    let (service, table) = start_service(
        a100win::coordinator::PlacementPolicy::GroupToChunk,
        1_024,
        4,
        1,
        SimTiming::Probed,
        quick_batcher(),
    );
    assert!(service.lookup(Arc::new(vec![table.rows])).is_err());
    assert_eq!(service.metrics().rejected, 1);
    // Still healthy.
    let out = service.lookup(Arc::new(vec![0, 1])).unwrap();
    verify(&out, &[0, 1], &table);
    assert_eq!(service.lookup(Arc::new(vec![])).unwrap().len(), 0);
    service.shutdown();
}

#[test]
fn admission_rejects_over_budget() {
    // Hold the first request in a slow batcher so it stays in flight, then
    // overflow a budget-1 session.
    let slow = BatcherConfig {
        max_batch_rows: 1 << 20,
        max_wait: Duration::from_millis(150),
        max_pending: 64,
    };
    let (service, table) = start_service(
        a100win::coordinator::PlacementPolicy::GroupToChunk,
        1_024,
        4,
        1,
        SimTiming::Probed,
        slow,
    );
    let session = service.session(
        "tenant-a",
        SessionConfig {
            max_in_flight: 1,
            overload: OverloadPolicy::Reject,
            deadline: None,
        },
    );
    let first = session.submit(Arc::new(vec![1])).unwrap();
    assert_eq!(session.in_flight(), 1);
    let err = session.submit(Arc::new(vec![2])).unwrap_err();
    assert!(err.to_string().contains("in-flight budget"), "{err}");
    assert_eq!(session.stats().rejected.load(std::sync::atomic::Ordering::Relaxed), 1);
    // Shedding is admission_rejected; `rejected` stays reserved for
    // invalid-input refusals.
    assert_eq!(service.metrics().admission_rejected, 1);
    assert_eq!(service.metrics().rejected, 0);
    // Redeeming the first ticket frees the slot.
    verify(&first.wait().unwrap(), &[1], &table);
    assert_eq!(session.in_flight(), 0);
    let second = session.submit(Arc::new(vec![2])).unwrap();
    verify(&second.wait().unwrap(), &[2], &table);
    service.shutdown();
}

#[test]
fn admission_queue_mode_backpressures() {
    let slow = BatcherConfig {
        max_batch_rows: 1 << 20,
        max_wait: Duration::from_millis(150),
        max_pending: 64,
    };
    let (service, table) = start_service(
        a100win::coordinator::PlacementPolicy::GroupToChunk,
        1_024,
        4,
        1,
        SimTiming::Probed,
        slow,
    );
    let session = Arc::new(service.session(
        "tenant-q",
        SessionConfig {
            max_in_flight: 1,
            overload: OverloadPolicy::Queue,
            deadline: None,
        },
    ));
    let first = session.submit(Arc::new(vec![3])).unwrap();
    let waiter = {
        let session = Arc::clone(&session);
        std::thread::spawn(move || session.lookup(Arc::new(vec![4])).unwrap())
    };
    // Give the waiter time to block on the budget, then release the slot
    // by redeeming the first ticket (~150 ms batch deadline away).
    std::thread::sleep(Duration::from_millis(30));
    verify(&first.wait().unwrap(), &[3], &table);
    verify(&waiter.join().unwrap(), &[4], &table);
    assert_eq!(
        session.stats().throttled.load(std::sync::atomic::Ordering::Relaxed),
        1
    );
    assert_eq!(service.metrics().throttled, 1);
    service.shutdown();
}

#[test]
fn dropped_ticket_releases_admission_slot() {
    let (service, _table) = start_service(
        a100win::coordinator::PlacementPolicy::GroupToChunk,
        1_024,
        4,
        1,
        SimTiming::Probed,
        quick_batcher(),
    );
    let session = service.session(
        "tenant-drop",
        SessionConfig {
            max_in_flight: 1,
            overload: OverloadPolicy::Reject,
            deadline: None,
        },
    );
    let t = session.submit(Arc::new(vec![7])).unwrap();
    assert_eq!(session.in_flight(), 1);
    drop(t); // abandon the request
    assert_eq!(session.in_flight(), 0);
    assert!(session.submit(Arc::new(vec![8])).is_ok());
    service.shutdown();
}

// ---------------------------------------------------------------------------
// Fleet
// ---------------------------------------------------------------------------

fn fleet_card(groups: usize, gbps: f64, mem_bytes: u64, reach_bytes: u64) -> CardSpec {
    CardSpec {
        map: TopologyMap {
            groups: (0..groups).map(|g| vec![g * 2, g * 2 + 1]).collect(),
            reach_bytes,
            solo_gbps: vec![gbps; groups],
            independent: true,
            card_id: format!("fleet-{groups}g"),
        },
        memory_bytes: mem_bytes,
    }
}

#[test]
fn fleet_merges_rows_in_request_order() {
    let d = 8usize;
    let row_bytes = (d * 4) as u64;
    let total_rows = 8_192u64;
    let table = Table::synthetic(total_rows, d);
    // Unequal capacities so the shard split is asymmetric, and a reach
    // small enough to force several windows per card (shard rows stay
    // under 4 * reach so GroupToChunk's 1:1 pinning remains possible).
    let cards = vec![
        (
            fleet_card(4, 120.0, total_rows * row_bytes, 2_048 * row_bytes),
            SimTiming::Probed,
        ),
        (
            fleet_card(4, 80.0, total_rows * row_bytes, 2_048 * row_bytes),
            SimTiming::Probed,
        ),
    ];
    let fleet = FleetService::build_sim(cards, &table, quick_batcher(), 5).unwrap();
    assert_eq!(fleet.plan().shards.len(), 2);
    assert!(fleet.plan().shards[0].rows > fleet.plan().shards[1].rows);
    assert!(fleet.plan().shards[0].plan.count() > 1, "want multi-window shards");

    let mut rng = Rng::seed_from_u64(17);
    let mut total = 0u64;
    for _ in 0..25 {
        // Requests straddle both shards; the merge must restore request
        // order exactly.
        let rows: Arc<Vec<u64>> =
            Arc::new((0..500).map(|_| rng.gen_range(total_rows)).collect());
        let out = fleet.lookup(Arc::clone(&rows)).unwrap();
        verify(&out, &rows, &table);
        total += rows.len() as u64;
    }
    assert!(total >= 10_000);

    // Per-card metrics: every card served something; rows sum to the total.
    let per_card = fleet.per_card_metrics();
    assert_eq!(per_card.len(), 2);
    let rows_sum: u64 = per_card.iter().map(|(_, m)| m.rows).sum();
    assert_eq!(rows_sum, total);
    for (card, m) in &per_card {
        assert!(m.rows > 0, "card {card} served nothing");
        assert_eq!(m.errors, 0, "card {card} errored");
    }
    fleet.shutdown();
}

#[test]
fn fleet_single_shard_requests_skip_other_cards() {
    let d = 4usize;
    let total_rows = 4_096u64;
    let table = Table::synthetic(total_rows, d);
    let cards = vec![
        (
            fleet_card(2, 100.0, total_rows * 16, 64 << 30),
            SimTiming::Probed,
        ),
        (
            fleet_card(2, 100.0, total_rows * 16, 64 << 30),
            SimTiming::Probed,
        ),
    ];
    let fleet = FleetService::build_sim(cards, &table, quick_batcher(), 2).unwrap();
    let plan = fleet.plan();
    let shard0 = &plan.shards[0];
    // All rows from shard 0 only.
    let rows: Arc<Vec<u64>> = Arc::new((0..64).map(|i| shard0.start_row + i).collect());
    let out = fleet.lookup(Arc::clone(&rows)).unwrap();
    verify(&out, &rows, &table);
    let per_card = fleet.per_card_metrics();
    assert_eq!(per_card[0].1.requests, 1);
    assert_eq!(per_card[1].1.requests, 0, "card 1 must not see the request");
    fleet.shutdown();
}

#[test]
fn fleet_rejects_out_of_range() {
    let d = 4usize;
    let total_rows = 1_024u64;
    let table = Table::synthetic(total_rows, d);
    let cards = vec![(
        fleet_card(2, 100.0, total_rows * 16, 64 << 30),
        SimTiming::Probed,
    )];
    let fleet = FleetService::build_sim(cards, &table, quick_batcher(), 1).unwrap();
    assert!(fleet.lookup(Arc::new(vec![total_rows])).is_err());
    let out = fleet.lookup(Arc::new(vec![0])).unwrap();
    verify(&out, &[0], &table);
    fleet.shutdown();
}

#[test]
fn backend_trait_object_serves() {
    // The facade consumes backends as trait objects: check the dyn path
    // explicitly (submit through Arc<dyn Backend>).
    let (service, table) = start_service(
        a100win::coordinator::PlacementPolicy::GroupToChunk,
        2_048,
        4,
        2,
        SimTiming::Probed,
        quick_batcher(),
    );
    let backend: &Arc<dyn Backend> = service.backend();
    let rows = Arc::new(vec![10u64, 20, 30]);
    let ticket = backend
        .submit(a100win::service::Batch::new(Arc::clone(&rows)))
        .unwrap();
    verify(&backend.wait(ticket).unwrap(), &rows, &table);
    assert_eq!(backend.d(), 4);
    assert_eq!(backend.rows(), 2_048);
    service.shutdown();
}

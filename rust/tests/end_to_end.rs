//! END-TO-END: the whole system on a real small workload.
//!
//! Mirrors examples/embedding_server.rs with assertions: serve a mixed
//! uniform + zipf workload from concurrent clients through the full
//! L3 -> PJRT -> AOT-kernel stack, verify every spot-checked row, replay a
//! recorded trace byte-identically, and run a short training loop whose
//! loss must fall.  Requires `make artifacts`.
//!
//! Gated behind the `pjrt` feature: it needs the real `xla` crate (the
//! offline build links an error-returning stub) plus `make artifacts`.
#![cfg(feature = "pjrt")]

use std::sync::Arc;

use a100win::coordinator::{
    BatcherConfig, EmbeddingServer, PlacementPolicy, ServerConfig, Table, WindowPlan,
};
use a100win::probe::TopologyMap;
use a100win::runtime::Runtime;
use a100win::workload::{synth::Distribution, RequestGen, Trace, WorkloadSpec};

fn map6() -> TopologyMap {
    TopologyMap {
        groups: (0..6).map(|g| vec![g * 2, g * 2 + 1]).collect(),
        reach_bytes: 64 << 30,
        solo_gbps: vec![120.0, 120.0, 118.0, 117.0, 90.0, 91.0],
        independent: true,
        card_id: "e2e".into(),
    }
}

fn start(windows: usize) -> (EmbeddingServer, Table) {
    let dir = Runtime::default_artifacts_dir().expect("run `make artifacts`");
    let rt = Runtime::new(&dir).unwrap();
    let meta = rt.manifest().first_of("lookup").unwrap();
    drop(rt);
    let rows = (meta.n * windows) as u64;
    let table = Table::synthetic(rows, meta.d);
    let plan = WindowPlan::split(rows, 128, windows);
    let mut cfg = ServerConfig::new(dir);
    cfg.policy = PlacementPolicy::GroupToChunk;
    cfg.batcher = BatcherConfig {
        max_batch_rows: 4096,
        max_wait: std::time::Duration::from_millis(1),
        max_pending: 512,
    };
    let server = EmbeddingServer::start(cfg, &map6(), plan, table.view()).unwrap();
    (server, table)
}

#[test]
fn serve_mixed_workload_concurrently() {
    let (server, table) = start(3);
    let server = Arc::new(server);
    let total_checked: u64 = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..6u64 {
            let server = Arc::clone(&server);
            let table = table.clone();
            handles.push(s.spawn(move || {
                let dist = if c % 2 == 0 {
                    Distribution::Uniform
                } else {
                    Distribution::ZipfScattered { theta: 0.99 }
                };
                let mut gen = RequestGen::new(WorkloadSpec {
                    total_rows: table.rows,
                    distribution: dist,
                    request_rows: (1, 700),
                    seed: 100 + c,
                });
                let mut checked = 0u64;
                for _ in 0..15 {
                    let req = Arc::new(gen.next_request());
                    let out = server.lookup(Arc::clone(&req)).unwrap();
                    assert_eq!(out.len(), req.len() * table.d);
                    for (i, &r) in req.iter().enumerate() {
                        assert_eq!(out[i * table.d], table.expected(r, 0));
                        assert_eq!(
                            out[i * table.d + table.d - 1],
                            table.expected(r, table.d - 1)
                        );
                        checked += 1;
                    }
                }
                checked
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    assert!(total_checked > 1000);
    let m = server.metrics();
    assert_eq!(m.requests, 90);
    assert_eq!(m.errors, 0);
    assert!(m.p99_latency_us > 0);
}

#[test]
fn trace_replay_is_reproducible() {
    let (server, table) = start(2);
    let mut gen = RequestGen::new(WorkloadSpec::uniform(table.rows, 128, 5));
    let trace = Trace::capture(&mut gen, 10);

    let run = |server: &EmbeddingServer| -> Vec<f32> {
        let mut all = Vec::new();
        for req in &trace.requests {
            all.extend(server.lookup(Arc::new(req.clone())).unwrap());
        }
        all
    };
    let a = run(&server);
    let b = run(&server);
    assert_eq!(a, b, "same trace must produce identical bytes");
    assert_eq!(a.len(), trace.total_rows() * table.d);
    server.shutdown();
}

#[test]
fn training_loop_loss_falls() {
    let dir = Runtime::default_artifacts_dir().expect("run `make artifacts`");
    let mut rt = Runtime::new(&dir).unwrap();
    let meta = rt.manifest().first_of("bag_loss_and_grad").unwrap();
    let (b, n, d, g) = (meta.b, meta.n, meta.d, meta.g.unwrap());
    rt.ensure_compiled(&meta.name).unwrap();

    let mut rng = a100win::util::rng::Rng::seed_from_u64(21);
    let mut table: Vec<f32> = (0..n * d)
        .map(|_| (rng.gen_f64() as f32 - 0.5) * 0.1)
        .collect();
    let indices: Vec<i32> = (0..b * g).map(|_| rng.gen_range(n as u64) as i32).collect();
    let targets: Vec<f32> = (0..b * d).map(|_| rng.gen_f64() as f32).collect();
    let idx = rt.upload_i32(&indices, &[b, g]).unwrap();
    let tgt = rt.upload_f32(&targets, &[b, d]).unwrap();

    // The loss is a mean over b*d elements, so grads scale as 1/(b*d);
    // scale the step to compensate (stable well below the max appearance-
    // cluster eigenvalue; ~0.95x decay per step for singly-used rows).
    let lr = (b * d) as f32 / 40.0;
    let mut losses = Vec::new();
    for _ in 0..24 {
        let tab = rt.upload_f32(&table, &[n, d]).unwrap();
        let outs = rt.execute(&meta.name, &[&idx, &tab, &tgt]).unwrap();
        let loss = outs[0].to_vec::<f32>().unwrap()[0];
        let grad = outs[1].to_vec::<f32>().unwrap();
        for (w, gr) in table.iter_mut().zip(&grad) {
            *w -= lr * gr;
        }
        losses.push(loss);
    }
    assert!(
        *losses.last().unwrap() < losses[0] * 0.5,
        "loss curve did not fall: {losses:?}"
    );
    // Monotone non-increasing within tolerance (quadratic loss, fixed batch).
    for w in losses.windows(2) {
        assert!(w[1] <= w[0] * 1.01, "loss rose: {losses:?}");
    }
}

#[test]
fn probe_artifact_feeds_server() {
    // TopologyMap round-trips through disk and boots a server (the real
    // deployment flow: `a100win probe` once, serve many times).
    let dir = std::env::temp_dir().join(format!("a100win-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("map.json");
    map6().save(&path).unwrap();
    let loaded = TopologyMap::load(&path).unwrap();
    assert_eq!(loaded, map6());

    let artifacts = Runtime::default_artifacts_dir().expect("run `make artifacts`");
    let rt = Runtime::new(&artifacts).unwrap();
    let meta = rt.manifest().first_of("lookup").unwrap();
    drop(rt);
    let rows = (meta.n * 2) as u64;
    let table = Table::synthetic(rows, meta.d);
    let plan = WindowPlan::split(rows, 128, 2);
    let cfg = ServerConfig::new(artifacts);
    let server = EmbeddingServer::start(cfg, &loaded, plan, table.view()).unwrap();
    let out = server.lookup(Arc::new(vec![0, rows - 1])).unwrap();
    assert_eq!(out[0], table.expected(0, 0));
    assert_eq!(out[meta.d], table.expected(rows - 1, 0));
    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

//! The network front door, end to end over real loopback sockets —
//! hermetic (sim backend, no artifacts, no real card).
//!
//! Covers the wire-level acceptance surface:
//! * binary round trips verify every returned cell against the table,
//!   and the HTTP channel answers `/healthz`, `/readyz` and `/v1/lookup`,
//! * the connection limit sheds with an explicit `shed(connection-limit)`
//!   answer (never a silently dropped socket) and the slot frees on close,
//! * per-tenant admission refuses over-budget requests on a connection
//!   that stays usable afterwards,
//! * a slow-loris peer (torn frame, then silence) is disconnected inside
//!   the frame budget without consuming a reply,
//! * `Outcome::Partial` masks survive the wire bit-exactly,
//! * ticket deadlines travel the wire and expire as refusals, not poison,
//! * graceful drain finishes in-flight tickets while new connections get
//!   `shed(draining)`,
//! * a seeded transport-fault chaos soak (client-side delays, splits,
//!   truncations, drops on top of backend stalls/outages) delivers zero
//!   corrupted rows through the pooled client.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use a100win::coordinator::{PlacementPolicy, Table, WindowPlan};
use a100win::net::{
    ClientConfig, NetClient, NetConfig, NetFaultPlan, NetServer, RemotePool, Target,
};
use a100win::probe::TopologyMap;
use a100win::service::{
    Outcome, ResilienceConfig, Service, SimBackend, SimBackendConfig, SimTiming,
};
use a100win::sim::{FaultPlan, StallKind};
use a100win::workload::chaos::{drive_chaos, ChaosConfig};
use a100win::workload::openloop::{drive, OpenLoopConfig};
use a100win::workload::synth::Distribution;
use a100win::workload::{RequestGen, WorkloadSpec};

const D: usize = 8;

/// Two-group map with controllable probed rates: `ns_per_row =
/// row_bytes / solo_gbps`, so 2 GB/s at 32 B rows = 16 ns of simulated
/// time per row — pacing tests can size request durations exactly.
fn map2(gbps: f64) -> TopologyMap {
    TopologyMap {
        groups: vec![vec![0, 1], vec![2, 3]],
        reach_bytes: 64 << 30,
        solo_gbps: vec![gbps, gbps],
        independent: true,
        card_id: "net-test".into(),
    }
}

fn map4() -> TopologyMap {
    TopologyMap {
        groups: (0..4).map(|g| vec![g * 2, g * 2 + 1]).collect(),
        reach_bytes: 64 << 30,
        solo_gbps: vec![120.0, 119.0, 91.0, 90.0],
        independent: true,
        card_id: "net-test".into(),
    }
}

/// Loopback server over a sim backend; returns the server plus the
/// ground-truth table so tests verify every cell that crosses the wire.
fn start_edge(
    map: &TopologyMap,
    rows: u64,
    windows: usize,
    net: NetConfig,
    mutate: impl FnOnce(&mut SimBackendConfig),
) -> (NetServer, Table) {
    let table = Table::synthetic(rows, D);
    let plan = WindowPlan::split(rows, (D * 4) as u64, windows);
    let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
    mutate(&mut cfg);
    let backend =
        Arc::new(SimBackend::start(cfg, map, plan, table.view(), SimTiming::Probed).unwrap());
    let server = NetServer::start(Target::Single(Service::new(backend)), net).unwrap();
    (server, table)
}

fn verify(out: &[f32], rows: &[u64], table: &Table) {
    assert_eq!(out.len(), rows.len() * table.d);
    for (k, &row) in rows.iter().enumerate() {
        for j in 0..table.d {
            assert_eq!(
                out[k * table.d + j],
                table.expected(row, j),
                "row {row} column {j}"
            );
        }
    }
}

fn some_rows(n: usize, total: u64, salt: u64) -> Vec<u64> {
    (0..n as u64).map(|i| (i * 37 + salt) % total).collect()
}

fn client(server: &NetServer) -> NetClient {
    NetClient::connect(&server.addr().to_string(), ClientConfig::default()).unwrap()
}

/// Minimal raw HTTP/1.1 round trip (no client library): returns
/// `(status, body)`.
fn http_req(addr: &str, request: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.set_write_timeout(Some(Duration::from_secs(5))).unwrap();
    s.write_all(request.as_bytes()).unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    let status: u16 = resp
        .strip_prefix("HTTP/1.1 ")
        .and_then(|r| r.get(..3))
        .unwrap_or_else(|| panic!("malformed response: {resp:.60}"))
        .parse()
        .unwrap();
    let body = resp
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (status, body)
}

fn http_get(addr: &str, path: &str) -> (u16, String) {
    http_req(
        addr,
        &format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n"),
    )
}

fn http_post_lookup(addr: &str, body: &str) -> (u16, String) {
    http_req(
        addr,
        &format!(
            "POST /v1/lookup HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n{body}",
            body.len()
        ),
    )
}

#[test]
fn wire_roundtrip_verifies_and_http_channel_answers() {
    let net = NetConfig {
        http_addr: Some("127.0.0.1:0".into()),
        ..NetConfig::default()
    };
    let (mut server, table) = start_edge(&map2(100.0), 8_192, 2, net, |_| {});
    let mut c = client(&server);
    assert_eq!(c.d(), table.d);
    assert_eq!(c.rows(), table.rows);
    for salt in 0..20u64 {
        let rows = some_rows(96, table.rows, salt * 11 + 1);
        match c.lookup(&rows, None).unwrap() {
            Outcome::Full(data) => verify(&data, &rows, &table),
            other => panic!("expected Full, got {other:?}"),
        }
    }
    // Malformed requests are refused per-request: the connection survives.
    let err = c.lookup(&[table.rows + 5], None).unwrap_err();
    assert!(format!("{err:#}").contains("out of range"), "{err:#}");
    let rows = some_rows(8, table.rows, 3);
    match c.lookup(&rows, None).unwrap() {
        Outcome::Full(data) => verify(&data, &rows, &table),
        other => panic!("expected Full after refusal, got {other:?}"),
    }

    // HTTP channel: health, readiness, lookup, and a 400.
    let http = server.http_addr().unwrap().to_string();
    let (status, body) = http_get(&http, "/healthz");
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"state\":\"serving\""), "{body}");
    let (status, body) = http_get(&http, "/readyz");
    assert_eq!(status, 200, "{body}");
    let (status, body) = http_post_lookup(&http, "{\"rows\":[1,2,3]}");
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"partial\":false"), "{body}");
    let (status, _) = http_post_lookup(&http, "{\"rows\":[]}");
    assert_eq!(status, 400);

    let m = server.metrics();
    assert!(m.responses_full >= 21, "{m}");
    assert_eq!(m.responses_partial, 0, "{m}");
    assert!(m.responses_error >= 1, "{m}");
    assert!(m.http_requests >= 4, "{m}");
    let report = server.drain(Duration::from_secs(5));
    assert!(report.completed, "{report:?}");
}

#[test]
fn connection_limit_sheds_explicitly_and_slot_frees_on_close() {
    let net = NetConfig {
        max_conns: 1,
        ..NetConfig::default()
    };
    let (mut server, table) = start_edge(&map2(100.0), 4_096, 1, net, |_| {});
    let addr = server.addr().to_string();
    let mut first = NetClient::connect(&addr, ClientConfig::default()).unwrap();
    // The limit is enforced with an answer, not a dropped socket.
    let err = NetClient::connect(&addr, ClientConfig::default()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("shed(connection-limit)"), "got: {msg}");
    // The admitted connection is unaffected by its neighbor's refusal.
    let rows = some_rows(32, table.rows, 1);
    match first.lookup(&rows, None).unwrap() {
        Outcome::Full(data) => verify(&data, &rows, &table),
        other => panic!("expected Full, got {other:?}"),
    }
    assert!(server.metrics().conns_shed >= 1);
    drop(first);
    // The slot releases once the connection closes (reader thread exit
    // lags the FIN slightly; poll briefly).
    let give_up = Instant::now() + Duration::from_secs(5);
    loop {
        match NetClient::connect(&addr, ClientConfig::default()) {
            Ok(_) => break,
            Err(_) if Instant::now() < give_up => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => panic!("connection slot never freed: {e:#}"),
        }
    }
    server.shutdown();
}

#[test]
fn over_budget_refusal_keeps_the_connection_usable() {
    // One in-flight slot for the tenant and ~8 ms paced requests
    // (512 rows / 2 groups * 16 ns * timescale 2000): two concurrent
    // submissions collide; the loser's refusal must not cost its socket.
    let net = NetConfig {
        per_tenant_in_flight: 1,
        ..NetConfig::default()
    };
    let (server, table) = start_edge(&map2(2.0), 4_096, 1, net, |cfg| {
        cfg.sim_timescale = 2_000.0;
    });
    let addr = server.addr().to_string();
    let table = &table;
    let mut shed_seen = false;
    for round in 0..20u64 {
        if shed_seen {
            break;
        }
        let sheds: Vec<bool> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..2u64)
                .map(|t| {
                    let addr = addr.clone();
                    let rows = some_rows(512, table.rows, round * 7 + t);
                    s.spawn(move || {
                        let mut c = NetClient::connect(&addr, ClientConfig::default()).unwrap();
                        match c.lookup(&rows, None) {
                            Ok(Outcome::Full(data)) => {
                                verify(&data, &rows, table);
                                false
                            }
                            Ok(other) => panic!("unexpected outcome {other:?}"),
                            Err(e) => {
                                let msg = format!("{e:#}");
                                assert!(
                                    msg.contains("shed(over-budget)"),
                                    "unexpected refusal: {msg}"
                                );
                                // The refusal left the stream in sync: a
                                // retry on the SAME socket succeeds once
                                // the slot frees.
                                let give_up = Instant::now() + Duration::from_secs(5);
                                loop {
                                    match c.lookup(&[5], None) {
                                        Ok(Outcome::Full(data)) => {
                                            verify(&data, &[5], table);
                                            break;
                                        }
                                        Ok(other) => panic!("unexpected outcome {other:?}"),
                                        Err(_) if Instant::now() < give_up => {
                                            std::thread::sleep(Duration::from_millis(2));
                                        }
                                        Err(e) => {
                                            panic!("connection died after a refusal: {e:#}")
                                        }
                                    }
                                }
                                true
                            }
                        }
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        shed_seen = sheds.into_iter().any(|s| s);
    }
    assert!(shed_seen, "two concurrent clients never collided on 1 slot");
    assert!(server.metrics().shed_over_budget >= 1);
    drop(server);
}

#[test]
fn slow_loris_is_disconnected_inside_the_frame_budget() {
    let net = NetConfig {
        hello_timeout: Duration::from_millis(200),
        frame_timeout: Duration::from_millis(200),
        ..NetConfig::default()
    };
    let (mut server, _table) = start_edge(&map2(100.0), 4_096, 1, net, |_| {});
    // Two bytes of a four-byte length prefix, then silence: a torn frame
    // must cost the peer its connection, not the server a read slot.
    let mut s = TcpStream::connect(server.addr()).unwrap();
    s.write_all(&[7, 0]).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = Vec::new();
    let n = s.read_to_end(&mut buf).unwrap();
    assert_eq!(n, 0, "server must close a torn frame without answering");
    let give_up = Instant::now() + Duration::from_secs(2);
    while server.metrics().slow_loris_closed == 0 && Instant::now() < give_up {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(server.metrics().slow_loris_closed >= 1);
    // The server is unharmed: a well-behaved client still gets served.
    let mut c = client(&server);
    assert!(c.lookup(&[1, 2, 3], None).is_ok());
    server.shutdown();
}

#[test]
fn partial_mask_travels_the_wire_bit_exact() {
    // Window 1's only group is permanently dead; partials are armed.  The
    // wire must carry the same mask the facade produces: delivered rows
    // exact, masked rows zero-filled, per-window consistency.
    let (mut server, table) = start_edge(&map2(100.0), 8_192, 2, NetConfig::default(), |cfg| {
        cfg.fault = Some(FaultPlan::new(13).outage(1, 0, u64::MAX));
        cfg.resilience = ResilienceConfig {
            partials: true,
            ..ResilienceConfig::default()
        };
    });
    let mut c = client(&server);
    let rows: Vec<u64> = vec![10, 20, 4_100, 4_200];
    let outcome = c.lookup(&rows, None).unwrap();
    let Outcome::Partial { rows: out, valid } = outcome else {
        panic!("expected Partial over the wire, got {outcome:?}");
    };
    assert_eq!(valid.len(), rows.len());
    assert_eq!(out.len(), rows.len() * table.d);
    assert_eq!(valid.iter().filter(|&&v| v).count(), 2, "{valid:?}");
    for (k, &row) in rows.iter().enumerate() {
        let span = &out[k * table.d..(k + 1) * table.d];
        if valid[k] {
            for (j, &got) in span.iter().enumerate() {
                assert_eq!(got, table.expected(row, j), "row {row} column {j}");
            }
        } else {
            assert!(span.iter().all(|&v| v == 0.0), "masked row {row} not zeroed");
        }
    }
    assert_eq!(server.metrics().responses_partial, 1);
    server.shutdown();
}

#[test]
fn deadline_expiry_travels_the_wire_without_poisoning() {
    // ~40 ms paced requests (512 rows / 2 groups * 16 ns * timescale
    // 10_000) against a 5 ms wire deadline; resilience stays OFF so the
    // expiry surfaces as an error, not a salvaged partial.
    let (mut server, table) = start_edge(&map2(2.0), 4_096, 1, NetConfig::default(), |cfg| {
        cfg.sim_timescale = 10_000.0;
    });
    let mut c = client(&server);
    let rows = some_rows(512, table.rows, 0);
    let err = c
        .lookup(&rows, Some(Duration::from_millis(5)))
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("deadline"), "got: {msg}");
    // Deadline refusals are per-request; the next unbounded lookup works.
    let rows = some_rows(32, table.rows, 9);
    match c.lookup(&rows, None).unwrap() {
        Outcome::Full(data) => verify(&data, &rows, &table),
        other => panic!("expected Full after expiry, got {other:?}"),
    }
    assert!(server.metrics().responses_error >= 1);
    server.shutdown();
}

#[test]
fn graceful_drain_finishes_in_flight_and_sheds_new_connections() {
    // Stall both groups so one request paces ~80 ms of wall clock — a
    // window wide enough to observe the drain ordering: the in-flight
    // ticket completes (and verifies), new connections get an explicit
    // `shed(draining)` frame.
    let (mut server, table) = start_edge(&map2(2.0), 4_096, 1, NetConfig::default(), |cfg| {
        cfg.sim_timescale = 10_000.0;
        cfg.fault = Some(
            FaultPlan::new(5)
                .stall(0, 0, u64::MAX, StallKind::Fixed(4.0))
                .stall(1, 0, u64::MAX, StallKind::Fixed(4.0)),
        );
    });
    let addr = server.addr().to_string();
    let mut c = client(&server);
    let rows = some_rows(256, table.rows, 5);
    let rows_ref = &rows[..];
    let server_ref = &mut server;
    let (outcome, in_flight_seen, report, shed_msg) = std::thread::scope(|s| {
        let lookup = s.spawn(move || c.lookup(rows_ref, None));
        let mut seen = 0;
        for _ in 0..5_000 {
            seen = server_ref.in_flight();
            if seen > 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        let probe_addr = addr.clone();
        let probe = s.spawn(move || {
            // Keep connecting until a refusal names the drain; tolerate
            // successes (still serving) and raw connect errors (listener
            // already down) by retrying inside the window.
            let give_up = Instant::now() + Duration::from_secs(10);
            let mut last = String::new();
            while Instant::now() < give_up {
                match NetClient::connect(&probe_addr, ClientConfig::default()) {
                    Err(e) => {
                        last = format!("{e:#}");
                        if last.contains("shed(draining)") {
                            return last;
                        }
                    }
                    Ok(_) => {}
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            last
        });
        let report = server_ref.drain(Duration::from_secs(30));
        (
            lookup.join().unwrap(),
            seen,
            report,
            probe.join().unwrap(),
        )
    });
    assert!(in_flight_seen > 0, "request never observed in flight");
    match outcome.unwrap() {
        Outcome::Full(data) => verify(&data, &rows, &table),
        other => panic!("drained ticket degraded to {other:?}"),
    }
    assert!(report.completed, "drain left work behind: {report:?}");
    assert!(
        shed_msg.contains("shed(draining)"),
        "probe never saw the drain refusal: {shed_msg:?}"
    );
    assert!(report.refused_conns >= 1, "{report:?}");
}

#[test]
fn transport_chaos_soak_delivers_no_corrupted_rows() {
    // The resilience chaos soak, pushed through the real socket path:
    // backend stalls/outages/flapping (FaultPlan::chaos) compose with
    // client-side transport faults (delays, split writes, truncations,
    // half-closes, drops).  Poisoned connections cost one request each —
    // the pool re-dials — and every delivered row is verified.
    let (mut server, table) = start_edge(&map4(), 16_384, 2, NetConfig::default(), |cfg| {
        cfg.fault = Some(FaultPlan::chaos(11, 4));
        cfg.resilience = ResilienceConfig::full();
    });
    let pool = RemotePool::with_faults(
        server.addr().to_string(),
        ClientConfig::default(),
        4,
        NetFaultPlan::chaos(11),
    );
    let report = drive_chaos(
        &pool,
        &table,
        &ChaosConfig {
            requests: 120,
            request_rows: (16, 64),
            distribution: Distribution::parse("drift:zipf:1.1:60").unwrap(),
            seed: 17,
            deadline: Some(Duration::from_millis(250)),
            concurrency: 4,
        },
    );
    assert_eq!(report.corrupted_rows, 0, "{report:?}");
    assert_eq!(report.mask_violations, 0, "{report:?}");
    assert!(report.completed > 0, "total outage: {report:?}");
    assert!(report.valid_rows_checked > 0, "{report:?}");
    // Failures must resolve in bounded time even when a transport fault
    // burns the whole retry budget (well under the 10 s response timeout
    // that would signal a hung connection).
    if report.failed > 0 {
        assert!(
            report.failure_p99_us < 5_000_000,
            "slow failure resolution: {report:?}"
        );
    }
    // Transport faults poisoned connections; the pool replaced them
    // instead of failing the rest of the run.
    assert!(pool.dials() >= 4, "dials: {}", pool.dials());
    let drained = server.drain(Duration::from_secs(10));
    assert!(drained.completed, "{drained:?}");
}

#[test]
fn remote_pool_drives_a_clean_open_loop_sweep() {
    // The `bench-serve --remote` measurement path in miniature: pooled
    // connections, pinned buffers, zero errors on a clean loopback run —
    // and zero re-dials (no fault, no poisoning, no connection churn).
    let (mut server, table) = start_edge(&map2(100.0), 8_192, 2, NetConfig::default(), |_| {});
    let pool = RemotePool::new(server.addr().to_string(), ClientConfig::default(), 4);
    pool.connect_warm(2).unwrap();
    let (d, rows) = pool.probe().unwrap();
    assert_eq!((d, rows), (table.d, table.rows));
    let mut gen = RequestGen::new(WorkloadSpec::uniform(table.rows, 64, 21));
    let point = drive(
        &pool,
        &mut gen,
        400.0,
        &OpenLoopConfig {
            duration: Duration::from_millis(250),
            max_requests: Some(60),
            ..OpenLoopConfig::default()
        },
    );
    assert_eq!(point.errors, 0, "clean loopback sweep errored: {point:?}");
    assert!(point.achieved_rps > 0.0, "{point:?}");
    assert!(
        pool.dials() <= 4,
        "clean run churned connections: {} dials",
        pool.dials()
    );
    let drained = server.drain(Duration::from_secs(5));
    assert!(drained.completed, "{drained:?}");
}

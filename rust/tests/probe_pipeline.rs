//! Integration: the full probe -> map -> placement pipeline on the
//! *full-size* simulated A100 (108 SMs, 14 groups), with an unknown
//! card-specific SM enumeration.
//!
//! This is the paper's whole method end to end: the prober sees only
//! throughput numbers, yet must recover the 12x8 + 2x6 group structure,
//! estimate ~64 GiB reach, pass the independence check, and produce a map
//! that the coordinator can pin windows with.

use a100win::config::MachineConfig;
use a100win::coordinator::{Placement, PlacementPolicy, WindowPlan};
use a100win::probe::{ProbeConfig, Prober};
use a100win::sim::Machine;

fn quick_probe(seed: u64) -> (Machine, a100win::probe::ProbeOutcome) {
    let mut cfg = MachineConfig::a100_80gb();
    cfg.topology.smid_permutation_seed = seed;
    let machine = Machine::new(cfg).unwrap();
    let mut pc = ProbeConfig::for_machine(&machine);
    // Keep the 5886-run pair sweep fast; the contention signal is a ~40%
    // throughput gap, far above the deterministic simulator's noise.
    pc.pair.accesses_per_sm = 800;
    pc.verify.accesses_per_sm = 2_500;
    pc.reach_sweep = {
        let gib = 1u64 << 30;
        vec![16 * gib, 32 * gib, 48 * gib, 64 * gib, 72 * gib, 80 * gib]
    };
    let outcome = Prober::with_config(&machine, pc).run().unwrap();
    (machine, outcome)
}

#[test]
fn probe_recovers_a100_topology() {
    let (machine, outcome) = quick_probe(0xCAFE);
    let topo = machine.topology();

    // 14 groups, sizes 12x8 + 2x6.
    assert_eq!(outcome.map.groups.len(), 14);
    let mut sizes: Vec<usize> = outcome.map.groups.iter().map(|g| g.len()).collect();
    sizes.sort_unstable();
    assert_eq!(&sizes[..2], &[6, 6]);
    assert!(sizes[2..].iter().all(|&s| s == 8));

    // Discovered partition == ground truth partition.
    for g in &outcome.map.groups {
        let want = topo.group_of(g[0]);
        for &sm in g {
            assert_eq!(topo.group_of(sm), want, "smid {sm} misplaced");
        }
    }

    // Reach estimate brackets 64 GiB.
    let reach = outcome.map.reach_bytes;
    assert!(
        reach >= 48 * (1 << 30) && reach <= 72 * (1u64 << 30),
        "reach estimate {} GiB",
        reach >> 30
    );

    // Independence (Fig 5) held.
    assert!(outcome.map.independent);
}

#[test]
fn probe_is_robust_to_card_enumeration() {
    // A different card (different smid permutation) must yield the same
    // *structure* even though the smid->group mapping differs.
    let (_m1, o1) = quick_probe(1);
    let (_m2, o2) = quick_probe(2);
    let sizes = |o: &a100win::probe::ProbeOutcome| {
        let mut v: Vec<usize> = o.map.groups.iter().map(|g| g.len()).collect();
        v.sort_unstable();
        v
    };
    assert_eq!(sizes(&o1), sizes(&o2));
    // And the mapping really is card-specific: the group containing smid 0
    // has different membership between cards (overwhelmingly likely under
    // a shuffle).
    let members = |o: &a100win::probe::ProbeOutcome| {
        let gid = o.map.group_of(0).unwrap();
        let mut v = o.map.groups[gid].clone();
        v.sort_unstable();
        v
    };
    assert_ne!(members(&o1), members(&o2));
}

#[test]
fn probed_map_drives_group_to_chunk_placement() {
    let (machine, outcome) = quick_probe(0xBEEF);
    // Window the full 80 GiB by the *probed* reach and pin groups.
    let row_bytes = 128u64;
    let total_rows = machine.config().memory.total_bytes / row_bytes;
    let plan = WindowPlan::for_reach(
        total_rows,
        row_bytes,
        outcome.map.reach_bytes,
        outcome.map.groups.len(),
    )
    .unwrap();
    assert!(
        plan.count() >= 2,
        "80 GiB needs >= 2 windows under 64 GiB reach"
    );
    let placement =
        Placement::build(PlacementPolicy::GroupToChunk, &outcome.map, &plan, 0).unwrap();

    // Every window pinned, and the paper's invariant holds: each group's
    // window is within probed reach.
    for w in 0..plan.count() {
        assert!(!placement.serving_groups(w).is_empty());
        assert!(plan.window_bytes(&plan.windows()[w]) <= outcome.map.reach_bytes);
    }

    // And the placement actually restores full speed on the simulator.
    let assignments = placement.sim_assignments(&outcome.map, &plan, &machine, 3);
    let spec = a100win::sim::MeasurementSpec {
        assignments,
        accesses_per_sm: 3_000,
        warmup_fraction: 0.25,
        txn_bytes: 128,
        seed: 3,
    };
    let meas = machine.run(&spec);
    assert!(
        meas.gbps > 1100.0,
        "probed group-to-chunk placement reached only {:.0} GB/s",
        meas.gbps
    );
}

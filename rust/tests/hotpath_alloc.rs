//! Steady-state allocation accounting for the serving hot path, behind
//! the `perf-assert` feature (it installs a process-global counting
//! allocator, so it lives in its own test binary and is compiled out of
//! ordinary tier-1 runs).
//!
//! The acceptance bar (ISSUE 5): after warmup, the request path performs
//! **zero heap allocations per sub-batch** — the per-request cost is a
//! small constant (the accumulator Arcs and the split's shell vector),
//! independent of how many sub-batches the request fans out to and how
//! many rows it carries.  Requests here fan out to 4 windows × 256 rows,
//! so any per-sub-batch or per-row allocation would blow the constant
//! bound by 4x / 1000x.
#![cfg(feature = "perf-assert")]

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use a100win::coordinator::{BatcherConfig, Table, WindowPlan};
use a100win::prelude::PlacementPolicy;
use a100win::probe::TopologyMap;
use a100win::service::{Service, SimBackend, SimBackendConfig, SimTiming};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn map4() -> TopologyMap {
    TopologyMap {
        groups: (0..4).map(|g| vec![g]).collect(),
        reach_bytes: 1 << 30,
        solo_gbps: vec![100.0; 4],
        independent: true,
        card_id: "alloc-test".into(),
    }
}

/// Allocation ceiling per request, averaged over the measured run.  The
/// real steady-state cost is ~4 now that the accumulator shell
/// (`RequestAcc` + its `Completion`) recycles through the dispatcher's
/// `AccPool` alongside the slab buffers and index shells (PR 8); what's
/// left is the split's sub-batch vector, the formed-batch vector, and
/// debug-build claim maps.  12 leaves headroom for allocator-internal
/// noise while still failing loudly on any per-sub-batch (≥4/request
/// here) or per-row (≥1024/request) regression.
const MAX_ALLOCS_PER_REQUEST: u64 = 12;

#[test]
fn steady_state_request_path_is_allocation_free_per_sub_batch() {
    let rows: u64 = 32_768;
    let d = 8usize;
    let windows = 4usize;
    let table = Table::synthetic(rows, d);
    let plan = WindowPlan::split(rows, (d * 4) as u64, windows);
    let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
    cfg.batcher = BatcherConfig {
        max_batch_rows: 4_096,
        max_wait: std::time::Duration::from_micros(100),
        max_pending: 256,
    };
    let backend = Arc::new(
        SimBackend::start(cfg, &map4(), plan, table.view(), SimTiming::Probed).unwrap(),
    );
    let service = Service::new(backend);

    // Fixed payloads spanning all four windows (4 sub-batches per
    // request), pre-generated so the *client's* request-building
    // allocations never land in the measurement.
    let per_window = rows / windows as u64;
    let payloads: Vec<Arc<Vec<u64>>> = (0..32)
        .map(|i| {
            Arc::new(
                (0..256u64)
                    .map(|k| (k % windows as u64) * per_window + (i * 37 + k * 13) % per_window)
                    .collect(),
            )
        })
        .collect();

    let run = |n: usize| {
        for i in 0..n {
            let rows = Arc::clone(&payloads[i % payloads.len()]);
            let out = service.lookup(rows).expect("lookup");
            service.recycle(out);
        }
    };

    // Warmup: fill the slab pool, the router's shell pool (via the worker
    // return rings), the batcher's deque, and the rate memos.
    run(400);

    let before = ALLOCS.load(Ordering::Relaxed);
    let measured = 200usize;
    run(measured);
    let delta = ALLOCS.load(Ordering::Relaxed) - before;
    let per_request = delta / measured as u64;
    println!("allocations: {delta} over {measured} requests ({per_request}/request)");
    assert!(
        per_request <= MAX_ALLOCS_PER_REQUEST,
        "steady-state request path allocates {per_request}/request (> {MAX_ALLOCS_PER_REQUEST}): \
         a per-sub-batch or per-row allocation crept back in ({delta} total over {measured})"
    );
    service.shutdown();
}

/// Per-request ceiling for the *remote* path (loopback TCP, one pinned
/// connection).  The counting allocator is process-global, so this
/// measures client AND server together.  The client side is fenced
/// zero-alloc (`lookup_reuse` recycles every buffer), but the server
/// still pays a small per-request constant: the mpsc node and reply
/// shell in the writer channel, the decoded row vector handed to the
/// facade, and the facade's own ~4 (bounded above).  64 keeps that
/// honest while failing loudly on any per-row cost — a 256-row request
/// regressing to one allocation per row would read ≥256.
const MAX_REMOTE_ALLOCS_PER_REQUEST: u64 = 64;

#[test]
fn steady_state_remote_request_path_has_constant_allocations() {
    use a100win::net::{ClientConfig, NetClient, NetConfig, NetServer, Target};

    let rows: u64 = 32_768;
    let d = 8usize;
    let windows = 4usize;
    let table = Table::synthetic(rows, d);
    let plan = WindowPlan::split(rows, (d * 4) as u64, windows);
    let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
    cfg.batcher = BatcherConfig {
        max_batch_rows: 4_096,
        max_wait: std::time::Duration::from_micros(100),
        max_pending: 256,
    };
    let backend = Arc::new(
        SimBackend::start(cfg, &map4(), plan, table.view(), SimTiming::Probed).unwrap(),
    );
    let mut server = NetServer::start(
        Target::Single(Service::new(backend)),
        NetConfig::default(),
    )
    .unwrap();
    let mut client =
        NetClient::connect(&server.addr().to_string(), ClientConfig::default()).unwrap();

    let per_window = rows / windows as u64;
    let payloads: Vec<Vec<u64>> = (0..32)
        .map(|i: u64| {
            (0..256u64)
                .map(|k| (k % windows as u64) * per_window + (i * 37 + k * 13) % per_window)
                .collect()
        })
        .collect();

    let mut run = |n: usize| {
        for i in 0..n {
            let partial = client
                .lookup_reuse(&payloads[i % payloads.len()], None)
                .expect("remote lookup");
            assert!(!partial, "clean loopback run went partial");
        }
    };

    // Warmup: grow the client's frame/result buffers to their high-water
    // marks and fill every server-side pool, exactly as the local test.
    run(400);

    let before = ALLOCS.load(Ordering::Relaxed);
    let measured = 200usize;
    run(measured);
    let delta = ALLOCS.load(Ordering::Relaxed) - before;
    let per_request = delta / measured as u64;
    println!("remote allocations: {delta} over {measured} requests ({per_request}/request)");
    assert!(
        per_request <= MAX_REMOTE_ALLOCS_PER_REQUEST,
        "steady-state remote path allocates {per_request}/request \
         (> {MAX_REMOTE_ALLOCS_PER_REQUEST}): a per-row or per-frame allocation crept into \
         the wire path ({delta} total over {measured})"
    );
    server.shutdown();
}

//! The two new seams end to end — hermetic (no `pjrt` feature, no
//! artifacts):
//!
//! * **Zero-copy storage**: fleet sharding hands every card a `TableView`
//!   over the one shared `Arc<[f32]>` (pointer-identity-verified — no
//!   copies), and views stay correct under serving.
//! * **Adaptive placement**: under zipf window skew the `AdaptivePlacer`
//!   beats static group-to-chunk on simulated aggregate GB/s (makespan
//!   over groups), shows parity under uniform load, preserves the paper's
//!   one-group-one-window invariant across swaps, and swaps generations
//!   live without draining in-flight tickets.
//! * **Cross-tenant admission**: the weighted global budget guarantees a
//!   quiet tenant's share while a noisy neighbor floods.
//! * **Pacing**: `sim_timescale` slows completions to the simulated
//!   device rate.

use std::sync::Arc;
use std::time::{Duration, Instant};

use a100win::coordinator::{
    AdaptiveConfig, BatcherConfig, CardSpec, PlacementPolicy, Table, WindowPlan,
};
use a100win::probe::TopologyMap;
use a100win::service::{
    Backend, GlobalAdmission, OverloadPolicy, Service, SessionConfig, SimBackend,
    SimBackendConfig, SimTiming,
};
use a100win::workload::{synth::Distribution, RequestGen, WorkloadSpec};

fn map(groups: usize, solo_gbps: f64) -> TopologyMap {
    TopologyMap {
        groups: (0..groups).map(|g| vec![g * 2, g * 2 + 1]).collect(),
        reach_bytes: 64 << 30,
        solo_gbps: vec![solo_gbps; groups],
        independent: true,
        card_id: format!("adaptive-{groups}g"),
    }
}

fn quick_batcher() -> BatcherConfig {
    BatcherConfig {
        max_batch_rows: 4096,
        max_wait: Duration::from_millis(1),
        max_pending: 512,
    }
}

fn verify(out: &[f32], rows: &[u64], table: &Table) {
    assert_eq!(out.len(), rows.len() * table.d);
    for (k, &row) in rows.iter().enumerate() {
        for j in 0..table.d {
            assert_eq!(
                out[k * table.d + j],
                table.expected(row, j),
                "row {row} column {j}"
            );
        }
    }
}

fn start(cfg: SimBackendConfig, table: &Table, windows: usize) -> Arc<SimBackend> {
    let plan = WindowPlan::split(table.rows, (table.d * 4) as u64, windows);
    Arc::new(
        SimBackend::start(cfg, &map(4, 100.0), plan, table.view(), SimTiming::Probed).unwrap(),
    )
}

fn drive_requests(backend: &Arc<SimBackend>, gen: &mut RequestGen, n: usize, table: &Table) {
    let dyn_backend: Arc<dyn Backend> = Arc::clone(backend);
    let service = Service::new(dyn_backend);
    for _ in 0..n {
        let rows = Arc::new(gen.next_request());
        verify(&service.lookup(Arc::clone(&rows)).unwrap(), &rows, table);
    }
}

fn workload(table: &Table, dist: Distribution) -> RequestGen {
    RequestGen::new(WorkloadSpec {
        total_rows: table.rows,
        distribution: dist,
        request_rows: (512, 512),
        seed: 99,
    })
}

// ---------------------------------------------------------------------------
// Zero-copy storage.
// ---------------------------------------------------------------------------

#[test]
fn fleet_build_sim_shares_storage_without_copies() {
    use a100win::service::FleetService;
    let d = 8usize;
    let total_rows = 8_192u64;
    let table = Table::synthetic(total_rows, d);
    let card = |gbps: f64| CardSpec {
        map: map(4, gbps),
        memory_bytes: total_rows * (d as u64) * 4,
    };
    let fleet = FleetService::build_sim(
        vec![
            (card(120.0), SimTiming::Probed),
            (card(80.0), SimTiming::Probed),
        ],
        &table,
        quick_batcher(),
        5,
    )
    .unwrap();
    assert_eq!(fleet.plan().shards.len(), 2);

    // Acceptance: per-card memory is O(view metadata) — every card's
    // backend view aliases the host table's storage Arc (no table copy).
    let cards = fleet.cards();
    let plan = fleet.plan();
    for (svc, shard) in cards.iter().zip(&plan.shards) {
        let view = svc
            .backend()
            .view()
            .expect("sim backends expose their view");
        assert!(
            Arc::ptr_eq(view.storage(), &table.data),
            "card {} copied its shard",
            shard.card
        );
        assert_eq!(view.rows(), shard.rows);
        assert_eq!(view.start_row(), shard.start_row);
    }
    // 1 host table + 2 card views + transient clones inside workers: the
    // storage allocation exists exactly once.
    assert!(Arc::strong_count(&table.data) >= 3);

    // And the views serve correct data end to end.
    let rows: Arc<Vec<u64>> = Arc::new((0..500).map(|i| (i * 13) % total_rows).collect());
    verify(&fleet.lookup(Arc::clone(&rows)).unwrap(), &rows, &table);
    fleet.shutdown();
}

// ---------------------------------------------------------------------------
// Adaptive placement.
// ---------------------------------------------------------------------------

#[test]
fn adaptive_beats_static_under_window_skew() {
    let table = Table::synthetic(8_192, 4);
    let skew = Distribution::Zipf { theta: 1.1 };

    // Static group-to-chunk: 2 of 4 groups pinned to the hot window.
    let static_backend = {
        let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
        cfg.batcher = quick_batcher();
        start(cfg, &table, 2)
    };
    // Adaptive: same start, manual epochs.
    let adaptive_backend = {
        let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
        cfg.batcher = quick_batcher();
        cfg.adaptive = Some(AdaptiveConfig::default());
        start(cfg, &table, 2)
    };

    // Phase 1: identical skewed traffic to both; then the adaptive backend
    // closes an epoch and re-deals groups toward the hot window.
    drive_requests(&static_backend, &mut workload(&table, skew.clone()), 30, &table);
    drive_requests(&adaptive_backend, &mut workload(&table, skew.clone()), 30, &table);
    let gen = adaptive_backend
        .rebalance_epoch()
        .expect("zipf(1.1) skew must trigger a rebalance");
    assert_eq!(gen, 1);
    let placement = adaptive_backend.placement();
    assert_eq!(placement.generation, 1);
    // Hot window (0: zipf front-loads low rows) earned a third group.
    assert_eq!(placement.groups_of_window[0].len(), 3, "{placement:?}");
    assert_eq!(placement.groups_of_window[1].len(), 1);

    // Phase 2: continue the stream on both.
    let mut gs = workload(&table, skew.clone());
    let mut ga = workload(&table, skew);
    for _ in 0..30 {
        gs.next_request();
        ga.next_request();
    }
    drive_requests(&static_backend, &mut gs, 90, &table);
    drive_requests(&adaptive_backend, &mut ga, 90, &table);

    // Acceptance: measurably higher simulated aggregate GB/s under skew.
    let s = static_backend.aggregate_sim_gbps();
    let a = adaptive_backend.aggregate_sim_gbps();
    assert!(
        a > s * 1.15,
        "adaptive {a:.2} GB/s not measurably above static {s:.2} GB/s"
    );

    static_backend.shutdown();
    adaptive_backend.shutdown();
}

#[test]
fn adaptive_matches_static_under_uniform_load() {
    let table = Table::synthetic(8_192, 4);
    let static_backend = {
        let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
        cfg.batcher = quick_batcher();
        start(cfg, &table, 2)
    };
    let adaptive_backend = {
        let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
        cfg.batcher = quick_batcher();
        cfg.adaptive = Some(AdaptiveConfig::default());
        start(cfg, &table, 2)
    };

    drive_requests(
        &static_backend,
        &mut workload(&table, Distribution::Uniform),
        40,
        &table,
    );
    drive_requests(
        &adaptive_backend,
        &mut workload(&table, Distribution::Uniform),
        40,
        &table,
    );
    // Uniform load: hysteresis keeps the original deal (generation 0)...
    assert!(adaptive_backend.rebalance_epoch().is_none());
    assert_eq!(adaptive_backend.placement().generation, 0);
    // ...and throughput parity holds (identical routing, deterministic
    // accounting).
    let s = static_backend.aggregate_sim_gbps();
    let a = adaptive_backend.aggregate_sim_gbps();
    assert!(
        (a / s - 1.0).abs() < 0.05,
        "uniform parity broken: adaptive {a:.2} vs static {s:.2} GB/s"
    );
    static_backend.shutdown();
    adaptive_backend.shutdown();
}

#[test]
fn rebalance_epochs_preserve_invariant_and_serve_through_swaps() {
    // Background epochs swap the placement while clients are mid-stream:
    // every response stays correct (no drain, no misroute) and every
    // accepted placement keeps the paper's invariant.
    let table = Table::synthetic(8_192, 4);
    let m = map(4, 100.0);
    let plan = WindowPlan::split(table.rows, (table.d * 4) as u64, 2);
    let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
    cfg.batcher = quick_batcher();
    cfg.adaptive = Some(AdaptiveConfig {
        epoch: Some(Duration::from_millis(5)),
        ..AdaptiveConfig::default()
    });
    let backend = Arc::new(
        SimBackend::start(cfg, &m, plan.clone(), table.view(), SimTiming::Probed).unwrap(),
    );

    let mut gen = workload(&table, Distribution::Zipf { theta: 1.1 });
    drive_requests(&backend, &mut gen, 120, &table);

    let placement = backend.placement();
    assert!(
        placement.generation >= 1,
        "background rebalancer never swapped under skew"
    );
    assert_eq!(placement.check_windowed_invariant(&m, &plan), Ok(()));
    backend.shutdown();
}

#[test]
fn unservable_prebuilt_placement_fails_at_startup() {
    // An uncovered window must error deterministically at start, not
    // panic the dispatcher on the first request that routes there.
    use a100win::coordinator::Placement;
    let table = Table::synthetic(1_024, 4);
    let m = map(4, 100.0);
    let plan = WindowPlan::split(table.rows, (table.d * 4) as u64, 2);
    let mut placement = Placement::build(PlacementPolicy::GroupToChunk, &m, &plan, 0).unwrap();
    placement.groups_of_window[1].clear();
    let err = SimBackend::start_with_placement(
        SimBackendConfig::new(PlacementPolicy::GroupToChunk),
        &m,
        plan,
        placement,
        table.view(),
        SimTiming::Probed,
    );
    assert!(err.is_err());
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("unservable"), "unexpected error: {msg}");
}

// ---------------------------------------------------------------------------
// Cross-tenant admission.
// ---------------------------------------------------------------------------

#[test]
fn global_budget_protects_quiet_tenant_from_flood() {
    // A slow batcher keeps tickets in flight so budgets bind.
    let slow = BatcherConfig {
        max_batch_rows: 1 << 20,
        max_wait: Duration::from_millis(150),
        max_pending: 64,
    };
    let table = Table::synthetic(1_024, 4);
    let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
    cfg.batcher = slow;
    let backend = start(cfg, &table, 1);
    let dyn_backend: Arc<dyn Backend> = Arc::clone(&backend);
    let service = Service::new(dyn_backend);

    // Global budget 4, weights 3:1 -> guarantees 3 and 1 (no slack).
    let global = GlobalAdmission::new(4);
    let reject = |max_in_flight| SessionConfig {
        max_in_flight,
        overload: OverloadPolicy::Reject,
        deadline: None,
    };
    let noisy = service.session_with_budget("noisy", reject(64), &global, 3.0);
    let quiet = service.session_with_budget("quiet", reject(64), &global, 1.0);

    // The noisy tenant floods: capped at its guarantee, not the budget.
    let mut held = Vec::new();
    loop {
        match noisy.submit(Arc::new(vec![1])) {
            Ok(t) => held.push(t),
            Err(e) => {
                assert!(e.to_string().contains("global admission budget"), "{e}");
                break;
            }
        }
        assert!(held.len() <= 4, "flood exceeded the global budget");
    }
    assert_eq!(held.len(), 3);
    assert_eq!(service.metrics().global_rejected, 1);

    // The quiet tenant's reservation survives the flood.
    let t = quiet.submit(Arc::new(vec![2])).expect("reserved share");
    assert!(quiet.submit(Arc::new(vec![3])).is_err(), "budget is full");

    // Redeeming releases global slots for the next round.
    verify(&t.wait().unwrap(), &[2], &table);
    for t in held {
        verify(&t.wait().unwrap(), &[1], &table);
    }
    assert_eq!(global.used_total(), 0);
    assert!(noisy.submit(Arc::new(vec![4])).is_ok());

    let shares = global.report();
    assert_eq!(shares.len(), 2);
    assert_eq!(shares[0].guaranteed, 3);
    assert_eq!(shares[1].guaranteed, 1);
    service.shutdown();
}

// ---------------------------------------------------------------------------
// Simulated-time pacing.
// ---------------------------------------------------------------------------

#[test]
fn sim_timescale_paces_completions() {
    // One group at 128 GB/s over 128 B rows -> exactly 1 ns of simulated
    // device time per row.  4096 rows at timescale 1e5 must take >= ~0.4 s
    // of wall clock; unpaced the same work is far faster.
    let table = Table::synthetic(4_096, 32);
    let m = map(1, 128.0);
    let plan = || WindowPlan::split(table.rows, (table.d * 4) as u64, 1);
    let run = |timescale: f64| -> Duration {
        let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
        cfg.batcher = quick_batcher();
        cfg.sim_timescale = timescale;
        let backend = Arc::new(
            SimBackend::start(cfg, &m, plan(), table.view(), SimTiming::Probed).unwrap(),
        );
        let dyn_backend: Arc<dyn Backend> = Arc::clone(&backend);
        let service = Service::new(dyn_backend);
        let rows: Arc<Vec<u64>> = Arc::new((0..table.rows).collect());
        let t = Instant::now();
        verify(&service.lookup(Arc::clone(&rows)).unwrap(), &rows, &table);
        let dt = t.elapsed();
        service.shutdown();
        dt
    };
    let unpaced = run(0.0);
    let paced = run(1e5);
    assert!(
        paced >= Duration::from_millis(300),
        "pacing too weak: {paced:?}"
    );
    assert!(paced > unpaced * 3, "paced {paced:?} vs {unpaced:?}");
}

//! The two-level repartitioning control plane end to end — hermetic (no
//! `pjrt` feature, no artifacts):
//!
//! * **Drift ladder** (the ISSUE's acceptance bar): under a rotating
//!   zipf(1.1) hotspot whose width is far below window granularity,
//!   two-level adaptive (re-deal + window re-split) beats deal-only
//!   adaptive by ≥1.25× and static group-to-chunk by ≥1.4× on simulated
//!   aggregate GB/s, while staying within 5% of static under uniform
//!   load.  Every published plan preserves the paper's
//!   one-group-one-≤reach-window invariant.
//! * **Zero-copy migration**: a fleet control epoch that escalates to
//!   `Migrate` re-slices the shared `Arc<[f32]>` into new per-card views
//!   (pointer identity asserted — no table data is copied), while a
//!   ticket submitted before the migration merges correctly under its old
//!   shard map and post-migration lookups stay row-identical.
//! * **Health drain**: a group marked Failed is drained by an immediate
//!   control-plane epoch (no timer), serving stays correct, and recovery
//!   folds the group back in.

use std::sync::Arc;
use std::time::Duration;

use a100win::coordinator::{
    AdaptiveConfig, BatcherConfig, CardSpec, ControlPlaneConfig, GroupHealth, Lever,
    PlacementPolicy, SplitterConfig, Table, WindowPlan,
};
use a100win::probe::TopologyMap;
use a100win::service::{
    Backend, FleetConfig, FleetService, RebalanceConfig, Service, SimBackend, SimBackendConfig,
    SimTiming,
};
use a100win::workload::{synth::Distribution, RequestGen, WorkloadSpec};

fn map(solo: &[f64]) -> TopologyMap {
    TopologyMap {
        groups: (0..solo.len()).map(|g| vec![g * 2, g * 2 + 1]).collect(),
        reach_bytes: 64 << 30,
        solo_gbps: solo.to_vec(),
        independent: true,
        card_id: format!("repartition-{}g", solo.len()),
    }
}

fn quick_batcher() -> BatcherConfig {
    BatcherConfig {
        max_batch_rows: 4096,
        max_wait: Duration::from_millis(1),
        max_pending: 512,
    }
}

fn verify(out: &[f32], rows: &[u64], table: &Table) {
    assert_eq!(out.len(), rows.len() * table.d);
    for (k, &row) in rows.iter().enumerate() {
        for j in 0..table.d {
            assert_eq!(
                out[k * table.d + j],
                table.expected(row, j),
                "row {row} column {j}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Drift ladder: static vs deal-only vs two-level.
// ---------------------------------------------------------------------------

/// An eager control plane for tests: act on the first failing epoch, no
/// cooldown between levers (manual epochs are already rate-limited by the
/// request loop).
fn eager_control() -> ControlPlaneConfig {
    ControlPlaneConfig {
        min_imbalance: 0.10,
        patience: 1,
        cooldown: 0,
        max_lever: Lever::Resplit, // clamped per backend anyway
        trace_len: 512,
    }
}

fn drift_spec(rows: u64, period: u64) -> WorkloadSpec {
    WorkloadSpec {
        total_rows: rows,
        distribution: Distribution::Drift {
            inner: Box::new(Distribution::Zipf { theta: 1.1 }),
            period,
        },
        request_rows: (512, 512),
        seed: 99,
    }
}

/// Drive `phases * requests_per_phase` requests (epoch after every
/// request) and return the simulated aggregate GB/s under a *per-phase
/// makespan* model: within a phase groups work in parallel (the slowest
/// bounds it), phases are serial (the hotspot has rotated between them).
fn run_arm(
    backend: &Arc<SimBackend>,
    table: &Table,
    mut gen: RequestGen,
    phases: usize,
    requests_per_phase: usize,
    check_invariant: bool,
) -> f64 {
    let m = map(&[120.0, 90.0, 90.0]);
    let dyn_backend: Arc<dyn Backend> = Arc::clone(backend);
    let service = Service::new(dyn_backend);
    let mut total_rows = 0u64;
    let mut sum_max_ns = 0f64;
    for _phase in 0..phases {
        for r in 0..requests_per_phase {
            let rows = Arc::new(gen.next_request());
            let out = service.lookup(Arc::clone(&rows)).unwrap();
            if r % 40 == 0 {
                verify(&out, &rows, table);
            }
            backend.rebalance_epoch();
            if check_invariant && r % 25 == 0 {
                let plan = backend.plan();
                let placement = backend.placement();
                assert_eq!(
                    placement.check_windowed_invariant(&m, &plan),
                    Ok(()),
                    "published plan violates the paper's invariant"
                );
            }
        }
        let report = backend.sim_report();
        let max_ns = report.iter().map(|r| r.sim_ms * 1e6).fold(0.0f64, f64::max);
        total_rows += report.iter().map(|r| r.rows).sum::<u64>();
        sum_max_ns += max_ns;
        backend.reset_sim_stats();
    }
    assert!(sum_max_ns > 0.0);
    let row_bytes = (table.d * 4) as f64;
    total_rows as f64 * row_bytes / sum_max_ns
}

fn arm_config(placer: &str) -> SimBackendConfig {
    let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
    cfg.batcher = quick_batcher();
    cfg.control = eager_control();
    match placer {
        "static" => {}
        "deal-only" => {
            cfg.adaptive = Some(AdaptiveConfig::default());
        }
        "two-level" => {
            cfg.adaptive = Some(AdaptiveConfig::default());
            cfg.resplit = Some(SplitterConfig {
                min_imbalance: 0.10,
                min_epoch_rows: 256,
                // The zipf(1.1) hot core is a handful of rows: let the
                // splitter isolate it.
                min_window_rows: 1,
            });
        }
        other => panic!("unknown arm {other}"),
    }
    cfg
}

fn start_arm(placer: &str, table: &Table) -> Arc<SimBackend> {
    let plan = WindowPlan::split(table.rows, (table.d * 4) as u64, 2);
    Arc::new(
        SimBackend::start(
            arm_config(placer),
            &map(&[120.0, 90.0, 90.0]),
            plan,
            table.view(),
            SimTiming::Probed,
        )
        .unwrap(),
    )
}

#[test]
fn drift_ladder_two_level_beats_deal_only_and_static() {
    let table = Table::synthetic(8_192, 4);
    // 3 phases = one full hotspot rotation (drift shifts by a third of the
    // table per period); period == requests_per_phase aligns them.  Phases
    // are long relative to the splitter's convergence (zipf 1.1's hot core
    // is a handful of rows, found by iterative quantile refinement over
    // ~20 epochs), so the score reflects the converged layouts.
    let phases = 3;
    let per_phase = 500;
    let period = per_phase as u64;

    let run = |placer: &str, check: bool| {
        let b = start_arm(placer, &table);
        let gen = RequestGen::new(drift_spec(table.rows, period));
        let g = run_arm(&b, &table, gen, phases, per_phase, check);
        let resplits = b.metrics().resplit_epochs;
        b.shutdown();
        (g, resplits)
    };
    let (static_gbps, _) = run("static", false);
    let (deal_only_gbps, _) = run("deal-only", true);
    let (two_level_gbps, resplits) = run("two-level", true);

    assert!(
        resplits > 0,
        "two-level arm never re-split under a rotating hotspot"
    );
    assert!(
        two_level_gbps >= deal_only_gbps * 1.25,
        "two-level {two_level_gbps:.2} GB/s not ≥1.25x deal-only {deal_only_gbps:.2} GB/s"
    );
    assert!(
        two_level_gbps >= static_gbps * 1.4,
        "two-level {two_level_gbps:.2} GB/s not ≥1.4x static {static_gbps:.2} GB/s"
    );
}

#[test]
fn uniform_load_parity_within_five_percent() {
    let table = Table::synthetic(8_192, 4);
    let uniform = |_| WorkloadSpec {
        total_rows: table.rows,
        distribution: Distribution::Uniform,
        request_rows: (512, 512),
        seed: 7,
    };
    let static_gbps = {
        let b = start_arm("static", &table);
        let g = run_arm(&b, &table, RequestGen::new(uniform(())), 1, 120, false);
        b.shutdown();
        g
    };
    let two_level_gbps = {
        let b = start_arm("two-level", &table);
        let g = run_arm(&b, &table, RequestGen::new(uniform(())), 1, 120, true);
        let m = b.metrics();
        assert_eq!(
            m.resplit_epochs, 0,
            "uniform load must never trigger a re-split"
        );
        b.shutdown();
        g
    };
    assert!(
        (two_level_gbps / static_gbps - 1.0).abs() < 0.05,
        "uniform parity broken: two-level {two_level_gbps:.2} vs static {static_gbps:.2} GB/s"
    );
}

// ---------------------------------------------------------------------------
// Zero-copy cross-card migration.
// ---------------------------------------------------------------------------

#[test]
fn migration_is_zero_copy_and_ticket_safe_mid_serving() {
    let d = 4usize;
    let total_rows = 8_192u64;
    let row_bytes = (d * 4) as u64;
    let table = Table::synthetic(total_rows, d);
    let card = || CardSpec {
        map: map(&[100.0, 100.0]),
        memory_bytes: total_rows * row_bytes,
    };
    let fleet = FleetService::build_sim_with(
        vec![(card(), SimTiming::Probed), (card(), SimTiming::Probed)],
        &table,
        FleetConfig {
            batcher: quick_batcher(),
            seed: 5,
            adaptive: Some(AdaptiveConfig::default()),
            resplit: None,
            rebalance: RebalanceConfig {
                min_imbalance: 0.15,
                min_epoch_rows: 512,
                min_move_rows: 16,
            },
            control: ControlPlaneConfig {
                min_imbalance: 0.15,
                patience: 1,
                cooldown: 0,
                max_lever: Lever::Migrate,
                trace_len: 64,
            },
            epoch: None, // manual control epochs
            sim_timescale: 0.0,
            legacy_path: false,
            ..FleetConfig::default()
        },
    )
    .unwrap();
    let plan0 = fleet.plan();
    assert_eq!(plan0.generation, 0);
    assert_eq!(plan0.shards.len(), 2);

    // Front-loaded zipf: card 0 owns the hot range and saturates.
    let mut gen = RequestGen::new(WorkloadSpec {
        total_rows,
        distribution: Distribution::Zipf { theta: 1.1 },
        request_rows: (512, 512),
        seed: 31,
    });
    let mut drive = |n: usize, fleet: &FleetService| {
        for _ in 0..n {
            let rows = Arc::new(gen.next_request());
            verify(&fleet.lookup(Arc::clone(&rows)).unwrap(), &rows, &table);
        }
    };

    // Escalate the fleet ladder to Migrate: redeal and resplit steps pass
    // first (per-card levers), then the migration applies.
    let mut migrated_gen = None;
    for _ in 0..6 {
        drive(5, &fleet);
        if let Some(g) = fleet.control_epoch() {
            migrated_gen = Some(g);
            break;
        }
    }
    let generation = migrated_gen.expect("fleet never escalated to a migration");
    assert_eq!(generation, 1);

    let plan1 = fleet.plan();
    assert_eq!(plan1.generation, 1);
    assert_ne!(
        plan1.shards[0].rows, plan0.shards[0].rows,
        "migration did not move the card boundary"
    );
    assert!(
        plan1.shards[0].rows < plan0.shards[0].rows,
        "the hot card must shed rows"
    );

    // Zero-copy: every post-migration card view aliases the original
    // table storage (no row was copied), and fleet counters recorded it.
    for svc in fleet.cards() {
        let view = svc.backend().view().expect("sim backends expose views");
        assert!(
            Arc::ptr_eq(view.storage(), &table.data),
            "migration copied table data"
        );
    }
    let fm = fleet.fleet_metrics();
    assert_eq!(fm.migrate_epochs, 1);
    assert_eq!(fm.generations_published, 1);
    assert_eq!(fm.rows_migrated, plan0.rows_moved(&plan1));
    assert!(fm.rows_migrated >= 16);

    // A ticket submitted under the OLD generation... (submit, then force
    // another migration-scale change by serving more load) ...must merge
    // under its own shard map.
    let rows: Arc<Vec<u64>> = Arc::new((0..1_000u64).map(|i| (i * 7) % total_rows).collect());
    let ticket = fleet.submit(Arc::clone(&rows), None).unwrap();
    drive(5, &fleet);
    verify(&ticket.wait().unwrap(), &rows, &table);

    // Row-content identity after the move: every row still reads the
    // synthetic ground truth through the new shard map.
    let all: Arc<Vec<u64>> = Arc::new((0..total_rows).step_by(37).collect());
    verify(&fleet.lookup(Arc::clone(&all)).unwrap(), &all, &table);
    fleet.shutdown();
}

// ---------------------------------------------------------------------------
// Health-driven drain.
// ---------------------------------------------------------------------------

#[test]
fn failed_group_drains_immediately_and_recovers() {
    let table = Table::synthetic(8_192, 4);
    let m = map(&[100.0, 100.0, 100.0, 100.0]);
    let plan = WindowPlan::split(table.rows, (table.d * 4) as u64, 2);
    let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
    cfg.batcher = quick_batcher();
    cfg.adaptive = Some(AdaptiveConfig::default());
    cfg.control = eager_control();
    let backend =
        Arc::new(SimBackend::start(cfg, &m, plan, table.view(), SimTiming::Probed).unwrap());
    let dyn_backend: Arc<dyn Backend> = Arc::clone(&backend);
    let service = Service::new(dyn_backend);
    let mut gen = RequestGen::new(WorkloadSpec::uniform(table.rows, 512, 3));
    let mut drive = |n: usize| {
        for _ in 0..n {
            let rows = Arc::new(gen.next_request());
            verify(&service.lookup(Arc::clone(&rows)).unwrap(), &rows, &table);
        }
    };
    drive(8);

    // Fail a group serving window 0: the swap happens *inside* the health
    // call (no timer epoch in between), and the failed group stops
    // receiving work immediately.
    let victim = backend.placement().serving_groups(0)[0];
    let swapped = backend
        .set_group_health(victim, GroupHealth::Failed)
        .unwrap();
    assert!(swapped.is_some(), "health transition must swap immediately");
    let placement = backend.placement();
    for w in 0..2 {
        assert!(
            !placement.serving_groups(w).contains(&victim),
            "failed group still serves window {w}"
        );
        assert!(!placement.serving_groups(w).is_empty());
    }
    let st = backend.health_state();
    assert_eq!(st.health[victim], GroupHealth::Failed);
    assert!(st.epoch >= 1);

    // Drain: rows credited to the victim stay frozen while serving
    // continues correctly on the survivors.
    let victim_rows_at_fail = backend
        .sim_report()
        .iter()
        .find(|r| r.group == victim)
        .map_or(0, |r| r.rows);
    drive(16);
    let victim_rows_after = backend
        .sim_report()
        .iter()
        .find(|r| r.group == victim)
        .map_or(0, |r| r.rows);
    assert_eq!(
        victim_rows_at_fail, victim_rows_after,
        "failed group kept receiving jobs"
    );

    // Recovery: mark Healthy; the immediate epoch (or the next regular
    // one, once signal accumulates) re-adds the group.
    backend.set_group_health(victim, GroupHealth::Healthy).unwrap();
    drive(8);
    backend.rebalance_epoch();
    let placement = backend.placement();
    let serves_again = (0..2).any(|w| placement.serving_groups(w).contains(&victim));
    assert!(serves_again, "recovered group was never re-dealt in");
    assert_eq!(
        placement.check_windowed_invariant(&m, &backend.plan()),
        Ok(()),
        "recovery must restore the paper's invariant"
    );
    backend.shutdown();
}

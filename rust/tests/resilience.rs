//! Fault injection + self-healing serving, end to end — hermetic (sim
//! backend, no artifacts, no real card).
//!
//! Covers the resilience acceptance surface:
//! * an injected outage fails a ticket fast when every feature is off
//!   (the baseline stays honest — no silent retries),
//! * per-sub-batch retry reroutes around a dying group and the circuit
//!   breaker walks its full closed -> open -> half-open -> closed cycle,
//!   visible in `Metrics` and the control plane's decision trace,
//! * straggler hedging rescues a stalled group's sub-batch via a sibling
//!   (first completion wins; the claim bitmap keeps duplicates out),
//! * partial results: a permanently dead window yields a `Partial`
//!   outcome whose validity mask exactly matches the delivered rows,
//! * a seeded chaos soak (stalls + outage + flapping health under
//!   drifting zipf load) delivers zero corrupted rows,
//! * fleet mode: killing one card's backend mid-flight degrades spanning
//!   requests to `Partial` (surviving card's rows, request order) and
//!   fails new submissions fast.

use std::sync::Arc;
use std::time::{Duration, Instant};

use a100win::coordinator::{CardSpec, PlacementPolicy, Table, WindowPlan};
use a100win::probe::TopologyMap;
use a100win::service::{
    BreakerConfig, HedgeConfig, Outcome, ResilienceConfig, RetryPolicy, Service, SimBackend,
    SimBackendConfig, SimTiming,
};
use a100win::service::{FleetConfig, FleetService};
use a100win::sim::{FaultPlan, StallKind};
use a100win::workload::chaos::{drive_chaos, ChaosConfig};
use a100win::workload::synth::Distribution;

/// A hand-rolled 2-group map with slow (controllable) probed rates:
/// `ns_per_row = row_bytes / solo_gbps`, so 2 GB/s at 32 B rows = 16 ns
/// of simulated time per row — pacing tests can size stalls exactly.
fn map2() -> TopologyMap {
    TopologyMap {
        groups: vec![vec![0, 1], vec![2, 3]],
        reach_bytes: 64 << 30,
        solo_gbps: vec![2.0, 2.0],
        independent: true,
        card_id: "resilience-test".into(),
    }
}

fn map4() -> TopologyMap {
    TopologyMap {
        groups: (0..4).map(|g| vec![g * 2, g * 2 + 1]).collect(),
        reach_bytes: 64 << 30,
        solo_gbps: vec![120.0, 119.0, 91.0, 90.0],
        independent: true,
        card_id: "resilience-test".into(),
    }
}

fn start(
    map: &TopologyMap,
    rows: u64,
    d: usize,
    windows: usize,
    mutate: impl FnOnce(&mut SimBackendConfig),
) -> (Service, Arc<SimBackend>, Table) {
    let table = Table::synthetic(rows, d);
    let plan = WindowPlan::split(rows, (d * 4) as u64, windows);
    let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
    mutate(&mut cfg);
    let backend = Arc::new(
        SimBackend::start(cfg, map, plan, table.view(), SimTiming::Probed).unwrap(),
    );
    (Service::new(backend.clone()), backend, table)
}

fn verify(out: &[f32], rows: &[u64], table: &Table) {
    assert_eq!(out.len(), rows.len() * table.d);
    for (k, &row) in rows.iter().enumerate() {
        for j in 0..table.d {
            assert_eq!(
                out[k * table.d + j],
                table.expected(row, j),
                "row {row} column {j}"
            );
        }
    }
}

fn some_rows(n: usize, total: u64, salt: u64) -> Arc<Vec<u64>> {
    Arc::new((0..n as u64).map(|i| (i * 37 + salt) % total).collect())
}

#[test]
fn injected_outage_fails_fast_without_resilience() {
    // Every group dead, every feature off: the ticket must surface the
    // injected fault as a plain error (no retry, no partial).
    let (service, backend, table) = start(&map2(), 4_096, 8, 1, |cfg| {
        cfg.fault = Some(
            FaultPlan::new(3)
                .outage(0, 0, u64::MAX)
                .outage(1, 0, u64::MAX),
        );
    });
    let err = service
        .submit(some_rows(64, table.rows, 0), None)
        .unwrap()
        .wait()
        .unwrap_err();
    assert!(
        err.to_string().contains("injected fault"),
        "unexpected error: {err:#}"
    );
    let m = service.metrics();
    assert_eq!(m.errors, 1);
    assert_eq!(m.retries, 0);
    let (_, fails) = backend.faults_injected().unwrap();
    assert!(fails >= 1);
    service.shutdown();
}

#[test]
fn retry_reroutes_and_breaker_walks_full_cycle() {
    // Group 0's first 6 jobs fail.  Retries reroute each failed sub-batch
    // through the live placement; after 3 consecutive failures the breaker
    // opens (group evicted via an immediate health epoch), after `open_for`
    // it half-opens (group re-included at half weight so real traffic
    // probes it), and once the outage window has passed, probe successes
    // close it again.  The whole cycle must be visible in Metrics and the
    // decision trace.
    let (service, backend, table) = start(&map2(), 8_192, 8, 1, |cfg| {
        cfg.fault = Some(FaultPlan::new(5).outage(0, 0, 6));
        cfg.resilience = ResilienceConfig {
            retry: Some(RetryPolicy {
                budget: 3,
                backoff: Duration::from_micros(100),
            }),
            breaker: Some(BreakerConfig {
                failure_threshold: 3,
                open_for: Duration::from_millis(10),
                probe_successes: 2,
            }),
            ..ResilienceConfig::default()
        };
    });

    let deadline = Instant::now() + Duration::from_secs(20);
    let mut verified = 0u64;
    let mut failed = 0u64;
    let mut salt = 0u64;
    loop {
        salt += 1;
        let rows = some_rows(128, table.rows, salt);
        match service.submit(Arc::clone(&rows), None).unwrap().wait() {
            Ok(out) => {
                verify(&out, &rows, &table);
                verified += 1;
            }
            Err(_) => failed += 1,
        }
        let m = service.metrics();
        if m.breaker_closes >= 1 && verified > 0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "breaker never closed: {} opens, {} half-opens, {} closes, \
             {} retries ({verified} ok, {failed} failed)",
            m.breaker_opens,
            m.breaker_half_opens,
            m.breaker_closes,
            m.retries
        );
        // Give the monitor thread room to expire the open timer.
        std::thread::sleep(Duration::from_micros(500));
    }

    let m = service.metrics();
    assert!(m.retries >= 1, "no retries recorded");
    assert!(m.breaker_opens >= 1);
    assert!(m.breaker_half_opens >= 1);
    assert!(m.breaker_closes >= 1);
    // Goodput degraded, never collapsed: retries kept most requests whole.
    assert!(verified > failed, "{verified} ok vs {failed} failed");
    let trace = backend.control_decisions();
    assert!(
        trace.iter().any(|d| d.why.contains("breaker")),
        "no breaker entries in the decision trace"
    );
    // Steady state after the cycle: lookups verify.
    let rows = some_rows(64, table.rows, 999);
    verify(
        &service.submit(Arc::clone(&rows), None).unwrap().wait().unwrap(),
        &rows,
        &table,
    );
    service.shutdown();
}

#[test]
fn hedging_rescues_stalled_group() {
    // Group 0 stalls 400x forever; pacing (timescale 50) makes that real
    // wall time: ~200 us per healthy job vs ~80 ms stalled.  The monitor
    // hedges any sub-batch in flight past 2 ms to the sibling group; the
    // sibling wins the claim and the ticket resolves fast and exact.
    let (service, _backend, table) = start(&map2(), 4_096, 8, 1, |cfg| {
        cfg.fault = Some(FaultPlan::new(9).stall(0, 0, u64::MAX, StallKind::Fixed(400.0)));
        cfg.sim_timescale = 50.0;
        cfg.resilience = ResilienceConfig {
            hedge: Some(HedgeConfig {
                min_after: Duration::from_millis(2),
                quantile: 0.99,
            }),
            ..ResilienceConfig::default()
        };
    });

    let mut wins = 0;
    for salt in 0..20u64 {
        let rows = some_rows(256, table.rows, salt * 7);
        let out = service.submit(Arc::clone(&rows), None).unwrap().wait().unwrap();
        verify(&out, &rows, &table);
        wins = service.metrics().hedge_wins;
        if wins >= 1 {
            break;
        }
    }
    let m = service.metrics();
    assert!(m.hedges >= 1, "monitor never hedged a straggler");
    assert!(wins >= 1, "no hedge ever won ({} dispatched)", m.hedges);
    service.shutdown();
}

#[test]
fn partial_outcome_masks_failed_window() {
    // Two windows, one group each; group 1 permanently dead, no retry.
    // A request spanning both windows must degrade to Partial: the
    // surviving window's rows delivered and verified, the dead window's
    // rows zero-filled and masked out.
    let (service, _backend, table) = start(&map2(), 8_192, 8, 2, |cfg| {
        cfg.fault = Some(FaultPlan::new(13).outage(1, 0, u64::MAX));
        cfg.resilience = ResilienceConfig {
            partials: true,
            ..ResilienceConfig::default()
        };
    });

    // Two rows in window 0 ([0, 4096)), two in window 1 ([4096, 8192)).
    let rows: Vec<u64> = vec![10, 20, 4_100, 4_200];
    let outcome = service
        .submit(Arc::new(rows.clone()), None)
        .unwrap()
        .wait_outcome()
        .unwrap();
    let Outcome::Partial { rows: out, valid } = outcome else {
        panic!("expected Partial, got {outcome:?}");
    };
    assert_eq!(valid.len(), rows.len());
    assert_eq!(out.len(), rows.len() * table.d);
    assert_eq!(
        valid.iter().filter(|&&v| v).count(),
        2,
        "exactly the surviving window's rows should be valid: {valid:?}"
    );
    // One window survived wholesale: the mask is per-window consistent.
    assert_eq!(valid[0], valid[1]);
    assert_eq!(valid[2], valid[3]);
    assert_ne!(valid[0], valid[2]);
    for (k, &row) in rows.iter().enumerate() {
        let span = &out[k * table.d..(k + 1) * table.d];
        if valid[k] {
            for (j, &v) in span.iter().enumerate() {
                assert_eq!(v, table.expected(row, j), "row {row} column {j}");
            }
        } else {
            assert!(span.iter().all(|&v| v == 0.0), "masked row {row} not zeroed");
        }
    }
    assert_eq!(service.metrics().partials, 1);
    service.shutdown();
}

#[test]
fn chaos_soak_delivers_no_corrupted_rows() {
    // The acceptance soak in miniature: seeded schedule with >= 3 fault
    // modes (outage, fixed + heavy-tailed stalls, flapping health) against
    // the fully armed stack under drifting zipf load.  Zero corrupted
    // rows, zero malformed masks, no total outage.
    let (service, backend, table) = start(&map4(), 16_384, 8, 2, |cfg| {
        cfg.fault = Some(FaultPlan::chaos(11, 4));
        cfg.resilience = ResilienceConfig::full();
    });

    let report = drive_chaos(
        &service,
        &table,
        &ChaosConfig {
            requests: 200,
            request_rows: (16, 64),
            distribution: Distribution::parse("drift:zipf:1.1:100").unwrap(),
            seed: 17,
            deadline: Some(Duration::from_millis(250)),
            concurrency: 4,
        },
    );
    assert_eq!(report.corrupted_rows, 0, "{report:?}");
    assert_eq!(report.mask_violations, 0, "{report:?}");
    assert!(report.completed > 0, "{report:?}");
    assert!(report.valid_rows_checked > 0, "{report:?}");
    let (stalls, fails) = backend.faults_injected().unwrap();
    assert!(stalls >= 1 && fails >= 1, "schedule never fired: {stalls}/{fails}");
    service.shutdown();
}

#[test]
fn fleet_card_death_yields_partials_and_fast_errors() {
    // Two sim cards, paced so jobs queue; kill card 1's backend with
    // requests in flight.  Queued jobs fail immediately, spanning tickets
    // degrade to Partial (card 0's rows, merged in request order), and
    // new submissions fail fast naming the dead shard.
    let mut specs = Vec::new();
    for _ in 0..2 {
        specs.push((
            CardSpec {
                map: map4(),
                memory_bytes: 1 << 32,
            },
            SimTiming::Probed,
        ));
    }
    let table = Table::synthetic(16_384, 8);
    let fleet = FleetService::build_sim_with(
        specs,
        &table,
        FleetConfig {
            sim_timescale: 20_000.0,
            ..FleetConfig::default()
        },
    )
    .unwrap();
    let shard1_start = fleet.plan().shards[1].start_row;

    // Spanning requests: half the rows on each card.
    let mut tickets = Vec::new();
    for salt in 0..16u64 {
        let rows: Arc<Vec<u64>> = Arc::new(
            (0..32u64)
                .map(|i| {
                    let local = (i * 97 + salt * 13) % shard1_start;
                    if i % 2 == 0 {
                        local
                    } else {
                        shard1_start + local
                    }
                })
                .collect(),
        );
        let ticket = fleet.submit(Arc::clone(&rows), None).unwrap();
        tickets.push((rows, ticket));
    }
    // Kill card 1 mid-flight: its dispatcher closes the rings; queued
    // jobs fail, the in-flight one may still complete.
    fleet.cards()[1].shutdown();

    let (mut full, mut partial, mut dead) = (0u64, 0u64, 0u64);
    for (rows, ticket) in tickets {
        match ticket.wait_outcome() {
            Ok(Outcome::Full(out)) => {
                verify(&out, &rows, &table);
                full += 1;
            }
            Ok(Outcome::Partial { rows: out, valid }) => {
                assert_eq!(valid.len(), rows.len());
                assert_eq!(out.len(), rows.len() * table.d);
                // Card 0's rows survive; merged in request order.
                for (k, &row) in rows.iter().enumerate() {
                    let span = &out[k * table.d..(k + 1) * table.d];
                    if valid[k] {
                        for (j, &v) in span.iter().enumerate() {
                            assert_eq!(v, table.expected(row, j), "row {row} column {j}");
                        }
                    } else {
                        assert!(row >= shard1_start, "card-0 row {row} masked out");
                        assert!(span.iter().all(|&v| v == 0.0));
                    }
                }
                assert!(valid.iter().any(|&v| v), "partial with no valid rows");
                partial += 1;
            }
            Err(_) => dead += 1,
        }
    }
    assert_eq!(full + partial + dead, 16);
    assert!(
        partial >= 1,
        "no in-flight ticket degraded to Partial ({full} full, {dead} dead)"
    );

    // New spanning submissions fail fast, naming the dead shard.
    let rows: Arc<Vec<u64>> = Arc::new(vec![1, shard1_start + 1]);
    let err = match fleet.submit(Arc::clone(&rows), None) {
        Err(e) => e,
        Ok(t) => t.wait_outcome().map(|_| ()).unwrap_err(),
    };
    assert!(
        format!("{err:#}").contains("card shard 1"),
        "error does not name the dead shard: {err:#}"
    );
    // Requests entirely on the surviving card still serve.
    let rows = some_rows(64, shard1_start, 3);
    verify(&fleet.lookup(Arc::clone(&rows)).unwrap(), &rows, &table);
    fleet.shutdown();
}

//! Integration: the full serving stack (batcher -> dispatcher -> router ->
//! per-group PJRT workers -> merge) over AOT artifacts.
//!
//! Gated behind the `pjrt` feature: it needs the real `xla` crate (the
//! offline build links an error-returning stub) plus `make artifacts`.
#![cfg(feature = "pjrt")]

use std::sync::Arc;

use a100win::coordinator::{
    BatcherConfig, EmbeddingServer, PlacementPolicy, ServerConfig, Table, WindowPlan,
};
use a100win::probe::TopologyMap;
use a100win::runtime::Runtime;
use a100win::util::rng::Rng;

/// A small fake probe map: 4 groups of 2 SMs (what matters here is group
/// count and capacities; the serving stack never touches simulated SMs).
fn map4() -> TopologyMap {
    TopologyMap {
        groups: (0..4).map(|g| vec![g * 2, g * 2 + 1]).collect(),
        reach_bytes: 64 << 30,
        solo_gbps: vec![120.0, 119.0, 91.0, 90.0],
        independent: true,
        card_id: "integration".into(),
    }
}

fn artifact_n() -> usize {
    let dir = Runtime::default_artifacts_dir().expect("run `make artifacts`");
    let rt = Runtime::new(&dir).unwrap();
    rt.manifest().by_entry("lookup").first().unwrap().n
}

fn start_server(windows: usize, policy: PlacementPolicy) -> (EmbeddingServer, Table) {
    let n = artifact_n();
    let rows = (n * windows) as u64;
    let table = Table::synthetic(rows, 32);
    let plan = WindowPlan::split(rows, 128, windows);
    let mut cfg = ServerConfig::new(Runtime::default_artifacts_dir().unwrap());
    cfg.policy = policy;
    cfg.batcher = BatcherConfig {
        max_batch_rows: 8192,
        max_wait: std::time::Duration::from_millis(1),
        max_pending: 256,
    };
    let server = EmbeddingServer::start(cfg, &map4(), plan, table.view()).unwrap();
    (server, table)
}

#[test]
fn lookup_roundtrip_group_to_chunk() {
    let (server, table) = start_server(2, PlacementPolicy::GroupToChunk);
    let mut rng = Rng::seed_from_u64(1);
    for _ in 0..5 {
        let rows: Arc<Vec<u64>> =
            Arc::new((0..300).map(|_| rng.gen_range(table.rows)).collect());
        let out = server.lookup(Arc::clone(&rows)).unwrap();
        assert_eq!(out.len(), rows.len() * table.d);
        for (i, &r) in rows.iter().enumerate() {
            for j in 0..table.d {
                assert_eq!(out[i * table.d + j], table.expected(r, j), "row {i}");
            }
        }
    }
    let m = server.metrics();
    assert_eq!(m.requests, 5);
    assert_eq!(m.rows, 1500);
    assert_eq!(m.errors, 0);
    server.shutdown();
}

#[test]
fn lookup_roundtrip_naive_policy() {
    // Naive placement must still produce correct answers (it is only
    // slower on the real device); all groups serve all windows.
    let (server, table) = start_server(2, PlacementPolicy::Naive);
    let rows: Arc<Vec<u64>> =
        Arc::new((0..500).map(|i| (i * 7919) as u64 % table.rows).collect());
    let out = server.lookup(Arc::clone(&rows)).unwrap();
    for (i, &r) in rows.iter().enumerate() {
        assert_eq!(out[i * table.d], table.expected(r, 0));
    }
    server.shutdown();
}

#[test]
fn concurrent_clients_all_get_correct_answers() {
    let (server, table) = start_server(2, PlacementPolicy::GroupToChunk);
    let server = Arc::new(server);
    let errors = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..8u64 {
            let server = Arc::clone(&server);
            let table = table.clone();
            handles.push(s.spawn(move || {
                let mut rng = Rng::seed_from_u64(c);
                let mut bad = 0;
                for _ in 0..10 {
                    let rows: Arc<Vec<u64>> =
                        Arc::new((0..64).map(|_| rng.gen_range(table.rows)).collect());
                    let out = server.lookup(Arc::clone(&rows)).unwrap();
                    for (i, &r) in rows.iter().enumerate() {
                        if out[i * table.d] != table.expected(r, 0) {
                            bad += 1;
                        }
                    }
                }
                bad
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).sum::<u32>()
    });
    assert_eq!(errors, 0);
    let m = server.metrics();
    assert_eq!(m.requests, 80);
    assert_eq!(m.rows, 80 * 64);
    assert!(m.batches >= 1);
}

#[test]
fn out_of_range_rows_rejected() {
    let (server, table) = start_server(1, PlacementPolicy::GroupToChunk);
    assert!(server.lookup(Arc::new(vec![table.rows])).is_err());
    assert!(server.lookup(Arc::new(vec![0, table.rows + 5])).is_err());
    assert_eq!(server.metrics().rejected, 2);
    // Server still healthy afterwards.
    let out = server.lookup(Arc::new(vec![0, 1])).unwrap();
    assert_eq!(out[0], table.expected(0, 0));
    server.shutdown();
}

#[test]
fn empty_lookup_is_noop() {
    let (server, _table) = start_server(1, PlacementPolicy::GroupToChunk);
    assert_eq!(server.lookup(Arc::new(vec![])).unwrap().len(), 0);
    server.shutdown();
}

#[test]
fn single_row_and_full_window_batches() {
    let (server, table) = start_server(2, PlacementPolicy::GroupToChunk);
    // 1 row.
    let out = server.lookup(Arc::new(vec![42])).unwrap();
    assert_eq!(out.len(), table.d);
    assert_eq!(out[0], table.expected(42, 0));
    // A batch larger than the biggest artifact (forces chunking).
    let rows: Arc<Vec<u64>> = Arc::new((0..5000).map(|i| i as u64 % table.rows).collect());
    let out = server.lookup(Arc::clone(&rows)).unwrap();
    for (i, &r) in rows.iter().enumerate().step_by(97) {
        assert_eq!(out[i * table.d], table.expected(r, 0));
    }
    // Padding happened (5000 is not a multiple of any artifact batch).
    assert!(server.metrics().padded_rows > 0);
    server.shutdown();
}

#[test]
fn windows_must_match_artifact_shape() {
    // A plan whose windows differ from the artifact n must fail at startup
    // with a clear error, not at serve time.
    let n = artifact_n();
    let rows = (n + 128) as u64;
    let table = Table::synthetic(rows, 32);
    let plan = WindowPlan::split(rows, 128, 1);
    let cfg = ServerConfig::new(Runtime::default_artifacts_dir().unwrap());
    let err = EmbeddingServer::start(cfg, &map4(), plan, table.view());
    assert!(err.is_err());
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("lowered for"), "unexpected error: {msg}");
}

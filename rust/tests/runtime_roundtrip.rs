//! Integration: the AOT bridge end to end.
//!
//! python (jax/pallas, `make artifacts`) lowered the gather kernels to HLO
//! text; here the Rust runtime loads, compiles, and executes them on the
//! PJRT CPU client and checks numerics against closed-form expectations —
//! the Rust half of the interchange contract (python/tests/test_aot.py is
//! the other half).
//!
//! Gated behind the `pjrt` feature: it needs the real `xla` crate (the
//! offline build links an error-returning stub) plus `make artifacts`.
#![cfg(feature = "pjrt")]

use a100win::coordinator::Table;
use a100win::runtime::Runtime;

fn runtime() -> Runtime {
    let dir = Runtime::default_artifacts_dir()
        .expect("artifacts missing: run `make artifacts` before cargo test");
    Runtime::new(&dir).expect("runtime init")
}

#[test]
fn manifest_lists_expected_entries() {
    let rt = runtime();
    let m = rt.manifest();
    assert!(!m.by_entry("lookup").is_empty());
    assert!(!m.by_entry("windowed_lookup").is_empty());
    assert!(!m.by_entry("bag_forward").is_empty());
    assert_eq!(m.by_entry("bag_loss_and_grad").len(), 1);
}

#[test]
fn gather_matches_synthetic_table() {
    let mut rt = runtime();
    let meta = rt.manifest().first_of("lookup").unwrap();
    let (b, n, d) = (meta.b, meta.n, meta.d);

    let table = Table::synthetic(n as u64, d);
    let buf = rt.upload_f32(&table.data, &[n, d]).unwrap();

    // Deterministic pseudo-random indices.
    let mut rng = a100win::util::rng::Rng::seed_from_u64(7);
    let indices: Vec<i32> = (0..b).map(|_| rng.gen_range(n as u64) as i32).collect();

    let out = rt.gather(&meta.name, &indices, &buf).unwrap();
    assert_eq!(out.len(), b * d);
    for (k, &idx) in indices.iter().enumerate() {
        for j in 0..d {
            assert_eq!(
                out[k * d + j],
                table.expected(idx as u64, j),
                "row {k} col {j} (index {idx})"
            );
        }
    }
}

#[test]
fn windowed_gather_remaps_into_window() {
    let mut rt = runtime();
    let meta = rt.manifest().first_of("windowed_lookup").unwrap();
    let (b, n, d) = (meta.b, meta.n, meta.d);

    let table = Table::synthetic(n as u64, d);
    let buf = rt.upload_f32(&table.data, &[n, d]).unwrap();

    // Indices intentionally larger than the window (and some larger than
    // the table): the kernel must remap them via base + idx % size.
    let mut rng = a100win::util::rng::Rng::seed_from_u64(8);
    let indices: Vec<i32> = (0..b)
        .map(|_| rng.gen_range(i32::MAX as u64) as i32)
        .collect();
    let (base, size) = ((n / 4) as i32, (n / 2) as i32);

    let out = rt
        .windowed_gather(&meta.name, [base, size], &indices, &buf)
        .unwrap();
    for (k, &idx) in indices.iter().enumerate() {
        let expect_row = base as u64 + (idx % size) as u64;
        assert!(expect_row >= base as u64 && expect_row < (base + size) as u64);
        for j in 0..d {
            assert_eq!(out[k * d + j], table.expected(expect_row, j));
        }
    }
}

#[test]
fn bag_forward_sums_rows() {
    let mut rt = runtime();
    let meta = rt.manifest().first_of("bag_forward").unwrap();
    let (b, n, d, g) = (meta.b, meta.n, meta.d, meta.g.unwrap());

    let table = Table::synthetic(n as u64, d);
    let buf = rt.upload_f32(&table.data, &[n, d]).unwrap();
    let mut rng = a100win::util::rng::Rng::seed_from_u64(9);
    let indices: Vec<i32> = (0..b * g).map(|_| rng.gen_range(n as u64) as i32).collect();

    rt.ensure_compiled(&meta.name).unwrap();
    let idx = rt.upload_i32(&indices, &[b, g]).unwrap();
    let outs = rt.execute(&meta.name, &[&idx, &buf]).unwrap();
    let out = outs[0].to_vec::<f32>().unwrap();
    assert_eq!(out.len(), b * d);
    for k in 0..b.min(16) {
        for j in 0..d {
            let want: f32 = (0..g)
                .map(|q| table.expected(indices[k * g + q] as u64, j))
                .sum();
            let got = out[k * d + j];
            assert!(
                (got - want).abs() <= want.abs() * 1e-5 + 1e-3,
                "bag {k} col {j}: got {got}, want {want}"
            );
        }
    }
}

#[test]
fn bag_train_returns_loss_and_grad() {
    let mut rt = runtime();
    let meta = rt.manifest().first_of("bag_loss_and_grad").unwrap();
    let (b, n, d, g) = (meta.b, meta.n, meta.d, meta.g.unwrap());

    let table = Table::synthetic(n as u64, d);
    let buf = rt.upload_f32(&table.data, &[n, d]).unwrap();
    let indices: Vec<i32> = vec![3; b * g]; // every bag = g copies of row 3
    let targets = vec![0.0f32; b * d];

    rt.ensure_compiled(&meta.name).unwrap();
    let idx = rt.upload_i32(&indices, &[b, g]).unwrap();
    let tgt = rt.upload_f32(&targets, &[b, d]).unwrap();
    let outs = rt.execute(&meta.name, &[&idx, &buf, &tgt]).unwrap();
    assert_eq!(outs.len(), 2);
    let loss = outs[0].to_vec::<f32>().unwrap()[0];
    let grad = outs[1].to_vec::<f32>().unwrap();
    assert_eq!(grad.len(), n * d);
    // Forward: every bag sums g copies of row 3 -> loss > 0 against zero
    // targets.
    assert!(loss > 0.0);
    // Gradient only touches row 3.
    for r in 0..n {
        for j in 0..d {
            let v = grad[r * d + j];
            if r == 3 {
                assert!(v != 0.0, "grad at used row must be nonzero");
            } else {
                assert_eq!(v, 0.0, "grad leaked to row {r}");
            }
        }
    }
}

#[test]
fn executable_cache_compiles_once() {
    let mut rt = runtime();
    let name = rt.manifest().first_of("lookup").unwrap().name;
    assert!(!rt.is_compiled(&name));
    rt.ensure_compiled(&name).unwrap();
    assert!(rt.is_compiled(&name));
    let t = std::time::Instant::now();
    rt.ensure_compiled(&name).unwrap(); // cached: must be instant
    assert!(t.elapsed() < std::time::Duration::from_millis(50));
}

#[test]
fn gather_rejects_wrong_batch() {
    let mut rt = runtime();
    let meta = rt.manifest().first_of("lookup").unwrap();
    let table = Table::synthetic(meta.n as u64, meta.d);
    let buf = rt.upload_f32(&table.data, &[meta.n, meta.d]).unwrap();
    let err = rt.gather(&meta.name, &[0, 1, 2], &buf);
    assert!(err.is_err());
}

#[test]
fn artifacts_lowered_to_intended_shapes() {
    // L2 graph-quality gate (EXPERIMENTS.md §Perf L2): every artifact must
    // contain a real `gather`, and none may contain a `while` loop — the
    // loop lowering is 68x slower on the CPU backend and its reappearance
    // should fail tests, not ship.
    let rt = runtime();
    let dir = Runtime::default_artifacts_dir().unwrap();
    for meta in rt.manifest().artifacts.clone() {
        let info = a100win::runtime::inspect_file(&dir.join(&meta.file)).unwrap();
        assert!(
            info.has_gather(),
            "{}: no gather op (ops: {:?})",
            meta.name,
            info.op_counts.keys().collect::<Vec<_>>()
        );
        assert!(!info.has_while(), "{}: while loop reintroduced", meta.name);
        // Operand count and order survive lowering.
        assert_eq!(
            info.entry_params.len(),
            meta.operands.len(),
            "{}: parameter count mismatch",
            meta.name
        );
        // Training artifact carries exactly the backward scatter(-add).
        if meta.entry == "bag_loss_and_grad" {
            assert!(info.has_scatter(), "{}: missing scatter-add bwd", meta.name);
        } else {
            assert!(!info.has_scatter(), "{}: unexpected scatter", meta.name);
        }
    }
}

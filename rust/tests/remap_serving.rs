//! The repack lever (TLB-aware hot-row packing) end to end — hermetic (no
//! `pjrt` feature, no artifacts):
//!
//! * **Live repack**: under zipf(1.1) the control plane escalates past
//!   re-deal and publishes a [`RemapPlan`] mid-serving with pipelined
//!   tickets in flight — every response stays row-identical, the original
//!   table slab is never copied or mutated (the packed prefix is a fresh
//!   `Arc`), and every published plan passes the permutation/alignment
//!   invariants.
//! * **Uniform floor**: flat traffic never clears `min_hot_share`, so the
//!   remap stays identity and no copy is ever made.
//! * **Drift soak**: a rotating hotspot re-learns and republishes packed
//!   layouts; invariants hold at every poll and the generation counters
//!   stay consistent.
//! * **DES payoff** (the ISSUE's acceptance bar): on a machine whose
//!   windows over-reach the group TLB 2x, packed serving beats identity
//!   by >= 1.2x on simulated aggregate GB/s under zipf(1.1).

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

use a100win::config::MachineConfig;
use a100win::coordinator::{
    AdaptiveConfig, BatcherConfig, ControlPlaneConfig, Lever, PlacementPolicy, RemapConfig, Table,
    WindowPlan,
};
use a100win::probe::TopologyMap;
use a100win::service::{Backend, Service, SimBackend, SimBackendConfig, SimTiming, Ticket};
use a100win::sim::Machine;
use a100win::workload::{synth::Distribution, RequestGen, WorkloadSpec};

fn map(solo: &[f64]) -> TopologyMap {
    TopologyMap {
        groups: (0..solo.len()).map(|g| vec![g * 2, g * 2 + 1]).collect(),
        reach_bytes: 64 << 30,
        solo_gbps: solo.to_vec(),
        independent: true,
        card_id: format!("remap-{}g", solo.len()),
    }
}

fn quick_batcher() -> BatcherConfig {
    BatcherConfig {
        max_batch_rows: 4096,
        max_wait: Duration::from_millis(1),
        max_pending: 512,
    }
}

/// Act on the first failing epoch, no cooldown: manual epochs are already
/// rate-limited by the request loop.
fn eager_control() -> ControlPlaneConfig {
    ControlPlaneConfig {
        min_imbalance: 0.10,
        patience: 1,
        cooldown: 0,
        max_lever: Lever::Repack, // clamped per backend anyway
        trace_len: 512,
    }
}

/// d=4 rows (16 B): a 4 KiB packing page is a 256-row granule.
fn small_remap() -> RemapConfig {
    RemapConfig {
        page_bytes: 1 << 12,
        ..RemapConfig::default()
    }
}

fn remap_cfg(table: &Table, timing: SimTiming, remap: Option<RemapConfig>) -> Arc<SimBackend> {
    let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
    cfg.batcher = quick_batcher();
    cfg.control = eager_control();
    cfg.adaptive = Some(AdaptiveConfig::default());
    cfg.remap = remap;
    let plan = WindowPlan::split(table.rows, (table.d * 4) as u64, 2);
    Arc::new(
        SimBackend::start(cfg, &map(&[120.0, 90.0, 90.0]), plan, table.view(), timing).unwrap(),
    )
}

fn spec(table: &Table, distribution: Distribution) -> WorkloadSpec {
    WorkloadSpec {
        total_rows: table.rows,
        distribution,
        request_rows: (512, 512),
        seed: 99,
    }
}

fn verify(out: &[f32], rows: &[u64], table: &Table) {
    assert_eq!(out.len(), rows.len() * table.d);
    for (k, &row) in rows.iter().enumerate() {
        for j in 0..table.d {
            assert_eq!(
                out[k * table.d + j],
                table.expected(row, j),
                "row {row} column {j}"
            );
        }
    }
}

/// Check the published remap against the published plan.
fn check_remap(backend: &Arc<SimBackend>) {
    backend
        .remap_plan()
        .check(&backend.plan())
        .expect("published remap plan violates invariants");
}

// ---------------------------------------------------------------------------
// 1. Live repack: zero-copy, ticket-safe, content-preserving.
// ---------------------------------------------------------------------------

#[test]
fn repack_is_live_zero_copy_and_content_preserving() {
    let table = Table::synthetic(8_192, 4);
    let backend = remap_cfg(&table, SimTiming::Probed, Some(small_remap()));
    let dyn_backend: Arc<dyn Backend> = Arc::clone(&backend);
    let service = Service::new(dyn_backend);
    let mut gen = RequestGen::new(spec(&table, Distribution::Zipf { theta: 1.1 }));

    // Pipelined depth-8 closed loop with an epoch after every submit, so
    // the repack publication lands while old-generation tickets are in
    // flight — exactly the swap the remap layer must make safe.
    let mut inflight: VecDeque<(Ticket, Arc<Vec<u64>>)> = VecDeque::new();
    let mut repacked_at = None;
    for i in 0..400 {
        let rows = Arc::new(gen.next_request());
        let ticket = service.submit(Arc::clone(&rows), None).unwrap();
        inflight.push_back((ticket, rows));
        backend.rebalance_epoch();
        if inflight.len() >= 8 {
            let (t, rows) = inflight.pop_front().unwrap();
            verify(&t.wait().unwrap(), &rows, &table);
        }
        if backend.metrics().repack_epochs > 0 {
            repacked_at = Some(i);
            break;
        }
    }
    let repacked_at = repacked_at.expect("zipf(1.1) never escalated to a repack in 400 epochs");
    for (t, rows) in inflight.drain(..) {
        verify(&t.wait().unwrap(), &rows, &table);
    }

    // The published remap is a checked permutation and actually packs.
    check_remap(&backend);
    let remap = backend.remap_plan();
    assert!(!remap.is_identity(), "repack counted but identity published");
    assert!(remap.packed_windows() >= 1);
    assert!(remap.generation > 0);

    // Zero-copy discipline (the PR-4 migration contract): the packed
    // prefix lives in a *fresh* slab; the shared table storage is not the
    // backing store of any packed window and its content is untouched.
    let plan = backend.plan();
    for w in plan.windows() {
        if let Some(r) = remap.window_remap(w.id) {
            assert!(
                !Arc::ptr_eq(r.storage(), &table.data),
                "packed window {} aliases the shared table slab",
                w.id
            );
            assert_eq!(r.hot_rows() % r.page_rows(), 0, "unaligned hot prefix");
        }
    }

    // Post-repack serving is row-identical across the whole table.
    let all: Vec<u64> = (0..table.rows).step_by(37).collect();
    let all = Arc::new(all);
    verify(&service.lookup(Arc::clone(&all)).unwrap(), &all, &table);

    // Counter discipline: every published generation is attributed to
    // exactly one lever.
    let m = backend.metrics();
    assert_eq!(m.repack_epochs, 1, "one repack (epoch {repacked_at})");
    assert!(m.rows_repacked > 0);
    assert_eq!(
        m.generations_published,
        m.redeal_epochs + m.resplit_epochs + m.migrate_epochs + m.repack_epochs,
        "generation counters inconsistent"
    );
    service.shutdown();
}

// ---------------------------------------------------------------------------
// 2. Uniform traffic never clears the hot-share floor.
// ---------------------------------------------------------------------------

#[test]
fn uniform_traffic_never_repacks() {
    let table = Table::synthetic(8_192, 4);
    let backend = remap_cfg(&table, SimTiming::Probed, Some(small_remap()));
    let dyn_backend: Arc<dyn Backend> = Arc::clone(&backend);
    let service = Service::new(dyn_backend);
    let mut gen = RequestGen::new(spec(&table, Distribution::Uniform));
    for i in 0..120 {
        let rows = Arc::new(gen.next_request());
        let out = service.lookup(Arc::clone(&rows)).unwrap();
        if i % 30 == 0 {
            verify(&out, &rows, &table);
        }
        backend.rebalance_epoch();
    }
    let m = backend.metrics();
    assert_eq!(m.repack_epochs, 0, "uniform load must not be packed");
    assert_eq!(m.rows_repacked, 0);
    assert!(
        backend.remap_plan().is_identity(),
        "identity expected under uniform load"
    );
    service.shutdown();
}

// ---------------------------------------------------------------------------
// 3. Drift soak: invariants at every poll, re-learning across rotations.
// ---------------------------------------------------------------------------

#[test]
fn drift_soak_remap_invariants() {
    let table = Table::synthetic(8_192, 4);
    let backend = remap_cfg(&table, SimTiming::Probed, Some(small_remap()));
    let dyn_backend: Arc<dyn Backend> = Arc::clone(&backend);
    let service = Service::new(dyn_backend);
    let mut gen = RequestGen::new(spec(
        &table,
        Distribution::Drift {
            inner: Box::new(Distribution::Zipf { theta: 1.1 }),
            period: 80,
        },
    ));
    for i in 0..400 {
        let rows = Arc::new(gen.next_request());
        let out = service.lookup(Arc::clone(&rows)).unwrap();
        if i % 40 == 0 {
            verify(&out, &rows, &table);
        }
        backend.rebalance_epoch();
        if i % 5 == 0 {
            check_remap(&backend);
        }
    }
    check_remap(&backend);
    let m = backend.metrics();
    assert!(
        m.repack_epochs >= 1,
        "a drifting zipf hotspot should repack at least once"
    );
    assert_eq!(
        m.generations_published,
        m.redeal_epochs + m.resplit_epochs + m.migrate_epochs + m.repack_epochs,
        "generation counters inconsistent"
    );
    // Full-table identity after the soak.
    let all: Vec<u64> = (0..table.rows).step_by(41).collect();
    let all = Arc::new(all);
    verify(&service.lookup(Arc::clone(&all)).unwrap(), &all, &table);
    service.shutdown();
}

// ---------------------------------------------------------------------------
// 4. The payoff: packed beats identity on the DES when windows over-reach.
// ---------------------------------------------------------------------------

/// A machine whose serving windows (2 MiB) over-reach the group TLB
/// (16 x 64 KiB pages = 1 MiB) 2x, while the packed hot prefix
/// (<= 25% of a window, 512 KiB cap; the sketch packs ~1024 rows = 128 KiB)
/// fits comfortably — the paper's cliff on one side, full-speed on the
/// other.
fn overreach_machine() -> Machine {
    let mut cfg = MachineConfig::tiny_test();
    cfg.tlb.entries = 16; // reach = 1 MiB
    cfg.memory.total_bytes = 4 << 20;
    Machine::new(cfg).expect("over-reach tiny machine is valid")
}

/// Warm (epoch per request, learning + publishing), reset the simulated
/// accounting, then measure: aggregate simulated GB/s over the measured
/// phase (makespan: the slowest group bounds the phase).
fn drive_des_arm(machine: &Machine, table: &Table, remap: Option<RemapConfig>) -> (f64, u64) {
    let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
    cfg.batcher = quick_batcher();
    cfg.control = eager_control();
    cfg.adaptive = Some(AdaptiveConfig::default());
    cfg.remap = remap;
    let plan = WindowPlan::split(table.rows, (table.d * 4) as u64, 2);
    let backend = Arc::new(
        SimBackend::start(
            cfg,
            &TopologyMap::ground_truth(machine),
            plan,
            table.view(),
            SimTiming::machine(machine.clone()),
        )
        .unwrap(),
    );
    let dyn_backend: Arc<dyn Backend> = Arc::clone(&backend);
    let service = Service::new(dyn_backend);
    let mut gen = RequestGen::new(spec(table, Distribution::Zipf { theta: 1.1 }));
    for _ in 0..120 {
        let rows = Arc::new(gen.next_request());
        service.lookup(Arc::clone(&rows)).unwrap();
        backend.rebalance_epoch();
    }
    backend.reset_sim_stats();
    for i in 0..150 {
        let rows = Arc::new(gen.next_request());
        let out = service.lookup(Arc::clone(&rows)).unwrap();
        if i % 50 == 0 {
            verify(&out, &rows, &table);
        }
        backend.rebalance_epoch();
        check_remap(&backend);
    }
    let report = backend.sim_report();
    let total_rows: u64 = report.iter().map(|r| r.rows).sum();
    let max_ns = report.iter().map(|r| r.sim_ms * 1e6).fold(0.0f64, f64::max);
    assert!(max_ns > 0.0, "no simulated time accounted");
    let gbps = total_rows as f64 * (table.d * 4) as f64 / max_ns;
    let repacks = backend.metrics().repack_epochs;
    service.shutdown();
    (gbps, repacks)
}

#[test]
fn packed_layout_beats_identity_on_the_des() {
    let machine = overreach_machine();
    let rows = machine.config().memory.total_bytes / 128; // d=32 rows
    let table = Table::synthetic(rows, 32);
    let window_bytes = rows / 2 * 128;
    assert!(
        window_bytes > machine.config().tlb.reach_bytes(),
        "premise: windows must over-reach the TLB"
    );

    let (identity_gbps, id_repacks) = drive_des_arm(&machine, &table, None);
    let (packed_gbps, pk_repacks) = drive_des_arm(
        &machine,
        &table,
        Some(RemapConfig {
            page_bytes: 1 << 16, // the machine's page
            ..RemapConfig::default()
        }),
    );
    assert_eq!(id_repacks, 0, "remap-off arm must never repack");
    assert!(pk_repacks >= 1, "remap arm never packed: ratio is vacuous");
    let ratio = packed_gbps / identity_gbps.max(1e-12);
    assert!(
        ratio >= 1.2,
        "packed {packed_gbps:.2} GB/s not >= 1.2x identity {identity_gbps:.2} GB/s \
         (ratio {ratio:.2})"
    );
}

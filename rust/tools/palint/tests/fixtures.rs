//! The seeded fixture pair is palint's own regression gate: `bad.rs` must
//! trip every rule, `clean.rs` none — both linted as if they lived in the
//! serving tree so the path-scoped rules (R2/R3/R4) apply.

use std::collections::BTreeSet;
use std::path::Path;

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

#[test]
fn bad_fixture_trips_every_rule() {
    let findings = palint::scan_file("src/service/ring.rs", &fixture("bad.rs"));
    let rules: BTreeSet<&str> = findings.iter().map(|f| f.rule).collect();
    for rule in ["R1", "R2", "R3", "R4"] {
        assert!(rules.contains(rule), "{rule} did not fire: {findings:#?}");
    }
}

#[test]
fn bad_fixture_is_nonzero_even_under_its_real_path() {
    // R1 has no path scoping, so a plain CLI run on the fixture file exits
    // non-zero too.
    let findings = palint::scan_file("tools/palint/fixtures/bad.rs", &fixture("bad.rs"));
    assert!(findings.iter().any(|f| f.rule == "R1"), "{findings:#?}");
}

#[test]
fn clean_fixture_is_clean() {
    let findings = palint::scan_file("src/service/ring.rs", &fixture("clean.rs"));
    assert!(findings.is_empty(), "{findings:#?}");
}

// palint seed fixture: every rule must fire on this file.  Never
// compiled — exercised by `tests/fixtures.rs`, and usable by hand:
// `cargo run -p palint -- tools/palint/fixtures/bad.rs` exits non-zero
// (R1 is path-independent; R2/R3/R4 need a serving-tree path, which the
// integration test spoofs).

use std::sync::atomic::{AtomicUsize, Ordering};

static HEAD: AtomicUsize = AtomicUsize::new(0);

pub fn r1_undocumented_unsafe(p: *mut u8) {
    unsafe {
        *p = 1;
    }
}

pub fn r2_unjustified_relaxed() -> usize {
    let head = HEAD.load(Ordering::Relaxed);
    head
}

pub fn r3_unwrap(v: Option<usize>) -> usize {
    v.unwrap()
}

pub fn r3_expect(v: Option<usize>) -> usize {
    v.expect("boom")
}

pub fn r3_panic() {
    panic!("boom");
}

// hotpath: begin
pub fn r4_alloc_in_hotpath() -> Vec<u8> {
    let b = Box::new(7u8);
    let mut v = Vec::with_capacity(4);
    v.push(*b);
    v.to_vec()
}
// hotpath: end

// palint seed fixture: the justified twin of bad.rs — zero findings even
// when linted under a serving-tree path.

use std::sync::atomic::{AtomicUsize, Ordering};

static HEAD: AtomicUsize = AtomicUsize::new(0);

pub fn r1_documented_unsafe(p: *mut u8) {
    // SAFETY: caller guarantees `p` is valid for writes (fixture contract).
    unsafe {
        *p = 1;
    }
}

pub fn r2_justified_relaxed() -> usize {
    // RELAXED: single-writer counter; the value is only read for telemetry.
    let head = HEAD.load(Ordering::Relaxed);
    head
}

pub fn r3_no_panics(v: Option<usize>) -> usize {
    v.unwrap_or(0)
}

pub fn r3_poison_allowance(m: &std::sync::Mutex<usize>) -> usize {
    *m.lock().unwrap()
}

pub fn r3_justified(v: Option<usize>) -> usize {
    // PANIC: invariant, not input — the fixture's caller always passes Some.
    v.expect("fixture invariant")
}

// hotpath: begin
pub fn r4_no_alloc(x: &mut [u8]) {
    x[0] = 1;
}
// hotpath: end

#[cfg(test)]
mod tests {
    // Everything after the test fence is ignored by palint.
    #[test]
    fn ignored() {
        Option::<usize>::None.unwrap();
    }
}

//! CLI for the palint lint gate.
//!
//! ```text
//! cargo run -p palint                      # lint the serving crate's src/
//! cargo run -p palint -- path/a path/b     # lint explicit files/dirs
//! cargo run -p palint -- --report out.txt  # also write the report to a file
//! ```
//!
//! Exit status: 0 clean, 1 findings, 2 usage/IO error.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    let mut report: Option<PathBuf> = None;
    let mut roots: Vec<PathBuf> = Vec::new();
    while let Some(a) = args.next() {
        match a.as_str() {
            "--report" => match args.next() {
                Some(p) => report = Some(PathBuf::from(p)),
                None => {
                    eprintln!("palint: --report requires a file argument");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                eprintln!("usage: palint [--report FILE] [PATH ...]");
                return ExitCode::SUCCESS;
            }
            _ => roots.push(PathBuf::from(a)),
        }
    }
    if roots.is_empty() {
        // Default targets: the serving crate's src tree plus palint's own
        // sources (fixtures are skipped), located relative to this tool's
        // manifest so the gate works from any cwd.
        let manifest = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
        roots.push(PathBuf::from(&manifest).join("../../src"));
        roots.push(PathBuf::from(&manifest));
    }

    let mut findings = Vec::new();
    let mut scanned = 0usize;
    for r in &roots {
        let r = r.canonicalize().unwrap_or_else(|_| r.clone());
        if let Err(e) = palint::scan_path(&r, &mut findings, &mut scanned) {
            eprintln!("palint: {}: {e}", r.display());
            return ExitCode::from(2);
        }
    }
    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));

    let mut out = String::new();
    for f in &findings {
        out.push_str(&format!("{f}\n"));
    }
    out.push_str(&format!(
        "palint: {} finding(s) across {} file(s)\n",
        findings.len(),
        scanned
    ));
    print!("{out}");
    if let Some(p) = &report {
        if let Err(e) = std::fs::write(p, &out) {
            eprintln!("palint: write {}: {e}", p.display());
            return ExitCode::from(2);
        }
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

//! palint — project-local static lint for the serving path's concurrency
//! hygiene.  Zero dependencies by design: it must run offline on a bare
//! toolchain as a CI hard gate (`cargo run -p palint` from `rust/`).
//!
//! Rules (companion to the model-checking layer, see EXPERIMENTS §Verify):
//!
//! * **R1 — undocumented `unsafe`.**  Every use of the `unsafe` keyword
//!   (block, fn, impl, trait) must carry a `// SAFETY:` justification on
//!   the same line or in the contiguous comment/attribute block directly
//!   above.
//! * **R2 — unjustified `Relaxed`.**  In the hot lock-free files
//!   (`service/{ring,scatter,backend,session,fleet}.rs`,
//!   `coordinator/placement.rs`), an `Ordering::Relaxed` on a line that
//!   names a hot-protocol atomic (`head`, `tail`, `sleeping`, `pushing`,
//!   `closed`, `state`, `claimed`, `taken`, `remaining`, `generation`,
//!   `depth`, `rr`, `slots[`) needs a `// RELAXED:` justification.
//!   Telemetry counters (other names) are exempt.
//! * **R3 — panic hygiene.**  Non-test code under `service/`,
//!   `coordinator/` and `net/` may not call `.unwrap()`, `.expect(…)`, `panic!`,
//!   `todo!`, or `unimplemented!`.  Exemptions: lock-poison unwraps
//!   (`.lock()`/`.read()`/`.write()`/`.wait*` on the same line, or a bare
//!   `.unwrap()` continuation directly under such a call) and sites
//!   justified with `// PANIC:`.  `unreachable!` is deliberately allowed —
//!   it documents dead arms, it does not hide fallible paths.
//! * **R4 — hot-path allocation.**  Between `// hotpath: begin` and
//!   `// hotpath: end` fences in `ring.rs`, `scatter.rs`, `backend.rs`,
//!   `fleet.rs`, `net/client.rs`:
//!   `Box::new`, `Vec::with_capacity`, `.to_vec(` and `vec![` are banned
//!   outright, with no justification override.
//!
//! Mechanics: string/char-literal contents and comments are blanked before
//! token matching (so `panic!` in a doc string never fires); justification
//! markers are read from the *raw* lines.  Everything from the first
//! `#[cfg(test)]` / `#[cfg(all(test, …))]` line to EOF is skipped — test
//! modules live at file tails throughout this repo.

use std::fmt;
use std::io;
use std::path::Path;

/// One lint violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Files whose `Ordering::Relaxed` uses are audited against hot atomics.
const HOT_ORDERING_FILES: &[&str] = &[
    "service/ring.rs",
    "service/scatter.rs",
    "service/backend.rs",
    "service/session.rs",
    "service/fleet.rs",
    "coordinator/placement.rs",
];

/// Atomic field names that belong to correctness-critical protocols.
const HOT_ATOMS: &[&str] = &[
    "head",
    "tail",
    "sleeping",
    "pushing",
    "closed",
    "state",
    "claimed",
    "taken",
    "remaining",
    "generation",
    // Replication routing (fleet.rs): queue-depth gauges and the P2C
    // rotation counter.
    "depth",
    "rr",
];

/// Files that may carry `// hotpath:` allocation fences.
const HOTPATH_FILES: &[&str] = &[
    "service/ring.rs",
    "service/scatter.rs",
    "service/backend.rs",
    "service/fleet.rs",
    "net/client.rs",
];

/// Tokens banned inside a hotpath fence.
const ALLOC_TOKENS: &[&str] = &["Box::new", "Vec::with_capacity", ".to_vec(", "vec!["];

fn is_word_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// `word` occurs in `line` with non-identifier characters (or edges) on
/// both sides.  ASCII tokens only.
fn has_word(line: &str, word: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = line[start..].find(word) {
        let p = start + pos;
        let before_ok = p == 0 || !is_word_char(line[..p].chars().next_back().unwrap_or(' '));
        let after = p + word.len();
        let after_ok =
            after >= line.len() || !is_word_char(line[after..].chars().next().unwrap_or(' '));
        if before_ok && after_ok {
            return true;
        }
        start = after;
    }
    false
}

/// Blank out comment bodies and string/char-literal contents, preserving
/// newlines (and the quote delimiters) so line numbers and most column
/// structure survive.
pub fn strip_source(text: &str) -> String {
    let b: Vec<char> = text.chars().collect();
    let n = b.len();
    let mut out = String::with_capacity(text.len());
    let mut i = 0;
    while i < n {
        let c = b[i];
        // Line comment.
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        // Block comment (Rust nests them).
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1;
            out.push_str("  ");
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    out.push_str("  ");
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    out.push_str("  ");
                    i += 2;
                } else {
                    out.push(if b[i] == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            continue;
        }
        // Raw string r"…" / r#"…"# (only when `r` is not the tail of an
        // identifier).
        if c == 'r' && (i == 0 || !is_word_char(b[i - 1])) {
            let mut j = i + 1;
            let mut hashes = 0usize;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' {
                for _ in i..=j {
                    out.push(' ');
                }
                i = j + 1;
                while i < n {
                    if b[i] == '"' {
                        let mut k = i + 1;
                        let mut h = 0usize;
                        while k < n && h < hashes && b[k] == '#' {
                            h += 1;
                            k += 1;
                        }
                        if h == hashes {
                            for _ in i..k {
                                out.push(' ');
                            }
                            i = k;
                            break;
                        }
                    }
                    out.push(if b[i] == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
                continue;
            }
        }
        // String literal.
        if c == '"' {
            out.push('"');
            i += 1;
            while i < n {
                if b[i] == '\\' && i + 1 < n {
                    out.push(' ');
                    out.push(if b[i + 1] == '\n' { '\n' } else { ' ' });
                    i += 2;
                    continue;
                }
                if b[i] == '"' {
                    out.push('"');
                    i += 1;
                    break;
                }
                out.push(if b[i] == '\n' { '\n' } else { ' ' });
                i += 1;
            }
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                out.push('\'');
                out.push(' ');
                i += 2;
                while i < n && b[i] != '\'' {
                    out.push(' ');
                    i += 1;
                }
                if i < n {
                    out.push('\'');
                    i += 1;
                }
            } else if i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\'' {
                out.push('\'');
                out.push(' ');
                out.push('\'');
                i += 3;
            } else {
                out.push('\'');
                i += 1;
            }
            continue;
        }
        out.push(c);
        i += 1;
    }
    out
}

/// `marker` appears on the flagged raw line, or anywhere in the contiguous
/// block of comment/attribute/blank lines directly above it.
fn justified(raw: &[&str], i: usize, marker: &str) -> bool {
    if raw[i].contains(marker) {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        let t = raw[j].trim();
        if t.is_empty() {
            continue;
        }
        if t.starts_with("//") || t.starts_with("#[") || t.starts_with("#![") {
            if t.contains(marker) {
                return true;
            }
            continue;
        }
        return false;
    }
    false
}

/// The nearest preceding line of actual code (skipping blanks and
/// comment-only lines), as stripped text.
fn prev_code_line<'a>(code: &'a [String], i: usize) -> Option<&'a str> {
    let mut j = i;
    while j > 0 {
        j -= 1;
        let t = code[j].trim();
        if !t.is_empty() {
            return Some(t);
        }
    }
    None
}

fn norm(path: &str) -> String {
    path.replace('\\', "/")
}

/// Run every rule over one file's text.  `path` is used both for
/// reporting and for rule scoping (R2/R3/R4 apply only to matching
/// paths), so callers can spoof it to lint fixture text as if it lived in
/// the serving tree.
pub fn scan_file(path: &str, text: &str) -> Vec<Finding> {
    let p = norm(path);
    let hot_ordering = HOT_ORDERING_FILES.iter().any(|f| p.ends_with(f));
    let hotpath_file = HOTPATH_FILES.iter().any(|f| p.ends_with(f));
    let svc_coord =
        p.contains("service/") || p.contains("coordinator/") || p.contains("net/");

    let stripped = strip_source(text);
    let raw: Vec<&str> = text.lines().collect();
    let code: Vec<String> = stripped.lines().map(str::to_owned).collect();
    debug_assert_eq!(raw.len(), code.len());

    // Skip everything from the first test fence to EOF (test modules live
    // at file tails in this repo).
    let cut = raw
        .iter()
        .position(|l| {
            let t = l.trim();
            t.starts_with("#[cfg(test)") || t.starts_with("#[cfg(all(test")
        })
        .unwrap_or(raw.len());

    let mut findings = Vec::new();
    let mut push = |line: usize, rule: &'static str, msg: String| {
        findings.push(Finding { file: path.to_owned(), line: line + 1, rule, msg });
    };
    let mut in_hotpath = false;

    for i in 0..cut {
        let rl = raw[i];
        let cl = &code[i];

        if hotpath_file {
            if rl.contains("hotpath: begin") {
                in_hotpath = true;
            } else if rl.contains("hotpath: end") {
                in_hotpath = false;
            }
        }

        // R1: undocumented unsafe.
        if has_word(cl, "unsafe") && !justified(&raw, i, "SAFETY:") {
            push(i, "R1", "`unsafe` without a `// SAFETY:` justification".into());
        }

        // R2: unjustified Relaxed on hot atomics.
        if hot_ordering
            && cl.contains("Ordering::Relaxed")
            && (HOT_ATOMS.iter().any(|a| has_word(cl, a)) || cl.contains("slots["))
            && !justified(&raw, i, "RELAXED:")
        {
            push(
                i,
                "R2",
                "`Ordering::Relaxed` on a hot-protocol atomic without `// RELAXED:`".into(),
            );
        }

        // R3: panic hygiene in the serving/coordination layers.
        if svc_coord {
            if cl.contains(".unwrap()") {
                let poison_same_line = cl.contains(".lock().unwrap()")
                    || cl.contains(".read().unwrap()")
                    || cl.contains(".write().unwrap()")
                    || cl.contains(".wait(")
                    || cl.contains(".wait_timeout(");
                let poison_continuation = cl.trim_start().starts_with(".unwrap()")
                    && prev_code_line(&code, i).is_some_and(|pl| {
                        pl.ends_with(".lock()")
                            || pl.ends_with(".read()")
                            || pl.ends_with(".write()")
                    });
                if !poison_same_line && !poison_continuation && !justified(&raw, i, "PANIC:") {
                    push(i, "R3", "`.unwrap()` in serving code without `// PANIC:`".into());
                }
            }
            if cl.contains(".expect(") && !justified(&raw, i, "PANIC:") {
                push(i, "R3", "`.expect(…)` in serving code without `// PANIC:`".into());
            }
            for mac in ["panic!(", "todo!(", "unimplemented!("] {
                if cl.contains(mac) && !justified(&raw, i, "PANIC:") {
                    push(i, "R3", format!("`{mac}…)` in serving code without `// PANIC:`"));
                }
            }
        }

        // R4: allocation inside a hotpath fence.  No override: move the
        // allocation out of the fence or shrink the fence.
        if in_hotpath {
            for tok in ALLOC_TOKENS {
                if cl.contains(tok) {
                    push(i, "R4", format!("allocation `{tok}` inside a `// hotpath:` fence"));
                }
            }
        }
    }

    if in_hotpath {
        push(cut.saturating_sub(1), "R4", "unclosed `// hotpath: begin` fence".into());
    }

    findings
}

/// Scan a file or directory tree (deterministic order).  Directories named
/// `target` or `fixtures` are skipped.
pub fn scan_path(
    path: &Path,
    findings: &mut Vec<Finding>,
    files_scanned: &mut usize,
) -> io::Result<()> {
    if path.is_dir() {
        let mut entries: Vec<_> =
            std::fs::read_dir(path)?.collect::<Result<Vec<_>, _>>()?.into_iter().collect();
        entries.sort_by_key(|e| e.file_name());
        for e in entries {
            let p = e.path();
            let name = e.file_name();
            if p.is_dir() {
                if name == "target" || name == "fixtures" {
                    continue;
                }
                scan_path(&p, findings, files_scanned)?;
            } else if p.extension().is_some_and(|x| x == "rs") {
                scan_path(&p, findings, files_scanned)?;
            }
        }
        return Ok(());
    }
    let text = std::fs::read_to_string(path)?;
    *files_scanned += 1;
    findings.extend(scan_file(&path.display().to_string(), &text));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(path: &str, text: &str) -> Vec<&'static str> {
        scan_file(path, text).into_iter().map(|f| f.rule).collect()
    }

    #[test]
    fn strip_blanks_comments_and_strings() {
        let s = strip_source("let x = \"panic!(boom)\"; // unsafe here\n");
        assert!(!s.contains("panic!"));
        assert!(!s.contains("unsafe"));
        assert!(s.contains("let x = \""));
    }

    #[test]
    fn strip_preserves_line_count() {
        let src = "a\n/* b\nc */\nr#\"d\ne\"#\n\"f\\\ng\"\n";
        assert_eq!(src.lines().count(), strip_source(src).lines().count());
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let s = strip_source("fn f<'a>(x: &'a str) -> &'a str { x }");
        assert!(s.contains("&'a str"));
    }

    #[test]
    fn r1_fires_without_safety_and_not_with() {
        assert_eq!(rules("m.rs", "unsafe { x() }\n"), vec!["R1"]);
        assert!(rules("m.rs", "// SAFETY: fixture.\nunsafe { x() }\n").is_empty());
        // Same-line marker also counts.
        assert!(rules("m.rs", "unsafe { x() } // SAFETY: fixture.\n").is_empty());
        // `unsafe_op_in_unsafe_fn` is not the keyword.
        assert!(rules("m.rs", "#![deny(unsafe_op_in_unsafe_fn)]\n").is_empty());
    }

    #[test]
    fn r2_scopes_to_hot_files_and_hot_names() {
        let hot = "let tail = t.load(Ordering::Relaxed);\n";
        assert_eq!(rules("src/service/ring.rs", hot), vec!["R2"]);
        // Not a hot file: no finding.
        assert!(rules("src/coordinator/cluster.rs", hot).is_empty());
        // fleet.rs joined the hot set with the replication router: the
        // depth gauges and the P2C rotation counter are audited.
        let depth = "let da = self.depth[ca].load(Ordering::Relaxed);\n";
        assert_eq!(rules("src/service/fleet.rs", depth), vec!["R2"]);
        let rr = "let t = rr.fetch_add(1, Ordering::Relaxed);\n";
        assert_eq!(rules("src/service/fleet.rs", rr), vec!["R2"]);
        // Hot file but a telemetry counter name: no finding.
        let counter = "stats.submitted.fetch_add(1, Ordering::Relaxed);\n";
        assert!(rules("src/service/session.rs", counter).is_empty());
        // Justified: no finding.
        let ok = "// RELAXED: producer-owned.\nlet tail = t.load(Ordering::Relaxed);\n";
        assert!(rules("src/service/ring.rs", ok).is_empty());
    }

    #[test]
    fn r3_allowances() {
        let p = "src/coordinator/batcher.rs";
        assert_eq!(rules(p, "v.unwrap();\n"), vec!["R3"]);
        assert!(rules(p, "m.lock().unwrap();\n").is_empty());
        assert!(rules(p, "cv.wait(st).unwrap();\n").is_empty());
        assert!(rules(p, "cv.wait_timeout(st, d).unwrap();\n").is_empty());
        // Multiline poison continuation.
        assert!(rules(p, "let g = m\n    .lock()\n    .unwrap()\n    .take();\n").is_empty());
        // `.unwrap_or_else` is not `.unwrap()`.
        assert!(rules(p, "v.unwrap_or_else(|| 0);\n").is_empty());
        // PANIC: justification clears every token.
        assert!(rules(p, "// PANIC: fixture.\nv.expect(\"boom\");\n").is_empty());
        assert_eq!(rules(p, "panic!(\"boom\");\n"), vec!["R3"]);
        // unreachable! documents dead arms and is allowed.
        assert!(rules(p, "unreachable!(\"dead arm\");\n").is_empty());
        // Out of scope: other layers may unwrap.
        assert!(rules("src/util/threads.rs", "v.unwrap();\n").is_empty());
        // The network edge joined the serving tree (PR 10): same hygiene.
        assert_eq!(rules("src/net/conn.rs", "v.unwrap();\n"), vec!["R3"]);
        assert!(rules("src/net/server.rs", "m.lock().unwrap();\n").is_empty());
    }

    #[test]
    fn r4_fences() {
        let p = "src/service/scatter.rs";
        let src =
            "// hotpath: begin\nlet b = Box::new(1);\n// hotpath: end\nlet c = Box::new(2);\n";
        let f = scan_file(p, src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "R4");
        assert_eq!(f[0].line, 2);
        // Unclosed fence is itself a finding.
        assert!(scan_file(p, "// hotpath: begin\n").iter().any(|f| f.rule == "R4"));
        // Fences are inert outside the hot files.
        assert!(scan_file("src/coordinator/cluster.rs", src).is_empty());
        // fleet.rs carries fences around the P2C routing path.
        assert_eq!(scan_file("src/service/fleet.rs", src).len(), 1);
        // net/client.rs fences the pinned remote-lookup path.
        assert_eq!(scan_file("src/net/client.rs", src).len(), 1);
    }

    #[test]
    fn test_fence_cuts_to_eof() {
        let src =
            "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() { v.unwrap(); unsafe { x() } }\n}\n";
        assert!(rules("src/service/ring.rs", src).is_empty());
    }
}

//! Figure 2: probing SM pairs — the raw (un-rearranged) throughput matrix.

use crate::probe::{pair_probe, PairMatrix, PairProbeConfig};
use crate::sim::Machine;

use super::common::{self, Effort};

pub struct Fig2 {
    pub matrix: PairMatrix,
}

pub fn run(effort: Effort, seed: u64) -> Fig2 {
    let machine = common::paper_machine();
    run_on(&machine, effort, seed)
}

pub fn run_on(machine: &Machine, effort: Effort, seed: u64) -> Fig2 {
    let mut cfg = PairProbeConfig::for_machine(machine);
    cfg.accesses_per_sm = match effort {
        Effort::Quick => 1_500,
        Effort::Full => 4_000,
    };
    cfg.seed = seed;
    Fig2 {
        matrix: pair_probe(machine, &cfg),
    }
}

/// The identity-permutation render (what the paper's Fig 2 shows: dark 2x2
/// TPC blocks scattered over the matrix).
pub fn render(f: &Fig2) -> String {
    let perm: Vec<usize> = (0..f.matrix.n).collect();
    f.matrix.render(&perm)
}

pub fn to_csv(f: &Fig2) -> String {
    let perm: Vec<usize> = (0..f.matrix.n).collect();
    f.matrix.to_csv(&perm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;

    #[test]
    fn fig2_shows_2x2_blocks_on_tiny() {
        // Tiny machine keeps the n^2 sweep fast; structure is identical.
        let machine = Machine::new(MachineConfig::tiny_test()).unwrap();
        let f = run_on(&machine, Effort::Quick, 3);
        let topo = machine.topology();
        // TPC mates (2k, 2k+1) must be dark (same group by construction).
        let mean = f.matrix.mean_offdiag();
        for k in 0..topo.sm_count() / 2 {
            let v = f.matrix.get(2 * k, 2 * k + 1);
            assert!(
                v < mean * 0.85,
                "TPC pair ({},{}) not dark: {v:.1} vs mean {mean:.1}",
                2 * k,
                2 * k + 1
            );
        }
        let txt = render(&f);
        assert!(txt.contains('#'));
    }
}

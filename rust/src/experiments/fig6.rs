//! Figure 6: memory throughput for random access, take 2 — the headline
//! result.
//!
//! Fig 1's two arms plus **group-to-chunk**: all SMs of a resource group
//! confined to the same memory half.  Expected: the group-to-chunk series
//! stays at the ~1.3 TB/s plateau across the entire 80 GB while the other
//! two collapse past 64 GB.

use crate::coordinator::PlacementPolicy;
use crate::util::benchkit::Table;

use super::common::{self, Effort};

#[derive(Debug, Clone)]
pub struct Fig6Row {
    pub region_gib: u64,
    pub uniform_gbps: f64,
    pub sm_to_chunk_gbps: f64,
    pub group_to_chunk_gbps: f64,
}

pub fn run(effort: Effort, seed: u64) -> Vec<Fig6Row> {
    let machine = common::paper_machine();
    let map = common::ground_truth_map(&machine);
    let per_sm = effort.accesses_per_sm();
    let sweep = common::region_sweep_gib(effort);
    // Three specs (one per policy arm) per sweep point, one parallel batch.
    let mut specs = Vec::with_capacity(sweep.len() * 3);
    for &gib in &sweep {
        let spec = |policy, chunks, salt: u64| {
            common::policy_spec(&machine, &map, policy, gib, chunks, per_sm, seed ^ gib ^ salt)
        };
        specs.push(spec(PlacementPolicy::Naive, 1, 0));
        specs.push(spec(PlacementPolicy::SmToChunk, 2, 0x5A));
        specs.push(spec(PlacementPolicy::GroupToChunk, 2, 0xC3));
    }
    let results = machine.run_many(&specs);
    sweep
        .iter()
        .zip(results.chunks_exact(3))
        .map(|(&gib, arms)| Fig6Row {
            region_gib: gib,
            uniform_gbps: arms[0].gbps,
            sm_to_chunk_gbps: arms[1].gbps,
            group_to_chunk_gbps: arms[2].gbps,
        })
        .collect()
}

pub fn table(rows: &[Fig6Row]) -> Table {
    let mut t = Table::new(&[
        "region_gib",
        "uniform_gbps",
        "sm_to_chunk_gbps",
        "group_to_chunk_gbps",
    ]);
    for r in rows {
        t.row(&[
            r.region_gib.to_string(),
            format!("{:.1}", r.uniform_gbps),
            format!("{:.1}", r.sm_to_chunk_gbps),
            format!("{:.1}", r.group_to_chunk_gbps),
        ]);
    }
    t
}

/// The paper's headline claim: group-to-chunk is flat at full speed over
/// the entire memory; the others collapse.
pub fn check(rows: &[Fig6Row]) -> anyhow::Result<()> {
    let at_80 = rows
        .iter()
        .find(|r| r.region_gib == 80)
        .ok_or_else(|| anyhow::anyhow!("sweep must include 80 GiB"))?;
    if at_80.group_to_chunk_gbps < 1100.0 {
        anyhow::bail!(
            "group-to-chunk at 80 GiB is {:.0} GB/s, not full speed",
            at_80.group_to_chunk_gbps
        );
    }
    if at_80.uniform_gbps > at_80.group_to_chunk_gbps / 2.5 {
        anyhow::bail!("uniform did not collapse at 80 GiB");
    }
    if at_80.sm_to_chunk_gbps > at_80.group_to_chunk_gbps / 2.5 {
        anyhow::bail!("sm-to-chunk should not benefit at 80 GiB");
    }
    // Flatness: group-to-chunk varies < 15% across the sweep.
    let min = rows
        .iter()
        .map(|r| r.group_to_chunk_gbps)
        .fold(f64::INFINITY, f64::min);
    let max = rows
        .iter()
        .map(|r| r.group_to_chunk_gbps)
        .fold(0.0f64, f64::max);
    if (max - min) / max > 0.15 {
        anyhow::bail!("group-to-chunk series not flat: {min:.0}..{max:.0}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_reproduces_headline_result() {
        let rows = run(Effort::Quick, 2);
        check(&rows).unwrap();
    }
}

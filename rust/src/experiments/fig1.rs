//! Figure 1: memory throughput for random access vs region size.
//!
//! Two arms, exactly the paper's §2.1 baseline experiment:
//!
//! * **uniform**     — every warp on every SM reads random lines in a
//!   region of varying size.  Expected: ~1.3 TB/s plateau up to the 64 GB
//!   TLB reach, then a precipitous collapse.
//! * **sm-to-chunk** — memory split in two; each SM picks a random half.
//!   Expected: *no benefit* (each group's TLB still sees both halves).

use crate::coordinator::PlacementPolicy;
use crate::util::benchkit::Table;

use super::common::{self, Effort};

#[derive(Debug, Clone)]
pub struct Fig1Row {
    pub region_gib: u64,
    pub uniform_gbps: f64,
    pub sm_to_chunk_gbps: f64,
}

pub fn run(effort: Effort, seed: u64) -> Vec<Fig1Row> {
    let machine = common::paper_machine();
    let map = common::ground_truth_map(&machine);
    let per_sm = effort.accesses_per_sm();
    let sweep = common::region_sweep_gib(effort);
    // Two specs per sweep point, executed as one parallel batch.
    let mut specs = Vec::with_capacity(sweep.len() * 2);
    for &gib in &sweep {
        specs.push(common::policy_spec(
            &machine,
            &map,
            PlacementPolicy::Naive,
            gib,
            1,
            per_sm,
            seed ^ gib,
        ));
        specs.push(common::policy_spec(
            &machine,
            &map,
            PlacementPolicy::SmToChunk,
            gib,
            2,
            per_sm,
            seed ^ gib ^ 0x5A,
        ));
    }
    let results = machine.run_many(&specs);
    sweep
        .iter()
        .zip(results.chunks_exact(2))
        .map(|(&gib, pair)| Fig1Row {
            region_gib: gib,
            uniform_gbps: pair[0].gbps,
            sm_to_chunk_gbps: pair[1].gbps,
        })
        .collect()
}

pub fn table(rows: &[Fig1Row]) -> Table {
    let mut t = Table::new(&["region_gib", "uniform_gbps", "sm_to_chunk_gbps"]);
    for r in rows {
        t.row(&[
            r.region_gib.to_string(),
            format!("{:.1}", r.uniform_gbps),
            format!("{:.1}", r.sm_to_chunk_gbps),
        ]);
    }
    t
}

/// The claims the paper's Fig 1 makes, as assertions over the series.
pub fn check(rows: &[Fig1Row]) -> anyhow::Result<()> {
    let below: Vec<&Fig1Row> = rows.iter().filter(|r| r.region_gib <= 56).collect();
    let above: Vec<&Fig1Row> = rows.iter().filter(|r| r.region_gib >= 72).collect();
    if below.is_empty() || above.is_empty() {
        anyhow::bail!("sweep does not bracket the cliff");
    }
    let plateau =
        below.iter().map(|r| r.uniform_gbps).sum::<f64>() / below.len() as f64;
    let floor = above.iter().map(|r| r.uniform_gbps).sum::<f64>() / above.len() as f64;
    if plateau < 1100.0 {
        anyhow::bail!("plateau {plateau:.0} GB/s too low");
    }
    if floor > plateau / 2.5 {
        anyhow::bail!("no precipitous drop: plateau {plateau:.0}, floor {floor:.0}");
    }
    // SM-to-chunk must track uniform (no benefit) past the cliff.
    for r in rows.iter().filter(|r| r.region_gib > 64) {
        let ratio = r.sm_to_chunk_gbps / r.uniform_gbps;
        if ratio > 1.6 {
            anyhow::bail!(
                "sm-to-chunk shows unexpected benefit at {} GiB: {ratio:.2}x",
                r.region_gib
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_reproduces_paper_shape() {
        let rows = run(Effort::Quick, 1);
        assert_eq!(
            rows.len(),
            common::region_sweep_gib(Effort::Quick).len()
        );
        check(&rows).unwrap();
    }
}

//! Figure 4: running each resource group individually.
//!
//! Expected: throughput proportional to SM count — the two 6-SM groups
//! underperform the twelve 8-SM groups by exactly 6/8 (paper: ~90 vs ~120
//! GB/s).

use crate::probe::{solo_groups, SoloGroupResult, VerifyConfig};
use crate::util::benchkit::Table;

use super::common::{self, Effort};

pub fn run(effort: Effort, seed: u64) -> Vec<SoloGroupResult> {
    let machine = common::paper_machine();
    let map = common::ground_truth_map(&machine);
    let mut cfg = VerifyConfig::for_machine(&machine);
    cfg.accesses_per_sm = effort.accesses_per_sm();
    cfg.seed = seed;
    solo_groups(&machine, &map.groups, &cfg)
}

pub fn table(rows: &[SoloGroupResult]) -> Table {
    let mut t = Table::new(&["group", "sms", "gbps"]);
    for r in rows {
        t.row(&[
            r.group_index.to_string(),
            r.sm_count.to_string(),
            format!("{:.1}", r.gbps),
        ]);
    }
    t
}

/// Paper claims: every group lands near its size class, and the class
/// ratio is ~8/6.
pub fn check(rows: &[SoloGroupResult]) -> anyhow::Result<()> {
    let mean_of = |n: usize| -> f64 {
        let v: Vec<f64> = rows
            .iter()
            .filter(|r| r.sm_count == n)
            .map(|r| r.gbps)
            .collect();
        v.iter().sum::<f64>() / v.len().max(1) as f64
    };
    let big = mean_of(8);
    let small = mean_of(6);
    if !(100.0..150.0).contains(&big) {
        anyhow::bail!("8-SM groups at {big:.1} GB/s (expected ~120)");
    }
    if !(75.0..115.0).contains(&small) {
        anyhow::bail!("6-SM groups at {small:.1} GB/s (expected ~90)");
    }
    let ratio = big / small;
    if (ratio - 8.0 / 6.0).abs() > 0.12 {
        anyhow::bail!("size ratio {ratio:.3} != 8/6");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_reproduces_paper_shape() {
        let rows = run(Effort::Quick, 7);
        assert_eq!(rows.len(), 14);
        check(&rows).unwrap();
    }
}

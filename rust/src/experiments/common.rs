//! Shared experiment plumbing.

use crate::config::{MachineConfig, GIB};
use crate::coordinator::{Placement, PlacementPolicy, WindowPlan};
use crate::probe::TopologyMap;
use crate::sim::{Machine, MeasurementSpec, SmAssignment};

/// How heavy to run the simulated benchmarks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Effort {
    /// CI-fast: fewer accesses, fewer sweep points.
    Quick,
    /// Paper-fidelity sweeps.
    Full,
}

impl Effort {
    pub fn accesses_per_sm(&self) -> u64 {
        match self {
            Effort::Quick => 2_000,
            Effort::Full => 6_000,
        }
    }

    pub fn from_env() -> Self {
        match std::env::var("A100WIN_EFFORT").as_deref() {
            Ok("full") => Effort::Full,
            _ => Effort::Quick,
        }
    }
}

/// The canonical experiment machine: the paper's SXM4-80GB card.
pub fn paper_machine() -> Machine {
    Machine::new(MachineConfig::a100_80gb()).expect("preset must validate")
}

/// Ground-truth topology map (cheap; used where the experiment is about
/// *placement*, not about discovery — discovery experiments run the real
/// probe).  Matches what a `Prober::run` would return on this machine.
pub fn ground_truth_map(machine: &Machine) -> TopologyMap {
    TopologyMap::ground_truth(machine)
}

/// Region sizes for Fig-1/Fig-6 sweeps (GiB).
pub fn region_sweep_gib(effort: Effort) -> Vec<u64> {
    match effort {
        Effort::Quick => vec![8, 24, 40, 56, 60, 64, 68, 72, 80],
        Effort::Full => vec![4, 8, 16, 24, 32, 40, 48, 56, 60, 62, 64, 66, 68, 70, 72, 76, 80],
    }
}

/// Build the measurement spec for one full-device run under a placement
/// policy over a region of `gib` GiB starting at byte 0.  Specs are built
/// serially (placement is cheap) and executed through
/// [`Machine::run_many`] so sweeps share one parallel engine pool.
pub fn policy_spec(
    machine: &Machine,
    map: &TopologyMap,
    policy: PlacementPolicy,
    gib: u64,
    chunks: usize,
    accesses_per_sm: u64,
    seed: u64,
) -> MeasurementSpec {
    let row_bytes = crate::config::LINE_BYTES;
    let total_rows = gib * GIB / row_bytes;
    let plan = WindowPlan::split(total_rows, row_bytes, chunks);
    let placement = Placement::build(policy, map, &plan, seed).expect("placement");
    let assignments: Vec<SmAssignment> = placement.sim_assignments(map, &plan, machine, seed);
    MeasurementSpec {
        assignments,
        accesses_per_sm,
        warmup_fraction: 0.25,
        txn_bytes: crate::config::LINE_BYTES,
        seed,
    }
}

/// Run one full-device measurement under a placement policy over a region
/// of `gib` GiB starting at byte 0.
pub fn run_policy(
    machine: &Machine,
    map: &TopologyMap,
    policy: PlacementPolicy,
    gib: u64,
    chunks: usize,
    accesses_per_sm: u64,
    seed: u64,
) -> f64 {
    machine
        .run(&policy_spec(machine, map, policy, gib, chunks, accesses_per_sm, seed))
        .gbps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effort_parses_env_values() {
        assert_eq!(Effort::Quick.accesses_per_sm() < Effort::Full.accesses_per_sm(), true);
    }

    #[test]
    fn sweeps_cover_the_cliff() {
        for e in [Effort::Quick, Effort::Full] {
            let s = region_sweep_gib(e);
            assert!(s.iter().any(|&g| g < 64));
            assert!(s.iter().any(|&g| g == 64));
            assert!(s.iter().any(|&g| g > 64));
            assert!(s.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn ground_truth_map_validates() {
        let m = paper_machine();
        let map = ground_truth_map(&m);
        map.validate().unwrap();
        assert_eq!(map.groups.len(), 14);
        assert_eq!(map.reach_bytes, 64 * GIB);
    }
}

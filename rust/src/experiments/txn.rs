//! The §2.1 aside: transaction-size sweep.
//!
//! "Reading 32 64-bit words achieves about 1400 GB/s, and 32 128-bit words
//! achieves about 1600 GB/s" — larger coalesced transactions amortize HBM
//! overheads.  Orthogonal to the TLB cliff, but part of the evaluation.

use crate::config::GIB;
use crate::sim::{MeasurementSpec, MemRegion, Pattern, SmAssignment};
use crate::util::benchkit::Table;

use super::common::{self, Effort};

#[derive(Debug, Clone)]
pub struct TxnRow {
    pub txn_bytes: u64,
    pub gbps: f64,
}

pub fn run(effort: Effort, seed: u64) -> Vec<TxnRow> {
    let machine = common::paper_machine();
    let sms = machine.topology().all_sms();
    let per_sm = effort.accesses_per_sm();
    let txns = [128u64, 256, 512];
    let specs: Vec<MeasurementSpec> = txns
        .iter()
        .map(|&txn| MeasurementSpec {
            assignments: sms
                .iter()
                .map(|&smid| SmAssignment {
                    smid,
                    pattern: Pattern::Uniform(MemRegion::new(0, 32 * GIB)),
                })
                .collect(),
            accesses_per_sm: per_sm,
            warmup_fraction: 0.25,
            txn_bytes: txn,
            seed: seed ^ txn,
        })
        .collect();
    txns.iter()
        .zip(machine.run_many(&specs))
        .map(|(&txn, meas)| TxnRow {
            txn_bytes: txn,
            gbps: meas.gbps,
        })
        .collect()
}

pub fn table(rows: &[TxnRow]) -> Table {
    let mut t = Table::new(&["txn_bytes", "gbps"]);
    for r in rows {
        t.row(&[r.txn_bytes.to_string(), format!("{:.1}", r.gbps)]);
    }
    t
}

/// Paper: 128 B ~1300, 256 B ~1400, 512 B ~1600 GB/s.
pub fn check(rows: &[TxnRow]) -> anyhow::Result<()> {
    let get = |b: u64| rows.iter().find(|r| r.txn_bytes == b).map(|r| r.gbps);
    let (t128, t256, t512) = (
        get(128).ok_or_else(|| anyhow::anyhow!("missing 128B"))?,
        get(256).ok_or_else(|| anyhow::anyhow!("missing 256B"))?,
        get(512).ok_or_else(|| anyhow::anyhow!("missing 512B"))?,
    );
    if !(1150.0..1400.0).contains(&t128) {
        anyhow::bail!("128 B at {t128:.0} (paper ~1300)");
    }
    if !(1250.0..1500.0).contains(&t256) {
        anyhow::bail!("256 B at {t256:.0} (paper ~1400)");
    }
    if !(1450.0..1700.0).contains(&t512) {
        anyhow::bail!("512 B at {t512:.0} (paper ~1600)");
    }
    if !(t128 < t256 && t256 < t512) {
        anyhow::bail!("efficiency must grow with transaction size");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn txn_sweep_matches_paper_aside() {
        let rows = run(Effort::Quick, 5);
        check(&rows).unwrap();
    }
}

//! One driver per figure in the paper's evaluation (DESIGN.md §5).
//!
//! Each `figN` module exposes `run(effort, seed)`, a table/render function,
//! and a `check()` that encodes the figure's qualitative claims — the same
//! assertions the test suite and the benches rely on.  The CLI's
//! `a100win fig <n>` prints the series; benches under `rust/benches/`
//! re-run them with timing and CSV output.

pub mod common;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod txn;

pub use common::Effort;

/// Run one figure by number and print it; `0` means the txn-size aside.
pub fn run_figure(n: u32, effort: Effort, seed: u64) -> anyhow::Result<()> {
    match n {
        1 => {
            let rows = fig1::run(effort, seed);
            println!("# Figure 1: throughput vs region size (GB/s)");
            fig1::table(&rows).print();
            fig1::check(&rows)
        }
        2 => {
            let f = fig2::run(effort, seed);
            println!("# Figure 2: SM-pair probe matrix (smid order)");
            println!("#   '@' diagonal, '#' strong contention (shared group),");
            println!("#   '+' faint contention (shared GPC hub), '.' none");
            print!("{}", fig2::render(&f));
            Ok(())
        }
        3 => {
            let f = fig3::run(effort, seed);
            println!("# Figure 3: rearranged SM indices (discovered groups)");
            print!("{}", fig3::render(&f));
            println!("{}", fig3::summary(&f));
            Ok(())
        }
        4 => {
            let rows = fig4::run(effort, seed);
            println!("# Figure 4: each resource group individually");
            fig4::table(&rows).print();
            fig4::check(&rows)
        }
        5 => {
            let f = fig5::run(effort, seed);
            println!("# Figure 5: pairs of resource groups, disjoint regions");
            fig5::table(&f).print();
            fig5::check(&f)
        }
        6 => {
            let rows = fig6::run(effort, seed);
            println!("# Figure 6: throughput vs region size, all policies");
            fig6::table(&rows).print();
            fig6::check(&rows)
        }
        0 => {
            let rows = txn::run(effort, seed);
            println!("# §2.1 aside: transaction-size sweep");
            txn::table(&rows).print();
            txn::check(&rows)
        }
        _ => anyhow::bail!("unknown figure {n} (paper has figures 1-6, 0 = txn aside)"),
    }
}

/// All figures in order.
pub fn run_all(effort: Effort, seed: u64) -> anyhow::Result<()> {
    for n in [1, 2, 3, 4, 5, 6, 0] {
        run_figure(n, effort, seed)?;
        println!();
    }
    Ok(())
}

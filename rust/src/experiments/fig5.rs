//! Figure 5: running pairs of resource groups over disjoint 40 GB regions.
//!
//! Expected: every pair achieves almost exactly the sum of its members'
//! solo throughput — the groups do not share a TLB.

use crate::probe::{group_pairs, solo_groups, GroupPairResult, VerifyConfig};
use crate::util::benchkit::Table;

use super::common::{self, Effort};

pub struct Fig5 {
    pub pairs: Vec<GroupPairResult>,
}

pub fn run(effort: Effort, seed: u64) -> Fig5 {
    let machine = common::paper_machine();
    let map = common::ground_truth_map(&machine);
    let mut cfg = VerifyConfig::for_machine(&machine);
    cfg.accesses_per_sm = effort.accesses_per_sm();
    cfg.seed = seed;
    let solos = solo_groups(&machine, &map.groups, &cfg);
    // The paper plots all pairs; Quick mode samples a representative set
    // (every group appears, both 6-SM groups included).
    let pairs_sel = match effort {
        Effort::Full => None,
        Effort::Quick => {
            let n = map.groups.len();
            let mut v: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
            v.push((0, n - 1));
            Some(v)
        }
    };
    Fig5 {
        pairs: group_pairs(&machine, &map.groups, &solos, pairs_sel, &cfg),
    }
}

pub fn table(f: &Fig5) -> Table {
    let mut t = Table::new(&["group_a", "group_b", "pair_gbps", "solo_sum_gbps", "ratio"]);
    for p in &f.pairs {
        t.row(&[
            p.a.to_string(),
            p.b.to_string(),
            format!("{:.1}", p.gbps),
            format!("{:.1}", p.solo_sum),
            format!("{:.3}", p.gbps / p.solo_sum),
        ]);
    }
    t
}

/// Paper claim: pairs ~= double the singles (within tolerance).
pub fn check(f: &Fig5) -> anyhow::Result<()> {
    for p in &f.pairs {
        let ratio = p.gbps / p.solo_sum;
        if (ratio - 1.0).abs() > 0.12 {
            anyhow::bail!(
                "pair ({},{}) at {:.2}x of independent prediction",
                p.a,
                p.b,
                ratio
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_reproduces_paper_shape() {
        let f = run(Effort::Quick, 11);
        assert!(!f.pairs.is_empty());
        check(&f).unwrap();
        // Every group appears at least once in the quick set.
        let mut seen = std::collections::HashSet::new();
        for p in &f.pairs {
            seen.insert(p.a);
            seen.insert(p.b);
        }
        assert_eq!(seen.len(), 14);
    }
}

//! Figure 3: rearranging SM indices to clarify the resource groups.
//!
//! Clusters the Fig-2 matrix and renders it under the discovered
//! permutation: the scattered dark cells collapse into contiguous blocks —
//! 14 groups of 6 or 8 SMs on the A100 preset.

use crate::probe::{cluster, Clustering};

use super::common::Effort;
use super::fig2::{self, Fig2};

pub struct Fig3 {
    pub fig2: Fig2,
    pub clustering: Clustering,
}

pub fn run(effort: Effort, seed: u64) -> Fig3 {
    let fig2 = fig2::run(effort, seed);
    let clustering = cluster(&fig2.matrix);
    Fig3 { fig2, clustering }
}

pub fn run_on(machine: &crate::sim::Machine, effort: Effort, seed: u64) -> Fig3 {
    let fig2 = fig2::run_on(machine, effort, seed);
    let clustering = cluster(&fig2.matrix);
    Fig3 { fig2, clustering }
}

/// Render under the group-sorted permutation (the paper's Fig-3 view).
pub fn render(f: &Fig3) -> String {
    f.fig2.matrix.render(&f.clustering.permutation)
}

/// Group summary: "group 0: 8 SMs [..]" lines.
pub fn summary(f: &Fig3) -> String {
    let mut s = String::new();
    for (gid, members) in f.clustering.groups.iter().enumerate() {
        s.push_str(&format!(
            "group {gid:2}: {} SMs {:?}\n",
            members.len(),
            members
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::sim::Machine;

    #[test]
    fn fig3_blocks_are_contiguous_on_tiny() {
        let machine = Machine::new(MachineConfig::tiny_test()).unwrap();
        let f = run_on(&machine, Effort::Quick, 4);
        // Discovered groups = ground truth count.
        assert_eq!(f.clustering.groups.len(), machine.topology().group_count());
        // Under the permutation, each row's dark cells must be contiguous
        // (a block diagonal): verify rows of the rendered matrix contain at
        // most one run of '#'.
        let txt = render(&f);
        for line in txt.lines() {
            let mut runs = 0;
            let mut inside = false;
            for c in line.chars() {
                let dark = c == '#' || c == '@';
                if dark && !inside {
                    runs += 1;
                }
                inside = dark;
            }
            assert!(runs <= 1, "non-contiguous block in row: {line}");
        }
        assert!(summary(&f).contains("group  0"));
    }
}

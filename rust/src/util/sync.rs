//! Concurrency shim: `std` primitives in normal builds, the in-tree
//! `interleave` model checker under `--features model`.
//!
//! The lock-free serving path (`service::ring`, `service::scatter`,
//! `service::session`, `service::backend`) imports its atomics, locks,
//! shared cells, and thread operations from here instead of `std` so that
//! one `cfg` flip routes every load/store/CAS, lock handoff, and
//! park/unpark through a scheduler that explores interleavings and flags
//! races (see `src/verify.rs` for the models).
//!
//! Under default features this module is **pure re-exports**: the same
//! `std`/`core` types, zero wrappers, zero overhead — normal builds are
//! byte-identical on the hot path (the `perf-assert` allocation test and
//! the serve benches run against exactly the `std` types).
//!
//! Under `--features model`:
//! - atomics/`Mutex`/`Condvar`/`thread::*` come from `interleave`, which
//!   passes through to `std` behavior whenever no model execution is
//!   active on the current thread — so the entire normal test suite also
//!   runs unchanged with the feature enabled;
//! - [`CellSlot`] becomes `interleave::cell::RaceCell`, whose `get()`
//!   records the access with a vector clock and aborts the execution on an
//!   unordered racing access *before* the pointer is dereferenced.
//!
//! Porting rule: a module on the shim must take **all** of its
//! synchronization from here. Mixing shim atomics with `std` locks in one
//! protocol would let a model execution block on a real lock held by a
//! descheduled model thread and wedge the scheduler.

#[cfg(not(feature = "model"))]
pub use std::sync::atomic::{fence, AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};

#[cfg(not(feature = "model"))]
pub use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};

/// Shared slot handed out by raw pointer: `UnsafeCell` in normal builds,
/// a race-detecting cell under the model.
#[cfg(not(feature = "model"))]
pub type CellSlot<T> = core::cell::UnsafeCell<T>;

#[cfg(not(feature = "model"))]
pub mod thread {
    pub use std::thread::{
        current, park, park_timeout, sleep, spawn, yield_now, JoinHandle, Thread,
    };
}

#[cfg(feature = "model")]
pub use interleave::atomic::{fence, AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};

#[cfg(feature = "model")]
pub use interleave::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};

#[cfg(feature = "model")]
pub type CellSlot<T> = interleave::cell::RaceCell<T>;

#[cfg(feature = "model")]
pub mod thread {
    pub use interleave::thread::{
        current, park, park_timeout, sleep, spawn, yield_now, JoinHandle, Thread,
    };
}

#[cfg(all(test, not(feature = "model")))]
mod tests {
    // Type-identity proof that normal builds pay nothing for the shim: a
    // value constructed as the `std` type is accepted where the shim type
    // is expected, so the re-exports above are the very same types (not
    // wrappers) and non-model binaries are unchanged by this module.
    #[test]
    fn shim_is_pure_reexports() {
        let a: super::AtomicUsize = std::sync::atomic::AtomicUsize::new(1);
        assert_eq!(a.load(std::sync::atomic::Ordering::SeqCst), 1);
        let m: super::Mutex<u32> = std::sync::Mutex::new(2);
        let _c: super::Condvar = std::sync::Condvar::new();
        let cell: super::CellSlot<u32> = core::cell::UnsafeCell::new(3);
        // SAFETY: exclusive access — the cell never leaves this frame.
        assert_eq!(unsafe { *cell.get() }, 3);
        let g: std::sync::MutexGuard<'_, u32> = m.lock().unwrap();
        let g: super::MutexGuard<'_, u32> = g;
        assert_eq!(*g, 2);
        drop(g);
        let h: std::thread::JoinHandle<u32> = super::thread::spawn(|| 4);
        assert_eq!(h.join().unwrap(), 4);
        let o: super::Ordering = std::sync::atomic::Ordering::Relaxed;
        assert!(matches!(o, std::sync::atomic::Ordering::Relaxed));
    }
}

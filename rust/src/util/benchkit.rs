//! Mini benchmark harness (offline substrate for `criterion`).
//!
//! `cargo bench` runs each bench target's `main()`; this module provides
//! warmup + repeated timing + median/MAD reporting, plus a table printer
//! for the figure-regeneration benches whose primary output is the paper's
//! data series rather than wall-clock time.

use std::time::Instant;

/// Timing summary of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mad_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<40} {:>12}/iter  (min {:>10}, max {:>10}, MAD {:>9}, n={})",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.min_ns),
            fmt_ns(self.max_ns),
            fmt_ns(self.mad_ns),
            self.iters
        );
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Time `f` `iters` times after `warmup` untimed runs; report median/MAD.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mut dev: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let r = BenchResult {
        name: name.to_string(),
        iters,
        median_ns: median,
        mad_ns: dev[dev.len() / 2],
        min_ns: samples[0],
        max_ns: *samples.last().unwrap(),
    };
    r.print();
    r
}

/// Keep a value alive / opaque to the optimizer (std-only black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Column-aligned table printer for figure benches.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!(
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  ")
        );
        for row in &self.rows {
            line(row);
        }
    }

    /// Also emit as CSV (for plotting the reproduced figures).
    pub fn to_csv(&self) -> String {
        let mut s = self.headers.join(",");
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }

    /// Write the CSV next to the bench run (under `bench_out/`).
    pub fn write_csv(&self, filename: &str) {
        let dir = std::path::Path::new("bench_out");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(filename);
        if let Err(e) = std::fs::write(&path, self.to_csv()) {
            eprintln!("warning: could not write {}: {e}", path.display());
        } else {
            println!("[csv] wrote {}", path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-ish", 1, 9, || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.max_ns);
        assert_eq!(r.iters, 9);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["region_gib", "gbps"]);
        t.row(&["8".into(), "1300.0".into()]);
        t.row(&["80".into(), "150.0".into()]);
        let csv = t.to_csv();
        assert_eq!(csv, "region_gib,gbps\n8,1300.0\n80,150.0\n");
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}

//! Deterministic PRNG (offline substrate for the `rand` crate).
//!
//! xoshiro256** seeded via SplitMix64 — the standard pairing: SplitMix64
//! diffuses any u64 seed into four well-mixed words, xoshiro256** passes
//! BigCrush and costs a handful of ops per draw.  Simulation determinism
//! (same seed => same measurement, bit-for-bit) is a test-suite invariant,
//! so the generator lives in-tree rather than behind a crate that could
//! change its stream between versions.

/// xoshiro256** by Blackman & Vigna (public domain reference).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so correlated seeds (0, 1, 2, ...) give
    /// uncorrelated streams.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        // All-zero state is a fixed point; SplitMix64 cannot produce it for
        // any seed, but guard anyway.
        debug_assert!(s.iter().any(|&x| x != 0));
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift with rejection).
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn gen_index(&mut self, n: usize) -> usize {
        self.gen_range(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` (53 mantissa bits).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(1);
        let mut c = Rng::seed_from_u64(2);
        let va: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..64).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn gen_range_bounds_and_uniformity() {
        let mut r = Rng::seed_from_u64(3);
        let n = 10u64;
        let mut counts = vec![0u32; n as usize];
        for _ in 0..100_000 {
            let x = r.gen_range(n);
            assert!(x < n);
            counts[x as usize] += 1;
        }
        // Chi-square-ish sanity: each bucket within 10% of expectation.
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 1_000.0, "counts {counts:?}");
        }
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(4);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn shuffle_handles_trivial_sizes() {
        let mut r = Rng::seed_from_u64(5);
        let mut empty: Vec<u8> = vec![];
        r.shuffle(&mut empty);
        let mut one = vec![42];
        r.shuffle(&mut one);
        assert_eq!(one, vec![42]);
    }

    #[test]
    fn gen_bool_rate() {
        let mut r = Rng::seed_from_u64(6);
        let hits = (0..100_000).filter(|_| r.gen_bool(0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }

    #[test]
    #[should_panic]
    fn gen_range_zero_panics() {
        Rng::seed_from_u64(0).gen_range(0);
    }
}

//! Minimal JSON (offline substrate for `serde_json`).
//!
//! Emitter + recursive-descent parser covering the JSON the project
//! actually exchanges: the AOT `manifest.json` written by python and the
//! probe's `TopologyMap` report.  Numbers parse to f64 (JSON's model);
//! integer accessors validate range.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---- constructors -------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---- accessors -----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    // ---- emit ----------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.emit(&mut out, None, 0);
        out
    }

    /// Pretty-print with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.emit(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn emit(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() <= 2f64.powi(53) {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => emit_string(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.emit(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    emit_string(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.emit(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }

    // ---- parse ---------------------------------------------------------
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let b = s.as_bytes();
        let mut p = Parser { b, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

fn emit_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs: JSON-escaped UTF-16.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                            .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.pos += 4;
                                    char::from_u32(
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00),
                                    )
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble multi-byte UTF-8 (input is valid &str).
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        self.pos = start + width;
                        out.push_str(
                            std::str::from_utf8(&self.b[start..self.pos])
                                .map_err(|_| self.err("bad utf8"))?,
                        );
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "1e3", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{s}");
        }
    }

    #[test]
    fn parse_manifest_shape() {
        let src = r#"{
          "version": 1,
          "artifacts": [
            {"name": "gather_b256", "file": "gather_b256.hlo.txt", "b": 256, "n": 65536, "d": 32,
             "operands": ["indices", "table"]}
          ]
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("version").unwrap().as_u64(), Some(1));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("gather_b256"));
        assert_eq!(arts[0].get("b").unwrap().as_usize(), Some(256));
        let ops = arts[0].get("operands").unwrap().as_arr().unwrap();
        assert_eq!(ops[1].as_str(), Some("table"));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::str("a\"b\\c\nd\te\u{8}\u{1}ü€");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn unicode_escape_parses() {
        assert_eq!(
            Json::parse(r#""ü😀""#).unwrap(),
            Json::str("ü😀")
        );
    }

    #[test]
    fn nested_roundtrip_pretty_and_compact() {
        let v = Json::obj(vec![
            ("a", Json::arr(vec![Json::num(1), Json::Null, Json::Bool(true)])),
            ("b", Json::obj(vec![("c", Json::str("x"))])),
            ("empty_arr", Json::arr(vec![])),
            ("empty_obj", Json::Obj(Default::default())),
        ]);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\":}", "tru", "1 2", "\"\\q\"", "{\"a\" 1}"] {
            assert!(Json::parse(s).is_err(), "{s:?} should fail");
        }
    }

    #[test]
    fn integer_accessor_rejects_fractions_and_negatives() {
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-3").unwrap().as_u64(), None);
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
    }

    #[test]
    fn big_ints_emit_without_exponent() {
        let v = Json::num(65536.0 * 65536.0);
        assert_eq!(v.to_string(), "4294967296");
    }
}

//! Offline substrates.
//!
//! This build runs with no network registry: the only "external" crates
//! are vendored in-tree under `rust/vendor/` (a minimal `anyhow`
//! substitute and an error-returning `xla`/PJRT stub).  The small
//! libraries a project like this would normally pull from crates.io are
//! implemented here instead (DESIGN.md "Offline substrates"):
//!
//! * [`rng`]      — deterministic xoshiro256** PRNG (for `rand`)
//! * [`json`]     — JSON emit + parse (for `serde_json`)
//! * [`prop`]     — property-test runner with replayable seeds (for `proptest`)
//! * [`benchkit`] — warmup/median benchmark harness + table/CSV output
//!                  (for `criterion`)
//! * [`threads`]  — scoped parallel map (for `rayon`)
//! * [`sync`]     — concurrency shim: `std` primitives normally, the
//!                  in-tree `interleave` model checker under
//!                  `--features model` (for `loom`/`shuttle`)

pub mod benchkit;
pub mod json;
pub mod prop;
pub mod rng;
pub mod sync;
pub mod threads;

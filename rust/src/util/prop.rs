//! Mini property-testing kit (offline substrate for `proptest`).
//!
//! `check(name, cases, |g| ...)` runs a closure over `cases` pseudo-random
//! inputs drawn through [`Gen`].  On failure it retries the same case to
//! confirm, then panics with the *case seed* so the exact input can be
//! replayed by setting `A100WIN_PROP_SEED`.  No shrinking — cases are kept
//! small by construction instead.

use crate::util::rng::Rng;

/// Per-case generator handle.
pub struct Gen {
    rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    pub fn u64(&mut self, lo: u64, hi_incl: u64) -> u64 {
        assert!(hi_incl >= lo);
        lo + self.rng.gen_range(hi_incl - lo + 1)
    }

    pub fn usize(&mut self, lo: usize, hi_incl: usize) -> usize {
        self.u64(lo as u64, hi_incl as u64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.gen_f64() * (hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.gen_bool(p)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.gen_index(xs.len())]
    }

    pub fn vec_u64(&mut self, len: usize, lo: u64, hi_incl: u64) -> Vec<u64> {
        (0..len).map(|_| self.u64(lo, hi_incl)).collect()
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        self.rng.shuffle(xs);
    }
}

/// Run `f` over `cases` generated inputs.  Panics (with replay seed) on the
/// first failing case.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: u64, mut f: F) {
    // Replay mode: run exactly one case with the given seed.
    if let Ok(s) = std::env::var("A100WIN_PROP_SEED") {
        let seed: u64 = s.parse().expect("A100WIN_PROP_SEED must be a u64");
        let mut g = Gen {
            rng: Rng::seed_from_u64(seed),
            case_seed: seed,
        };
        f(&mut g);
        return;
    }
    let base = fxhash(name);
    for i in 0..cases {
        let case_seed = base.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen {
                rng: Rng::seed_from_u64(case_seed),
                case_seed,
            };
            f(&mut g);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {i} (replay with \
                 A100WIN_PROP_SEED={case_seed}): {msg}"
            );
        }
    }
}

/// Stable name hash (FNV-1a) so case seeds don't change run to run.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("always-true", 50, |g| {
            let x = g.u64(1, 10);
            assert!(x >= 1 && x <= 10);
            n += 1;
        });
        assert_eq!(n, 50);
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always-false", 10, |_g| panic!("boom"));
        });
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap();
        assert!(msg.contains("A100WIN_PROP_SEED="), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn cases_are_deterministic() {
        let mut a = Vec::new();
        check("det", 5, |g| a.push(g.u64(0, 1000)));
        let mut b = Vec::new();
        check("det", 5, |g| b.push(g.u64(0, 1000)));
        assert_eq!(a, b);
    }

    #[test]
    fn gen_helpers_in_bounds() {
        check("helpers", 20, |g| {
            let v = g.vec_u64(10, 5, 9);
            assert_eq!(v.len(), 10);
            assert!(v.iter().all(|&x| (5..=9).contains(&x)));
            let f = g.f64(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let choice = *g.pick(&[1, 2, 3]);
            assert!((1..=3).contains(&choice));
        });
    }
}

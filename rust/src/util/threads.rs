//! Scoped parallel map (offline substrate for `rayon`).
//!
//! `parallel_map(items, workers, f)` fans `f` over `items` on `workers`
//! OS threads with work-stealing-free round-robin chunking by atomic index
//! (items are claimed one at a time, so uneven item costs still balance).
//! Result order matches input order.
//!
//! Results land in a pre-sized, lock-free buffer: each slot is written by
//! exactly the worker that claimed its index (the atomic `fetch_add` hands
//! out every index once), so no per-item `Mutex` is needed — at sweep
//! scale (thousands of sub-millisecond simulations) the old
//! lock-per-result overhead was pure waste.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicUsize, Ordering};

/// One result slot.  Safety contract: written at most once, by the single
/// worker that claimed the slot's index; read only after all workers have
/// joined (the thread scope enforces the happens-before edge).
struct Slot<R>(UnsafeCell<MaybeUninit<R>>);

// SAFETY: distinct threads access distinct slots — the claim counter hands
// each index to exactly one worker, and the post-join read is ordered after
// every write by the scope's join edge — so `&Slot` crossing threads is
// safe for R: Send.  (Audited for the verification PR: the Relaxed claim
// counter is fine because slot writes are ordered by claim uniqueness plus
// the join, not by the counter's ordering; Miri runs this module's tests.)
unsafe impl<R: Send> Sync for Slot<R> {}

/// Map `f` over `items` in parallel, preserving order.
///
/// If `f` panics the panic propagates after the scope joins; results
/// already produced are leaked (never dropped), which is acceptable for
/// this offline substrate.
pub fn parallel_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return items.iter().map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Vec<Slot<R>> = (0..n)
        .map(|_| Slot(UnsafeCell::new(MaybeUninit::uninit())))
        .collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                // SAFETY: `i` was handed out exactly once by `fetch_add`,
                // so this thread is the only writer of slot `i`.
                unsafe { (*out[i].0.get()).write(r) };
            });
        }
    });
    out.into_iter()
        // SAFETY: every index in 0..n was claimed and written before the
        // scope joined (a missing write implies a worker panic, which has
        // already propagated out of `scope`).
        .map(|slot| unsafe { slot.0.into_inner().assume_init() })
        .collect()
}

/// Default worker count: available parallelism minus one (leave a core for
/// the harness), at least 1.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

/// Pin the calling thread to one CPU (`core`, wrapped modulo the visible
/// CPU count, so callers can pass a worker index directly).  NUMA hygiene
/// for long-lived simulation workers: a pinned gather loop keeps its table
/// pages on one node instead of bouncing with the scheduler.
///
/// Raw `sched_setaffinity(2)` syscall shim — in-tree by design (no `libc`
/// dependency; this crate stays std-only).  On non-Linux targets, or Linux
/// architectures without the shim, this is a successful no-op so callers
/// may pin unconditionally when configured.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn pin_to_core(core: usize) -> std::io::Result<()> {
    // 128 bytes (1024 CPUs) of mask, matching glibc's `cpu_set_t`.
    let mut mask = [0u64; 16];
    let size = core::mem::size_of_val(&mask);
    // Read the thread's *current* affinity and pick the `core`-th allowed
    // CPU: under a restricted cpuset (containers), absolute CPU ids may
    // not be permitted at all.  Raw syscalls return -errno directly;
    // sched_getaffinity returns the copied mask size on success.
    let rc = unsafe {
        // SAFETY: the kernel writes at most `size` bytes into `mask`, a
        // live local of exactly that size.
        sched_affinity_raw(SYS_SCHED_GETAFFINITY, size, mask.as_mut_ptr())
    };
    if rc < 0 {
        return Err(std::io::Error::from_raw_os_error(-rc as i32));
    }
    let allowed: Vec<usize> = (0..16 * 64)
        .filter(|&c| (mask[c / 64] >> (c % 64)) & 1 == 1)
        .collect();
    if allowed.is_empty() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            "empty affinity mask",
        ));
    }
    let cpu = allowed[core % allowed.len()];
    let mut pin = [0u64; 16];
    pin[cpu / 64] |= 1u64 << (cpu % 64);
    let rc = unsafe {
        // SAFETY: the kernel reads `size` bytes from `pin`, a live local
        // of exactly that size (set path never writes through the pointer).
        sched_affinity_raw(SYS_SCHED_SETAFFINITY, size, pin.as_mut_ptr())
    };
    if rc == 0 {
        Ok(())
    } else {
        Err(std::io::Error::from_raw_os_error(-rc as i32))
    }
}

/// See the Linux variant: elsewhere pinning is a successful no-op.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub fn pin_to_core(_core: usize) -> std::io::Result<()> {
    Ok(())
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
const SYS_SCHED_SETAFFINITY: i64 = 203;
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
const SYS_SCHED_GETAFFINITY: i64 = 204;
#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
const SYS_SCHED_SETAFFINITY: i64 = 122;
#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
const SYS_SCHED_GETAFFINITY: i64 = 123;

/// `syscall(nr, 0 /* calling thread */, size, mask)` without libc.
///
/// SAFETY: caller must pass a `mask` valid for `size` bytes — readable
/// for the set syscall, writable for the get syscall.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
unsafe fn sched_affinity_raw(nr: i64, size: usize, mask: *mut u64) -> i64 {
    let ret: i64;
    // SAFETY: x86_64 Linux syscall ABI; rcx/r11 are clobbered (declared),
    // and the mask buffer access is bounded by the caller's guarantee.
    unsafe {
        core::arch::asm!(
            "syscall",
            inlateout("rax") nr => ret,
            in("rdi") 0,
            in("rsi") size,
            in("rdx") mask,
            out("rcx") _,
            out("r11") _,
            options(nostack),
        );
    }
    ret
}

/// See the x86_64 variant.
///
/// SAFETY: caller must pass a `mask` valid for `size` bytes — readable
/// for the set syscall, writable for the get syscall.
#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
unsafe fn sched_affinity_raw(nr: i64, size: usize, mask: *mut u64) -> i64 {
    let ret: i64;
    // SAFETY: `svc 0` with the aarch64 Linux syscall ABI; the mask buffer
    // access is bounded by the caller's guarantee.
    unsafe {
        core::arch::asm!(
            "svc 0",
            in("x8") nr,
            inlateout("x0") 0i64 => ret,
            in("x1") size,
            in("x2") mask,
            options(nostack),
        );
    }
    ret
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_values() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 8, |&x| x * x);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i as u64) * (i as u64));
        }
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<u32> = parallel_map(&[] as &[u32], 4, |&x| x);
        assert!(out.is_empty());
        assert_eq!(parallel_map(&[7], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn uneven_work_balances() {
        // Items with wildly different costs still all complete.
        let items: Vec<u64> = (0..64).collect();
        let out = parallel_map(&items, 4, |&x| {
            if x % 7 == 0 {
                // Simulate a heavy item.
                (0..100_000u64).sum::<u64>() + x
            } else {
                x
            }
        });
        assert_eq!(out.len(), 64);
        assert_eq!(out[1], 1);
        assert_eq!(out[0], (0..100_000u64).sum::<u64>());
    }

    #[test]
    fn pin_to_core_succeeds_and_wraps() {
        // Any index must pin (the shim wraps modulo visible CPUs) — and a
        // pinned thread must still compute correctly.
        let h = std::thread::spawn(|| {
            pin_to_core(0).unwrap();
            pin_to_core(usize::MAX - 1).unwrap();
            (0..100u64).sum::<u64>()
        });
        assert_eq!(h.join().unwrap(), 4950);
    }

    #[test]
    fn workers_clamped() {
        // More workers than items must not deadlock or panic.
        let out = parallel_map(&[1, 2, 3], 64, |&x| x);
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn non_copy_results_move_out_intact() {
        let items: Vec<usize> = (0..257).collect();
        let out = parallel_map(&items, 8, |&x| vec![x; 3]);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(v, &vec![i; 3]);
        }
    }

    #[test]
    fn every_slot_written_under_contention() {
        // Many more workers than cores, tiny items: exercises the claim
        // counter's hand-off; assume_init would be UB (and MIRI/debug
        // would catch a logic slip) if any slot were skipped.
        // Shrunk under Miri: its interpreter serializes threads anyway, so
        // a small run keeps the uninit-slot checking without the wall time.
        let (rounds, n, workers) = if cfg!(miri) { (2, 40, 8) } else { (20, 199, 16) };
        for _ in 0..rounds {
            let items: Vec<u64> = (0..n).collect();
            let out = parallel_map(&items, workers, |&x| x + 1);
            assert_eq!(out.len(), n as usize);
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v, i as u64 + 1);
            }
        }
    }
}

//! Scoped parallel map (offline substrate for `rayon`).
//!
//! `parallel_map(items, workers, f)` fans `f` over `items` on `workers`
//! OS threads with work-stealing-free round-robin chunking by atomic index
//! (items are claimed one at a time, so uneven item costs still balance).
//! Result order matches input order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Map `f` over `items` in parallel, preserving order.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *out[i].lock().unwrap() = Some(r);
            });
        }
    });
    out.into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

/// Default worker count: available parallelism minus one (leave a core for
/// the harness), at least 1.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_values() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(items, 8, |&x| x * x);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i as u64) * (i as u64));
        }
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<u32> = parallel_map(Vec::<u32>::new(), 4, |&x| x);
        assert!(out.is_empty());
        assert_eq!(parallel_map(vec![7], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn uneven_work_balances() {
        // Items with wildly different costs still all complete.
        let items: Vec<u64> = (0..64).collect();
        let out = parallel_map(items, 4, |&x| {
            if x % 7 == 0 {
                // Simulate a heavy item.
                (0..100_000u64).sum::<u64>() + x
            } else {
                x
            }
        });
        assert_eq!(out.len(), 64);
        assert_eq!(out[1], 1);
        assert_eq!(out[0], (0..100_000u64).sum::<u64>());
    }

    #[test]
    fn workers_clamped() {
        // More workers than items must not deadlock or panic.
        let out = parallel_map(vec![1, 2, 3], 64, |&x| x);
        assert_eq!(out, vec![1, 2, 3]);
    }
}

//! # a100win — full-speed random access to the entire (simulated) A100 memory
//!
//! Reproduction of Alden Walker, *"Enabling full-speed random access to the
//! entire memory on the A100 GPU"* (2024), as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * [`sim`] — the substrate: a discrete-event model of the A100 memory
//!   hierarchy (resource groups, per-group 64 GB TLBs, page walkers, HBM
//!   channels).  We have no A100; this module stands in for the silicon
//!   (DESIGN.md §2).
//! * [`probe`] — the paper's technique: reverse-engineer which SMs share
//!   memory resources from throughput measurements alone (Figs 2–5).
//! * [`coordinator`] — the productized contribution: a TLB-aware placement
//!   and serving layer that shards a huge random-access table into
//!   per-group windows smaller than TLB reach and routes lookups to the
//!   owning group (Fig 6 as a system feature).
//! * [`runtime`] — PJRT executor for the AOT-compiled JAX/Pallas gather
//!   kernels (`artifacts/*.hlo.txt`); python never runs at request time.
//! * [`workload`] — request/trace generators for benches and examples.
//! * [`experiments`] — one driver per paper figure.

pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod probe;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Convenient re-exports for examples and benches.
pub mod prelude {
    pub use crate::config::{MachineConfig, GIB, LINE_BYTES};
    pub use crate::coordinator::placement::PlacementPolicy;
    pub use crate::probe::{report::TopologyMap, Prober};
    pub use crate::sim::{
        Machine, Measurement, MeasurementSpec, MemRegion, Pattern, SmAssignment,
    };
}

//! # a100win — full-speed random access to the entire (simulated) A100 memory
//!
//! Reproduction of Alden Walker, *"Enabling full-speed random access to the
//! entire memory on the A100 GPU"* (2024), as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * [`service`] — **the front door**: an async ticketed serving facade
//!   ([`service::Service`]) over interchangeable backends — the hermetic
//!   sim-backed one and the PJRT one — with per-tenant admission control
//!   ([`service::Session`]) and multi-card fleet routing
//!   ([`service::FleetService`]).  Start here.
//! * [`sim`] — the substrate: a discrete-event model of the A100 memory
//!   hierarchy (resource groups, per-group 64 GB TLBs, page walkers, HBM
//!   channels).  We have no A100; this module stands in for the silicon
//!   (DESIGN.md §2).
//! * [`probe`] — the paper's technique: reverse-engineer which SMs share
//!   memory resources from throughput measurements alone (Figs 2–5).
//! * [`coordinator`] — the serving mechanics under the facade: windows,
//!   placement, routing, batching, the PJRT server, fleet plans, metrics.
//! * [`runtime`] — PJRT executor for the AOT-compiled JAX/Pallas gather
//!   kernels (`artifacts/*.hlo.txt`); python never runs at request time.
//! * [`net`] — the network front door over the facade: a fault-tolerant
//!   length-prefixed binary protocol plus an HTTP health/lookup channel,
//!   with explicit overload shedding and a graceful-drain lifecycle.
//! * [`workload`] — request/trace/open-loop generators; backend-agnostic
//!   clients of the facade (local or remote via [`net::RemotePool`]).
//! * [`experiments`] — one driver per paper figure.
//!
//! ## Concurrency verification
//!
//! The lock-free serving primitives take their synchronization from the
//! [`util::sync`] shim: plain `std` types in normal builds, the in-tree
//! `interleave` model checker under `--features model`. The models live in
//! `src/verify.rs`; the `palint` tool (`cargo run -p palint`) gates the
//! `unsafe`/`Ordering::Relaxed`/panic/hot-path-allocation conventions.

// Every `unsafe` operation inside an `unsafe fn` must be written in an
// explicit `unsafe { }` block, and every such block carries a `// SAFETY:`
// comment (also enforced by palint rule R1).
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod net;
pub mod probe;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod util;
pub mod workload;

// Model-checked proofs for the five riskiest lock-free primitives; compiled
// only under `--features model` (EXPERIMENTS.md §Verify).
#[cfg(feature = "model")]
mod verify;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Convenient re-exports for examples and benches.
pub mod prelude {
    pub use crate::config::{MachineConfig, GIB, LINE_BYTES};
    pub use crate::coordinator::adaptive::{AdaptiveConfig, AdaptivePlacer};
    pub use crate::coordinator::controlplane::{ControlPlane, ControlPlaneConfig, Lever};
    pub use crate::coordinator::placement::{Placer, PlacementPolicy, StaticPlacer};
    pub use crate::coordinator::replan::{PlanSplitter, SplitterConfig};
    pub use crate::coordinator::table::{Table, TableView};
    pub use crate::net::{
        ClientConfig, NetClient, NetConfig, NetFaultPlan, NetServer, RemotePool, Target,
    };
    pub use crate::probe::{report::TopologyMap, Prober};
    pub use crate::service::{
        Backend, FleetConfig, FleetService, GlobalAdmission, Service, SessionConfig,
        SimBackend, SimBackendConfig, SimTiming, Ticket, TicketState,
    };
    pub use crate::sim::{
        Machine, Measurement, MeasurementSpec, MemRegion, Pattern, SmAssignment,
    };
}

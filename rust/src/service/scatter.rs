//! Slab output buffers with single-copy scatter.
//!
//! The serving data path used to pay two copies per row — worker gathers
//! into a fresh `Vec<f32>`, then copies again into a `Mutex<Vec<f32>>`
//! request accumulator.  [`ScatterBuf`] removes both: workers write each
//! gathered row *directly* into the request's output buffer at its final
//! position, with no lock, because the router guarantees the positions of
//! different sub-batches are disjoint (every request position lands in
//! exactly one sub-batch — the same invariant the ordered-merge property
//! test pins).  That makes concurrent `write_row` calls from different
//! workers race-free by construction; debug builds additionally claim each
//! position in an atomic bitmap and panic on any alias.
//!
//! Buffers come from a [`SlabPool`] and retain their capacity: a caller
//! that returns finished results via `Service::recycle` makes the
//! steady-state output path allocation-free (EXPERIMENTS.md §Perf L4).

use std::sync::Arc;

use crate::util::sync::{AtomicBool, AtomicU8, AtomicUsize, CellSlot, Mutex, Ordering};

/// Pooled, capacity-retaining `Vec<f32>` slabs for request outputs.
///
/// The free list is **striped**: several independent mutexed lists, with
/// gets and puts spread round-robin so concurrent submitters and the
/// dispatcher's recycle loop rarely contend on the same lock.  A get whose
/// home stripe is empty *steals* — it scans the remaining stripes before
/// giving up and allocating — so striping never costs a pooled slab, only
/// a little lock locality.  Each stripe carries `1/n` of the global count
/// and byte budgets, keeping the total bound unchanged.
///
/// Under `--features model` the default collapses to a single stripe so
/// the model checker's state space stays where PR-7 tuned it; the
/// steal path itself is modeled explicitly over a two-stripe pool
/// (`verify::slab_pool_*`).
#[derive(Debug)]
pub(crate) struct SlabPool {
    /// Striped free lists: slabs plus each stripe's retained capacity in
    /// floats (both bounds checked on put).
    stripes: Box<[Mutex<(Vec<Vec<f32>>, usize)>]>,
    /// Round-robin cursor spreading traffic across stripes.
    next: AtomicUsize,
    /// Per-stripe count bound (global bound / stripes).
    stripe_slabs: usize,
    /// Per-stripe float bound (global bound / stripes).
    stripe_floats: usize,
    /// Buffers minted from this pool track per-slot completion state even
    /// in release builds, enabling [`ScatterBuf::take_partial`].  Set when
    /// the backend serves partial results; costs one `AtomicU8` per row.
    claims: bool,
}

/// Free-list count bound across all stripes: beyond this the put is
/// dropped (the allocator takes the slab back).  Sized to comfortably
/// cover the default admission budgets.
const MAX_POOLED: usize = 256;

/// Free-list *byte* bound across all stripes (in f32 elements, 64 MiB): a
/// burst of huge requests must not pin count × largest-request memory for
/// the life of the backend.
const MAX_POOLED_FLOATS: usize = 16 << 20;

/// Default stripe count (normal builds).  Eight covers the contention the
/// serve bench sees (submitters × dispatcher) without fragmenting the
/// byte budget into uselessly small stripes.
const DEFAULT_STRIPES: usize = 8;

impl Default for SlabPool {
    fn default() -> Self {
        Self::build(Self::default_stripes(), false)
    }
}

impl SlabPool {
    /// One stripe under the model feature (bounded state space for the
    /// PR-7 completion/scatter models), [`DEFAULT_STRIPES`] otherwise.
    fn default_stripes() -> usize {
        if cfg!(feature = "model") {
            1
        } else {
            DEFAULT_STRIPES
        }
    }

    fn build(stripes: usize, claims: bool) -> Self {
        let stripes = stripes.max(1);
        Self {
            stripes: (0..stripes)
                .map(|_| Mutex::new((Vec::new(), 0)))
                .collect(),
            next: AtomicUsize::new(0),
            stripe_slabs: (MAX_POOLED / stripes).max(1),
            stripe_floats: (MAX_POOLED_FLOATS / stripes).max(1),
            claims,
        }
    }

    pub(crate) fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// A pool whose buffers carry per-slot claim state when `claims` is
    /// set (required for partial results; debug builds claim regardless).
    pub(crate) fn with_claims(claims: bool) -> Arc<Self> {
        Arc::new(Self::build(Self::default_stripes(), claims))
    }

    /// A pool with an explicit stripe count — the concurrency models pin
    /// the steal path over exactly two stripes, and the bounds tests pin
    /// the budget math over one.
    #[cfg(test)]
    pub(crate) fn with_stripes(stripes: usize) -> Arc<Self> {
        Arc::new(Self::build(stripes, false))
    }

    /// A buffer of exactly `len` elements.  Reuses a pooled slab's
    /// capacity when one is available — from the home stripe, else stolen
    /// from any other; a reused slab keeps its previous request's prefix
    /// contents (shrinking truncates for free, growing zero-fills only the
    /// delta beyond the old length).  Stale data is unobservable because
    /// [`ScatterBuf`]'s contract is that the writers cover every position
    /// before the buffer surfaces — the disjointness property test pins
    /// exactly that.
    pub(crate) fn get(&self, len: usize) -> Vec<f32> {
        let n = self.stripes.len();
        // RELAXED: the cursor only spreads traffic; list contents are
        // ordered by each stripe's mutex, not by this counter.
        let home = self.next.fetch_add(1, Ordering::Relaxed) % n;
        let mut buf = Vec::new();
        for k in 0..n {
            let mut stripe = self.stripes[(home + k) % n].lock().unwrap();
            if let Some(b) = stripe.0.pop() {
                stripe.1 -= b.capacity();
                buf = b;
                break;
            }
        }
        buf.resize(len, 0.0);
        buf
    }

    /// Return a buffer's capacity to the pool (round-robin stripe; a full
    /// stripe drops the slab rather than overflowing into a sibling —
    /// the budgets are per-stripe by construction).
    pub(crate) fn put(&self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        let n = self.stripes.len();
        // RELAXED: see `get` — distribution only.
        let home = self.next.fetch_add(1, Ordering::Relaxed) % n;
        let mut stripe = self.stripes[home].lock().unwrap();
        if stripe.0.len() < self.stripe_slabs && stripe.1 + buf.capacity() <= self.stripe_floats {
            stripe.1 += buf.capacity();
            stripe.0.push(buf);
        }
    }

    #[cfg(test)]
    pub(crate) fn pooled(&self) -> usize {
        self.stripes
            .iter()
            .map(|s| s.lock().unwrap().0.len())
            .sum()
    }
}

/// One request's output buffer, written in place by the workers.
///
/// Safety model: the buffer is logically partitioned into `rows` slots of
/// `d` floats.  [`ScatterBuf::write_row`] writes one slot; the router
/// invariant (each request position appears in exactly one sub-batch,
/// exactly once) means no two writes — from any threads — touch the same
/// slot, so plain raw-pointer copies are race-free.  The release/acquire
/// chain of the request's sub-batch countdown orders every write before
/// the final [`ScatterBuf::take`].  Debug builds verify the invariant at
/// runtime with an atomic claim per slot.
///
/// Model checking (`--features model`, `verify::scatter_*`): the claim
/// bitmap is exercised under concurrent duplicate writes — the PR-6
/// hedging race — and the checker's `RaceCell` flags any interleaving
/// where a data write could alias.
pub(crate) struct ScatterBuf {
    data: CellSlot<Vec<f32>>,
    /// Total floats (= rows * d).
    len: usize,
    /// Floats per row slot.
    d: usize,
    taken: AtomicBool,
    pool: Arc<SlabPool>,
    /// Per-row slot state (empty → writing → done).  Always present in
    /// debug builds (the alias assertion); present in release only for
    /// pools with claims on, where the `done` state is what makes a
    /// partial delivery's validity mask exact.
    slots: Option<Box<[AtomicU8]>>,
}

/// Slot states: no write started / a writer is mid-copy / the row's write
/// completed (its `Release` store pairs with `take_partial`'s `Acquire`).
const SLOT_EMPTY: u8 = 0;
const SLOT_WRITING: u8 = 1;
const SLOT_DONE: u8 = 2;

// SAFETY: `data` is the only non-Sync field; it is written through raw
// pointers only under the disjoint-slot contract above (distinct `write_row`
// positions touch disjoint ranges; move-out is gated by the `taken` swap),
// so sending the buffer or sharing `&ScatterBuf` across threads is sound.
unsafe impl Send for ScatterBuf {}
// SAFETY: see the Send impl — shared access is what the slot partition and
// the `taken` flag are designed to make race-free.
unsafe impl Sync for ScatterBuf {}

impl ScatterBuf {
    /// Take a `rows * d` buffer from the pool.
    pub(crate) fn new(pool: &Arc<SlabPool>, rows: usize, d: usize) -> Self {
        assert!(d > 0, "row width must be positive");
        let len = rows * d;
        let track = cfg!(debug_assertions) || pool.claims;
        Self {
            data: CellSlot::new(pool.get(len)),
            len,
            d,
            taken: AtomicBool::new(false),
            pool: Arc::clone(pool),
            slots: track.then(|| (0..rows).map(|_| AtomicU8::new(SLOT_EMPTY)).collect()),
        }
    }

    // hotpath: begin — per-row scatter; no allocation permitted (palint R4).
    /// Write one row (`d` floats) into its final position.  Callable
    /// concurrently from many workers for *distinct* positions; aliased
    /// positions are a router-invariant violation (panics in debug).
    #[inline]
    pub(crate) fn write_row(&self, pos: usize, row: &[f32]) {
        assert_eq!(row.len(), self.d, "row width mismatch");
        let start = pos * self.d;
        assert!(start + self.d <= self.len, "position {pos} out of buffer");
        if let Some(slots) = &self.slots {
            let prev = slots[pos].swap(SLOT_WRITING, Ordering::AcqRel);
            assert!(
                prev == SLOT_EMPTY,
                "position {pos} written twice: sub-batch views alias"
            );
        }
        // SAFETY: `start + d <= len` is asserted above, and the router
        // invariant (each position in exactly one sub-batch, once) makes
        // writes from concurrent callers disjoint; the buffer cannot be
        // moved out concurrently because `take`/`discard` run only after
        // the sub-batch countdown's Release/Acquire chain orders every
        // write before them.
        unsafe {
            let base = (*self.data.get()).as_mut_ptr();
            std::ptr::copy_nonoverlapping(row.as_ptr(), base.add(start), self.d);
        }
        if let Some(slots) = &self.slots {
            slots[pos].store(SLOT_DONE, Ordering::Release);
        }
    }

    /// Scatter a sub-batch: `rows[k]` (each `d` wide) lands at
    /// `positions[k]`.
    pub(crate) fn scatter(&self, positions: &[u32], rows: &[f32]) {
        debug_assert_eq!(rows.len(), positions.len() * self.d);
        for (k, &pos) in positions.iter().enumerate() {
            self.write_row(pos as usize, &rows[k * self.d..(k + 1) * self.d]);
        }
    }
    // hotpath: end

    /// Move the filled buffer out (last-finisher only: the request's
    /// sub-batch countdown guarantees a unique caller, after all writes).
    pub(crate) fn take(&self) -> Vec<f32> {
        // PANIC: invariant, not input — the sub-batch countdown hands the
        // buffer to exactly one last finisher; a second take is a logic bug.
        self.try_take().expect("ScatterBuf taken twice")
    }

    /// Move the filled buffer out, or `None` if it was already taken
    /// (e.g. delivered early as a partial result).
    pub(crate) fn try_take(&self) -> Option<Vec<f32>> {
        if self.taken.swap(true, Ordering::AcqRel) {
            None
        } else {
            // SAFETY: the AcqRel swap on `taken` admits exactly one mover,
            // and callers invoke take/try_take only after the sub-batch
            // countdown proves all writers finished — so no `write_row`
            // pointer into the Vec is live when it is moved out.
            Some(unsafe { std::mem::take(&mut *self.data.get()) })
        }
    }

    /// Deliver what completed so far: a full-size buffer plus a per-row
    /// validity mask (`true` = that row's write finished; invalid rows are
    /// zeroed).  `None` when slot tracking is off or the buffer was
    /// already taken.
    ///
    /// The completed rows are **copied out**, never moved: outstanding
    /// sub-batches (stragglers, hedged losers) still hold raw pointers
    /// into the original allocation, which stays in place until every
    /// writer is done and the buffer drops.  Only rows whose slot reads
    /// `done` (Acquire, pairing with the writer's Release) are read, so
    /// the copy never races a mid-flight write.
    pub(crate) fn take_partial(&self) -> Option<(Vec<f32>, Vec<bool>)> {
        let slots = self.slots.as_ref()?;
        if self.taken.swap(true, Ordering::AcqRel) {
            return None;
        }
        let mut out = self.pool.get(self.len);
        let mut valid = vec![false; slots.len()];
        for (i, slot) in slots.iter().enumerate() {
            let span = i * self.d..(i + 1) * self.d;
            if slot.load(Ordering::Acquire) == SLOT_DONE {
                valid[i] = true;
                // SAFETY: only rows whose slot reads SLOT_DONE (Acquire,
                // pairing with the writer's Release store) are read, so the
                // copy never overlaps a mid-flight write; the allocation
                // stays in place (copied, not moved) for late writers.
                unsafe {
                    let base = (*self.data.get()).as_ptr().add(i * self.d);
                    std::ptr::copy_nonoverlapping(base, out[span].as_mut_ptr(), self.d);
                }
            } else {
                // The pool reuses slabs with stale contents; an invalid
                // row must read as zeros, not a previous request's data.
                out[span].fill(0.0);
            }
        }
        Some((out, valid))
    }

    /// Return the buffer to the pool without surfacing it (failure path).
    pub(crate) fn discard(&self) {
        if !self.taken.swap(true, Ordering::AcqRel) {
            // SAFETY: same unique-mover argument as `try_take` — the swap
            // on `taken` admits exactly one caller to move the Vec out.
            let buf = unsafe { std::mem::take(&mut *self.data.get()) };
            self.pool.put(buf);
        }
    }
}

impl Drop for ScatterBuf {
    fn drop(&mut self) {
        // Whatever allocation is still here goes back to the pool: the
        // un-taken case (request abandoned before completion) and the
        // partial-delivery case (taken, but the original stayed in place
        // for late writers).  `take`/`discard` leave an empty Vec behind,
        // which `put` ignores.
        self.pool.put(std::mem::take(self.data.get_mut()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::placement::{Placement, PlacementPolicy};
    use crate::coordinator::{Router, WindowPlan};
    use crate::probe::TopologyMap;
    use crate::util::prop;

    #[test]
    fn pool_retains_capacity() {
        let pool = SlabPool::new();
        let buf = pool.get(128);
        assert_eq!(buf.len(), 128);
        pool.put(buf);
        assert_eq!(pool.pooled(), 1);
        let again = pool.get(64);
        assert_eq!(again.len(), 64);
        assert!(again.capacity() >= 128, "capacity must be retained");
        assert_eq!(pool.pooled(), 0);
    }

    #[test]
    fn pool_bounds_retained_capacity_bytes() {
        // One stripe so the stripe budget *is* the global budget.
        let pool = SlabPool::with_stripes(1);
        // with_capacity: reserves address space without touching pages.
        pool.put(Vec::with_capacity(MAX_POOLED_FLOATS));
        assert_eq!(pool.pooled(), 1);
        pool.put(Vec::with_capacity(64));
        assert_eq!(pool.pooled(), 1, "byte budget exhausted: put must drop");
        let b = pool.get(16);
        assert!(b.capacity() >= MAX_POOLED_FLOATS);
        pool.put(Vec::with_capacity(64));
        assert_eq!(pool.pooled(), 1, "budget freed by get: small put accepted");
    }

    #[test]
    fn get_steals_from_sibling_stripes() {
        let pool = SlabPool::with_stripes(4);
        pool.put(Vec::with_capacity(128));
        assert_eq!(pool.pooled(), 1);
        // Wherever the round-robin cursor points, the lone pooled slab
        // must be found — an empty home stripe steals, never allocates.
        for _ in 0..8 {
            let b = pool.get(16);
            assert!(b.capacity() >= 128, "home-stripe miss must steal");
            pool.put(b);
        }
        assert_eq!(pool.pooled(), 1);
    }

    #[test]
    fn stripe_budgets_partition_the_global_bound() {
        let pool = SlabPool::with_stripes(4);
        // Per-stripe slab cap is MAX_POOLED / 4; pushing well past the
        // global bound must saturate at it (puts rotate stripes evenly).
        for _ in 0..MAX_POOLED * 2 {
            pool.put(Vec::with_capacity(8));
        }
        assert!(pool.pooled() <= MAX_POOLED);
        assert!(pool.pooled() >= MAX_POOLED / 2, "stripes should fill");
    }

    #[test]
    fn write_rows_land_at_positions() {
        let pool = SlabPool::new();
        let buf = ScatterBuf::new(&pool, 3, 2);
        buf.write_row(2, &[5.0, 6.0]);
        buf.write_row(0, &[1.0, 2.0]);
        buf.scatter(&[1], &[3.0, 4.0]);
        assert_eq!(buf.take(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "written twice")]
    fn aliased_position_panics_in_debug() {
        let pool = SlabPool::new();
        let buf = ScatterBuf::new(&pool, 2, 1);
        buf.write_row(1, &[1.0]);
        buf.write_row(1, &[2.0]);
    }

    #[test]
    fn take_partial_masks_missing_rows() {
        let pool = SlabPool::with_claims(true);
        let buf = ScatterBuf::new(&pool, 3, 2);
        buf.write_row(0, &[1.0, 2.0]);
        buf.write_row(2, &[5.0, 6.0]);
        let (out, valid) = buf.take_partial().expect("claims on: partial available");
        assert_eq!(valid, vec![true, false, true]);
        assert_eq!(out, vec![1.0, 2.0, 0.0, 0.0, 5.0, 6.0]);
        // The original buffer was not moved; a second take yields nothing.
        assert!(buf.try_take().is_none());
        assert!(buf.take_partial().is_none());
    }

    #[test]
    fn take_partial_zeroes_stale_pool_contents() {
        let pool = SlabPool::with_claims(true);
        // Seed the pool with a dirty slab.
        pool.put(vec![9.0f32; 8]);
        let buf = ScatterBuf::new(&pool, 4, 1);
        // Fill everything so the dirty slab is fully overwritten, then
        // partial-deliver into a *second* dirty slab.
        pool.put(vec![7.0f32; 8]);
        buf.write_row(1, &[1.5]);
        let (out, valid) = buf.take_partial().unwrap();
        assert_eq!(valid, vec![false, true, false, false]);
        assert_eq!(out, vec![0.0, 1.5, 0.0, 0.0]);
    }

    #[test]
    fn partial_taken_buffer_still_pools_on_drop() {
        let pool = SlabPool::with_claims(true);
        let buf = ScatterBuf::new(&pool, 8, 4);
        buf.write_row(0, &[1.0; 4]);
        let _ = buf.take_partial().unwrap();
        let before = pool.pooled();
        drop(buf);
        assert_eq!(
            pool.pooled(),
            before + 1,
            "the in-place original must return to the pool at drop"
        );
    }

    #[test]
    fn dropped_buffer_returns_to_pool() {
        let pool = SlabPool::new();
        drop(ScatterBuf::new(&pool, 8, 4));
        assert_eq!(pool.pooled(), 1);
        let b = ScatterBuf::new(&pool, 8, 4);
        b.discard();
        drop(b);
        assert_eq!(pool.pooled(), 1, "discard + drop must not double-pool");
    }

    /// The tentpole safety property, mirroring the router's split/merge
    /// property test: for random requests split under a random plan, the
    /// per-sub-batch views (a) never alias — each position is written at
    /// most once, which the debug claim map enforces — and (b) cover the
    /// request exactly, which writing identity payloads and checking every
    /// output slot proves.  Sub-batches are scattered from separate
    /// threads so the concurrent-writer contract is exercised, not just
    /// stated.
    #[test]
    fn property_disjoint_views_cover_exactly_and_never_alias() {
        let map = TopologyMap {
            groups: (0..4).map(|g| vec![g * 2, g * 2 + 1]).collect(),
            reach_bytes: 1 << 30,
            solo_gbps: vec![100.0; 4],
            independent: true,
            card_id: "t".into(),
        };
        // Miri interprets every raw-pointer write; a handful of iterations
        // already exercises the disjointness contract it checks for UB.
        let iters = if cfg!(miri) { 4 } else { 40 };
        prop::check("scatterbuf-disjoint-cover", iters, |g| {
            let windows = g.usize(1, 4);
            let total_rows = 8_192u64;
            let plan = WindowPlan::split(total_rows, 128, windows);
            let placement =
                Placement::build(PlacementPolicy::GroupToChunk, &map, &plan, 0).unwrap();
            let mut router = Router::new();
            let len = g.usize(1, 400);
            let rows: Vec<u64> = (0..len).map(|_| g.u64(0, total_rows - 1)).collect();
            let split = router.split(&rows, &plan, &placement);

            let d = 2usize;
            let pool = SlabPool::new();
            let buf = ScatterBuf::new(&pool, len, d);
            std::thread::scope(|s| {
                for sb in &split.sub_batches {
                    let w = plan.windows()[sb.window];
                    let buf = &buf;
                    s.spawn(move || {
                        for (k, &local) in sb.local_rows.iter().enumerate() {
                            let v = (w.start_row + local as u64) as f32;
                            buf.write_row(sb.positions[k] as usize, &[v, v]);
                        }
                    });
                }
            });
            let out = buf.take();
            assert_eq!(out.len(), len * d);
            for (i, &row) in rows.iter().enumerate() {
                assert_eq!(out[i * d], row as f32, "position {i} not covered");
                assert_eq!(out[i * d + 1], row as f32);
            }
        });
    }
}

//! Lock-light primitives for the serving hot path: a bounded SPSC work
//! ring, a one-shot park/unpark completion cell, and a spin epoch gate.
//!
//! All three are vendored-deps-only (std atomics + `thread::park`): the
//! build runs with no network registry, so `crossbeam`-style queues are
//! reimplemented at the small sizes this crate actually needs.
//!
//! * [`spsc`] — a single-producer single-consumer ring replacing the
//!   per-worker `mpsc::Sender<WorkerMsg>`: one dispatcher thread feeds one
//!   worker thread, so the general MPMC machinery (and its allocation per
//!   send) is pure overhead.  Push/pop are a slot write plus one
//!   release-store; blocking uses `park_timeout` with a Dekker-style
//!   sleeping flag (the timeout bounds the lost-wakeup window, the flag
//!   makes it rare).
//! * [`Completion`] — a one-shot result cell replacing the per-ticket
//!   `mpsc::sync_channel(1)`: the last sub-batch's atomic countdown
//!   publishes the result and unparks the waiter; redeeming a ticket costs
//!   no channel, no queue, no allocation.
//! * [`EpochGate`] — an atomic-flag mutual-exclusion gate for control-plane
//!   epochs (rare, never on the request path), replacing a `Mutex<()>`.
//!
//! ## Verification
//!
//! All synchronization here comes from the [`crate::util::sync`] shim:
//! plain `std` in normal builds, the `interleave` model checker under
//! `--features model`. `src/verify.rs` exhaustively explores the SPSC
//! send/recv handshake (including the sleeping-flag park/unpark *without*
//! the `PARK_BACKSTOP` timeout), the close/drop-drain race, the
//! `Completion` one-shot protocol, and `EpochGate` mutual exclusion.
//!
//! Ordering audit (PR 7): the Dekker handshake — store own sleeping flag,
//! then load the peer-owned queue counter; peer stores the counter, then
//! loads the flag — is `SeqCst` on all four accesses, as Dekker-style
//! mutual exclusion requires (store-buffering reordering of a
//! `Release` store past an `Acquire` load loses the wakeup). The model
//! regression `verify::dekker_handshake_requires_seqcst` re-derives this:
//! the same protocol under `Release`/`Acquire` deadlocks, under `SeqCst`
//! it passes exhaustively.

use std::mem::MaybeUninit;
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

use crate::util::sync::thread;
use crate::util::sync::thread::Thread;
use crate::util::sync::{AtomicBool, AtomicU8, AtomicUsize, CellSlot, Ordering};

use super::scatter::SlabPool;

/// Backstop for the park handshake: a lost wakeup costs at most this much
/// latency.  The SeqCst sleeping-flag protocol (set flag → re-check →
/// park, peer checks the flag after every state change) already makes the
/// unpark reliable, so this is belt-and-braces only — long enough that an
/// *idle* worker costs ~10 timer wakeups/s, not a kilohertz poll.
const PARK_BACKSTOP: Duration = Duration::from_millis(100);

// ---------------------------------------------------------------------------
// Bounded SPSC ring.
// ---------------------------------------------------------------------------

struct RingInner<T> {
    /// Power-of-two slot array; slot `i & mask` holds sequence number `i`.
    slots: Box<[CellSlot<MaybeUninit<T>>]>,
    mask: usize,
    /// Next sequence the producer writes (monotonic, wraps via `mask`).
    tail: AtomicUsize,
    /// Next sequence the consumer reads.
    head: AtomicUsize,
    closed: AtomicBool,
    /// Set by the (single) producer around each push attempt: the
    /// consumer's drop-drain spins until no push is mid-flight, so a push
    /// that raced past the close check can never strand an item.
    pushing: AtomicBool,
    /// Dekker flags: each side sets its flag, re-checks the queue, then
    /// parks; the peer checks the flag after every state change.
    cons_sleeping: AtomicBool,
    prod_sleeping: AtomicBool,
    /// Registered lazily on first blocking call from each side.
    /// Deliberately `std` even under the model: each cell has exactly one
    /// initializing thread (its own endpoint), so `get_or_init` can never
    /// block on a descheduled model thread, and the peer's `get` is a
    /// lock-free load — the shim's no-mixed-primitives rule is satisfied.
    cons_thread: OnceLock<Thread>,
    prod_thread: OnceLock<Thread>,
}

// SAFETY: the slots are only touched under the head/tail handoff protocol
// (each slot is owned by exactly one side at a time: the producer until the
// tail store publishes it, the consumer after the acquire of that store),
// so sending the ring or sharing &RingInner across the two endpoint
// threads never produces concurrent slot access. T: Send bounds both
// impls because items cross from producer to consumer thread.
unsafe impl<T: Send> Send for RingInner<T> {}
// SAFETY: see Send above; &RingInner only exposes atomics, OnceLock, and
// the protocol-guarded slots.
unsafe impl<T: Send> Sync for RingInner<T> {}

impl<T> RingInner<T> {
    fn wake_consumer(&self) {
        if self.cons_sleeping.load(Ordering::SeqCst) {
            if let Some(t) = self.cons_thread.get() {
                t.unpark();
            }
        }
    }

    fn wake_producer(&self) {
        if self.prod_sleeping.load(Ordering::SeqCst) {
            if let Some(t) = self.prod_thread.get() {
                t.unpark();
            }
        }
    }

    fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        if let Some(t) = self.cons_thread.get() {
            t.unpark();
        }
        if let Some(t) = self.prod_thread.get() {
            t.unpark();
        }
    }
}

impl<T> Drop for RingInner<T> {
    fn drop(&mut self) {
        // Both handles are gone: drain the undelivered items so their
        // payloads drop (e.g. a Job's accumulator resolves its ticket).
        let head = *self.head.get_mut();
        let tail = *self.tail.get_mut();
        for seq in head..tail {
            // SAFETY: &mut self proves both endpoints are gone, so every
            // sequence in head..tail was fully written by a completed push
            // (the tail store is the last step of a push) and never popped;
            // each slot in that range holds an initialized T exactly once.
            unsafe {
                (*self.slots[seq & self.mask].get()).assume_init_drop();
            }
        }
    }
}

/// Why a send did not complete.
#[derive(Debug)]
pub(crate) enum SendError<T> {
    /// The consumer side is gone (or the ring was closed).
    Closed(T),
    /// Non-blocking send found the ring full.
    Full(T),
}

impl<T> SendError<T> {
    pub(crate) fn into_inner(self) -> T {
        match self {
            SendError::Closed(v) | SendError::Full(v) => v,
        }
    }
}

/// Producer half (single thread).  Dropping it closes the ring.
///
/// `!Sync` (but `Send`): the slot-write protocol is only race-free with
/// one producing thread, so the type system forbids sharing a `&Producer`
/// across threads rather than leaving SPSC as a comment-level contract.
pub(crate) struct Producer<T> {
    inner: Arc<RingInner<T>>,
    _single: std::marker::PhantomData<std::cell::Cell<()>>,
}

/// Consumer half (single thread, `!Sync` like [`Producer`]).  Dropping it
/// closes the ring and fails queued items immediately.
pub(crate) struct Consumer<T> {
    inner: Arc<RingInner<T>>,
    _single: std::marker::PhantomData<std::cell::Cell<()>>,
}

/// Create a bounded SPSC ring with capacity `cap` (rounded up to a power
/// of two, minimum 2).
pub(crate) fn spsc<T: Send>(cap: usize) -> (Producer<T>, Consumer<T>) {
    let cap = cap.max(2).next_power_of_two();
    let slots = (0..cap)
        .map(|_| CellSlot::new(MaybeUninit::uninit()))
        .collect::<Vec<_>>()
        .into_boxed_slice();
    let inner = Arc::new(RingInner {
        slots,
        mask: cap - 1,
        tail: AtomicUsize::new(0),
        head: AtomicUsize::new(0),
        closed: AtomicBool::new(false),
        pushing: AtomicBool::new(false),
        cons_sleeping: AtomicBool::new(false),
        prod_sleeping: AtomicBool::new(false),
        cons_thread: OnceLock::new(),
        prod_thread: OnceLock::new(),
    });
    (
        Producer {
            inner: Arc::clone(&inner),
            _single: std::marker::PhantomData,
        },
        Consumer {
            inner,
            _single: std::marker::PhantomData,
        },
    )
}

impl<T: Send> Producer<T> {
    /// Non-blocking push.
    pub(crate) fn try_send(&self, v: T) -> Result<(), SendError<T>> {
        let inner = &self.inner;
        // Bracket the closed-check → publish window so the consumer's
        // drop-drain can wait out a racing push instead of missing it.
        inner.pushing.store(true, Ordering::SeqCst);
        let result = self.try_send_inner(v);
        inner.pushing.store(false, Ordering::SeqCst);
        result
    }

    fn try_send_inner(&self, v: T) -> Result<(), SendError<T>> {
        // hotpath: begin (no allocation between here and the publish)
        let inner = &self.inner;
        if inner.closed.load(Ordering::SeqCst) {
            return Err(SendError::Closed(v));
        }
        // RELAXED: tail is producer-owned — this thread is the only writer
        // (Producer is !Sync), so it re-reads its own last store.
        let tail = inner.tail.load(Ordering::Relaxed);
        let head = inner.head.load(Ordering::SeqCst);
        if tail.wrapping_sub(head) > inner.mask {
            return Err(SendError::Full(v));
        }
        // SAFETY: tail - head <= mask, so slot `tail & mask` is not owned
        // by the consumer (it only reads slots below tail); this producer
        // is the unique writer (single-producer contract, enforced by
        // !Sync), and the slot's previous item was already popped or never
        // written, so writing MaybeUninit here never overwrites a live T.
        unsafe {
            (*inner.slots[tail & inner.mask].get()).write(v);
        }
        inner.tail.store(tail.wrapping_add(1), Ordering::SeqCst);
        inner.wake_consumer();
        Ok(())
        // hotpath: end
    }

    /// Blocking push: parks while the ring is full; fails only when the
    /// ring is closed (consumer gone or explicit close).
    pub(crate) fn send(&self, v: T) -> Result<(), SendError<T>> {
        let mut v = v;
        loop {
            match self.try_send(v) {
                Ok(()) => return Ok(()),
                Err(SendError::Closed(x)) => return Err(SendError::Closed(x)),
                Err(SendError::Full(x)) => v = x,
            }
            let inner = &self.inner;
            inner.prod_thread.get_or_init(thread::current);
            // Dekker store side: the flag store and the head re-load below
            // must both be SeqCst — with Release/Acquire the flag store may
            // be reordered past the load (store-buffering), both sides see
            // stale state, and the wakeup is lost (model-checked by
            // verify::dekker_handshake_requires_seqcst).
            inner.prod_sleeping.store(true, Ordering::SeqCst);
            // Re-check after publishing the flag (Dekker): a pop or close
            // that raced the store will see the flag and unpark us — or we
            // see its effect here and skip parking.
            // RELAXED: tail is producer-owned (see try_send_inner).
            let tail = inner.tail.load(Ordering::Relaxed);
            let head = inner.head.load(Ordering::SeqCst);
            if tail.wrapping_sub(head) <= inner.mask || inner.closed.load(Ordering::SeqCst) {
                inner.prod_sleeping.store(false, Ordering::SeqCst);
                continue;
            }
            thread::park_timeout(PARK_BACKSTOP);
            inner.prod_sleeping.store(false, Ordering::SeqCst);
        }
    }

    /// Close the ring: the consumer drains what is queued, then sees end
    /// of stream.
    pub(crate) fn close(&self) {
        self.inner.close();
    }
}

impl<T> Drop for Producer<T> {
    fn drop(&mut self) {
        self.inner.close();
    }
}

impl<T> RingInner<T> {
    /// Consumer-side pop (callable only from the consumer handle — single
    /// consumer is the ring's contract).
    fn pop_one(&self) -> Option<T> {
        // hotpath: begin (no allocation on the pop path)
        // RELAXED: head is consumer-owned — this thread is the only writer
        // (Consumer is !Sync), so it re-reads its own last store.
        let head = self.head.load(Ordering::Relaxed);
        // SeqCst pairs with the close flag: a drain attempt after
        // observing `closed` must see every push sequenced before it.
        let tail = self.tail.load(Ordering::SeqCst);
        if head == tail {
            return None;
        }
        // SAFETY: head < tail, and the SeqCst load of tail synchronizes
        // with the producer's SeqCst store that published slot `head`, so
        // the slot holds a fully written T; this consumer is its unique
        // reader (single-consumer contract, enforced by !Sync) and the
        // head store below retires the slot before any reuse.
        let v = unsafe { (*self.slots[head & self.mask].get()).assume_init_read() };
        self.head.store(head.wrapping_add(1), Ordering::SeqCst);
        self.wake_producer();
        Some(v)
        // hotpath: end
    }
}

impl<T: Send> Consumer<T> {
    /// Non-blocking pop.  `None` means "currently empty" (closed or not).
    pub(crate) fn try_recv(&self) -> Option<T> {
        self.inner.pop_one()
    }

    /// Blocking pop: parks while empty; `None` once the ring is closed
    /// *and* drained (mirrors `mpsc::Receiver::recv`'s end of stream).
    pub(crate) fn recv(&self) -> Option<T> {
        loop {
            if let Some(v) = self.try_recv() {
                return Some(v);
            }
            let inner = &self.inner;
            if inner.closed.load(Ordering::SeqCst) {
                // Drain-after-close: one more pop attempt so items pushed
                // before the close are never lost.
                return self.try_recv();
            }
            inner.cons_thread.get_or_init(thread::current);
            // Dekker store side: SeqCst required, see Producer::send.
            inner.cons_sleeping.store(true, Ordering::SeqCst);
            // RELAXED: head is consumer-owned (see pop_one).
            let head = inner.head.load(Ordering::Relaxed);
            let tail = inner.tail.load(Ordering::SeqCst);
            if head != tail || inner.closed.load(Ordering::SeqCst) {
                inner.cons_sleeping.store(false, Ordering::SeqCst);
                continue;
            }
            thread::park_timeout(PARK_BACKSTOP);
            inner.cons_sleeping.store(false, Ordering::SeqCst);
        }
    }
}

impl<T> Drop for Consumer<T> {
    fn drop(&mut self) {
        // Close first (so new pushes fail), then drain what is queued:
        // a worker that dies must fail its pending jobs *now* — dropping
        // a Job resolves its ticket with an error — not at pipeline
        // teardown, or a deadline-less waiter would park until shutdown.
        // A push that raced past the close check holds `pushing`, so
        // spin the drain until no push is mid-flight and the ring stays
        // empty (`RingInner::drop` remains the final backstop).
        self.inner.close();
        loop {
            while self.inner.pop_one().is_some() {}
            if !self.inner.pushing.load(Ordering::SeqCst) {
                // Publish happens before `pushing` clears, so an empty
                // ring with no in-flight push is final.
                if self.inner.pop_one().is_none() {
                    break;
                }
            } else {
                thread::yield_now();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// One-shot completion cell.
// ---------------------------------------------------------------------------

const PENDING: u8 = 0;
const WAITING: u8 = 1;
const READY: u8 = 2;

/// One request's completion: the last worker publishes the result with one
/// release-store and (only if the waiter is parked) one unpark — no
/// channel, no allocation, no mutex.  Exactly one completer wins
/// ([`Completion::complete`] is first-caller-takes-it) and exactly one
/// waiter may block (the `Ticket` is an owned handle).
pub(crate) struct Completion {
    state: AtomicU8,
    /// Gate so a defensive double-complete (e.g. accumulator drop after a
    /// normal completion) never races the result cell.
    claimed: AtomicBool,
    result: CellSlot<Option<anyhow::Result<Vec<f32>>>>,
    /// Written by the (single) waiter before it CASes `state` to WAITING;
    /// read by the completer only after observing WAITING.
    waiter: CellSlot<Option<Thread>>,
    /// When set, a published-but-never-redeemed `Ok` buffer returns its
    /// capacity to this pool at drop (an expired/abandoned ticket must not
    /// leak the slab — under chaos soaks expiry is routine, not rare).
    pool: Option<Arc<SlabPool>>,
}

// SAFETY: the result cell is written once by the winning completer (the
// `claimed` CAS elects it) before the READY swap publishes it, and read
// only by the single owning ticket after an Acquire of READY; the waiter
// cell is written by the single waiter before its CAS to WAITING and read
// by the completer only after observing WAITING. Every cell access is
// therefore ordered by an atomic edge (model-checked in verify.rs).
unsafe impl Send for Completion {}
// SAFETY: see Send above.
unsafe impl Sync for Completion {}

impl Default for Completion {
    fn default() -> Self {
        Self::new()
    }
}

impl Completion {
    pub(crate) fn new() -> Self {
        Self {
            state: AtomicU8::new(PENDING),
            claimed: AtomicBool::new(false),
            result: CellSlot::new(None),
            waiter: CellSlot::new(None),
            pool: None,
        }
    }

    /// A completion whose unredeemed `Ok` buffer is pooled at drop.
    pub(crate) fn with_pool(pool: Arc<SlabPool>) -> Self {
        Self {
            pool: Some(pool),
            ..Self::new()
        }
    }

    /// Has a completer already claimed this cell?  (It may still be
    /// mid-publish; use [`Completion::try_take`] to observe the result.)
    pub(crate) fn is_claimed(&self) -> bool {
        self.claimed.load(Ordering::Acquire)
    }

    /// Publish the result and wake the waiter.  The first caller wins;
    /// later calls drop their result silently.
    pub(crate) fn complete(&self, result: anyhow::Result<Vec<f32>>) {
        if self
            .claimed
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return;
        }
        // SAFETY: the claimed CAS above elected this thread the unique
        // writer, and no reader touches the cell until the READY swap
        // below publishes it (try_take Acquire-loads READY first).
        unsafe {
            *self.result.get() = Some(result);
        }
        let prev = self.state.swap(READY, Ordering::AcqRel);
        if prev == WAITING {
            // The waiter registered its handle before CASing to WAITING;
            // the swap above synchronizes with that CAS.
            // SAFETY: observing WAITING acquires the waiter's CAS, which
            // happens after its write of the cell; the waiter never
            // touches the cell again once registered, so this read is
            // exclusive.
            if let Some(t) = unsafe { (*self.waiter.get()).take() } {
                t.unpark();
            }
        }
    }

    /// Non-blocking: take the result if it has been published.  Single
    /// consumer (the owning ticket).
    pub(crate) fn try_take(&self) -> Option<anyhow::Result<Vec<f32>>> {
        if self.state.load(Ordering::Acquire) == READY {
            // SAFETY: the Acquire of READY synchronizes with the
            // completer's AcqRel swap, which happens after its write; the
            // completer never touches the cell again after READY, and the
            // owning ticket is the single reader.
            unsafe { (*self.result.get()).take() }
        } else {
            None
        }
    }

    /// Block until the result is published or `deadline` passes.
    /// `Err(())` is the deadline; a result that arrives first always wins.
    pub(crate) fn wait(&self, deadline: Option<Instant>) -> Result<anyhow::Result<Vec<f32>>, ()> {
        let mut registered = false;
        loop {
            if let Some(r) = self.try_take() {
                return Ok(r);
            }
            let now = Instant::now();
            // 50 ms backstop: the unpark arrives immediately in practice;
            // the timeout only bounds a lost wakeup.
            let timeout = match deadline {
                Some(d) if d <= now => return Err(()),
                Some(d) => (d - now).min(Duration::from_millis(50)),
                None => Duration::from_millis(50),
            };
            if !registered {
                // SAFETY: the single waiter (owning ticket) writes its
                // handle before CASing state to WAITING; the completer
                // reads it only after observing WAITING, so the write is
                // exclusive.
                unsafe {
                    *self.waiter.get() = Some(thread::current());
                }
                match self.state.compare_exchange(
                    PENDING,
                    WAITING,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => registered = true,
                    // READY slipped in: consume on the next loop pass.
                    Err(_) => continue,
                }
            }
            thread::park_timeout(timeout);
        }
    }

    /// Rewind an exclusively-owned cell to its pristine state so the
    /// accumulator pool can reuse it for a fresh request.  The caller
    /// proved exclusivity (`Arc::get_mut`), so no waiter can be parked and
    /// no completer mid-publish: plain `get_mut` access, no atomics.  An
    /// unredeemed pooled `Ok` buffer is recycled exactly as in `Drop`.
    pub(crate) fn reset(&mut self) {
        if let Some(pool) = &self.pool {
            if *self.state.get_mut() == READY {
                if let Some(Ok(buf)) = self.result.get_mut().take() {
                    pool.put(buf);
                }
            }
        }
        *self.state.get_mut() = PENDING;
        *self.claimed.get_mut() = false;
        *self.result.get_mut() = None;
        *self.waiter.get_mut() = None;
    }
}

impl Drop for Completion {
    fn drop(&mut self) {
        // A ticket abandoned after its result was published (deadline
        // expiry, caller dropped the handle) would otherwise free the
        // output slab instead of recycling it.
        if let Some(pool) = &self.pool {
            if *self.state.get_mut() == READY {
                if let Some(Ok(buf)) = self.result.get_mut().take() {
                    pool.put(buf);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Epoch gate.
// ---------------------------------------------------------------------------

/// Atomic-flag mutual exclusion for control-plane epochs.  Epochs are
/// rare (timer ticks and health transitions, never the request path), so
/// an atomic gate is cheaper than a mutex and keeps the serving structs
/// free of poisoning.  An epoch can be *long* (a fleet migration rebuilds
/// card backends), so contenders back off to short sleeps after a few
/// yields rather than busy-spinning a core for the whole rebuild.
#[derive(Debug, Default)]
pub(crate) struct EpochGate(AtomicBool);

pub(crate) struct EpochGuard<'a>(&'a AtomicBool);

impl EpochGate {
    pub(crate) fn new() -> Self {
        Self(AtomicBool::new(false))
    }

    /// Acquire the gate: a few yields, then sleep-backoff until free.
    pub(crate) fn lock(&self) -> EpochGuard<'_> {
        let mut attempts = 0u32;
        while self
            .0
            .compare_exchange_weak(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            attempts += 1;
            if attempts < 16 {
                thread::yield_now();
            } else {
                // Epochs can be seconds-long (a fleet migration rebuilds
                // card backends): back off to a coarse sleep so the rare
                // contender (timer thread vs. a manual epoch) costs a few
                // hundred wakeups/s, not a spinning core.
                thread::sleep(Duration::from_millis(5));
            }
        }
        EpochGuard(&self.0)
    }
}

impl Drop for EpochGuard<'_> {
    fn drop(&mut self) {
        self.0.store(false, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fifo_order_single_thread() {
        let (tx, rx) = spsc::<u32>(4);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        assert_eq!(rx.try_recv(), Some(1));
        tx.try_send(3).unwrap();
        assert_eq!(rx.try_recv(), Some(2));
        assert_eq!(rx.try_recv(), Some(3));
        assert!(rx.try_recv().is_none());
    }

    #[test]
    fn full_ring_rejects_then_accepts() {
        let (tx, rx) = spsc::<u32>(2);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        assert!(matches!(tx.try_send(3), Err(SendError::Full(3))));
        assert_eq!(rx.try_recv(), Some(1));
        tx.try_send(3).unwrap();
        assert_eq!(rx.try_recv(), Some(2));
        assert_eq!(rx.try_recv(), Some(3));
    }

    #[test]
    fn close_drains_then_ends() {
        let (tx, rx) = spsc::<u32>(8);
        tx.send(7).unwrap();
        tx.send(8).unwrap();
        tx.close();
        assert!(matches!(tx.try_send(9), Err(SendError::Closed(9))));
        assert_eq!(rx.recv(), Some(7));
        assert_eq!(rx.recv(), Some(8));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn consumer_drop_closes_producer() {
        let (tx, rx) = spsc::<u32>(2);
        drop(rx);
        assert!(matches!(tx.send(1), Err(SendError::Closed(1))));
    }

    #[test]
    fn consumer_drop_fails_queued_items_immediately() {
        // A dead worker must resolve its queued jobs' tickets now, not at
        // pipeline teardown: the consumer drop alone reclaims the queue.
        let item = Arc::new(());
        let (tx, rx) = spsc::<Arc<()>>(4);
        tx.try_send(Arc::clone(&item)).unwrap();
        drop(rx);
        assert_eq!(Arc::strong_count(&item), 1, "queued item must drop with rx");
        drop(tx);
    }

    #[test]
    fn dropped_ring_drops_undelivered_items() {
        let item = Arc::new(());
        let (tx, rx) = spsc::<Arc<()>>(4);
        tx.try_send(Arc::clone(&item)).unwrap();
        tx.try_send(Arc::clone(&item)).unwrap();
        drop(tx);
        drop(rx);
        assert_eq!(Arc::strong_count(&item), 1, "ring must drop queued items");
    }

    /// Loom-style seeded interleaving test: a producer and consumer run
    /// concurrently with pseudo-random yield/sleep points drawn from a
    /// seeded RNG, across several seeds, and the consumer must observe
    /// exactly the produced sequence in order (blocking on both full and
    /// empty along the way — the ring is much smaller than the stream).
    #[test]
    fn seeded_interleavings_preserve_fifo_and_lose_nothing() {
        // Miri executes every access through its interpreter (~1000x
        // slower) but checks each one for UB, so a short stream already
        // buys the full protocol coverage; native runs keep the long one.
        let (seeds, n): (u64, u64) = if cfg!(miri) { (2, 60) } else { (8, 2_000) };
        for seed in 0..seeds {
            let (tx, rx) = spsc::<u64>(4);
            let producer = std::thread::spawn(move || {
                let mut rng = Rng::seed_from_u64(seed);
                for i in 0..n {
                    if rng.gen_bool(0.05) {
                        std::thread::yield_now();
                    }
                    if !cfg!(miri) && rng.gen_bool(0.002) {
                        std::thread::sleep(Duration::from_micros(50));
                    }
                    tx.send(i).unwrap();
                }
                tx.close();
            });
            let mut rng = Rng::seed_from_u64(seed ^ 0xDEAD);
            let mut expect = 0u64;
            while let Some(v) = rx.recv() {
                assert_eq!(v, expect, "seed {seed}: out of order or lost");
                expect += 1;
                if rng.gen_bool(0.05) {
                    std::thread::yield_now();
                }
                if !cfg!(miri) && rng.gen_bool(0.002) {
                    std::thread::sleep(Duration::from_micros(50));
                }
            }
            assert_eq!(expect, n, "seed {seed}: stream ended early");
            producer.join().unwrap();
        }
    }

    #[test]
    fn completion_immediate_and_waited() {
        let c = Completion::new();
        assert!(c.try_take().is_none());
        c.complete(Ok(vec![1.0]));
        assert_eq!(c.try_take().unwrap().unwrap(), vec![1.0]);
        // Double-complete: first writer won; the cell is now consumed.
        c.complete(Ok(vec![2.0]));
        assert!(c.try_take().is_none());
    }

    #[test]
    fn completion_wakes_parked_waiter() {
        let c = Arc::new(Completion::new());
        let c2 = Arc::clone(&c);
        let t = std::thread::spawn(move || c2.wait(None).unwrap().unwrap());
        std::thread::sleep(Duration::from_millis(20));
        c.complete(Ok(vec![3.0, 4.0]));
        assert_eq!(t.join().unwrap(), vec![3.0, 4.0]);
    }

    #[test]
    fn completion_deadline_expires_but_result_wins_races() {
        let c = Completion::new();
        let deadline = Instant::now() + Duration::from_millis(10);
        assert!(c.wait(Some(deadline)).is_err(), "no result: must expire");
        // A result that arrived first always wins, even past the deadline.
        let c = Completion::new();
        c.complete(Ok(vec![9.0]));
        let past = Instant::now() - Duration::from_millis(1);
        assert_eq!(c.wait(Some(past)).unwrap().unwrap(), vec![9.0]);
    }

    #[test]
    fn completion_drop_pools_unredeemed_result() {
        let pool = SlabPool::new();
        let c = Completion::with_pool(Arc::clone(&pool));
        c.complete(Ok(pool.get(64)));
        drop(c); // published but never redeemed: slab must return
        assert_eq!(pool.pooled(), 1);
        // A redeemed completion leaves nothing behind...
        let c = Completion::with_pool(Arc::clone(&pool));
        c.complete(Ok(pool.get(64)));
        let buf = c.try_take().unwrap().unwrap();
        drop(c);
        assert_eq!(pool.pooled(), 0);
        pool.put(buf);
        // ...and an Err result has no buffer to recycle.
        let c = Completion::with_pool(Arc::clone(&pool));
        c.complete(Err(anyhow::anyhow!("boom")));
        drop(c);
        assert_eq!(pool.pooled(), 1);
    }

    #[test]
    fn epoch_gate_mutual_exclusion() {
        let gate = Arc::new(EpochGate::new());
        let counter = Arc::new(AtomicUsize::new(0));
        let (threads, rounds) = if cfg!(miri) { (3, 40) } else { (4, 1_000) };
        let mut handles = Vec::new();
        for _ in 0..threads {
            let gate = Arc::clone(&gate);
            let counter = Arc::clone(&counter);
            handles.push(std::thread::spawn(move || {
                for _ in 0..rounds {
                    let _g = gate.lock();
                    // Non-atomic-looking increment under the gate: racy
                    // unless the gate excludes.
                    let v = counter.load(Ordering::Relaxed);
                    counter.store(v + 1, Ordering::Relaxed);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(counter.load(Ordering::Relaxed), threads * rounds);
    }
}

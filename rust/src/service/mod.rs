//! **The crate's front door**: an async, ticketed serving facade over
//! interchangeable execution backends.
//!
//! The paper's payoff is a *serving* construction — probe the card's SM
//! resource groups, pin each group to a sub-reach window, and random
//! lookups over the entire memory run at full speed.  This module turns
//! the whole "probe → map → place → serve" pipeline into one API:
//!
//! ```text
//!  Session (admission control) ─┐
//!  Session ... ─────────────────┤
//!                               ▼
//!                           Service ── submit(rows, deadline) → Ticket
//!                               │
//!                        Backend trait
//!                     ┌─────────┴──────────┐
//!                SimBackend          EmbeddingServer      FleetService
//!              (sim::Machine,          (PJRT, AOT        (FleetPlan over
//!               hermetic, no           artifacts)         several probed
//!               artifacts)                                cards)
//! ```
//!
//! * [`Backend`] — `submit(Batch) -> Ticket`, `poll`/`wait`, `shutdown`.
//!   Two implementations: the hermetic [`SimBackend`] (gathers on the host,
//!   device cost from the discrete-event [`crate::sim::Machine`]) and the
//!   PJRT [`crate::coordinator::EmbeddingServer`] (AOT gather artifacts).
//! * [`Service`] — ticketed async submission.  No per-request blocking:
//!   `submit` returns a [`Ticket`] carrying an optional deadline; redeem it
//!   with `wait` (deadline-aware) or check it with `poll`.
//! * [`Session`] — per-tenant admission control: an in-flight budget with
//!   reject-or-queue overload handling, surfaced in
//!   [`Metrics`](crate::coordinator::Metrics); [`GlobalAdmission`] adds a
//!   cross-tenant budget with weighted fair sharing on top.
//! * [`FleetService`] — the same facade over several probed cards via
//!   [`crate::coordinator::FleetPlan`], merging rows in request order —
//!   each card serves a zero-copy
//!   [`TableView`](crate::coordinator::TableView) of the one shared table.
//!
//! ```no_run
//! use std::sync::Arc;
//! use a100win::prelude::*;
//! use a100win::coordinator::{Table, WindowPlan};
//! use a100win::service::{Service, SimBackend, SimBackendConfig, SimTiming};
//!
//! let machine = Machine::new(MachineConfig::a100_80gb()).unwrap();
//! let map = TopologyMap::ground_truth(&machine);        // or probe + load
//! let table = Table::synthetic(1 << 16, 32);
//! let plan = WindowPlan::split(table.rows, 128, 2);
//! let backend = SimBackend::start(
//!     SimBackendConfig::new(PlacementPolicy::GroupToChunk),
//!     &map, plan, table.view(), SimTiming::machine(machine),
//! ).unwrap();
//! let service = Service::new(Arc::new(backend));
//! let ticket = service.submit(Arc::new(vec![7, 99, 12345]), None).unwrap();
//! let rows = ticket.wait().unwrap();                    // 3 * 32 f32s
//! service.shutdown();
//! ```
//!
//! The open-loop load generator ([`crate::workload::openloop`]) is a
//! backend-agnostic client of this facade; `a100win serve --backend sim`
//! and `a100win bench-serve` drive it from the CLI.

pub mod backend;
pub mod fleet;
pub mod rebalance;
pub mod resilience;
pub(crate) mod ring;
pub(crate) mod scatter;
pub mod session;
pub mod sim_backend;

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::{Metrics, MetricsSnapshot};

pub use backend::{Backend, Batch, Outcome, Ticket, TicketState};
pub use fleet::{FleetConfig, FleetService, FleetTicket};
pub use rebalance::{FleetRebalancer, MigrationProposal, RebalanceConfig};
pub use resilience::{BreakerConfig, BreakerState, HedgeConfig, ResilienceConfig, RetryPolicy};
pub use session::{
    GlobalAdmission, OverloadPolicy, Session, SessionConfig, SessionStats, TenantShare,
};
pub use sim_backend::{GroupSimReport, SimBackend, SimBackendConfig, SimTiming};

/// The serving facade: a cheaply clonable handle over one backend.
///
/// All clones (and the [`Session`]s minted from them) share the backend
/// and its metrics registry.
#[derive(Clone)]
pub struct Service {
    backend: Arc<dyn Backend>,
    metrics: Arc<Metrics>,
}

impl Service {
    pub fn new(backend: Arc<dyn Backend>) -> Self {
        let metrics = backend.metrics_handle();
        Self { backend, metrics }
    }

    /// Ticketed async submission.  `deadline` bounds the whole request:
    /// expired tickets fail at `wait`/`poll`, and the dispatcher culls
    /// requests whose deadline passed before execution.
    pub fn submit(
        &self,
        rows: Arc<Vec<u64>>,
        deadline: Option<Duration>,
    ) -> anyhow::Result<Ticket> {
        self.backend.submit(Batch {
            rows,
            deadline: deadline.map(|d| Instant::now() + d),
        })
    }

    /// Blocking convenience: submit + wait.
    pub fn lookup(&self, rows: Arc<Vec<u64>>) -> anyhow::Result<Vec<f32>> {
        self.submit(rows, None)?.wait()
    }

    /// Return a redeemed result buffer's capacity to the backend's output
    /// slab pool.  Optional: cooperating callers (bench harnesses, the
    /// open-loop driver) make the steady-state output path allocation-free;
    /// dropping the `Vec` instead is always correct.
    pub fn recycle(&self, buf: Vec<f32>) {
        self.backend.recycle(buf);
    }

    /// Mint a per-tenant session with its own admission budget.
    pub fn session(&self, tenant: &str, cfg: SessionConfig) -> Session {
        Session::new(self.clone(), tenant, cfg)
    }

    /// Mint a per-tenant session that additionally draws on a shared
    /// cross-tenant [`GlobalAdmission`] budget with weighted fair sharing:
    /// `weight` reserves this tenant's guaranteed slice of the global
    /// in-flight total.  Sessions under the same tenant name share one
    /// reservation (refcounted — it is released when the last one drops,
    /// with the latest `weight` winning).  Denials are counted in
    /// [`Metrics::global_rejected`](crate::coordinator::Metrics).
    pub fn session_with_budget(
        &self,
        tenant: &str,
        cfg: SessionConfig,
        global: &Arc<GlobalAdmission>,
        weight: f64,
    ) -> Session {
        Session::with_global(self.clone(), tenant, cfg, global, weight)
    }

    /// Row width (f32 elements per row).
    pub fn d(&self) -> usize {
        self.backend.d()
    }

    /// Rows in the served table.
    pub fn rows(&self) -> u64 {
        self.backend.rows()
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.backend.metrics()
    }

    pub(crate) fn metrics_handle(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// The backend, for implementation-specific reporting (e.g.
    /// [`SimBackend::sim_report`]).
    pub fn backend(&self) -> &Arc<dyn Backend> {
        &self.backend
    }

    /// Drain and stop the backend (idempotent).
    pub fn shutdown(&self) {
        self.backend.shutdown();
    }
}

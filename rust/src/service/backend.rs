//! The backend contract of the serving facade, plus the request plumbing
//! shared by every backend implementation.
//!
//! A [`Backend`] turns a [`Batch`] of global row indices into a [`Ticket`]
//! immediately — no per-request blocking — and resolves the ticket with the
//! gathered rows when its workers finish.  Two implementations exist:
//!
//! * [`crate::coordinator::EmbeddingServer`] — the PJRT path: per-group
//!   worker threads executing AOT gather artifacts (needs `make artifacts`
//!   and a real `xla` crate).
//! * [`crate::service::SimBackend`] — the hermetic path: host-side gathers
//!   timed by the discrete-event [`crate::sim::Machine`], so every serving
//!   scenario runs under tier-1 with no artifacts.
//!
//! Both share the same internal shape (batcher → dispatcher →
//! [`Router`](crate::coordinator::Router) split → per-group workers →
//! ordered merge), so the split/accumulate/respond machinery lives here:
//! [`RequestAcc`], [`Job`], [`dispatch_formed`] and [`submit_ticketed`].
//!
//! **The hot path is allocation-free and lock-light after warmup**
//! (EXPERIMENTS.md §Perf L4): request outputs come from a pooled
//! [`SlabPool`] slab that workers scatter into *directly* over disjoint
//! row ranges ([`ScatterBuf`] — no per-job gather `Vec`, no accumulator
//! mutex); jobs travel over bounded SPSC [`ring`]s (one dispatcher → one
//! worker each) whose emptied index shells ride a return ring back to the
//! router's pool; and a request completes with one atomic countdown plus a
//! park/unpark [`Completion`] instead of a `sync_channel` per ticket.  The
//! pre-slab pipeline (mutexed accumulator + mpsc channels + per-job gather
//! `Vec`) is retained behind [`DataPath::Legacy`] as the
//! `benches/serve_hotpath.rs --legacy-path` oracle.
//!
//! The countdown + completion protocol takes its atomics and locks from
//! the `util::sync` shim and is model-checked under `--features model`
//! (`verify::completion_*`): every interleaving of N workers'
//! `finish_part` countdowns against a parked waiter is explored.

use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::util::sync::{AtomicUsize, Mutex, Ordering};

use anyhow::{anyhow, Context};

use crate::coordinator::batcher::{BatchWait, Batcher};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::placement::{Placement, PlacementCell};
use crate::coordinator::remap::{RemapPlan, WindowRemap};
use crate::coordinator::router::Router;
use crate::coordinator::table::TableView;

use super::resilience::{PartToken, ResMsg, ResilienceCtx};
use super::ring::{self, Completion};
use super::scatter::{ScatterBuf, SlabPool};
use super::session::{GlobalSlotGuard, SlotGuard};

/// One submission: shared row indices plus an optional completion deadline.
///
/// Indices travel by `Arc` end to end (caller → batcher → router), so a
/// caller that keeps a handle for verification pays one refcount bump, not
/// a `Vec` clone per request.
#[derive(Debug, Clone)]
pub struct Batch {
    pub rows: Arc<Vec<u64>>,
    pub deadline: Option<Instant>,
}

impl Batch {
    pub fn new(rows: Arc<Vec<u64>>) -> Self {
        Self {
            rows,
            deadline: None,
        }
    }

    /// A batch that must complete within `budget` of now.
    pub fn with_deadline(rows: Arc<Vec<u64>>, budget: Duration) -> Self {
        Self {
            rows,
            deadline: Some(Instant::now() + budget),
        }
    }
}

/// Observable state of an in-flight [`Ticket`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TicketState {
    /// Still in the backend; the deadline (if any) has not passed.
    Pending,
    /// The result (or a backend error) is available; `wait` will not block.
    Ready,
    /// The deadline passed before the result arrived.
    Expired,
}

/// What a deadline-aware redemption can deliver: everything, or — when the
/// backend serves partial results
/// ([`ResilienceConfig::partials`](super::ResilienceConfig)) — whatever
/// completed before the request failed or expired, with a per-row validity
/// mask.  Redeem with [`Ticket::wait_outcome`]; plain [`Ticket::wait`]
/// keeps the all-or-nothing contract.
#[derive(Debug, Clone, PartialEq)]
pub enum Outcome {
    /// Every requested row, in request order.
    Full(Vec<f32>),
    /// Graceful degradation: `rows` is full-size (request length × d), but
    /// only positions with `valid[i] == true` carry data (others are
    /// zeroed).
    Partial { rows: Vec<f32>, valid: Vec<bool> },
}

impl Outcome {
    /// The delivered buffer, discarding the mask.
    pub fn into_rows(self) -> Vec<f32> {
        match self {
            Outcome::Full(rows) => rows,
            Outcome::Partial { rows, .. } => rows,
        }
    }

    pub fn is_partial(&self) -> bool {
        matches!(self, Outcome::Partial { .. })
    }
}

/// Legacy response channel (capacity 1: one response per request, so a
/// worker send never blocks).  Only the [`DataPath::Legacy`] oracle uses
/// it; the default path completes through a [`Completion`].
pub(crate) type ResponseTx = mpsc::SyncSender<anyhow::Result<Vec<f32>>>;

/// How a ticket observes its result.
enum TicketInner {
    /// Already resolved at submit (e.g. the empty request) — channel-free.
    Done,
    /// Default path: the request accumulator's completion cell.
    Slot(Arc<Completion>),
    /// Legacy oracle path: a one-shot channel.
    Channel(mpsc::Receiver<anyhow::Result<Vec<f32>>>),
}

/// A claim on one in-flight request.  Tickets carry their deadline;
/// [`Ticket::wait`] returns an error (and counts `Metrics::expired`) if the
/// result does not arrive in time.  Dropping a ticket abandons the request
/// (the backend still completes it; the response is discarded).
pub struct Ticket {
    inner: TicketInner,
    deadline: Option<Instant>,
    submitted: Instant,
    buffered: Option<anyhow::Result<Vec<f32>>>,
    metrics: Arc<Metrics>,
    /// Admission-control slot released when the ticket resolves or drops.
    pub(crate) slot: Option<SlotGuard>,
    /// Cross-tenant budget slot (weighted fair sharing), same lifecycle.
    pub(crate) global_slot: Option<GlobalSlotGuard>,
    /// Partial-result source: when the backend serves partials, the ticket
    /// keeps a handle on its accumulator so [`Ticket::wait_outcome`] can
    /// salvage completed rows after a failure or deadline expiry.
    pub(crate) partial: Option<Arc<RequestAcc>>,
}

impl std::fmt::Debug for Ticket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Ticket")
            .field("deadline", &self.deadline)
            .field("age", &self.age())
            .field("buffered", &self.buffered.is_some())
            .field("admission_slot", &self.slot.is_some())
            .finish()
    }
}

impl Ticket {
    fn with_inner(inner: TicketInner, deadline: Option<Instant>, metrics: Arc<Metrics>) -> Self {
        Self {
            inner,
            deadline,
            submitted: Instant::now(),
            buffered: None,
            metrics,
            slot: None,
            global_slot: None,
            partial: None,
        }
    }

    /// A ticket completed by a [`Completion`] cell (default path).
    pub(crate) fn from_completion(
        done: Arc<Completion>,
        deadline: Option<Instant>,
        metrics: Arc<Metrics>,
    ) -> Self {
        Self::with_inner(TicketInner::Slot(done), deadline, metrics)
    }

    /// A ticket completed over a one-shot channel (legacy oracle path).
    pub(crate) fn new(
        rx: mpsc::Receiver<anyhow::Result<Vec<f32>>>,
        deadline: Option<Instant>,
        metrics: Arc<Metrics>,
    ) -> Self {
        Self::with_inner(TicketInner::Channel(rx), deadline, metrics)
    }

    /// A ticket that is already resolved (e.g. the empty request) — no
    /// channel, no completion cell, nothing to wait on.
    pub(crate) fn resolved(result: anyhow::Result<Vec<f32>>, metrics: Arc<Metrics>) -> Self {
        let mut t = Self::with_inner(TicketInner::Done, None, metrics);
        t.buffered = Some(result);
        t
    }

    pub fn deadline(&self) -> Option<Instant> {
        self.deadline
    }

    /// Time since submission.
    pub fn age(&self) -> Duration {
        self.submitted.elapsed()
    }

    /// Non-blocking progress check.
    pub fn poll(&mut self) -> TicketState {
        if self.buffered.is_some() {
            return TicketState::Ready;
        }
        let got = match &mut self.inner {
            TicketInner::Done => Some(Err(anyhow!("resolved ticket already redeemed"))),
            TicketInner::Slot(done) => done.try_take(),
            TicketInner::Channel(rx) => match rx.try_recv() {
                Ok(r) => Some(r),
                Err(mpsc::TryRecvError::Empty) => None,
                Err(mpsc::TryRecvError::Disconnected) => {
                    Some(Err(anyhow!("backend dropped the request")))
                }
            },
        };
        match got {
            Some(r) => {
                self.buffered = Some(r);
                TicketState::Ready
            }
            None => {
                if self.deadline.is_some_and(|d| Instant::now() >= d) {
                    TicketState::Expired
                } else {
                    TicketState::Pending
                }
            }
        }
    }

    /// Redeem the ticket: block until the gathered rows arrive, the
    /// backend reports an error, or the deadline passes.
    pub fn wait(mut self) -> anyhow::Result<Vec<f32>> {
        let result = self.wait_inner();
        // Release the admission slots the moment the request resolves (the
        // whole ticket drops right after, but the intent is load-bearing).
        drop(self.slot.take());
        drop(self.global_slot.take());
        result
    }

    fn wait_inner(&mut self) -> anyhow::Result<Vec<f32>> {
        if let Some(r) = self.buffered.take() {
            return r;
        }
        match &mut self.inner {
            TicketInner::Done => Err(anyhow!("resolved ticket already redeemed")),
            TicketInner::Slot(done) => {
                // A result that already arrived always wins, even past the
                // deadline — wait and poll must agree on an identical
                // state ([`Completion::wait`] checks readiness first).
                match done.wait(self.deadline) {
                    Ok(r) => r,
                    Err(()) => Err(self.expire()),
                }
            }
            TicketInner::Channel(rx) => {
                if let Ok(r) = rx.try_recv() {
                    return r;
                }
                match self.deadline {
                    None => rx.recv().context("backend dropped the request")?,
                    Some(d) => {
                        let now = Instant::now();
                        if d <= now {
                            return Err(self.expire());
                        }
                        match rx.recv_timeout(d - now) {
                            Ok(r) => r,
                            Err(mpsc::RecvTimeoutError::Timeout) => Err(self.expire()),
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                Err(anyhow!("backend dropped the request"))
                            }
                        }
                    }
                }
            }
        }
    }

    /// Redeem the ticket, degrading gracefully: a fully-gathered request
    /// returns [`Outcome::Full`]; on failure or deadline expiry, a backend
    /// serving partials returns whatever sub-batches completed as
    /// [`Outcome::Partial`] (counted in `Metrics::partials`).  Without
    /// partials enabled this is `wait` with a `Full` wrapper.
    pub fn wait_outcome(mut self) -> anyhow::Result<Outcome> {
        let result = self.wait_inner();
        drop(self.slot.take());
        drop(self.global_slot.take());
        match result {
            Ok(rows) => Ok(Outcome::Full(rows)),
            Err(err) => {
                if let Some(acc) = self.partial.take() {
                    if let Some((rows, valid)) = acc.take_partial() {
                        self.metrics.partials.fetch_add(1, Ordering::Relaxed);
                        return Ok(Outcome::Partial { rows, valid });
                    }
                }
                Err(err)
            }
        }
    }

    fn expire(&self) -> anyhow::Error {
        self.metrics.expired.fetch_add(1, Ordering::Relaxed);
        anyhow!("ticket deadline expired after {:?}", self.age())
    }
}

/// A serving backend: asynchronous ticketed gathers over a row table.
///
/// `submit` must not block on request *execution* (it may block briefly on
/// queue backpressure); completion is observed through the returned
/// [`Ticket`].
pub trait Backend: Send + Sync {
    /// Enqueue a batch of global row indices.
    fn submit(&self, batch: Batch) -> anyhow::Result<Ticket>;

    /// Non-blocking progress check for one of this backend's tickets.
    fn poll(&self, ticket: &mut Ticket) -> TicketState {
        ticket.poll()
    }

    /// Redeem a ticket (blocking, deadline-aware).
    fn wait(&self, ticket: Ticket) -> anyhow::Result<Vec<f32>> {
        ticket.wait()
    }

    /// Row width (f32 elements per row).
    fn d(&self) -> usize;

    /// Rows in this backend's (local) table.
    fn rows(&self) -> u64;

    /// The zero-copy view this backend serves from, when it serves host
    /// storage directly.  Pointer identity of `view().storage()` across
    /// backends proves shared (un-copied) sharding.
    fn view(&self) -> Option<&TableView> {
        None
    }

    /// Return a redeemed result buffer's capacity to the backend's output
    /// slab pool.  Purely an optimization: cooperating callers (the bench
    /// harness, the open-loop driver) make the steady-state output path
    /// allocation-free; everyone else just drops their `Vec`.
    fn recycle(&self, _buf: Vec<f32>) {}

    fn metrics(&self) -> MetricsSnapshot;

    /// The live counter registry: the facade and sessions record admission
    /// rejections and deadline expiries into the same place the backend
    /// records batches and latency.
    fn metrics_handle(&self) -> Arc<Metrics>;

    /// Drain in-flight work and stop worker threads (idempotent).
    fn shutdown(&self);
}

// ---------------------------------------------------------------------------
// Shared request plumbing (used by EmbeddingServer and SimBackend).
// ---------------------------------------------------------------------------

/// Scatter gathered `rows` (each `d` wide) into `out` at their original
/// request `positions`.  The one ordered-merge loop in the crate: the
/// legacy accumulator, the fleet merge, and the router's `merge_rows` all
/// call this.
pub(crate) fn scatter_rows(out: &mut [f32], positions: &[u32], rows: &[f32], d: usize) {
    debug_assert_eq!(rows.len(), positions.len() * d);
    for (k, &pos) in positions.iter().enumerate() {
        out[pos as usize * d..(pos as usize + 1) * d].copy_from_slice(&rows[k * d..(k + 1) * d]);
    }
}

/// Which request plumbing a backend runs.
#[derive(Clone)]
pub(crate) enum DataPath {
    /// Default: pooled slab outputs, direct disjoint scatter, SPSC rings,
    /// park/unpark completion.  Carries both the output-slab pool and the
    /// accumulator-shell pool ([`AccPool`]): a request's *entire* per-flight
    /// state recycles, so the steady state allocates nothing at submit.
    Slab {
        pool: Arc<SlabPool>,
        accs: Arc<AccPool>,
    },
    /// The pre-slab pipeline (mutexed accumulator, mpsc worker channels,
    /// `sync_channel(1)` tickets, per-job gather `Vec`), kept as the
    /// `--legacy-path` perf oracle.
    Legacy,
}

/// Recycled [`RequestAcc`] shells: the last two per-request heap
/// allocations (the accumulator `Arc` and its completion `Arc`) ride the
/// workers' shell-return rings back to the dispatcher, land here, and are
/// reissued at submit.  An entry is only reusable when nothing else still
/// holds it (`Arc::get_mut`) — a partial-salvage ticket or late hedge copy
/// keeps its accumulator alive and the pool simply drops that entry.
pub(crate) struct AccPool {
    accs: Mutex<Vec<Arc<RequestAcc>>>,
}

/// Pooled accumulator cap; overflow just drops (same shape as
/// [`SlabPool`]'s bound).
const MAX_POOLED_ACCS: usize = 256;

impl AccPool {
    pub(crate) fn new() -> Arc<Self> {
        Arc::new(Self {
            accs: Mutex::new(Vec::new()),
        })
    }

    /// Park a retired accumulator for reuse (bounded; overflow drops).
    pub(crate) fn put(&self, acc: Arc<RequestAcc>) {
        let Ok(mut accs) = self.accs.lock() else {
            return;
        };
        if accs.len() < MAX_POOLED_ACCS {
            accs.push(acc);
        }
    }

    /// Reissue a pooled accumulator reset for a fresh request, or `None`
    /// when the pool is empty or the candidate is still shared (the caller
    /// allocates fresh; the shared candidate is dropped, not re-queued —
    /// its other holder owns its fate now).
    pub(crate) fn get(
        &self,
        pool: &Arc<SlabPool>,
        rows: usize,
        d: usize,
        partials: bool,
    ) -> Option<Arc<RequestAcc>> {
        let mut cand = self.accs.lock().ok()?.pop()?;
        match Arc::get_mut(&mut cand) {
            Some(acc) => {
                acc.reset_for_reuse(pool, rows, d, partials);
                Some(cand)
            }
            None => None,
        }
    }

    #[cfg(test)]
    pub(crate) fn pooled(&self) -> usize {
        self.accs.lock().unwrap().len()
    }
}

/// Where a request's rows accumulate.
enum OutBuf {
    Slab(ScatterBuf),
    Legacy(Mutex<Vec<f32>>),
}

/// How the finished request reaches its ticket.
enum Responder {
    Slot(Arc<Completion>),
    Channel(Mutex<Option<ResponseTx>>),
}

/// Per-request accumulator: workers scatter their sub-batch directly into
/// the output buffer (disjoint row ranges — no lock), and the last
/// [`RequestAcc::finish_part`] publishes the result: **one atomic
/// decrement per sub-batch, one completion per request**, zero heap
/// allocations and zero mutex acquisitions on the success path.
pub(crate) struct RequestAcc {
    out: OutBuf,
    remaining: AtomicUsize,
    responder: Responder,
    /// Rare path: a failed sub-batch flips the flag, then records the
    /// message under a lock nothing on the success path touches.
    failed: AtomicUsize,
    failed_msg: Mutex<Option<String>>,
    /// Latency-measurement origin: the batcher *enqueue* instant, matching
    /// the pre-slab pipeline (which stamped after any producer-side
    /// backpressure wait), so the histogram means the same thing on both
    /// arms.  Written by the dispatcher in [`RequestAcc::arm`], read once
    /// at completion — two uncontended per-*request* lock touches, which
    /// keeps the whole struct compiler-checked Sync (no blanket unsafe)
    /// while the per-sub-batch path stays mutex-free.
    start: Mutex<Instant>,
    /// Partial delivery enabled: a failed request keeps its buffer so the
    /// ticket can salvage completed rows instead of discarding them.
    partials: bool,
}

impl RequestAcc {
    /// Default-path accumulator: slab output + completion cell.  Created
    /// at submit with the part count unknown; [`RequestAcc::arm`] sets it
    /// (and the latency origin) at dispatch, before any job is sent.  The
    /// completion is pool-backed: an abandoned (never-redeemed) success
    /// result returns its slab on drop instead of leaking capacity.
    pub(crate) fn new_slab(pool: &Arc<SlabPool>, rows: usize, d: usize, partials: bool) -> Self {
        Self {
            out: OutBuf::Slab(ScatterBuf::new(pool, rows, d)),
            remaining: AtomicUsize::new(0),
            responder: Responder::Slot(Arc::new(Completion::with_pool(Arc::clone(pool)))),
            failed: AtomicUsize::new(0),
            failed_msg: Mutex::new(None),
            start: Mutex::new(Instant::now()),
            partials,
        }
    }

    /// Rebuild a retired accumulator in place for a fresh request
    /// ([`AccPool`] reuse path; caller proved exclusive ownership via
    /// `Arc::get_mut`).  The output slab comes from the pool and the
    /// completion cell is reused when the old ticket has fully let go —
    /// after warmup a recycled request allocates nothing at submit.
    pub(crate) fn reset_for_reuse(
        &mut self,
        pool: &Arc<SlabPool>,
        rows: usize,
        d: usize,
        partials: bool,
    ) {
        self.out = OutBuf::Slab(ScatterBuf::new(pool, rows, d));
        self.remaining.store(0, Ordering::Release);
        self.failed.store(0, Ordering::Release);
        *self.failed_msg.lock().unwrap() = None;
        *self.start.lock().unwrap() = Instant::now();
        self.partials = partials;
        match &mut self.responder {
            Responder::Slot(done) => match Arc::get_mut(done) {
                Some(c) => c.reset(),
                // The previous ticket still holds the cell (e.g. it was
                // never redeemed): leave it theirs, mint a fresh one.
                None => *done = Arc::new(Completion::with_pool(Arc::clone(pool))),
            },
            Responder::Channel(_) => {
                self.responder = Responder::Slot(Arc::new(Completion::with_pool(Arc::clone(pool))));
            }
        }
    }

    /// Legacy-path accumulator (created at dispatch, parts known).
    pub(crate) fn new_legacy(
        len_floats: usize,
        parts: usize,
        ticket: ResponseTx,
        start: Instant,
    ) -> Self {
        Self {
            out: OutBuf::Legacy(Mutex::new(vec![0.0; len_floats])),
            remaining: AtomicUsize::new(parts),
            responder: Responder::Channel(Mutex::new(Some(ticket))),
            failed: AtomicUsize::new(0),
            failed_msg: Mutex::new(None),
            start: Mutex::new(start),
            partials: false,
        }
    }

    /// The completion cell the ticket waits on (default path only).
    pub(crate) fn completion(&self) -> Arc<Completion> {
        match &self.responder {
            Responder::Slot(c) => Arc::clone(c),
            Responder::Channel(_) => unreachable!("legacy accumulators complete over channels"),
        }
    }

    /// Set the sub-batch count and the latency origin (default path;
    /// called by the dispatcher before the first job is sent, so the
    /// countdown can never hit zero early and no reader races the write).
    pub(crate) fn arm(&self, parts: usize, enqueued: Instant) {
        debug_assert!(parts > 0);
        *self.start.lock().unwrap() = enqueued;
        self.remaining.store(parts, Ordering::Release);
    }

    /// Grow the countdown mid-flight: a retry that re-splits one failed
    /// sub-batch into `1 + extra` pieces adds the extra parts *before* any
    /// replacement job is sent, so the countdown cannot hit zero early.
    pub(crate) fn add_parts(&self, extra: usize) {
        if extra > 0 {
            self.remaining.fetch_add(extra, Ordering::AcqRel);
        }
    }

    /// Is this the legacy (gather-then-locked-scatter) path?
    pub(crate) fn is_legacy(&self) -> bool {
        matches!(self.out, OutBuf::Legacy(_))
    }

    // hotpath: begin — per-sub-batch success path; no allocation (palint R4).
    /// Write one gathered row (`d` floats) at its request position —
    /// the default path's single copy, lock-free by the disjointness
    /// invariant.  Slab accumulators only: the legacy oracle scatters per
    /// sub-batch (one lock) through [`RequestAcc::scatter`]; a per-row
    /// lock here would silently distort the oracle's cost model.
    #[inline]
    pub(crate) fn write_row(&self, pos: u32, row: &[f32]) {
        match &self.out {
            OutBuf::Slab(buf) => buf.write_row(pos as usize, row),
            OutBuf::Legacy(_) => {
                unreachable!("legacy accumulators scatter per sub-batch, not per row")
            }
        }
    }

    /// Scatter one sub-batch's gathered rows (each `d` wide) into the
    /// request buffer at their original positions.
    pub(crate) fn scatter(&self, positions: &[u32], rows: &[f32], d: usize) {
        match &self.out {
            OutBuf::Slab(buf) => buf.scatter(positions, rows),
            OutBuf::Legacy(out) => scatter_rows(&mut out.lock().unwrap(), positions, rows, d),
        }
    }

    /// Mark one sub-batch done; the last part publishes the response.
    /// Returns `true` for that final part — the caller that retired the
    /// request may hand the accumulator shell back for pooling.
    pub(crate) fn finish_part(&self, metrics: &Metrics) -> bool {
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let result = if self.failed.load(Ordering::Acquire) > 0 {
                let msg = self
                    .failed_msg
                    .lock()
                    .unwrap()
                    .take()
                    .unwrap_or_else(|| "sub-batch failed".into());
                if let OutBuf::Slab(buf) = &self.out {
                    if !self.partials {
                        // The output never surfaces: keep its capacity pooled.
                        buf.discard();
                    }
                    // Partials: the buffer stays in place so the ticket can
                    // salvage completed rows; its slab pools when the
                    // accumulator drops.
                }
                metrics.errors.fetch_add(1, Ordering::Relaxed);
                Err(anyhow!(msg))
            } else {
                match &self.out {
                    OutBuf::Slab(buf) => match buf.try_take() {
                        Some(v) => Ok(v),
                        // The waiter expired and already salvaged a partial;
                        // the late full result yields to it (not a backend
                        // error — the rows were all gathered).
                        None => Err(anyhow!("result already delivered as partial")),
                    },
                    OutBuf::Legacy(out) => Ok(std::mem::take(&mut *out.lock().unwrap())),
                }
            };
            let start = *self.start.lock().unwrap();
            metrics.latency.record(start.elapsed());
            self.respond(result);
            return true;
        }
        false
    }
    // hotpath: end

    /// Record a failure for this part and finish it (returning whether
    /// this was the final part, as [`RequestAcc::finish_part`] does).  The
    /// *first* failure message wins — it names the root cause; later
    /// failures are usually downstream collateral (queue closures after a
    /// worker died) and are still counted in `failed`.
    pub(crate) fn fail_part(&self, metrics: &Metrics, why: &str) -> bool {
        {
            let mut msg = self.failed_msg.lock().unwrap();
            if msg.is_none() {
                *msg = Some(why.to_string());
            }
        }
        self.failed.fetch_add(1, Ordering::Release);
        self.finish_part(metrics)
    }

    /// Salvage completed rows after a failure or expiry (slab path with
    /// slot tracking only).  Copies out; late writers may still hold raw
    /// pointers into the original buffer, which stays put until drop.
    pub(crate) fn take_partial(&self) -> Option<(Vec<f32>, Vec<bool>)> {
        match &self.out {
            OutBuf::Slab(buf) => buf.take_partial(),
            OutBuf::Legacy(_) => None,
        }
    }

    /// Resolve the whole request with an error without touching the
    /// countdown (dispatcher-side culls: no jobs were sent).
    pub(crate) fn resolve_err(&self, err: anyhow::Error) {
        if let OutBuf::Slab(buf) = &self.out {
            buf.discard();
        }
        self.respond(Err(err));
    }

    fn respond(&self, result: anyhow::Result<Vec<f32>>) {
        match &self.responder {
            Responder::Slot(done) => done.complete(result),
            Responder::Channel(tx) => {
                if let Some(t) = tx.lock().unwrap().take() {
                    // The waiter may have expired or dropped its ticket;
                    // discarding the response is correct then.
                    let _ = t.send(result);
                }
            }
        }
    }
}

impl Drop for RequestAcc {
    fn drop(&mut self) {
        // The pipeline died with this request in flight (worker panic,
        // ring torn down mid-job): the waiter must not park forever.  A
        // normally-completed request is a no-op here.
        if let Responder::Slot(done) = &self.responder {
            if !done.is_claimed() {
                done.complete(Err(anyhow!("backend dropped the request")));
            }
        }
    }
}

/// One unit of work for a group worker.
///
/// Carries its window's geometry (`win_start_row`/`win_rows`, in the
/// serving view's local row space) rather than a window id to be resolved
/// against a plan: the window plan is *live* (the control plane re-splits
/// boundaries between batches), so a job must stay executable under the
/// plan generation it was routed with even after the plan has moved on.
pub(crate) struct Job {
    pub(crate) window: usize,
    /// First row of the job's window in the serving view's row space.
    pub(crate) win_start_row: u64,
    /// Rows in the job's window (the calibration cache key, with start).
    pub(crate) win_rows: u64,
    pub(crate) local_rows: Vec<u32>,
    pub(crate) positions: Vec<u32>,
    /// Live layout permutation for this job's window, when the published
    /// [`RemapPlan`] has one whose geometry matches the routed window: the
    /// worker gathers through the packed storage instead of the base view.
    /// Pinned per job (like the window geometry above) so a repack landing
    /// mid-flight never mixes layouts within one sub-batch.  `None` =
    /// identity layout, the zero-cost default.
    pub(crate) remap: Option<Arc<WindowRemap>>,
    pub(crate) acc: Arc<RequestAcc>,
    /// Retry generation: 0 for first dispatch, incremented per re-send.
    /// Workers pass it back so the retry budget is enforced per sub-batch.
    pub(crate) attempt: u32,
    /// Hedging claim: when two copies of a sub-batch race (original +
    /// speculative re-dispatch), the first completion claims the token and
    /// writes; the loser stays silent.  `None` when hedging is off — the
    /// hot path carries no extra state.
    pub(crate) token: Option<Arc<PartToken>>,
    /// This copy *is* the speculative one (for `Metrics::hedge_wins`).
    pub(crate) hedge: bool,
}

impl Job {
    /// Recycle this job's shells after execution: the cleared index
    /// vectors ride the worker's return ring back to the dispatcher's
    /// router pool (dropped silently when the ring is full — the next
    /// split simply allocates).  When this job's `finish_part` retired the
    /// whole request (`done`), the accumulator `Arc` rides along too so
    /// the dispatcher can park it in the [`AccPool`].
    pub(crate) fn recycle_shells(self, ret: Option<&ring::Producer<Shells>>, done: bool) {
        let Job {
            mut local_rows,
            mut positions,
            acc,
            ..
        } = self;
        if let Some(ret) = ret {
            local_rows.clear();
            positions.clear();
            let _ = ret.try_send(Shells {
                local_rows,
                positions,
                acc: done.then_some(acc),
            });
        }
    }
}

/// Emptied (capacity-retaining) per-flight state riding back to the
/// dispatcher: the index vectors return to the router pool on every job;
/// the accumulator shell returns to the [`AccPool`] on the job that
/// finished its request.
pub(crate) struct Shells {
    pub(crate) local_rows: Vec<u32>,
    pub(crate) positions: Vec<u32>,
    pub(crate) acc: Option<Arc<RequestAcc>>,
}

/// Bounded per-worker job ring (the dispatcher blocks when a worker falls
/// this far behind — the same backpressure the batcher's `max_pending`
/// gives the front door).  Shared by every backend that rings its
/// workers.
pub(crate) const JOB_RING_CAP: usize = 1024;

/// Bounded per-worker shell-return ring (overflow just drops shells; the
/// next split re-allocates).
pub(crate) const SHELL_RING_CAP: usize = 1024;

/// Legacy worker message (mpsc path only; rings close instead).
pub(crate) enum WorkerMsg {
    Job(Job),
    Shutdown,
}

/// The dispatcher's handle on one worker's queue.
pub(crate) enum WorkSender {
    Ring(ring::Producer<Job>),
    Legacy(mpsc::Sender<WorkerMsg>),
}

impl WorkSender {
    /// Hand a job to the worker (blocking on ring backpressure); returns
    /// the job when the worker is gone.
    fn send(&self, job: Job) -> Result<(), Job> {
        match self {
            WorkSender::Ring(tx) => tx.send(job).map_err(|e| e.into_inner()),
            WorkSender::Legacy(tx) => tx.send(WorkerMsg::Job(job)).map_err(|e| match e.0 {
                WorkerMsg::Job(job) => job,
                WorkerMsg::Shutdown => unreachable!("send() only wraps jobs"),
            }),
        }
    }

    /// Signal end of stream (the worker drains, then exits).
    fn shutdown(&self) {
        match self {
            WorkSender::Ring(tx) => tx.close(),
            WorkSender::Legacy(tx) => {
                let _ = tx.send(WorkerMsg::Shutdown);
            }
        }
    }
}

/// The worker's end of its queue.
pub(crate) enum WorkQueue {
    Ring(ring::Consumer<Job>),
    Legacy(mpsc::Receiver<WorkerMsg>),
}

impl WorkQueue {
    /// Run `f` over every job until the queue ends (ring closed+drained,
    /// or legacy Shutdown message).
    pub(crate) fn for_each_job(self, mut f: impl FnMut(Job)) {
        match self {
            WorkQueue::Ring(rx) => {
                while let Some(job) = rx.recv() {
                    f(job);
                }
            }
            WorkQueue::Legacy(rx) => {
                while let Ok(msg) = rx.recv() {
                    match msg {
                        WorkerMsg::Shutdown => break,
                        WorkerMsg::Job(job) => f(job),
                    }
                }
            }
        }
    }
}

/// What rides through the batcher per request: the pre-built accumulator
/// (default path) or the legacy response channel.
pub(crate) enum ReqHandle {
    Acc(Arc<RequestAcc>),
    Legacy(ResponseTx),
}

/// Split every request of a formed batch under `placement` and fan
/// sub-batches out to the per-group workers.  Requests whose deadline
/// already passed are failed fast (counted in `Metrics::expired`) without
/// touching a worker.  Per-window routed rows are recorded in `metrics` —
/// the adaptive placer's load signal — and sampled into the row-frequency
/// sketch when one is enabled, the repack lever's hot-set signal.  Each
/// sub-batch pins its window's live [`WindowRemap`] (if the published
/// `remap` plan has one with matching geometry) so workers gather from
/// the packed layout.
pub(crate) fn dispatch_formed(
    formed: crate::coordinator::batcher::Batch<ReqHandle>,
    router: &mut Router,
    plan: &crate::coordinator::chunks::WindowPlan,
    placement: &Placement,
    remap: &RemapPlan,
    senders: &[Option<WorkSender>],
    metrics: &Arc<Metrics>,
    resilience: Option<&Arc<ResilienceCtx>>,
    d: usize,
) {
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    let now = Instant::now();
    for req in formed.requests {
        if req.deadline.is_some_and(|dl| dl <= now) {
            metrics.expired.fetch_add(1, Ordering::Relaxed);
            let err = || anyhow!("deadline expired before dispatch");
            match req.ticket {
                ReqHandle::Acc(acc) => acc.resolve_err(err()),
                ReqHandle::Legacy(tx) => {
                    let _ = tx.send(Err(err()));
                }
            }
            continue;
        }
        let split = router.split(&req.rows, plan, placement);
        let acc = match req.ticket {
            ReqHandle::Acc(acc) => {
                acc.arm(split.sub_batches.len(), req.enqueued);
                acc
            }
            ReqHandle::Legacy(tx) => Arc::new(RequestAcc::new_legacy(
                req.rows.len() * d,
                split.sub_batches.len(),
                tx,
                req.enqueued,
            )),
        };
        for sb in split.sub_batches {
            let win = plan.windows()[sb.window];
            metrics.record_window_rows(sb.window, sb.local_rows.len() as u64);
            metrics.record_routed_rows(win.start_row, &sb.local_rows);
            let win_remap = remap
                .window_remap(sb.window)
                .filter(|r| r.matches(&win))
                .cloned();
            // Hedging: mint a claim token and remember the sub-batch
            // (global rows + final positions) so the monitor can re-issue
            // it to a sibling group if it straggles past the watermark.
            let hedge_entry = match resilience {
                Some(res) if res.hedge_enabled() => {
                    let token = Arc::new(PartToken::new());
                    let rows: Vec<u64> = sb
                        .local_rows
                        .iter()
                        .map(|&l| win.start_row + l as u64)
                        .collect();
                    Some((res, token, rows, sb.positions.clone()))
                }
                _ => None,
            };
            let job = Job {
                window: sb.window,
                win_start_row: win.start_row,
                win_rows: win.rows,
                local_rows: sb.local_rows,
                positions: sb.positions,
                remap: win_remap,
                acc: Arc::clone(&acc),
                attempt: 0,
                token: hedge_entry.as_ref().map(|(_, t, _, _)| Arc::clone(t)),
                hedge: false,
            };
            match senders.get(sb.group).and_then(|s| s.as_ref()) {
                Some(tx) => {
                    if let Err(job) = tx.send(job) {
                        drop(job);
                        acc.fail_part(metrics, "worker queue closed");
                    } else if let Some((res, token, rows, positions)) = hedge_entry {
                        res.register_hedge(token, sb.group, rows, positions, Arc::clone(&acc));
                    }
                }
                None => acc.fail_part(metrics, "no worker for group"),
            }
        }
    }
}

/// Re-dispatch a retry or hedge that flowed back to the dispatcher (the
/// worker rings' single producer).  The rows are re-split under the *live*
/// placement, so rows from a failed or breaker-evicted group land on
/// whichever sibling serves their window now.
fn redispatch(
    msg: ResMsg,
    router: &mut Router,
    cell: &PlacementCell,
    senders: &[Option<WorkSender>],
    metrics: &Arc<Metrics>,
    res: &Arc<ResilienceCtx>,
) {
    let (plan, placement, remap) = cell.load_routed();
    let split = router.split(&msg.rows, &plan, &placement);
    if msg.hedge {
        // PANIC: invariant, not input — the monitor mints a token for every
        // hedge it registers; a hedge message without one is a logic bug.
        let token = Arc::clone(msg.token.as_ref().expect("hedge messages carry a claim token"));
        let mut delivered = false;
        // A hedge duplicates exactly one original sub-batch; if the live
        // plan now splits those rows across windows the speculation is
        // stale — abandon the copy rather than fan one token across
        // several jobs.
        if split.sub_batches.len() == 1 {
            // PANIC: guarded by the length check on the line above.
            let mut sb = split.sub_batches.into_iter().next().unwrap();
            // Prefer a sibling group over the straggling original.
            let mut group = sb.group;
            if msg.exclude == Some(group) {
                if let Some(&alt) = placement
                    .serving_groups(sb.window)
                    .iter()
                    .find(|&&g| Some(g) != msg.exclude)
                {
                    group = alt;
                }
            }
            // Sub-split positions index msg.rows; remap to final request
            // positions in place.
            for p in sb.positions.iter_mut() {
                *p = msg.positions[*p as usize];
            }
            let win = plan.windows()[sb.window];
            let job = Job {
                window: sb.window,
                win_start_row: win.start_row,
                win_rows: win.rows,
                local_rows: sb.local_rows,
                positions: sb.positions,
                remap: remap
                    .window_remap(sb.window)
                    .filter(|r| r.matches(&win))
                    .cloned(),
                acc: Arc::clone(&msg.acc),
                attempt: msg.attempt,
                token: Some(Arc::clone(&token)),
                hedge: true,
            };
            if let Some(tx) = senders.get(group).and_then(|s| s.as_ref()) {
                delivered = tx.send(job).is_ok();
            }
        }
        if !delivered && token.copy_failed() {
            // The original failed concurrently and deferred to this copy;
            // the part is ours to finish — retry it or fail the request.
            if !(res.can_retry(msg.attempt)
                && res.send_retry(msg.rows, msg.positions, Arc::clone(&msg.acc), msg.attempt))
            {
                msg.acc
                    .fail_part(metrics, "hedge undeliverable after original failed");
            }
        }
        return;
    }
    // Retry: grow the countdown for any extra sub-batches *before* sending,
    // then fan out exactly like a fresh dispatch.  Retries carry no hedge
    // token — a retry is already the recovery path; hedging it would
    // compound speculation.
    let extra = split.sub_batches.len().saturating_sub(1);
    if split.sub_batches.is_empty() {
        msg.acc.fail_part(metrics, "retry found no serving group");
        return;
    }
    msg.acc.add_parts(extra);
    for mut sb in split.sub_batches {
        metrics.record_window_rows(sb.window, sb.local_rows.len() as u64);
        for p in sb.positions.iter_mut() {
            *p = msg.positions[*p as usize];
        }
        let win = plan.windows()[sb.window];
        let job = Job {
            window: sb.window,
            win_start_row: win.start_row,
            win_rows: win.rows,
            local_rows: sb.local_rows,
            positions: sb.positions,
            remap: remap
                .window_remap(sb.window)
                .filter(|r| r.matches(&win))
                .cloned(),
            acc: Arc::clone(&msg.acc),
            attempt: msg.attempt,
            token: None,
            hedge: false,
        };
        match senders.get(sb.group).and_then(|s| s.as_ref()) {
            Some(tx) => {
                if let Err(job) = tx.send(job) {
                    drop(job);
                    msg.acc.fail_part(metrics, "worker queue closed");
                }
            }
            None => msg.acc.fail_part(metrics, "no worker for group"),
        }
    }
}

/// The batcher → dispatcher → worker thread scaffolding both backends
/// share: owns the queue and every thread handle, spawns the dispatcher
/// loop, and knows how to drain and join on shutdown.  Backends only
/// differ in *what a worker does with a [`Job`]* — they spawn their own
/// workers and hand the senders + handles here.
pub(crate) struct Pipeline {
    pub(crate) batcher: Arc<Batcher<ReqHandle>>,
    dispatcher: Mutex<Option<std::thread::JoinHandle<()>>>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Pipeline {
    /// Spawn the dispatcher over `senders` and adopt the worker handles.
    /// The dispatcher loads the (plan, placement, remap) triple from `cell`
    /// once per formed batch, so a [`PlacementCell::store`] (re-deal),
    /// [`PlacementCell::store_replan`] (window re-split) or
    /// [`PlacementCell::store_remap`] (hot-row repack) from the control
    /// plane takes effect at the next batch — in-flight splits finish under
    /// the generation they started with (no drain).  `shell_returns` are
    /// the workers' recycling rings: their emptied index vectors are
    /// drained into the router pool between batches, and retired
    /// accumulator shells into `acc_pool`, closing the allocation loop.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn start(
        cfg: crate::coordinator::batcher::BatcherConfig,
        cell: Arc<PlacementCell>,
        metrics: Arc<Metrics>,
        d: usize,
        senders: Vec<Option<WorkSender>>,
        shell_returns: Vec<ring::Consumer<Shells>>,
        acc_pool: Option<Arc<AccPool>>,
        workers: Vec<std::thread::JoinHandle<()>>,
        resilience: Option<Arc<ResilienceCtx>>,
    ) -> anyhow::Result<Self> {
        let batcher = Arc::new(Batcher::new(cfg));
        let drain_shells = move |router: &mut Router, shell_returns: &[ring::Consumer<Shells>]| {
            for ret in shell_returns {
                while let Some(sh) = ret.try_recv() {
                    router.adopt_shells(sh.local_rows, sh.positions);
                    if let (Some(pool), Some(acc)) = (&acc_pool, sh.acc) {
                        pool.put(acc);
                    }
                }
            }
        };
        let dispatcher = {
            let batcher = Arc::clone(&batcher);
            std::thread::Builder::new()
                .name("a100win-dispatcher".into())
                .spawn(move || match resilience {
                    None => {
                        // Hot path, bit-identical to the resilience-free
                        // pipeline: block on the batcher, dispatch, repeat.
                        let mut router = Router::new();
                        while let Some(batch) = batcher.next_batch() {
                            drain_shells(&mut router, &shell_returns);
                            let (plan, placement, remap) = cell.load_routed();
                            dispatch_formed(
                                batch, &mut router, &plan, &placement, &remap, &senders,
                                &metrics, None, d,
                            );
                        }
                        for s in senders.iter().flatten() {
                            s.shutdown();
                        }
                    }
                    Some(res) => {
                        // Resilient dispatcher: the single producer for
                        // every worker ring (preserving the SPSC
                        // invariant), so retries and hedges from workers
                        // and the monitor flow back here over one mpsc
                        // channel and re-enter the rings in-line.
                        let rx = res
                            .take_receiver()
                            // PANIC: invariant — the context is built with
                            // its receiver present and exactly one
                            // dispatcher takes it.
                            .expect("resilience receiver taken once, by the dispatcher");
                        let mut router = Router::new();
                        let mut pending: Vec<ResMsg> = Vec::new();
                        const IDLE_TICK: Duration = Duration::from_millis(1);
                        loop {
                            let now = Instant::now();
                            let mut wait = IDLE_TICK;
                            for m in &pending {
                                wait = wait.min(m.due.saturating_duration_since(now));
                            }
                            let batch = match batcher.next_batch_or_timeout(wait) {
                                BatchWait::Batch(b) => Some(b),
                                BatchWait::TimedOut => None,
                                BatchWait::Closed => break,
                            };
                            drain_shells(&mut router, &shell_returns);
                            while let Ok(m) = rx.try_recv() {
                                pending.push(m);
                            }
                            let now = Instant::now();
                            let mut i = 0;
                            while i < pending.len() {
                                if pending[i].due <= now {
                                    let msg = pending.swap_remove(i);
                                    redispatch(msg, &mut router, &cell, &senders, &metrics, &res);
                                } else {
                                    i += 1;
                                }
                            }
                            if let Some(batch) = batch {
                                let (plan, placement, remap) = cell.load_routed();
                                dispatch_formed(
                                    batch,
                                    &mut router,
                                    &plan,
                                    &placement,
                                    &remap,
                                    &senders,
                                    &metrics,
                                    Some(&res),
                                    d,
                                );
                            }
                        }
                        for s in senders.iter().flatten() {
                            s.shutdown();
                        }
                        // Undelivered retries still own an outstanding part
                        // of their request; fail them so waiters resolve.
                        for msg in pending.drain(..) {
                            let abandoned = match &msg.token {
                                Some(tok) => tok.copy_failed(),
                                None => true,
                            };
                            if abandoned {
                                msg.acc
                                    .fail_part(&metrics, "backend shut down before retry");
                            }
                        }
                    }
                })
                .context("spawning dispatcher")?
        };
        Ok(Self {
            batcher,
            dispatcher: Mutex::new(Some(dispatcher)),
            workers: Mutex::new(workers),
        })
    }

    /// Close the queue, drain queued requests, and join every thread
    /// (idempotent; both backends call this from shutdown *and* Drop).
    pub(crate) fn stop(&self) {
        self.batcher.close();
        if let Some(d) = self.dispatcher.lock().unwrap().take() {
            let _ = d.join();
        }
        for w in self.workers.lock().unwrap().drain(..) {
            let _ = w.join();
        }
    }
}

/// The common `Backend::submit` body: validate, count, enqueue, ticket.
/// On the default path the request's slab accumulator is built here
/// (output length is known at submit) and armed with its sub-batch count
/// by the dispatcher.
pub(crate) fn submit_ticketed(
    batcher: &Batcher<ReqHandle>,
    metrics: &Arc<Metrics>,
    total_rows: u64,
    d: usize,
    path: &DataPath,
    partials: bool,
    batch: Batch,
) -> anyhow::Result<Ticket> {
    for &r in batch.rows.iter() {
        if r >= total_rows {
            metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow!("row {r} out of table ({total_rows} rows)"));
        }
    }
    metrics.requests.fetch_add(1, Ordering::Relaxed);
    metrics
        .rows
        .fetch_add(batch.rows.len() as u64, Ordering::Relaxed);
    if batch.rows.is_empty() {
        return Ok(Ticket::resolved(Ok(Vec::new()), Arc::clone(metrics)));
    }
    match path {
        DataPath::Slab { pool, accs } => {
            // Steady state: the accumulator shell (the request's two Arc
            // allocations) comes back from the pool; a fresh one is built
            // only while the pool warms up or the candidate is shared.
            let acc = match accs.get(pool, batch.rows.len(), d, partials) {
                Some(acc) => acc,
                None => Arc::new(RequestAcc::new_slab(pool, batch.rows.len(), d, partials)),
            };
            let done = acc.completion();
            let partial_src = partials.then(|| Arc::clone(&acc));
            batcher
                .submit(batch.rows, batch.deadline, ReqHandle::Acc(acc))
                .map_err(|_| anyhow!("backend is shutting down"))?;
            let mut ticket = Ticket::from_completion(done, batch.deadline, Arc::clone(metrics));
            ticket.partial = partial_src;
            Ok(ticket)
        }
        DataPath::Legacy => {
            let (tx, rx) = mpsc::sync_channel(1);
            batcher
                .submit(batch.rows, batch.deadline, ReqHandle::Legacy(tx))
                .map_err(|_| anyhow!("backend is shutting down"))?;
            Ok(Ticket::new(rx, batch.deadline, Arc::clone(metrics)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics() -> Arc<Metrics> {
        Arc::new(Metrics::new())
    }

    #[test]
    fn resolved_ticket_is_ready_immediately() {
        let mut t = Ticket::resolved(Ok(vec![1.0, 2.0]), metrics());
        assert_eq!(t.poll(), TicketState::Ready);
        assert_eq!(t.wait().unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn ticket_pending_then_ready() {
        let (tx, rx) = mpsc::sync_channel(1);
        let mut t = Ticket::new(rx, None, metrics());
        assert_eq!(t.poll(), TicketState::Pending);
        tx.send(Ok(vec![3.0])).unwrap();
        assert_eq!(t.poll(), TicketState::Ready);
        // Poll buffers the result; wait returns it without a channel read.
        assert_eq!(t.wait().unwrap(), vec![3.0]);
    }

    #[test]
    fn completion_ticket_pending_then_ready() {
        let done = Arc::new(Completion::new());
        let mut t = Ticket::from_completion(Arc::clone(&done), None, metrics());
        assert_eq!(t.poll(), TicketState::Pending);
        done.complete(Ok(vec![5.0]));
        assert_eq!(t.poll(), TicketState::Ready);
        assert_eq!(t.wait().unwrap(), vec![5.0]);
    }

    #[test]
    fn ticket_deadline_expires() {
        let m = metrics();
        let done = Arc::new(Completion::new());
        let t = Ticket::from_completion(
            done,
            Some(Instant::now() + Duration::from_millis(10)),
            Arc::clone(&m),
        );
        let err = t.wait().unwrap_err();
        assert!(err.to_string().contains("deadline expired"), "{err}");
        assert_eq!(m.expired.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn ticket_poll_reports_expired() {
        let done = Arc::new(Completion::new());
        let mut t = Ticket::from_completion(
            done,
            Some(Instant::now() - Duration::from_millis(1)),
            metrics(),
        );
        assert_eq!(t.poll(), TicketState::Expired);
    }

    #[test]
    fn disconnected_backend_surfaces_as_error() {
        let (tx, rx) = mpsc::sync_channel::<anyhow::Result<Vec<f32>>>(1);
        drop(tx);
        let mut t = Ticket::new(rx, None, metrics());
        assert_eq!(t.poll(), TicketState::Ready);
        assert!(t.wait().is_err());
    }

    #[test]
    fn dropped_pipeline_resolves_slab_ticket_with_error() {
        // The accumulator dropping un-completed (worker died mid-job) must
        // wake the waiter with an error, mirroring channel disconnection.
        let pool = SlabPool::new();
        let acc = Arc::new(RequestAcc::new_slab(&pool, 2, 2, false));
        let mut t = Ticket::from_completion(acc.completion(), None, metrics());
        drop(acc);
        assert_eq!(t.poll(), TicketState::Ready);
        let err = t.wait().unwrap_err();
        assert!(err.to_string().contains("dropped"), "{err}");
    }

    fn slab_acc(rows: usize, d: usize, parts: usize) -> (Arc<RequestAcc>, Arc<Completion>) {
        let pool = SlabPool::new();
        let acc = Arc::new(RequestAcc::new_slab(&pool, rows, d, false));
        acc.arm(parts, Instant::now());
        let done = acc.completion();
        (acc, done)
    }

    #[test]
    fn request_acc_merges_parts_and_responds_once() {
        let m = metrics();
        let (acc, done) = slab_acc(2, 2, 2);
        acc.scatter(&[1], &[3.0, 4.0], 2);
        acc.finish_part(&m);
        assert!(done.try_take().is_none(), "must wait for all parts");
        acc.scatter(&[0], &[1.0, 2.0], 2);
        acc.finish_part(&m);
        assert_eq!(done.try_take().unwrap().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.latency.count(), 1);
    }

    #[test]
    fn request_acc_failure_propagates() {
        let m = metrics();
        let (acc, done) = slab_acc(1, 2, 2);
        acc.fail_part(&m, "boom");
        acc.finish_part(&m);
        assert!(done.try_take().unwrap().is_err());
        assert_eq!(m.errors.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn legacy_request_acc_merges_parts_and_responds_once() {
        let m = metrics();
        let (tx, rx) = mpsc::sync_channel(1);
        let acc = RequestAcc::new_legacy(4, 2, tx, Instant::now());
        assert!(acc.is_legacy());
        acc.scatter(&[1], &[3.0, 4.0], 2);
        acc.finish_part(&m);
        assert!(rx.try_recv().is_err(), "must wait for all parts");
        acc.scatter(&[0], &[1.0, 2.0], 2);
        acc.finish_part(&m);
        assert_eq!(rx.recv().unwrap().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.latency.count(), 1);
    }

    #[test]
    fn legacy_request_acc_failure_propagates() {
        let m = metrics();
        let (tx, rx) = mpsc::sync_channel(1);
        let acc = RequestAcc::new_legacy(2, 2, tx, Instant::now());
        acc.fail_part(&m, "boom");
        acc.finish_part(&m);
        assert!(rx.recv().unwrap().is_err());
        assert_eq!(m.errors.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn write_row_is_the_single_copy() {
        let m = metrics();
        let (acc, done) = slab_acc(3, 2, 1);
        acc.write_row(2, &[5.0, 6.0]);
        acc.write_row(0, &[1.0, 2.0]);
        acc.write_row(1, &[3.0, 4.0]);
        acc.finish_part(&m);
        assert_eq!(
            done.try_take().unwrap().unwrap(),
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]
        );
    }

    #[test]
    fn first_failure_message_wins() {
        // The root cause must surface, not whichever part failed last;
        // later failures are still counted.
        let m = metrics();
        let (acc, done) = slab_acc(1, 2, 3);
        acc.fail_part(&m, "worker died: injected fault");
        acc.fail_part(&m, "worker queue closed");
        acc.fail_part(&m, "worker queue closed");
        let err = done.try_take().unwrap().unwrap_err();
        assert!(err.to_string().contains("injected fault"), "{err}");
        assert_eq!(acc.failed.load(Ordering::Relaxed), 3);
        assert_eq!(m.errors.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn acc_pool_recycles_shells_and_resets_state() {
        let m = metrics();
        let pool = SlabPool::new();
        let accs = AccPool::new();
        let acc = Arc::new(RequestAcc::new_slab(&pool, 1, 2, false));
        acc.arm(1, Instant::now());
        let done = acc.completion();
        acc.write_row(0, &[1.0, 2.0]);
        assert!(acc.finish_part(&m), "final part retires the request");
        assert_eq!(done.try_take().unwrap().unwrap(), vec![1.0, 2.0]);
        drop(done); // ticket fully redeemed: the completion cell is free too
        accs.put(acc);
        assert_eq!(accs.pooled(), 1);
        // Reissue for a *different* shape; the reset shell must behave
        // exactly like a fresh accumulator.
        let acc2 = accs.get(&pool, 2, 2, false).expect("pool reissues the shell");
        acc2.arm(2, Instant::now());
        let done2 = acc2.completion();
        acc2.write_row(1, &[5.0, 6.0]);
        assert!(!acc2.finish_part(&m), "one part still outstanding");
        acc2.write_row(0, &[3.0, 4.0]);
        assert!(acc2.finish_part(&m));
        assert_eq!(done2.try_take().unwrap().unwrap(), vec![3.0, 4.0, 5.0, 6.0]);
        assert_eq!(accs.pooled(), 0);
    }

    #[test]
    fn acc_pool_declines_shared_candidates() {
        let pool = SlabPool::new();
        let accs = AccPool::new();
        let acc = Arc::new(RequestAcc::new_slab(&pool, 1, 2, false));
        let held = Arc::clone(&acc); // e.g. a partial-salvage ticket
        accs.put(acc);
        assert!(accs.get(&pool, 1, 2, false).is_none());
        assert_eq!(accs.pooled(), 0, "shared candidate drops, never re-queues");
        drop(held);
    }

    #[test]
    fn reset_mints_a_fresh_completion_when_the_ticket_still_holds_it() {
        let m = metrics();
        let pool = SlabPool::new();
        let accs = AccPool::new();
        let acc = Arc::new(RequestAcc::new_slab(&pool, 1, 2, false));
        acc.arm(1, Instant::now());
        let done = acc.completion(); // an abandoned, never-redeemed ticket
        acc.write_row(0, &[1.0, 2.0]);
        assert!(acc.finish_part(&m));
        accs.put(acc);
        let acc2 = accs.get(&pool, 1, 2, false).expect("shell is exclusive");
        let done2 = acc2.completion();
        assert!(
            !Arc::ptr_eq(&done, &done2),
            "a still-held completion must not be recycled under its waiter"
        );
        assert_eq!(done.try_take().unwrap().unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn finishing_job_sends_acc_back_over_the_shell_ring() {
        let m = metrics();
        let pool = SlabPool::new();
        let acc = Arc::new(RequestAcc::new_slab(&pool, 1, 2, false));
        acc.arm(1, Instant::now());
        let (shell_tx, shell_rx) = ring::spsc::<Shells>(4);
        let job = Job {
            window: 0,
            win_start_row: 0,
            win_rows: 8,
            local_rows: vec![0],
            positions: vec![0],
            remap: None,
            acc: Arc::clone(&acc),
            attempt: 0,
            token: None,
            hedge: false,
        };
        job.acc.write_row(0, &[1.0, 2.0]);
        let done = job.acc.finish_part(&m);
        drop(acc);
        job.recycle_shells(Some(&shell_tx), done);
        let sh = shell_rx.try_recv().expect("shells ride back");
        assert!(sh.local_rows.is_empty() && sh.positions.is_empty());
        let acc = sh.acc.expect("the finishing job returns its accumulator");
        assert_eq!(Arc::strong_count(&acc), 1, "shell is exclusively pooled");
    }

    #[test]
    fn partial_outcome_salvages_completed_rows() {
        let m = metrics();
        let pool = SlabPool::with_claims(true);
        let acc = Arc::new(RequestAcc::new_slab(&pool, 2, 2, true));
        acc.arm(2, Instant::now());
        let mut ticket = Ticket::from_completion(acc.completion(), None, Arc::clone(&m));
        ticket.partial = Some(Arc::clone(&acc));
        acc.write_row(0, &[1.0, 2.0]);
        acc.finish_part(&m);
        acc.fail_part(&m, "injected fault");
        match ticket.wait_outcome().unwrap() {
            Outcome::Partial { rows, valid } => {
                assert_eq!(rows, vec![1.0, 2.0, 0.0, 0.0]);
                assert_eq!(valid, vec![true, false]);
            }
            other => panic!("expected partial, got {other:?}"),
        }
        assert_eq!(m.partials.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn full_success_without_partials_is_unchanged() {
        let m = metrics();
        let pool = SlabPool::with_claims(true);
        let acc = Arc::new(RequestAcc::new_slab(&pool, 1, 2, true));
        acc.arm(1, Instant::now());
        let mut ticket = Ticket::from_completion(acc.completion(), None, Arc::clone(&m));
        ticket.partial = Some(Arc::clone(&acc));
        acc.write_row(0, &[7.0, 8.0]);
        acc.finish_part(&m);
        assert_eq!(
            ticket.wait_outcome().unwrap(),
            Outcome::Full(vec![7.0, 8.0])
        );
        assert_eq!(m.partials.load(Ordering::Relaxed), 0);
    }
}

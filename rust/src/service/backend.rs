//! The backend contract of the serving facade, plus the request plumbing
//! shared by every backend implementation.
//!
//! A [`Backend`] turns a [`Batch`] of global row indices into a [`Ticket`]
//! immediately — no per-request blocking — and resolves the ticket with the
//! gathered rows when its workers finish.  Two implementations exist:
//!
//! * [`crate::coordinator::EmbeddingServer`] — the PJRT path: per-group
//!   worker threads executing AOT gather artifacts (needs `make artifacts`
//!   and a real `xla` crate).
//! * [`crate::service::SimBackend`] — the hermetic path: host-side gathers
//!   timed by the discrete-event [`crate::sim::Machine`], so every serving
//!   scenario runs under tier-1 with no artifacts.
//!
//! Both share the same internal shape (batcher → dispatcher →
//! [`Router`](crate::coordinator::Router) split → per-group workers →
//! ordered merge), so the split/accumulate/respond machinery lives here:
//! [`RequestAcc`], [`Job`], [`WorkerMsg`], [`dispatch_formed`] and
//! [`submit_ticketed`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context};

use crate::coordinator::batcher::Batcher;
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::placement::{Placement, PlacementCell};
use crate::coordinator::router::Router;
use crate::coordinator::table::TableView;

use super::session::{GlobalSlotGuard, SlotGuard};

/// One submission: shared row indices plus an optional completion deadline.
///
/// Indices travel by `Arc` end to end (caller → batcher → router), so a
/// caller that keeps a handle for verification pays one refcount bump, not
/// a `Vec` clone per request.
#[derive(Debug, Clone)]
pub struct Batch {
    pub rows: Arc<Vec<u64>>,
    pub deadline: Option<Instant>,
}

impl Batch {
    pub fn new(rows: Arc<Vec<u64>>) -> Self {
        Self {
            rows,
            deadline: None,
        }
    }

    /// A batch that must complete within `budget` of now.
    pub fn with_deadline(rows: Arc<Vec<u64>>, budget: Duration) -> Self {
        Self {
            rows,
            deadline: Some(Instant::now() + budget),
        }
    }
}

/// Observable state of an in-flight [`Ticket`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TicketState {
    /// Still in the backend; the deadline (if any) has not passed.
    Pending,
    /// The result (or a backend error) is available; `wait` will not block.
    Ready,
    /// The deadline passed before the result arrived.
    Expired,
}

/// Response channel the workers complete into.  Capacity 1: exactly one
/// response per request, so a worker send never blocks.
pub(crate) type ResponseTx = mpsc::SyncSender<anyhow::Result<Vec<f32>>>;

/// A claim on one in-flight request.  Tickets carry their deadline;
/// [`Ticket::wait`] returns an error (and counts `Metrics::expired`) if the
/// result does not arrive in time.  Dropping a ticket abandons the request
/// (the backend still completes it; the response is discarded).
pub struct Ticket {
    rx: mpsc::Receiver<anyhow::Result<Vec<f32>>>,
    deadline: Option<Instant>,
    submitted: Instant,
    buffered: Option<anyhow::Result<Vec<f32>>>,
    metrics: Arc<Metrics>,
    /// Admission-control slot released when the ticket resolves or drops.
    pub(crate) slot: Option<SlotGuard>,
    /// Cross-tenant budget slot (weighted fair sharing), same lifecycle.
    pub(crate) global_slot: Option<GlobalSlotGuard>,
}

impl std::fmt::Debug for Ticket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Ticket")
            .field("deadline", &self.deadline)
            .field("age", &self.age())
            .field("buffered", &self.buffered.is_some())
            .field("admission_slot", &self.slot.is_some())
            .finish()
    }
}

impl Ticket {
    pub(crate) fn new(
        rx: mpsc::Receiver<anyhow::Result<Vec<f32>>>,
        deadline: Option<Instant>,
        metrics: Arc<Metrics>,
    ) -> Self {
        Self {
            rx,
            deadline,
            submitted: Instant::now(),
            buffered: None,
            metrics,
            slot: None,
            global_slot: None,
        }
    }

    /// A ticket that is already resolved (e.g. the empty request).
    pub(crate) fn resolved(result: anyhow::Result<Vec<f32>>, metrics: Arc<Metrics>) -> Self {
        let (_tx, rx) = mpsc::sync_channel(1);
        let mut t = Self::new(rx, None, metrics);
        t.buffered = Some(result);
        t
    }

    pub fn deadline(&self) -> Option<Instant> {
        self.deadline
    }

    /// Time since submission.
    pub fn age(&self) -> Duration {
        self.submitted.elapsed()
    }

    /// Non-blocking progress check.
    pub fn poll(&mut self) -> TicketState {
        if self.buffered.is_some() {
            return TicketState::Ready;
        }
        match self.rx.try_recv() {
            Ok(r) => {
                self.buffered = Some(r);
                TicketState::Ready
            }
            Err(mpsc::TryRecvError::Empty) => {
                if self.deadline.is_some_and(|d| Instant::now() >= d) {
                    TicketState::Expired
                } else {
                    TicketState::Pending
                }
            }
            Err(mpsc::TryRecvError::Disconnected) => {
                self.buffered = Some(Err(anyhow!("backend dropped the request")));
                TicketState::Ready
            }
        }
    }

    /// Redeem the ticket: block until the gathered rows arrive, the
    /// backend reports an error, or the deadline passes.
    pub fn wait(mut self) -> anyhow::Result<Vec<f32>> {
        let result = self.wait_inner();
        // Release the admission slots the moment the request resolves (the
        // whole ticket drops right after, but the intent is load-bearing).
        drop(self.slot.take());
        drop(self.global_slot.take());
        result
    }

    fn wait_inner(&mut self) -> anyhow::Result<Vec<f32>> {
        if let Some(r) = self.buffered.take() {
            return r;
        }
        // A result that already arrived always wins, even past the
        // deadline — wait and poll must agree on an identical state.
        if let Ok(r) = self.rx.try_recv() {
            return r;
        }
        match self.deadline {
            None => self.rx.recv().context("backend dropped the request")?,
            Some(d) => {
                let now = Instant::now();
                if d <= now {
                    return Err(self.expire());
                }
                match self.rx.recv_timeout(d - now) {
                    Ok(r) => r,
                    Err(mpsc::RecvTimeoutError::Timeout) => Err(self.expire()),
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        Err(anyhow!("backend dropped the request"))
                    }
                }
            }
        }
    }

    fn expire(&self) -> anyhow::Error {
        self.metrics.expired.fetch_add(1, Ordering::Relaxed);
        anyhow!("ticket deadline expired after {:?}", self.age())
    }
}

/// A serving backend: asynchronous ticketed gathers over a row table.
///
/// `submit` must not block on request *execution* (it may block briefly on
/// queue backpressure); completion is observed through the returned
/// [`Ticket`].
pub trait Backend: Send + Sync {
    /// Enqueue a batch of global row indices.
    fn submit(&self, batch: Batch) -> anyhow::Result<Ticket>;

    /// Non-blocking progress check for one of this backend's tickets.
    fn poll(&self, ticket: &mut Ticket) -> TicketState {
        ticket.poll()
    }

    /// Redeem a ticket (blocking, deadline-aware).
    fn wait(&self, ticket: Ticket) -> anyhow::Result<Vec<f32>> {
        ticket.wait()
    }

    /// Row width (f32 elements per row).
    fn d(&self) -> usize;

    /// Rows in this backend's (local) table.
    fn rows(&self) -> u64;

    /// The zero-copy view this backend serves from, when it serves host
    /// storage directly.  Pointer identity of `view().storage()` across
    /// backends proves shared (un-copied) sharding.
    fn view(&self) -> Option<&TableView> {
        None
    }

    fn metrics(&self) -> MetricsSnapshot;

    /// The live counter registry: the facade and sessions record admission
    /// rejections and deadline expiries into the same place the backend
    /// records batches and latency.
    fn metrics_handle(&self) -> Arc<Metrics>;

    /// Drain in-flight work and stop worker threads (idempotent).
    fn shutdown(&self);
}

// ---------------------------------------------------------------------------
// Shared request plumbing (used by EmbeddingServer and SimBackend).
// ---------------------------------------------------------------------------

/// Scatter gathered `rows` (each `d` wide) into `out` at their original
/// request `positions`.  The one ordered-merge loop in the crate: request
/// accumulators, the fleet merge, and the router's `merge_rows` all call
/// this.
pub(crate) fn scatter_rows(out: &mut [f32], positions: &[u32], rows: &[f32], d: usize) {
    debug_assert_eq!(rows.len(), positions.len() * d);
    for (k, &pos) in positions.iter().enumerate() {
        out[pos as usize * d..(pos as usize + 1) * d].copy_from_slice(&rows[k * d..(k + 1) * d]);
    }
}

/// Per-request accumulator: workers scatter their slice, the last one
/// responds on the ticket channel.
pub(crate) struct RequestAcc {
    out: Mutex<Vec<f32>>,
    remaining: AtomicUsize,
    ticket: Mutex<Option<ResponseTx>>,
    failed: Mutex<Option<String>>,
    start: Instant,
}

impl RequestAcc {
    pub(crate) fn new(len_floats: usize, parts: usize, ticket: ResponseTx, start: Instant) -> Self {
        Self {
            out: Mutex::new(vec![0.0; len_floats]),
            remaining: AtomicUsize::new(parts),
            ticket: Mutex::new(Some(ticket)),
            failed: Mutex::new(None),
            start,
        }
    }

    /// Scatter one sub-batch's gathered rows (each `d` wide) into the
    /// request buffer at their original positions.
    pub(crate) fn scatter(&self, positions: &[u32], rows: &[f32], d: usize) {
        scatter_rows(&mut self.out.lock().unwrap(), positions, rows, d);
    }

    /// Mark one sub-batch done; the last part sends the response.
    pub(crate) fn finish_part(&self, metrics: &Metrics) {
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let ticket = self.ticket.lock().unwrap().take();
            if let Some(t) = ticket {
                let failed = self.failed.lock().unwrap().take();
                let result = match failed {
                    Some(e) => Err(anyhow!(e)),
                    None => Ok(std::mem::take(&mut *self.out.lock().unwrap())),
                };
                if result.is_err() {
                    metrics.errors.fetch_add(1, Ordering::Relaxed);
                }
                metrics.latency.record(self.start.elapsed());
                // The waiter may have expired or dropped its ticket;
                // discarding the response is correct then.
                let _ = t.send(result);
            }
        }
    }

    /// Record a failure for this part and finish it.
    pub(crate) fn fail_part(&self, metrics: &Metrics, why: &str) {
        *self.failed.lock().unwrap() = Some(why.to_string());
        self.finish_part(metrics);
    }
}

/// One unit of work for a group worker.
///
/// Carries its window's geometry (`win_start_row`/`win_rows`, in the
/// serving view's local row space) rather than a window id to be resolved
/// against a plan: the window plan is *live* (the control plane re-splits
/// boundaries between batches), so a job must stay executable under the
/// plan generation it was routed with even after the plan has moved on.
pub(crate) struct Job {
    pub(crate) window: usize,
    /// First row of the job's window in the serving view's row space.
    pub(crate) win_start_row: u64,
    /// Rows in the job's window (the calibration cache key, with start).
    pub(crate) win_rows: u64,
    pub(crate) local_rows: Vec<u32>,
    pub(crate) positions: Vec<u32>,
    pub(crate) acc: Arc<RequestAcc>,
}

pub(crate) enum WorkerMsg {
    Job(Job),
    Shutdown,
}

/// Split every request of a formed batch under `placement` and fan
/// sub-batches out to the per-group workers.  Requests whose deadline
/// already passed are failed fast (counted in `Metrics::expired`) without
/// touching a worker.  Per-window routed rows are recorded in `metrics` —
/// the adaptive placer's load signal.
pub(crate) fn dispatch_formed(
    formed: crate::coordinator::batcher::Batch<ResponseTx>,
    router: &mut Router,
    plan: &crate::coordinator::chunks::WindowPlan,
    placement: &Placement,
    senders: &[Option<mpsc::Sender<WorkerMsg>>],
    metrics: &Arc<Metrics>,
    d: usize,
) {
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    let now = Instant::now();
    for req in formed.requests {
        if req.deadline.is_some_and(|dl| dl <= now) {
            metrics.expired.fetch_add(1, Ordering::Relaxed);
            let _ = req
                .ticket
                .send(Err(anyhow!("deadline expired before dispatch")));
            continue;
        }
        let split = router.split(&req.rows, plan, placement);
        let acc = Arc::new(RequestAcc::new(
            req.rows.len() * d,
            split.sub_batches.len(),
            req.ticket,
            req.enqueued,
        ));
        for sb in split.sub_batches {
            metrics.record_window_rows(sb.window, sb.local_rows.len() as u64);
            let win = plan.windows()[sb.window];
            let job = Job {
                window: sb.window,
                win_start_row: win.start_row,
                win_rows: win.rows,
                local_rows: sb.local_rows,
                positions: sb.positions,
                acc: Arc::clone(&acc),
            };
            match senders.get(sb.group).and_then(|s| s.as_ref()) {
                Some(tx) => {
                    if tx.send(WorkerMsg::Job(job)).is_err() {
                        acc.fail_part(metrics, "worker channel closed");
                    }
                }
                None => acc.fail_part(metrics, "no worker for group"),
            }
        }
    }
}

/// The batcher → dispatcher → worker thread scaffolding both backends
/// share: owns the queue and every thread handle, spawns the dispatcher
/// loop, and knows how to drain and join on shutdown.  Backends only
/// differ in *what a worker does with a [`Job`]* — they spawn their own
/// workers and hand the senders + handles here.
pub(crate) struct Pipeline {
    pub(crate) batcher: Arc<Batcher<ResponseTx>>,
    dispatcher: Mutex<Option<std::thread::JoinHandle<()>>>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Pipeline {
    /// Spawn the dispatcher over `senders` and adopt the worker handles.
    /// The dispatcher loads the (plan, placement) pair from `cell` once per
    /// formed batch, so a [`PlacementCell::store`] (re-deal) or
    /// [`PlacementCell::store_replan`] (window re-split) from the control
    /// plane takes effect at the next batch — in-flight splits finish under
    /// the generation they started with (no drain).
    pub(crate) fn start(
        cfg: crate::coordinator::batcher::BatcherConfig,
        cell: Arc<PlacementCell>,
        metrics: Arc<Metrics>,
        d: usize,
        senders: Vec<Option<mpsc::Sender<WorkerMsg>>>,
        workers: Vec<std::thread::JoinHandle<()>>,
    ) -> anyhow::Result<Self> {
        let batcher = Arc::new(Batcher::new(cfg));
        let dispatcher = {
            let batcher = Arc::clone(&batcher);
            std::thread::Builder::new()
                .name("a100win-dispatcher".into())
                .spawn(move || {
                    let mut router = Router::new();
                    while let Some(batch) = batcher.next_batch() {
                        let (plan, placement) = cell.load_planned();
                        dispatch_formed(
                            batch, &mut router, &plan, &placement, &senders, &metrics, d,
                        );
                    }
                    for s in senders.iter().flatten() {
                        let _ = s.send(WorkerMsg::Shutdown);
                    }
                })
                .context("spawning dispatcher")?
        };
        Ok(Self {
            batcher,
            dispatcher: Mutex::new(Some(dispatcher)),
            workers: Mutex::new(workers),
        })
    }

    /// Close the queue, drain queued requests, and join every thread
    /// (idempotent; both backends call this from shutdown *and* Drop).
    pub(crate) fn stop(&self) {
        self.batcher.close();
        if let Some(d) = self.dispatcher.lock().unwrap().take() {
            let _ = d.join();
        }
        for w in self.workers.lock().unwrap().drain(..) {
            let _ = w.join();
        }
    }
}

/// The common `Backend::submit` body: validate, count, enqueue, ticket.
pub(crate) fn submit_ticketed(
    batcher: &Batcher<ResponseTx>,
    metrics: &Arc<Metrics>,
    total_rows: u64,
    batch: Batch,
) -> anyhow::Result<Ticket> {
    for &r in batch.rows.iter() {
        if r >= total_rows {
            metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow!("row {r} out of table ({total_rows} rows)"));
        }
    }
    metrics.requests.fetch_add(1, Ordering::Relaxed);
    metrics
        .rows
        .fetch_add(batch.rows.len() as u64, Ordering::Relaxed);
    if batch.rows.is_empty() {
        return Ok(Ticket::resolved(Ok(Vec::new()), Arc::clone(metrics)));
    }
    let (tx, rx) = mpsc::sync_channel(1);
    batcher
        .submit(batch.rows, batch.deadline, tx)
        .map_err(|_| anyhow!("backend is shutting down"))?;
    Ok(Ticket::new(rx, batch.deadline, Arc::clone(metrics)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics() -> Arc<Metrics> {
        Arc::new(Metrics::new())
    }

    #[test]
    fn resolved_ticket_is_ready_immediately() {
        let mut t = Ticket::resolved(Ok(vec![1.0, 2.0]), metrics());
        assert_eq!(t.poll(), TicketState::Ready);
        assert_eq!(t.wait().unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn ticket_pending_then_ready() {
        let (tx, rx) = mpsc::sync_channel(1);
        let mut t = Ticket::new(rx, None, metrics());
        assert_eq!(t.poll(), TicketState::Pending);
        tx.send(Ok(vec![3.0])).unwrap();
        assert_eq!(t.poll(), TicketState::Ready);
        // Poll buffers the result; wait returns it without a channel read.
        assert_eq!(t.wait().unwrap(), vec![3.0]);
    }

    #[test]
    fn ticket_deadline_expires() {
        let m = metrics();
        let (tx, rx) = mpsc::sync_channel::<anyhow::Result<Vec<f32>>>(1);
        let t = Ticket::new(
            rx,
            Some(Instant::now() + Duration::from_millis(10)),
            Arc::clone(&m),
        );
        let err = t.wait().unwrap_err();
        assert!(err.to_string().contains("deadline expired"), "{err}");
        assert_eq!(m.expired.load(Ordering::Relaxed), 1);
        drop(tx);
    }

    #[test]
    fn ticket_poll_reports_expired() {
        let (_tx, rx) = mpsc::sync_channel::<anyhow::Result<Vec<f32>>>(1);
        let mut t = Ticket::new(
            rx,
            Some(Instant::now() - Duration::from_millis(1)),
            metrics(),
        );
        assert_eq!(t.poll(), TicketState::Expired);
    }

    #[test]
    fn disconnected_backend_surfaces_as_error() {
        let (tx, rx) = mpsc::sync_channel::<anyhow::Result<Vec<f32>>>(1);
        drop(tx);
        let mut t = Ticket::new(rx, None, metrics());
        assert_eq!(t.poll(), TicketState::Ready);
        assert!(t.wait().is_err());
    }

    #[test]
    fn request_acc_merges_parts_and_responds_once() {
        let m = metrics();
        let (tx, rx) = mpsc::sync_channel(1);
        let acc = RequestAcc::new(4, 2, tx, Instant::now());
        acc.scatter(&[1], &[3.0, 4.0], 2);
        acc.finish_part(&m);
        assert!(rx.try_recv().is_err(), "must wait for all parts");
        acc.scatter(&[0], &[1.0, 2.0], 2);
        acc.finish_part(&m);
        assert_eq!(rx.recv().unwrap().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.latency.count(), 1);
    }

    #[test]
    fn request_acc_failure_propagates() {
        let m = metrics();
        let (tx, rx) = mpsc::sync_channel(1);
        let acc = RequestAcc::new(2, 2, tx, Instant::now());
        acc.fail_part(&m, "boom");
        acc.finish_part(&m);
        assert!(rx.recv().unwrap().is_err());
        assert_eq!(m.errors.load(Ordering::Relaxed), 1);
    }
}

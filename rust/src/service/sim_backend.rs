//! The hermetic backend: gathers execute host-side against a zero-copy
//! [`TableView`] while the discrete-event [`Machine`] supplies the
//! *device* cost model — what each SM resource group's gather rate would
//! be on the simulated A100 given the placement it was pinned under.
//!
//! This is the facade implementation every serving scenario can run under
//! tier-1: no PJRT, no artifacts, same batcher → dispatcher →
//! [`Router`](crate::coordinator::Router) split → per-group worker → merge
//! pipeline as the PJRT [`EmbeddingServer`](crate::coordinator::EmbeddingServer).
//!
//! Timing model: serving a sub-batch of `k` rows from a window on group
//! `g` costs `k * ns_per_row(g, window)` of simulated device time, where
//! `ns_per_row` is calibrated once per (group, window-geometry) pair by
//! running the DES with that group's SMs uniform-random over the window's
//! byte region (then memoized).  Under `GroupToChunk` the regions sit
//! below TLB reach and the rates land at the paper's full-speed plateau;
//! under `Naive` whole-table placement they collapse exactly like Fig 1.
//! With [`SimTiming::Probed`] the DES is skipped and the probe map's
//! `solo_gbps` is used directly (fast startup for load-generation tests).
//!
//! Live knobs on top of the cost model:
//!
//! * **Pacing** (`sim_timescale > 0`): each group completes jobs no faster
//!   than `sim_ns * timescale` of wall clock (a serial device per group),
//!   so bench-serve's wall-clock knee becomes policy-dependent — thrashing
//!   placements knee earlier, exactly like the real device would.
//! * **Repartitioning** (`adaptive: Some(..)`): the live (plan, placement)
//!   pair sits in a generation-stamped [`PlacementCell`]; each epoch
//!   ([`SimBackend::rebalance_epoch`] or the background thread) the
//!   embedded [`ControlPlane`] judges the load/capacity imbalance and
//!   permits the cheapest fixing lever — a group re-*deal*
//!   ([`AdaptivePlacer`]) first, then (with `resplit: Some(..)`) a window
//!   boundary re-*split* ([`PlanSplitter`]) for skew hotter than group
//!   granularity can absorb.  Swaps land at the next formed batch, never
//!   draining in-flight tickets.
//! * **Health** ([`SimBackend::set_group_health`]): a group marked
//!   Degraded/Failed triggers an *immediate* control-plane epoch (no
//!   timer wait) that re-deals the windows over the surviving groups;
//!   recovery is folded back in by the next regular epoch.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context};

use crate::coordinator::adaptive::{AdaptiveConfig, AdaptivePlacer};
use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::chunks::WindowPlan;
use crate::coordinator::controlplane::{
    capacity_imbalance, committed_delta_atomic, load_shares, rebaseline_atomic, ControlPlane,
    ControlPlaneConfig, Decision, Lever,
};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::placement::{
    Placement, PlacementCell, PlacementPolicy, Placer, StaticPlacer, WindowSignals,
};
use crate::coordinator::remap::{RemapConfig, RemapPlan, WindowRemap};
use crate::coordinator::replan::{PlanSplitter, SplitterConfig};
use crate::coordinator::state::{CoordinatorState, GroupHealth};
use crate::coordinator::table::TableView;
use crate::probe::TopologyMap;
use crate::sim::{
    FaultInjector, FaultPlan, JobFault, Machine, MeasurementSpec, MemRegion, Pattern, SmId,
};

use super::backend::{
    submit_ticketed, AccPool, Backend, Batch, DataPath, Job, Pipeline, ReqHandle, Shells,
    Ticket, WorkQueue, WorkSender, JOB_RING_CAP, SHELL_RING_CAP,
};
use super::resilience::{BreakerState, ResilienceConfig, ResilienceCtx};
use super::ring::{self, EpochGate};
use super::scatter::SlabPool;

/// Where the per-(group, window) service rates come from.
#[derive(Clone)]
pub enum SimTiming {
    /// Calibrate by running the DES (one short measurement per pair,
    /// memoized; workers share the machine's warm-TLB cache).  Boxed: a
    /// `Machine` is ~40x the size of the other variant.
    Machine(Box<Machine>),
    /// Use the probe map's `solo_gbps` as-is — no DES at serve time.
    Probed,
}

impl SimTiming {
    /// Convenience constructor for the DES-calibrated variant.
    pub fn machine(m: Machine) -> Self {
        Self::Machine(Box::new(m))
    }
}

#[derive(Debug, Clone)]
pub struct SimBackendConfig {
    pub policy: PlacementPolicy,
    pub batcher: BatcherConfig,
    pub seed: u64,
    /// Accesses per SM for each calibration measurement.
    pub calib_accesses_per_sm: u64,
    /// Skew-aware rebalancing: `Some` routes placement through an
    /// [`AdaptivePlacer`] (initially the group-to-chunk deal; `policy` is
    /// ignored for placement then) and enables epoch rebalancing.
    pub adaptive: Option<AdaptiveConfig>,
    /// Two-level repartitioning: additionally let the control plane
    /// re-*split* window boundaries when the re-deal cannot balance the
    /// observed skew.  Requires `adaptive` (ignored without it).
    pub resplit: Option<SplitterConfig>,
    /// TLB-aware hot-row packing: `Some` enables the repack lever — routed
    /// rows feed a decayed frequency sketch, and when the control plane
    /// escalates past re-deal/re-split, hot rows are densified into
    /// page-aligned window prefixes published as a live [`RemapPlan`].
    /// Requires `adaptive` (the epoch machinery); ignored without it.
    pub remap: Option<RemapConfig>,
    /// Escalation policy for the embedded [`ControlPlane`] (thresholds,
    /// patience, cooldown).  `max_lever` is clamped to what this backend
    /// can actually do: `Redeal` without `resplit`, `Resplit` with it,
    /// `Repack` when `remap` is enabled (the per-card ladder skips the
    /// fleet-only `Migrate` rung by honest decline).
    pub control: ControlPlaneConfig,
    /// Wall-clock pacing of simulated device time: each group's job
    /// completions are delayed so wall ≥ `sim_ns * sim_timescale`
    /// (1.0 = a simulated nanosecond costs a wall nanosecond).  0 disables
    /// pacing — gathers complete at host speed and device time is only
    /// *accounted* (`sim_report`).
    pub sim_timescale: f64,
    /// Run the pre-slab request pipeline (mutexed accumulator, mpsc worker
    /// channels, per-ticket `sync_channel`, per-job gather `Vec`) instead
    /// of the slab/ring path.  Kept as the perf oracle for
    /// `benches/serve_hotpath.rs --legacy-path`; results are identical,
    /// only the copy/lock/allocation count differs.
    pub legacy_path: bool,
    /// Self-healing knobs: retries, hedging, partial results, circuit
    /// breakers.  The default (everything off) leaves the hot path
    /// bit-identical to a resilience-free build.
    pub resilience: ResilienceConfig,
    /// Deterministic fault injection (tests and the chaos harness): a
    /// seeded schedule of worker stalls, outages, and health flaps,
    /// evaluated per job on each group's own job clock.  `None` injects
    /// nothing and costs nothing.
    pub fault: Option<FaultPlan>,
    /// Pin each group's worker thread to its own core
    /// ([`crate::util::threads::pin_to_core`], best effort, Linux only):
    /// NUMA hygiene for long-lived gather loops.  Off by default — CI
    /// runners and laptops share cores with everything else.
    pub pin_cores: bool,
}

impl SimBackendConfig {
    pub fn new(policy: PlacementPolicy) -> Self {
        Self {
            policy,
            batcher: BatcherConfig::default(),
            seed: 0xC0FFEE,
            calib_accesses_per_sm: 2_000,
            adaptive: None,
            resplit: None,
            remap: None,
            control: ControlPlaneConfig::default(),
            sim_timescale: 0.0,
            legacy_path: false,
            resilience: ResilienceConfig::default(),
            fault: None,
            pin_cores: false,
        }
    }

    /// Convenience: enable both repartitioning levers with defaults.
    pub fn two_level(policy: PlacementPolicy) -> Self {
        Self {
            adaptive: Some(AdaptiveConfig::default()),
            resplit: Some(SplitterConfig::default()),
            ..Self::new(policy)
        }
    }
}

/// Simulated-device accounting per group.
#[derive(Debug, Default)]
struct GroupServeStats {
    rows: AtomicU64,
    sim_ns: AtomicU64,
}

/// One group's slice of the simulated-device report.
#[derive(Debug, Clone)]
pub struct GroupSimReport {
    pub group: usize,
    /// Rows this group gathered.
    pub rows: u64,
    /// Simulated device time it spent doing so, milliseconds.
    pub sim_ms: f64,
    /// Implied device-side gather throughput, GB/s.
    pub simulated_gbps: f64,
}

/// Everything a control-plane epoch needs — shared between
/// [`SimBackend::rebalance_epoch`], [`SimBackend::set_group_health`], and
/// the optional background thread.
struct ControlCtx {
    placer: Arc<dyn Placer>,
    splitter: Option<PlanSplitter>,
    plane: ControlPlane,
    cell: Arc<PlacementCell>,
    map: TopologyMap,
    metrics: Arc<Metrics>,
    batcher: Arc<Batcher<ReqHandle>>,
    /// Repack-lever tuning (None disables the lever entirely).
    remap_cfg: Option<RemapConfig>,
    /// Zero-copy gather source the repack lever builds packed slabs from.
    view: TableView,
    /// The placer's signal floor (0 for static placers): epochs below it
    /// accumulate into the next one instead of being discarded.
    min_epoch_rows: u64,
    /// Serializes whole epochs (and health transitions with their
    /// immediate epoch): without it, a timer epoch that read "all healthy"
    /// could publish a health-blind re-deal *after* a concurrent
    /// `set_group_health` swap, transiently re-including a Failed group.
    /// An atomic spin gate, not a mutex: epochs are rare and short.
    gate: EpochGate,
    /// Per-window routed-row totals at the previous *committed* epoch
    /// boundary (atomics, sized like `metrics.window_rows` — the maximum
    /// window count a re-split can publish).
    last_rows: Vec<AtomicU64>,
    /// Group health as last reported via `set_group_health`, plus the
    /// versioned coordinator view of it (epochs, degraded-reach flag).
    health: Mutex<CoordinatorState>,
}

impl ControlCtx {
    /// Delta the per-window load counters since the last committed epoch
    /// (see [`committed_delta_atomic`]: starved epochs roll their rows
    /// into the next one).
    fn window_delta(&self, windows: usize) -> Vec<u64> {
        let totals = self.metrics.window_rows_snapshot();
        let mut delta = committed_delta_atomic(&self.last_rows, &totals, self.min_epoch_rows);
        delta.truncate(windows);
        delta
    }

    /// Close one epoch: observe, let the control plane pick the strongest
    /// permitted lever, try levers cheapest-first, publish.  Returns the
    /// new generation when a swap happened.
    fn epoch(&self) -> Option<u64> {
        let _serialized = self.gate.lock();
        self.epoch_inner()
    }

    fn epoch_inner(&self) -> Option<u64> {
        // Age the hot-set signal once per epoch: the sketch must track the
        // *current* skew, not everything since startup, or drift could
        // never displace a stale hot set.
        if self.remap_cfg.is_some() {
            if let Some(sketch) = &self.metrics.row_freq {
                sketch.decay();
            }
        }
        let (plan, current) = self.cell.load_planned();
        let w = plan.count();
        let signals = WindowSignals {
            rows: self.window_delta(w),
            mean_latency_us: self.metrics.latency.mean_us(),
            queued_rows: self.batcher.pending_rows() as u64,
        };

        // Unhealthy groups override the escalation ladder: a Failed or
        // Degraded group must come out of (or be deprioritized in) the
        // deal now, not after hysteresis.
        let all_healthy = {
            let st = self.health.lock().unwrap();
            st.health.iter().all(|&h| h == GroupHealth::Healthy)
        };
        if !all_healthy {
            return self.health_epoch(&plan, &current, &signals);
        }

        let imbalance = match load_shares(&signals.rows) {
            None => 0.0,
            Some(load) => {
                let total_cap: f64 = self.map.solo_gbps.iter().sum();
                let caps: Vec<f64> = (0..w)
                    .map(|wid| {
                        current.groups_of_window[wid]
                            .iter()
                            .map(|&q| self.map.solo_gbps[q])
                            .sum::<f64>()
                            / total_cap
                    })
                    .collect();
                capacity_imbalance(&load, &caps)
            }
        };

        let permitted = self.plane.permit(imbalance);
        if permitted == Lever::Hold {
            self.plane
                .record(permitted, None, imbalance, None, "healthy or cooling down");
            return None;
        }

        // Lever 1 (cheapest): re-deal groups under the current boundaries.
        if let Some(next) = self.placer.rebalance(&current, &self.map, &plan, &signals) {
            // Live-swap safety gate, active in release builds: a placement
            // the router cannot serve (custom `Placer`s are untrusted) is
            // dropped rather than published — stranding the swap, never
            // the tickets.
            if let Err(why) = next.check_servable(plan.count(), self.map.groups.len()) {
                debug_assert!(false, "placer proposed an unservable placement: {why}");
                self.plane
                    .record(permitted, None, imbalance, None, "unservable re-deal dropped");
                return None;
            }
            let generation = self.cell.store(next);
            self.metrics.redeal_epochs.fetch_add(1, Ordering::Relaxed);
            self.metrics
                .generations_published
                .fetch_add(1, Ordering::Relaxed);
            self.plane.record(
                permitted,
                Some(Lever::Redeal),
                imbalance,
                Some(generation),
                "re-dealt groups over current windows",
            );
            return Some(generation);
        }

        // Lever 2: re-split the window boundaries themselves.
        if permitted >= Lever::Resplit {
            if let Some(splitter) = &self.splitter {
                if let Some((new_plan, placement)) = splitter.replan(&plan, &self.map, &signals)
                {
                    if let Err(why) =
                        placement.check_servable(new_plan.count(), self.map.groups.len())
                    {
                        debug_assert!(false, "splitter proposed an unservable plan: {why}");
                        self.plane.record(
                            permitted,
                            None,
                            imbalance,
                            None,
                            "unservable re-split dropped",
                        );
                        return None;
                    }
                    let count = new_plan.count();
                    let generation = self.cell.store_replan(new_plan, placement);
                    // Window ids changed meaning: re-baseline the signal.
                    rebaseline_atomic(&self.last_rows, &self.metrics.window_rows_snapshot());
                    self.metrics.resplit_epochs.fetch_add(1, Ordering::Relaxed);
                    self.metrics
                        .generations_published
                        .fetch_add(1, Ordering::Relaxed);
                    self.plane.record(
                        permitted,
                        Some(Lever::Resplit),
                        imbalance,
                        Some(generation),
                        format!("re-split boundaries into {count} windows"),
                    );
                    return Some(generation);
                }
            }
        }

        // Lever 3 (migrate) is fleet-wide — FleetService moves shards
        // between cards; a per-card backend declines that rung honestly
        // and the plane's streak escalates past it to the next epoch's
        // permit.  Lever 4 (repack): copy learned hot rows into
        // page-aligned packed prefixes — the only lever that moves row
        // *data* within a card, so it sits last on the ladder.
        if permitted >= Lever::Repack && self.remap_cfg.is_some() {
            return self.plan_repack(&plan, permitted, imbalance);
        }

        self.plane
            .record(permitted, None, imbalance, None, "permitted levers declined");
        None
    }

    /// The repack lever: read the decayed row-frequency sketch, group the
    /// surviving hot rows by window, and pack every window whose hot set
    /// carries at least `min_hot_share` of the *guaranteed* observed
    /// traffic into a page-aligned prefix.  Windows whose live remap still
    /// covers the learned hot set (`min_overlap_to_hold`) carry it over
    /// unchanged — hysteresis against re-copying a stable hot set.
    fn plan_repack(&self, plan: &WindowPlan, permitted: Lever, imbalance: f64) -> Option<u64> {
        // PANIC: guarded by the remap_cfg.is_some() gate at the call site.
        let cfg = self.remap_cfg.as_ref().expect("repack lever needs a config");
        let sketch = self.metrics.row_freq.as_ref()?;
        let observed = sketch.observed();
        if observed == 0 {
            self.plane
                .record(permitted, None, imbalance, None, "repack: no routed-row signal yet");
            return None;
        }
        // Sketch rows are global; bucket them by owning window as local
        // ids, keeping the sketch's hottest-first order per window.
        let w = plan.count();
        let mut cands: Vec<Vec<u32>> = vec![Vec::new(); w];
        let mut guaranteed: Vec<u64> = vec![0; w];
        for (row, count) in sketch.top() {
            if row >= plan.total_rows {
                continue; // stale entry from before a table change
            }
            let win = plan.window_of(row);
            cands[win.id].push((row - win.start_row) as u32);
            guaranteed[win.id] += count;
        }

        let live = self.cell.remap();
        let mut next = RemapPlan::with_windows(w);
        let mut packed = 0usize;
        let mut carried = 0usize;
        let mut rows_packed = 0u64;
        for win in plan.windows() {
            let wid = win.id;
            let share = guaranteed[wid] as f64 / observed as f64;
            // Hold: the live packing still covers (almost all of) the
            // learned hot set — keep the existing slab, no copy.
            if let Some(cur) = live.window_remap(wid) {
                if cur.matches(win) && !cands[wid].is_empty() {
                    let cur_hot: std::collections::HashSet<u32> =
                        cur.hot_logical_rows().into_iter().collect();
                    let overlap = cands[wid].iter().filter(|c| cur_hot.contains(c)).count();
                    if overlap as f64 / cands[wid].len() as f64 >= cfg.min_overlap_to_hold {
                        next.set_window(wid, Some(Arc::clone(cur)));
                        carried += 1;
                        continue;
                    }
                }
            }
            if share < cfg.min_hot_share || cands[wid].is_empty() {
                continue; // identity: traffic here is too flat to pack
            }
            if let Some(remap) = WindowRemap::pack(&self.view, win, &cands[wid], share, cfg) {
                rows_packed += remap.hot_rows() as u64;
                packed += 1;
                next.set_window(wid, Some(remap));
            }
        }
        if packed == 0 {
            let why = if carried > 0 {
                "repack: live packing still covers the hot set"
            } else {
                "repack: no window clears the hot-share floor"
            };
            self.plane.record(permitted, None, imbalance, None, why);
            return None;
        }
        let generation = self.cell.store_remap(next);
        self.metrics.repack_epochs.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .rows_repacked
            .fetch_add(rows_packed, Ordering::Relaxed);
        self.metrics
            .generations_published
            .fetch_add(1, Ordering::Relaxed);
        self.plane.record(
            permitted,
            Some(Lever::Repack),
            imbalance,
            Some(generation),
            format!("repacked {packed} window(s): {rows_packed} hot rows into page-aligned prefixes"),
        );
        Some(generation)
    }

    /// The health lever: re-deal the current windows over the surviving
    /// groups (Failed groups excluded, Degraded at half weight).  Runs
    /// outside the escalation ladder, but only *eviction* bypasses
    /// hysteresis: while a Failed group still sits on a serving list the
    /// swap is unconditional (drain correctness); once every serving list
    /// is clean, steady-state re-deals under long-lived Degraded/Failed
    /// groups gate on the plane's `min_imbalance` so noisy load cannot
    /// churn a generation per epoch.
    fn health_epoch(
        &self,
        plan: &WindowPlan,
        current: &Placement,
        signals: &WindowSignals,
    ) -> Option<u64> {
        // Health bypasses the ladder but still opens a plane epoch, so the
        // decision trace stays strictly epoch-ordered.
        self.plane.open_unladdered();
        let g = self.map.groups.len();
        let w = plan.count();
        let weights: Vec<f64> = {
            let st = self.health.lock().unwrap();
            (0..g)
                .map(|q| match st.health[q] {
                    GroupHealth::Failed => 0.0,
                    GroupHealth::Degraded => self.map.solo_gbps[q] * 0.5,
                    GroupHealth::Healthy => self.map.solo_gbps[q],
                })
                .collect()
        };
        let live: Vec<usize> = (0..g).filter(|&q| weights[q] > 0.0).collect();
        if live.is_empty() {
            self.plane
                .record(Lever::Redeal, None, 1.0, None, "all groups failed");
            return None;
        }
        let load_share: Vec<f64> =
            load_shares(&signals.rows).unwrap_or_else(|| vec![1.0 / w as f64; w]);

        // Steady-state hysteresis: when no failed group needs evicting,
        // only act on a real load/weighted-capacity mismatch.  Exception:
        // a recovered group (half-open breaker, Degraded health) absent
        // from *every* serving list must be folded back in now — probe
        // traffic cannot reach a group no placement routes to, so the
        // breaker could never close.
        let must_evict = current
            .groups_of_window
            .iter()
            .flatten()
            .any(|&q| weights[q] == 0.0);
        let must_include = (0..g).any(|q| {
            weights[q] > 0.0 && !current.groups_of_window.iter().any(|ws| ws.contains(&q))
        });
        if !must_evict && !must_include {
            let total_weight: f64 = weights.iter().sum();
            let caps: Vec<f64> = (0..w)
                .map(|wid| {
                    current.groups_of_window[wid]
                        .iter()
                        .map(|&q| weights[q])
                        .sum::<f64>()
                        / total_weight.max(1e-9)
                })
                .collect();
            let imbalance = capacity_imbalance(&load_share, &caps);
            if imbalance < self.plane.config().min_imbalance {
                self.plane.record(
                    Lever::Redeal,
                    None,
                    imbalance,
                    None,
                    "degraded but balanced; holding",
                );
                return None;
            }
        }

        let mut groups_of_window: Vec<Vec<usize>> = vec![Vec::new(); w];
        let mut window_of_group: Vec<usize> = (0..g)
            .map(|q| current.window_of_group.get(q).copied().unwrap_or(0))
            .collect();
        if live.len() >= w {
            // Capacity-proportional deal over the live sub-map; indices
            // mapped back through `live`.
            let sub_map = TopologyMap {
                groups: live.iter().map(|&q| self.map.groups[q].clone()).collect(),
                reach_bytes: self.map.reach_bytes,
                solo_gbps: live.iter().map(|&q| weights[q]).collect(),
                independent: self.map.independent,
                card_id: self.map.card_id.clone(),
            };
            let (sub_gow, _) = AdaptivePlacer::deal(&sub_map, &load_share);
            for (wid, subs) in sub_gow.into_iter().enumerate() {
                for si in subs {
                    groups_of_window[wid].push(live[si]);
                    window_of_group[live[si]] = wid;
                }
            }
        } else {
            // Degraded-reach mode (the Fig-1 regime): fewer live groups
            // than windows — live groups straddle several windows rather
            // than failing the table.
            for wid in 0..w {
                let q = live[wid % live.len()];
                groups_of_window[wid].push(q);
                // Last assignment wins; serving correctness only reads
                // groups_of_window.
                window_of_group[q] = wid;
            }
        }
        if groups_of_window == current.groups_of_window {
            self.plane
                .record(Lever::Redeal, None, 0.0, None, "health deal unchanged");
            return None;
        }
        let next = Placement {
            policy: PlacementPolicy::GroupToChunk,
            generation: current.generation,
            groups_of_window,
            window_of_group,
        };
        if let Err(why) = next.check_servable(w, g) {
            debug_assert!(false, "health deal unservable: {why}");
            return None;
        }
        let generation = self.cell.store(next);
        self.metrics.redeal_epochs.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .generations_published
            .fetch_add(1, Ordering::Relaxed);
        self.plane.record(
            Lever::Redeal,
            Some(Lever::Redeal),
            0.0,
            Some(generation),
            "health-driven re-deal over surviving groups",
        );
        Some(generation)
    }
}

/// The running sim-backed server.
pub struct SimBackend {
    pipeline: Pipeline,
    metrics: Arc<Metrics>,
    row_bytes: u64,
    view: TableView,
    placement: Arc<PlacementCell>,
    stats: Arc<Vec<GroupServeStats>>,
    control: Arc<ControlCtx>,
    /// Which request pipeline `submit` runs (slab/ring default, or the
    /// `legacy_path` oracle); the slab variant carries the output pool
    /// that `Backend::recycle` feeds.
    path: DataPath,
    /// Tickets carry a partial-result source (slab path only).
    partials: bool,
    /// The resilience runtime (retry/hedge/breaker), when any is enabled.
    resilience: Option<Arc<ResilienceCtx>>,
    /// The fault injector, when a plan is installed (test/chaos only).
    injector: Option<Arc<FaultInjector>>,
    epoch_stop: Arc<AtomicBool>,
    epoch_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl SimBackend {
    /// Start the backend with a placement built by `cfg`'s placer (the
    /// static `cfg.policy` arm, or the adaptive group-to-chunk deal when
    /// `cfg.adaptive` is set).
    pub fn start(
        cfg: SimBackendConfig,
        map: &TopologyMap,
        plan: WindowPlan,
        view: TableView,
        timing: SimTiming,
    ) -> anyhow::Result<Self> {
        map.validate()?;
        let placer = Self::placer_of(&cfg);
        let placement = placer.place(map, &plan, cfg.seed)?;
        Self::start_inner(cfg, map, plan, placement, view, timing)
    }

    /// Start with a prebuilt placement (fleet shards carry their own).
    pub fn start_with_placement(
        cfg: SimBackendConfig,
        map: &TopologyMap,
        plan: WindowPlan,
        placement: Placement,
        view: TableView,
        timing: SimTiming,
    ) -> anyhow::Result<Self> {
        Self::start_inner(cfg, map, plan, placement, view, timing)
    }

    fn placer_of(cfg: &SimBackendConfig) -> Arc<dyn Placer> {
        match &cfg.adaptive {
            Some(a) => Arc::new(AdaptivePlacer::new(a.clone())),
            None => Arc::new(StaticPlacer(cfg.policy)),
        }
    }

    fn start_inner(
        cfg: SimBackendConfig,
        map: &TopologyMap,
        plan: WindowPlan,
        placement: Placement,
        view: TableView,
        timing: SimTiming,
    ) -> anyhow::Result<Self> {
        if view.rows() != plan.total_rows {
            return Err(anyhow!(
                "table view has {} rows but plan covers {}",
                view.rows(),
                plan.total_rows
            ));
        }
        // The legacy oracle predates claim tokens and partial masks; its
        // mutexed accumulator cannot express either.  Refuse the combination
        // rather than silently double-writing under hedges.
        if cfg.legacy_path && cfg.resilience.enabled() {
            return Err(anyhow!(
                "resilience features are not supported on --legacy-path"
            ));
        }
        // A mismatched placement must fail deterministically here, not as
        // an index panic in the dispatcher mid-serving (the router only
        // debug-asserts; prebuilt placements arrive via
        // `start_with_placement`).
        if let Err(why) = placement.check_servable(plan.count(), map.groups.len()) {
            return Err(anyhow!("placement is unservable: {why}"));
        }
        // The window-rows registry is sized for the *largest* plan a
        // re-split can publish: one window per group.
        let metrics = Metrics::for_windows(map.groups.len().max(plan.count()));
        // The repack lever learns its hot set from a space-bounded
        // row-frequency sketch fed by the dispatcher; without `--remap`
        // the sketch (and its hot-path sampling cost) does not exist.
        let metrics = Arc::new(match &cfg.remap {
            Some(rc) => metrics.with_row_sketch(rc.sketch_rows),
            None => metrics,
        });
        let row_bytes = plan.row_bytes;
        let stats: Arc<Vec<GroupServeStats>> =
            Arc::new((0..map.groups.len()).map(|_| Default::default()).collect());

        // One worker per group in the map — not just the initially-serving
        // ones: a placement swap may hand any group any window, and the
        // memoized per-window calibration happens lazily on first contact.
        //
        // Each worker gets a bounded SPSC job ring from the dispatcher and
        // a return ring carrying emptied index shells back (the default
        // path); the legacy oracle keeps the original mpsc channels.
        let path = if cfg.legacy_path {
            DataPath::Legacy
        } else {
            // Partial delivery needs the per-slot claim bitmap tracked in
            // release builds too.
            DataPath::Slab {
                pool: SlabPool::with_claims(cfg.resilience.partials),
                accs: AccPool::new(),
            }
        };
        let acc_pool = match &path {
            DataPath::Slab { accs, .. } => Some(Arc::clone(accs)),
            DataPath::Legacy => None,
        };
        // The resilience runtime exists only when a recovery feature is on;
        // `None` keeps workers and dispatcher on the exact pre-existing
        // code path.
        let resilience = cfg
            .resilience
            .needs_ctx()
            .then(|| ResilienceCtx::new(cfg.resilience.clone(), Arc::clone(&metrics), map.groups.len()));
        let injector = cfg
            .fault
            .as_ref()
            .filter(|p| !p.is_empty())
            .map(|p| Arc::new(FaultInjector::new(p.clone(), map.groups.len())));
        let mut senders: Vec<Option<WorkSender>> = Vec::new();
        let mut shell_returns: Vec<ring::Consumer<Shells>> = Vec::new();
        let mut workers = Vec::new();
        for g in 0..map.groups.len() {
            let (sender, queue, shells) = if cfg.legacy_path {
                let (tx, rx) = mpsc::channel();
                (WorkSender::Legacy(tx), WorkQueue::Legacy(rx), None)
            } else {
                let (tx, rx) = ring::spsc::<Job>(JOB_RING_CAP);
                let (shell_tx, shell_rx) = ring::spsc::<Shells>(SHELL_RING_CAP);
                shell_returns.push(shell_rx);
                (WorkSender::Ring(tx), WorkQueue::Ring(rx), Some(shell_tx))
            };
            senders.push(Some(sender));
            let mut worker = SimWorker {
                group: g,
                sms: map.groups[g].clone(),
                machine: match &timing {
                    SimTiming::Machine(m) => Some(m.as_ref().clone()),
                    SimTiming::Probed => None,
                },
                solo_gbps: map.solo_gbps[g].max(1e-9),
                calib_accesses: cfg.calib_accesses_per_sm.max(1),
                row_bytes,
                view: view.clone(),
                metrics: Arc::clone(&metrics),
                stats: Arc::clone(&stats),
                ns_per_row: HashMap::new(),
                last_rate: None,
                // Non-finite or negative timescales disable pacing rather
                // than poisoning every Duration computation downstream.
                timescale: if cfg.sim_timescale.is_finite() {
                    cfg.sim_timescale.max(0.0)
                } else {
                    0.0
                },
                next_free: None,
                shells,
                resilience: resilience.clone(),
                injector: injector.clone(),
            };
            let pin = cfg.pin_cores;
            let handle = std::thread::Builder::new()
                .name(format!("a100win-sim-g{g}"))
                .spawn(move || {
                    if pin {
                        // Best effort: an unpinnable core (shrunk cpuset,
                        // exotic arch) must not take the worker down.
                        let _ = crate::util::threads::pin_to_core(g);
                    }
                    queue.for_each_job(|job| worker.execute(job))
                })
                .context("spawning sim worker")?;
            workers.push(handle);
        }

        let state = CoordinatorState::new(&placement, map.groups.len());
        let cell = Arc::new(PlacementCell::new(Arc::new(plan), placement));
        let pipeline = Pipeline::start(
            cfg.batcher.clone(),
            Arc::clone(&cell),
            Arc::clone(&metrics),
            view.d(),
            senders,
            shell_returns,
            acc_pool,
            workers,
            resilience.clone(),
        )?;

        // The control plane may only pull levers this backend has.  Repack
        // sits above re-split on the ladder, so enabling it implies the
        // adaptive signal plumbing is on too.
        let mut plane_cfg = cfg.control.clone();
        plane_cfg.max_lever = if cfg.adaptive.is_some() && cfg.remap.is_some() {
            Lever::Repack
        } else if cfg.adaptive.is_some() && cfg.resplit.is_some() {
            Lever::Resplit
        } else {
            Lever::Redeal
        };
        let control = Arc::new(ControlCtx {
            placer: Self::placer_of(&cfg),
            splitter: cfg
                .adaptive
                .as_ref()
                .and(cfg.resplit.as_ref())
                .map(|s| PlanSplitter::new(s.clone())),
            plane: ControlPlane::new(plane_cfg),
            cell: Arc::clone(&cell),
            map: map.clone(),
            metrics: Arc::clone(&metrics),
            batcher: Arc::clone(&pipeline.batcher),
            remap_cfg: cfg.remap.clone(),
            view: view.clone(),
            min_epoch_rows: cfg.adaptive.as_ref().map_or(0, |a| a.min_epoch_rows),
            gate: EpochGate::new(),
            // Sized like the window-rows registry (maximum plan a re-split
            // can publish), so re-splits never re-shape the baseline.
            last_rows: (0..metrics.window_rows.len())
                .map(|_| AtomicU64::new(0))
                .collect(),
            health: Mutex::new(state),
        });

        // Wire breaker transitions into the control plane: a state change
        // becomes a health transition + an immediate epoch (under the same
        // gate `set_group_health` uses), audited in the decision trace.
        // The breaker never routes traffic itself — eviction/re-inclusion
        // always flows through the placement the dispatcher already reads.
        if let Some(res) = &resilience {
            if res.cfg.breaker.is_some() {
                let ctx = Arc::clone(&control);
                res.install_hook(Arc::new(move |group, state| {
                    let health = match state {
                        BreakerState::Closed => GroupHealth::Healthy,
                        BreakerState::HalfOpen => GroupHealth::Degraded,
                        BreakerState::Open => GroupHealth::Failed,
                    };
                    let _serialized = ctx.gate.lock();
                    {
                        let mut st = ctx.health.lock().unwrap();
                        let _ = st.set_health(group, health, &ctx.map);
                    }
                    ctx.plane.note(format!("breaker: group {group} -> {state:?}"));
                    let _ = ctx.epoch_inner();
                }));
            }
            res.start_monitor();
        }

        let epoch_stop = Arc::new(AtomicBool::new(false));
        let epoch_thread = match cfg.adaptive.as_ref().and_then(|a| a.epoch) {
            None => None,
            Some(epoch) => {
                let ctx = Arc::clone(&control);
                let stop = Arc::clone(&epoch_stop);
                let tick = epoch
                    .min(Duration::from_millis(5))
                    .max(Duration::from_micros(100));
                Some(
                    std::thread::Builder::new()
                        .name("a100win-rebalancer".into())
                        .spawn(move || {
                            let mut since = Duration::ZERO;
                            while !stop.load(Ordering::Relaxed) {
                                std::thread::sleep(tick);
                                since += tick;
                                if since >= epoch {
                                    since = Duration::ZERO;
                                    let _ = ctx.epoch();
                                }
                            }
                        })
                        .context("spawning rebalancer")?,
                )
            }
        };

        Ok(Self {
            pipeline,
            metrics,
            row_bytes,
            view,
            placement: cell,
            stats,
            control,
            path,
            partials: cfg.resilience.partials && !cfg.legacy_path,
            resilience,
            injector,
            epoch_stop,
            epoch_thread: Mutex::new(epoch_thread),
        })
    }

    /// The current live window plan (re-splits swap it between batches).
    pub fn plan(&self) -> Arc<WindowPlan> {
        self.placement.plan()
    }

    pub fn table_view(&self) -> &TableView {
        &self.view
    }

    /// The current live placement (generation-stamped; swaps bump it).
    pub fn placement(&self) -> Arc<Placement> {
        self.placement.load()
    }

    /// The live hot-row remap plan (identity until the repack lever
    /// publishes a packing).  Harnesses use this to audit invariants
    /// mid-serving via [`RemapPlan::check`].
    pub fn remap_plan(&self) -> Arc<RemapPlan> {
        self.placement.remap()
    }

    /// Close one control-plane epoch by hand: observe the epoch's
    /// per-window load, pick the cheapest permitted lever (re-deal, then
    /// re-split), publish.  Returns the new generation when a swap
    /// happened.  (The background thread configured by
    /// `AdaptiveConfig::epoch` calls exactly this.)
    pub fn rebalance_epoch(&self) -> Option<u64> {
        self.control.epoch()
    }

    /// Report a group health transition and run an immediate control-plane
    /// epoch (ROADMAP item (a): health events must not wait for the
    /// timer).  Returns the generation published by the resulting swap, if
    /// any.
    pub fn set_group_health(
        &self,
        group: usize,
        health: GroupHealth,
    ) -> anyhow::Result<Option<u64>> {
        // Transition + immediate epoch are one atomic unit under the epoch
        // gate: a concurrent timer epoch cannot publish a health-blind
        // re-deal built before this transition after its swap.
        let _serialized = self.control.gate.lock();
        {
            let mut st = self.control.health.lock().unwrap();
            st.set_health(group, health, &self.control.map)?;
        }
        Ok(self.control.epoch_inner())
    }

    /// The coordinator's versioned view of group health (epochs bumped per
    /// transition, degraded-reach flag when fewer live groups than
    /// windows).
    pub fn health_state(&self) -> CoordinatorState {
        self.control.health.lock().unwrap().clone()
    }

    /// The control plane's audited decision trace, oldest first.
    pub fn control_decisions(&self) -> Vec<Decision> {
        self.control.plane.decisions()
    }

    /// What the simulated device did: per-group rows, device time, and the
    /// implied gather throughput under the active placement.
    pub fn sim_report(&self) -> Vec<GroupSimReport> {
        let row_bytes = self.row_bytes as f64;
        self.stats
            .iter()
            .enumerate()
            .filter(|(_, s)| s.rows.load(Ordering::Relaxed) > 0)
            .map(|(group, s)| {
                let rows = s.rows.load(Ordering::Relaxed);
                let ns = s.sim_ns.load(Ordering::Relaxed).max(1) as f64;
                GroupSimReport {
                    group,
                    rows,
                    sim_ms: ns / 1e6,
                    simulated_gbps: rows as f64 * row_bytes / ns,
                }
            })
            .collect()
    }

    /// Device-side aggregate throughput implied by the busiest group
    /// (makespan model: groups gather in parallel, so the slowest group's
    /// simulated time bounds the run).  This is the number skew-aware
    /// placement moves: balancing load across groups shrinks the max.
    pub fn aggregate_sim_gbps(&self) -> f64 {
        let total_rows: u64 = self
            .stats
            .iter()
            .map(|s| s.rows.load(Ordering::Relaxed))
            .sum();
        let max_ns = self
            .stats
            .iter()
            .map(|s| s.sim_ns.load(Ordering::Relaxed))
            .max()
            .unwrap_or(0);
        if max_ns == 0 {
            return 0.0;
        }
        total_rows as f64 * self.row_bytes as f64 / max_ns as f64
    }

    /// Zero the simulated-device accounting (benchmark harness hook:
    /// measure a steady state without the convergence phase's makespan).
    pub fn reset_sim_stats(&self) {
        for s in self.stats.iter() {
            s.rows.store(0, Ordering::Relaxed);
            s.sim_ns.store(0, Ordering::Relaxed);
        }
    }

    /// Stalls and failures the installed fault plan has injected so far
    /// (None when no plan is installed) — the chaos harness's ground truth
    /// that the schedule actually fired.
    pub fn faults_injected(&self) -> Option<(u64, u64)> {
        self.injector.as_ref().map(|i| i.injected())
    }

    /// The live breaker state for `group` (None when breakers are off).
    pub fn breaker_state(&self, group: usize) -> Option<BreakerState> {
        self.resilience.as_ref()?.breaker_state(group)
    }

    fn stop(&self) {
        if let Some(res) = &self.resilience {
            res.stop_monitor();
        }
        self.epoch_stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.epoch_thread.lock().unwrap().take() {
            let _ = t.join();
        }
        self.pipeline.stop();
    }
}

impl Backend for SimBackend {
    fn submit(&self, batch: Batch) -> anyhow::Result<Ticket> {
        submit_ticketed(
            &self.pipeline.batcher,
            &self.metrics,
            self.view.rows(),
            self.view.d(),
            &self.path,
            self.partials,
            batch,
        )
    }

    fn d(&self) -> usize {
        self.view.d()
    }

    fn rows(&self) -> u64 {
        self.view.rows()
    }

    fn view(&self) -> Option<&TableView> {
        Some(&self.view)
    }

    fn recycle(&self, buf: Vec<f32>) {
        // The legacy oracle never draws from the pool — pooling there
        // would just pin dead memory.
        if let DataPath::Slab { pool, .. } = &self.path {
            pool.put(buf);
        }
    }

    fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    fn metrics_handle(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    fn shutdown(&self) {
        self.stop();
    }
}

impl Drop for SimBackend {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One group's worker: host gathers + simulated-device accounting (and,
/// when pacing is on, completion delayed to the simulated rate).
///
/// Plan-agnostic: jobs carry their window's geometry (start row + rows in
/// the view's row space), so the worker stays correct across live window
/// re-splits — a job formed under generation N executes identically after
/// the control plane publishes generation N+1.
struct SimWorker {
    group: usize,
    /// The probe map's smids for this group (filtered against the machine
    /// when calibrating).
    sms: Vec<SmId>,
    machine: Option<Machine>,
    solo_gbps: f64,
    calib_accesses: u64,
    row_bytes: u64,
    /// Zero-copy gather source (job rows are view-local).
    view: TableView,
    metrics: Arc<Metrics>,
    stats: Arc<Vec<GroupServeStats>>,
    /// Memoized calibration results per window geometry (start, rows).
    ns_per_row: HashMap<(u64, u64), f64>,
    /// Inline one-entry cache over the map: consecutive jobs almost always
    /// share their window geometry (splits batch by window), so the steady
    /// state skips even the hash lookup.
    last_rate: Option<(u64, u64, f64)>,
    /// Wall-clock multiplier on simulated time (see
    /// [`SimBackendConfig::sim_timescale`]); 0 = unpaced.
    timescale: f64,
    /// When this group's simulated device frees up (pacing only): the
    /// group is a serial device, jobs queue behind each other.
    next_free: Option<Instant>,
    /// Return ring for emptied job index shells (None on the legacy path).
    shells: Option<ring::Producer<Shells>>,
    /// Retry/hedge/breaker runtime (None when every feature is off).
    resilience: Option<Arc<ResilienceCtx>>,
    /// Deterministic fault schedule (None outside tests/chaos runs).
    injector: Option<Arc<FaultInjector>>,
}

impl SimWorker {
    fn execute(&mut self, job: Job) {
        // Fault draw happens before any write: a failed job must leave the
        // output buffer untouched (recovery re-gathers the same rows).
        let fault = match &self.injector {
            Some(inj) => inj.next_job(self.group),
            None => JobFault::NONE,
        };
        if fault.fail {
            self.fail_job(job);
            return;
        }
        // A stall multiplies the simulated device cost; with pacing on it
        // becomes real wall-clock straggling (what hedging races against).
        // A pinned remap prices the packed layout: hot hits land in the
        // page-aligned prefix (TLB-dense), misses pay the full window.
        let base = match &job.remap {
            Some(r) => self.remapped_ns_per_row(
                r.hot_rows() as u64,
                r.hot_share(),
                job.win_start_row,
                job.win_rows,
            ),
            None => self.ns_per_row(job.win_start_row, job.win_rows),
        };
        let rate = base * fault.stall_mult;
        let n = job.local_rows.len();
        if job.acc.is_legacy() {
            // Oracle path (--legacy-path): gather into a fresh Vec, then a
            // second locked copy into the accumulator — the exact pre-slab
            // pipeline the bench compares against.
            let d = self.view.d();
            let mut rows = Vec::with_capacity(n * d);
            for &local in &job.local_rows {
                rows.extend_from_slice(self.view.row(job.win_start_row + local as u64));
            }
            self.account(n, rate);
            job.acc.scatter(&job.positions, &rows, d);
        } else if let Some(token) = &job.token {
            // Hedge-tracked job (original or speculative copy): gather
            // first, claim, then write — the losing copy must never touch
            // the buffer, or the scatter claim bitmap would (correctly)
            // trip on the duplicate.
            let d = self.view.d();
            let mut rows = Vec::with_capacity(n * d);
            for &local in &job.local_rows {
                rows.extend_from_slice(self.view.row(job.win_start_row + local as u64));
            }
            self.account(n, rate);
            let done = if token.claim() {
                job.acc.scatter(&job.positions, &rows, d);
                if job.hedge {
                    self.metrics.hedge_wins.fetch_add(1, Ordering::Relaxed);
                }
                self.note_success();
                job.acc.finish_part(&self.metrics)
            } else {
                // The loser: its sibling already finished the part.
                false
            };
            job.recycle_shells(self.shells.as_ref(), done);
            return;
        } else {
            // Single copy: each row goes straight from the zero-copy source
            // to its final position in the request's slab buffer (the
            // positions of distinct sub-batches are disjoint, so no lock).
            // Under a pinned remap the source is the packed slab — same
            // bytes per logical row, permuted physical order.
            match &job.remap {
                Some(r) => {
                    for (k, &local) in job.local_rows.iter().enumerate() {
                        job.acc.write_row(job.positions[k], r.row(local));
                    }
                }
                None => {
                    for (k, &local) in job.local_rows.iter().enumerate() {
                        job.acc.write_row(
                            job.positions[k],
                            self.view.row(job.win_start_row + local as u64),
                        );
                    }
                }
            }
            self.account(n, rate);
        }
        self.note_success();
        let done = job.acc.finish_part(&self.metrics);
        job.recycle_shells(self.shells.as_ref(), done);
    }

    /// Injected-failure path: nothing was written.  A hedged copy defers
    /// to its surviving sibling; the last copy standing consumes retry
    /// budget; only then does the part (and with it the request) fail.
    fn fail_job(&mut self, job: Job) {
        if let Some(res) = &self.resilience {
            res.note_failure(self.group);
            if let Some(tok) = &job.token {
                if !tok.copy_failed() {
                    // A sibling copy is in flight (or already won); the
                    // part is its responsibility now.
                    job.recycle_shells(self.shells.as_ref(), false);
                    return;
                }
            }
            if res.can_retry(job.attempt) {
                let rows: Vec<u64> = job
                    .local_rows
                    .iter()
                    .map(|&l| job.win_start_row + l as u64)
                    .collect();
                if res.send_retry(rows, job.positions.clone(), Arc::clone(&job.acc), job.attempt)
                {
                    job.recycle_shells(self.shells.as_ref(), false);
                    return;
                }
            }
        }
        let why = format!("injected fault: group {} failed", self.group);
        let done = job.acc.fail_part(&self.metrics, &why);
        job.recycle_shells(self.shells.as_ref(), done);
    }

    #[inline]
    fn note_success(&self) {
        if let Some(res) = &self.resilience {
            res.note_success(self.group);
        }
    }

    /// Simulated-device accounting + optional pacing for `n` rows.
    fn account(&mut self, n: usize, rate: f64) {
        let cost_ns = n as f64 * rate;
        let st = &self.stats[self.group];
        st.rows.fetch_add(n as u64, Ordering::Relaxed);
        st.sim_ns.fetch_add(cost_ns as u64, Ordering::Relaxed);
        if self.timescale > 0.0 {
            self.pace(cost_ns);
        }
    }

    /// Delay completion so this group serves no faster than the simulated
    /// device would: the job starts when the (serial) device frees up and
    /// occupies it for `cost_ns * timescale` of wall time.  The per-job
    /// delay is clamped to 60 s: a nonsensical timescale must degrade into
    /// slow serving, never a `Duration` overflow panic that would strand
    /// the job's ticket forever.
    fn pace(&mut self, cost_ns: f64) {
        let mut secs = cost_ns.max(0.0) * 1e-9 * self.timescale;
        if !secs.is_finite() || secs > 60.0 {
            secs = 60.0;
        }
        let cost = Duration::from_secs_f64(secs);
        let now = Instant::now();
        let start = match self.next_free {
            Some(t) if t > now => t,
            _ => now,
        };
        let free = start + cost;
        self.next_free = Some(free);
        if free > now {
            std::thread::sleep(free - now);
        }
    }

    /// Simulated device cost of one row gathered from the window spanning
    /// view rows `[start, start + rows)` by this group (ns).  GB/s ≡
    /// bytes/ns, so `ns_per_row = row_bytes / gbps`.  Keyed by the window
    /// *geometry*, so re-split plans calibrate their new windows lazily on
    /// first contact while identical geometry reuses the cache.
    fn ns_per_row(&mut self, start: u64, rows: u64) -> f64 {
        // Inline fast path: unchanged window geometry skips even the map.
        if let Some((s, r, rate)) = self.last_rate {
            if s == start && r == rows {
                return rate;
            }
        }
        if let Some(&r) = self.ns_per_row.get(&(start, rows)) {
            self.last_rate = Some((start, rows, r));
            return r;
        }
        let row_bytes = self.row_bytes as f64;
        let rate = match &self.machine {
            Some(m) => {
                let sms: Vec<SmId> = self
                    .sms
                    .iter()
                    .copied()
                    .filter(|&s| s < m.topology().sm_count())
                    .collect();
                if sms.is_empty() {
                    row_bytes / self.solo_gbps
                } else {
                    let region =
                        MemRegion::new(start * self.row_bytes, rows * self.row_bytes);
                    let mut spec = MeasurementSpec::uniform_all(
                        &sms,
                        Pattern::Uniform(region),
                        self.calib_accesses,
                        0xCA11B ^ start ^ rows.rotate_left(32),
                    );
                    spec.txn_bytes = self.row_bytes;
                    row_bytes / m.run(&spec).gbps.max(1e-9)
                }
            }
            None => row_bytes / self.solo_gbps,
        };
        self.ns_per_row.insert((start, rows), rate);
        self.last_rate = Some((start, rows, rate));
        rate
    }

    /// Packed-layout cost model: a share `s` of accesses hits the hot
    /// prefix (priced as a window of `hot_rows` rows — denser pages, fewer
    /// TLB entries, so the DES machine quotes a faster rate when the full
    /// window over-reaches the group's TLB), the rest still pays the full
    /// window's scattered rate.  Both legs memoize through `ns_per_row`:
    /// `(start, hot_rows)` and `(start, rows)` are distinct cache keys.
    fn remapped_ns_per_row(&mut self, hot_rows: u64, hot_share: f64, start: u64, rows: u64) -> f64 {
        let full = self.ns_per_row(start, rows);
        if hot_rows == 0 || hot_rows >= rows {
            return full;
        }
        let hot = self.ns_per_row(start, hot_rows);
        let s = hot_share.clamp(0.0, 1.0);
        s * hot + (1.0 - s) * full
    }
}

//! The hermetic backend: gathers execute host-side against a zero-copy
//! [`TableView`] while the discrete-event [`Machine`] supplies the
//! *device* cost model — what each SM resource group's gather rate would
//! be on the simulated A100 given the placement it was pinned under.
//!
//! This is the facade implementation every serving scenario can run under
//! tier-1: no PJRT, no artifacts, same batcher → dispatcher →
//! [`Router`](crate::coordinator::Router) split → per-group worker → merge
//! pipeline as the PJRT [`EmbeddingServer`](crate::coordinator::EmbeddingServer).
//!
//! Timing model: serving a sub-batch of `k` rows from window `w` on group
//! `g` costs `k * ns_per_row(g, w)` of simulated device time, where
//! `ns_per_row` is calibrated once per (group, window) pair by running the
//! DES with that group's SMs uniform-random over the window's byte region
//! (then memoized).  Under `GroupToChunk` the regions sit below TLB reach
//! and the rates land at the paper's full-speed plateau; under `Naive`
//! whole-table placement they collapse exactly like Fig 1.  With
//! [`SimTiming::Probed`] the DES is skipped and the probe map's
//! `solo_gbps` is used directly (fast startup for load-generation tests).
//!
//! Two live knobs on top of the cost model:
//!
//! * **Pacing** (`sim_timescale > 0`): each group completes jobs no faster
//!   than `sim_ns * timescale` of wall clock (a serial device per group),
//!   so bench-serve's wall-clock knee becomes policy-dependent — thrashing
//!   placements knee earlier, exactly like the real device would.
//! * **Adaptive placement** (`adaptive: Some(..)`): a
//!   [`Placer`]-produced placement lives in a generation-stamped
//!   [`PlacementCell`]; [`SimBackend::rebalance_epoch`] (or a background
//!   epoch thread) feeds per-window load signals to the placer and swaps
//!   the deal without draining in-flight tickets.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context};

use crate::coordinator::adaptive::{AdaptiveConfig, AdaptivePlacer};
use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::chunks::WindowPlan;
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::placement::{
    Placement, PlacementCell, PlacementPolicy, Placer, StaticPlacer, WindowSignals,
};
use crate::coordinator::table::TableView;
use crate::probe::TopologyMap;
use crate::sim::{Machine, MeasurementSpec, Pattern, SmId};

use super::backend::{
    submit_ticketed, Backend, Batch, Job, Pipeline, ResponseTx, Ticket, WorkerMsg,
};

/// Where the per-(group, window) service rates come from.
#[derive(Clone)]
pub enum SimTiming {
    /// Calibrate by running the DES (one short measurement per pair,
    /// memoized; workers share the machine's warm-TLB cache).  Boxed: a
    /// `Machine` is ~40x the size of the other variant.
    Machine(Box<Machine>),
    /// Use the probe map's `solo_gbps` as-is — no DES at serve time.
    Probed,
}

impl SimTiming {
    /// Convenience constructor for the DES-calibrated variant.
    pub fn machine(m: Machine) -> Self {
        Self::Machine(Box::new(m))
    }
}

#[derive(Debug, Clone)]
pub struct SimBackendConfig {
    pub policy: PlacementPolicy,
    pub batcher: BatcherConfig,
    pub seed: u64,
    /// Accesses per SM for each calibration measurement.
    pub calib_accesses_per_sm: u64,
    /// Skew-aware rebalancing: `Some` routes placement through an
    /// [`AdaptivePlacer`] (initially the group-to-chunk deal; `policy` is
    /// ignored for placement then) and enables epoch rebalancing.
    pub adaptive: Option<AdaptiveConfig>,
    /// Wall-clock pacing of simulated device time: each group's job
    /// completions are delayed so wall ≥ `sim_ns * sim_timescale`
    /// (1.0 = a simulated nanosecond costs a wall nanosecond).  0 disables
    /// pacing — gathers complete at host speed and device time is only
    /// *accounted* (`sim_report`).
    pub sim_timescale: f64,
}

impl SimBackendConfig {
    pub fn new(policy: PlacementPolicy) -> Self {
        Self {
            policy,
            batcher: BatcherConfig::default(),
            seed: 0xC0FFEE,
            calib_accesses_per_sm: 2_000,
            adaptive: None,
            sim_timescale: 0.0,
        }
    }
}

/// Simulated-device accounting per group.
#[derive(Debug, Default)]
struct GroupServeStats {
    rows: AtomicU64,
    sim_ns: AtomicU64,
}

/// One group's slice of the simulated-device report.
#[derive(Debug, Clone)]
pub struct GroupSimReport {
    pub group: usize,
    /// Rows this group gathered.
    pub rows: u64,
    /// Simulated device time it spent doing so, milliseconds.
    pub sim_ms: f64,
    /// Implied device-side gather throughput, GB/s.
    pub simulated_gbps: f64,
}

/// Everything the epoch rebalancer needs — shared between
/// [`SimBackend::rebalance_epoch`] and the optional background thread.
struct RebalanceCtx {
    placer: Arc<dyn Placer>,
    placement: Arc<PlacementCell>,
    plan: Arc<WindowPlan>,
    map: TopologyMap,
    metrics: Arc<Metrics>,
    batcher: Arc<Batcher<ResponseTx>>,
    /// The placer's signal floor (0 for static placers): epochs below it
    /// accumulate into the next one instead of being discarded.
    min_epoch_rows: u64,
    /// Per-window routed-row totals at the previous *committed* epoch
    /// boundary.
    last_rows: Mutex<Vec<u64>>,
}

impl RebalanceCtx {
    /// Close one epoch: delta the per-window load counters, ask the placer
    /// for a rebalanced deal, publish it.  Returns the new generation when
    /// a swap happened.
    fn epoch(&self) -> Option<u64> {
        let totals = self.metrics.window_rows_snapshot();
        let delta = {
            let mut last = self.last_rows.lock().unwrap();
            if last.len() != totals.len() {
                *last = vec![0; totals.len()];
            }
            let delta: Vec<u64> = totals
                .iter()
                .zip(last.iter())
                .map(|(t, l)| t.saturating_sub(*l))
                .collect();
            // Commit the baseline only when the epoch carried enough
            // signal for the placer to decide on; a starved epoch rolls
            // its rows into the next one, so persistent low-rate skew
            // still accumulates to a rebalance instead of being dropped.
            if delta.iter().sum::<u64>() >= self.min_epoch_rows {
                *last = totals;
            }
            delta
        };
        let signals = WindowSignals {
            rows: delta,
            mean_latency_us: self.metrics.latency.mean_us(),
            queued_rows: self.batcher.pending_rows() as u64,
        };
        let current = self.placement.load();
        let next = self
            .placer
            .rebalance(&current, &self.map, &self.plan, &signals)?;
        // Live-swap safety gate, active in release builds: a placement the
        // router cannot serve (custom `Placer`s are untrusted) is dropped
        // rather than published — stranding the swap, never the tickets.
        if let Err(why) = next.check_servable(self.plan.count(), self.map.groups.len()) {
            debug_assert!(false, "placer proposed an unservable placement: {why}");
            return None;
        }
        Some(self.placement.store(next))
    }
}

/// The running sim-backed server.
pub struct SimBackend {
    pipeline: Pipeline,
    metrics: Arc<Metrics>,
    plan: Arc<WindowPlan>,
    view: TableView,
    placement: Arc<PlacementCell>,
    stats: Arc<Vec<GroupServeStats>>,
    rebalance: Arc<RebalanceCtx>,
    epoch_stop: Arc<AtomicBool>,
    epoch_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl SimBackend {
    /// Start the backend with a placement built by `cfg`'s placer (the
    /// static `cfg.policy` arm, or the adaptive group-to-chunk deal when
    /// `cfg.adaptive` is set).
    pub fn start(
        cfg: SimBackendConfig,
        map: &TopologyMap,
        plan: WindowPlan,
        view: TableView,
        timing: SimTiming,
    ) -> anyhow::Result<Self> {
        map.validate()?;
        let placer = Self::placer_of(&cfg);
        let placement = placer.place(map, &plan, cfg.seed)?;
        Self::start_inner(cfg, map, plan, placement, view, timing)
    }

    /// Start with a prebuilt placement (fleet shards carry their own).
    pub fn start_with_placement(
        cfg: SimBackendConfig,
        map: &TopologyMap,
        plan: WindowPlan,
        placement: Placement,
        view: TableView,
        timing: SimTiming,
    ) -> anyhow::Result<Self> {
        Self::start_inner(cfg, map, plan, placement, view, timing)
    }

    fn placer_of(cfg: &SimBackendConfig) -> Arc<dyn Placer> {
        match &cfg.adaptive {
            Some(a) => Arc::new(AdaptivePlacer::new(a.clone())),
            None => Arc::new(StaticPlacer(cfg.policy)),
        }
    }

    fn start_inner(
        cfg: SimBackendConfig,
        map: &TopologyMap,
        plan: WindowPlan,
        placement: Placement,
        view: TableView,
        timing: SimTiming,
    ) -> anyhow::Result<Self> {
        if view.rows() != plan.total_rows {
            return Err(anyhow!(
                "table view has {} rows but plan covers {}",
                view.rows(),
                plan.total_rows
            ));
        }
        // A mismatched placement must fail deterministically here, not as
        // an index panic in the dispatcher mid-serving (the router only
        // debug-asserts; prebuilt placements arrive via
        // `start_with_placement`).
        if let Err(why) = placement.check_servable(plan.count(), map.groups.len()) {
            return Err(anyhow!("placement is unservable: {why}"));
        }
        let metrics = Arc::new(Metrics::for_windows(plan.count()));
        let plan = Arc::new(plan);
        let stats: Arc<Vec<GroupServeStats>> =
            Arc::new((0..map.groups.len()).map(|_| Default::default()).collect());

        // One worker per group in the map — not just the initially-serving
        // ones: a placement swap may hand any group any window, and the
        // memoized per-window calibration happens lazily on first contact.
        let mut senders: Vec<Option<mpsc::Sender<WorkerMsg>>> = Vec::new();
        let mut workers = Vec::new();
        for g in 0..map.groups.len() {
            let (tx, rx) = mpsc::channel::<WorkerMsg>();
            senders.push(Some(tx));
            let mut worker = SimWorker {
                group: g,
                sms: map.groups[g].clone(),
                machine: match &timing {
                    SimTiming::Machine(m) => Some(m.as_ref().clone()),
                    SimTiming::Probed => None,
                },
                solo_gbps: map.solo_gbps[g].max(1e-9),
                calib_accesses: cfg.calib_accesses_per_sm.max(1),
                plan: Arc::clone(&plan),
                view: view.clone(),
                metrics: Arc::clone(&metrics),
                stats: Arc::clone(&stats),
                ns_per_row: HashMap::new(),
                // Non-finite or negative timescales disable pacing rather
                // than poisoning every Duration computation downstream.
                timescale: if cfg.sim_timescale.is_finite() {
                    cfg.sim_timescale.max(0.0)
                } else {
                    0.0
                },
                next_free: None,
            };
            let handle = std::thread::Builder::new()
                .name(format!("a100win-sim-g{g}"))
                .spawn(move || {
                    while let Ok(msg) = rx.recv() {
                        match msg {
                            WorkerMsg::Shutdown => break,
                            WorkerMsg::Job(job) => worker.execute(job),
                        }
                    }
                })
                .context("spawning sim worker")?;
            workers.push(handle);
        }

        let cell = Arc::new(PlacementCell::new(placement));
        let pipeline = Pipeline::start(
            cfg.batcher.clone(),
            Arc::clone(&plan),
            Arc::clone(&cell),
            Arc::clone(&metrics),
            view.d(),
            senders,
            workers,
        )?;

        let rebalance = Arc::new(RebalanceCtx {
            placer: Self::placer_of(&cfg),
            placement: Arc::clone(&cell),
            plan: Arc::clone(&plan),
            map: map.clone(),
            metrics: Arc::clone(&metrics),
            batcher: Arc::clone(&pipeline.batcher),
            min_epoch_rows: cfg.adaptive.as_ref().map_or(0, |a| a.min_epoch_rows),
            last_rows: Mutex::new(vec![0; plan.count()]),
        });

        let epoch_stop = Arc::new(AtomicBool::new(false));
        let epoch_thread = match cfg.adaptive.as_ref().and_then(|a| a.epoch) {
            None => None,
            Some(epoch) => {
                let ctx = Arc::clone(&rebalance);
                let stop = Arc::clone(&epoch_stop);
                let tick = epoch
                    .min(Duration::from_millis(5))
                    .max(Duration::from_micros(100));
                Some(
                    std::thread::Builder::new()
                        .name("a100win-rebalancer".into())
                        .spawn(move || {
                            let mut since = Duration::ZERO;
                            while !stop.load(Ordering::Relaxed) {
                                std::thread::sleep(tick);
                                since += tick;
                                if since >= epoch {
                                    since = Duration::ZERO;
                                    let _ = ctx.epoch();
                                }
                            }
                        })
                        .context("spawning rebalancer")?,
                )
            }
        };

        Ok(Self {
            pipeline,
            metrics,
            plan,
            view,
            placement: cell,
            stats,
            rebalance,
            epoch_stop,
            epoch_thread: Mutex::new(epoch_thread),
        })
    }

    pub fn plan(&self) -> &WindowPlan {
        &self.plan
    }

    pub fn table_view(&self) -> &TableView {
        &self.view
    }

    /// The current live placement (generation-stamped; swaps bump it).
    pub fn placement(&self) -> Arc<Placement> {
        self.placement.load()
    }

    /// Close one rebalance epoch by hand: feed the epoch's per-window load
    /// to the placer and swap the placement if it proposes a new deal.
    /// Returns the new generation when a swap happened.  (The background
    /// thread configured by `AdaptiveConfig::epoch` calls exactly this.)
    pub fn rebalance_epoch(&self) -> Option<u64> {
        self.rebalance.epoch()
    }

    /// What the simulated device did: per-group rows, device time, and the
    /// implied gather throughput under the active placement.
    pub fn sim_report(&self) -> Vec<GroupSimReport> {
        let row_bytes = self.plan.row_bytes as f64;
        self.stats
            .iter()
            .enumerate()
            .filter(|(_, s)| s.rows.load(Ordering::Relaxed) > 0)
            .map(|(group, s)| {
                let rows = s.rows.load(Ordering::Relaxed);
                let ns = s.sim_ns.load(Ordering::Relaxed).max(1) as f64;
                GroupSimReport {
                    group,
                    rows,
                    sim_ms: ns / 1e6,
                    simulated_gbps: rows as f64 * row_bytes / ns,
                }
            })
            .collect()
    }

    /// Device-side aggregate throughput implied by the busiest group
    /// (makespan model: groups gather in parallel, so the slowest group's
    /// simulated time bounds the run).  This is the number skew-aware
    /// placement moves: balancing load across groups shrinks the max.
    pub fn aggregate_sim_gbps(&self) -> f64 {
        let total_rows: u64 = self
            .stats
            .iter()
            .map(|s| s.rows.load(Ordering::Relaxed))
            .sum();
        let max_ns = self
            .stats
            .iter()
            .map(|s| s.sim_ns.load(Ordering::Relaxed))
            .max()
            .unwrap_or(0);
        if max_ns == 0 {
            return 0.0;
        }
        total_rows as f64 * self.plan.row_bytes as f64 / max_ns as f64
    }

    fn stop(&self) {
        self.epoch_stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.epoch_thread.lock().unwrap().take() {
            let _ = t.join();
        }
        self.pipeline.stop();
    }
}

impl Backend for SimBackend {
    fn submit(&self, batch: Batch) -> anyhow::Result<Ticket> {
        submit_ticketed(&self.pipeline.batcher, &self.metrics, self.view.rows(), batch)
    }

    fn d(&self) -> usize {
        self.view.d()
    }

    fn rows(&self) -> u64 {
        self.view.rows()
    }

    fn view(&self) -> Option<&TableView> {
        Some(&self.view)
    }

    fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    fn metrics_handle(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    fn shutdown(&self) {
        self.stop();
    }
}

impl Drop for SimBackend {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One group's worker: host gathers + simulated-device accounting (and,
/// when pacing is on, completion delayed to the simulated rate).
struct SimWorker {
    group: usize,
    /// The probe map's smids for this group (filtered against the machine
    /// when calibrating).
    sms: Vec<SmId>,
    machine: Option<Machine>,
    solo_gbps: f64,
    calib_accesses: u64,
    plan: Arc<WindowPlan>,
    /// Zero-copy gather source (rows are plan-local).
    view: TableView,
    metrics: Arc<Metrics>,
    stats: Arc<Vec<GroupServeStats>>,
    /// Memoized calibration results per window.
    ns_per_row: HashMap<usize, f64>,
    /// Wall-clock multiplier on simulated time (see
    /// [`SimBackendConfig::sim_timescale`]); 0 = unpaced.
    timescale: f64,
    /// When this group's simulated device frees up (pacing only): the
    /// group is a serial device, jobs queue behind each other.
    next_free: Option<Instant>,
}

impl SimWorker {
    fn execute(&mut self, job: Job) {
        let rate = self.ns_per_row(job.window);
        let w = self.plan.windows()[job.window];
        let d = self.view.d();
        let mut rows = Vec::with_capacity(job.local_rows.len() * d);
        for &local in &job.local_rows {
            rows.extend_from_slice(self.view.row(w.start_row + local as u64));
        }
        let cost_ns = job.local_rows.len() as f64 * rate;
        let st = &self.stats[self.group];
        st.rows
            .fetch_add(job.local_rows.len() as u64, Ordering::Relaxed);
        st.sim_ns.fetch_add(cost_ns as u64, Ordering::Relaxed);
        if self.timescale > 0.0 {
            self.pace(cost_ns);
        }
        job.acc.scatter(&job.positions, &rows, d);
        job.acc.finish_part(&self.metrics);
    }

    /// Delay completion so this group serves no faster than the simulated
    /// device would: the job starts when the (serial) device frees up and
    /// occupies it for `cost_ns * timescale` of wall time.  The per-job
    /// delay is clamped to 60 s: a nonsensical timescale must degrade into
    /// slow serving, never a `Duration` overflow panic that would strand
    /// the job's ticket forever.
    fn pace(&mut self, cost_ns: f64) {
        let mut secs = cost_ns.max(0.0) * 1e-9 * self.timescale;
        if !secs.is_finite() || secs > 60.0 {
            secs = 60.0;
        }
        let cost = Duration::from_secs_f64(secs);
        let now = Instant::now();
        let start = match self.next_free {
            Some(t) if t > now => t,
            _ => now,
        };
        let free = start + cost;
        self.next_free = Some(free);
        if free > now {
            std::thread::sleep(free - now);
        }
    }

    /// Simulated device cost of one row gathered from `window` by this
    /// group (ns).  GB/s ≡ bytes/ns, so `ns_per_row = row_bytes / gbps`.
    fn ns_per_row(&mut self, window: usize) -> f64 {
        if let Some(&r) = self.ns_per_row.get(&window) {
            return r;
        }
        let row_bytes = self.plan.row_bytes as f64;
        let rate = match &self.machine {
            Some(m) => {
                let sms: Vec<SmId> = self
                    .sms
                    .iter()
                    .copied()
                    .filter(|&s| s < m.topology().sm_count())
                    .collect();
                if sms.is_empty() {
                    row_bytes / self.solo_gbps
                } else {
                    let region = self.plan.region_of(&self.plan.windows()[window]);
                    let mut spec = MeasurementSpec::uniform_all(
                        &sms,
                        Pattern::Uniform(region),
                        self.calib_accesses,
                        0xCA11B ^ window as u64,
                    );
                    spec.txn_bytes = self.plan.row_bytes;
                    row_bytes / m.run(&spec).gbps.max(1e-9)
                }
            }
            None => row_bytes / self.solo_gbps,
        };
        self.ns_per_row.insert(window, rate);
        rate
    }
}

//! The hermetic backend: gathers execute host-side against the table while
//! the discrete-event [`Machine`] supplies the *device* cost model — what
//! each SM resource group's gather rate would be on the simulated A100
//! given the placement it was pinned under.
//!
//! This is the facade implementation every serving scenario can run under
//! tier-1: no PJRT, no artifacts, same batcher → dispatcher →
//! [`Router`](crate::coordinator::Router) split → per-group worker → merge
//! pipeline as the PJRT [`EmbeddingServer`](crate::coordinator::EmbeddingServer).
//!
//! Timing model: serving a sub-batch of `k` rows from window `w` on group
//! `g` costs `k * ns_per_row(g, w)` of simulated device time, where
//! `ns_per_row` is calibrated once per (group, window) pair by running the
//! DES with that group's SMs uniform-random over the window's byte region
//! (then memoized).  Under `GroupToChunk` the regions sit below TLB reach
//! and the rates land at the paper's full-speed plateau; under `Naive`
//! whole-table placement they collapse exactly like Fig 1.  With
//! [`SimTiming::Probed`] the DES is skipped and the probe map's
//! `solo_gbps` is used directly (fast startup for load-generation tests).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::{anyhow, Context};

use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::chunks::WindowPlan;
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::placement::{Placement, PlacementPolicy};
use crate::coordinator::Table;
use crate::probe::TopologyMap;
use crate::sim::{Machine, MeasurementSpec, Pattern, SmId};

use super::backend::{submit_ticketed, Backend, Batch, Job, Pipeline, Ticket, WorkerMsg};

/// Where the per-(group, window) service rates come from.
#[derive(Clone)]
pub enum SimTiming {
    /// Calibrate by running the DES (one short measurement per pair,
    /// memoized; workers share the machine's warm-TLB cache).  Boxed: a
    /// `Machine` is ~40x the size of the other variant.
    Machine(Box<Machine>),
    /// Use the probe map's `solo_gbps` as-is — no DES at serve time.
    Probed,
}

impl SimTiming {
    /// Convenience constructor for the DES-calibrated variant.
    pub fn machine(m: Machine) -> Self {
        Self::Machine(Box::new(m))
    }
}

#[derive(Debug, Clone)]
pub struct SimBackendConfig {
    pub policy: PlacementPolicy,
    pub batcher: BatcherConfig,
    pub seed: u64,
    /// Accesses per SM for each calibration measurement.
    pub calib_accesses_per_sm: u64,
}

impl SimBackendConfig {
    pub fn new(policy: PlacementPolicy) -> Self {
        Self {
            policy,
            batcher: BatcherConfig::default(),
            seed: 0xC0FFEE,
            calib_accesses_per_sm: 2_000,
        }
    }
}

/// Simulated-device accounting per group.
#[derive(Debug, Default)]
struct GroupServeStats {
    rows: AtomicU64,
    sim_ns: AtomicU64,
}

/// One group's slice of the simulated-device report.
#[derive(Debug, Clone)]
pub struct GroupSimReport {
    pub group: usize,
    /// Rows this group gathered.
    pub rows: u64,
    /// Simulated device time it spent doing so, milliseconds.
    pub sim_ms: f64,
    /// Implied device-side gather throughput, GB/s.
    pub simulated_gbps: f64,
}

/// The running sim-backed server.
pub struct SimBackend {
    pipeline: Pipeline,
    metrics: Arc<Metrics>,
    plan: Arc<WindowPlan>,
    table: Table,
    placement: Placement,
    stats: Arc<Vec<GroupServeStats>>,
}

impl SimBackend {
    /// Start the backend with a placement built from `cfg.policy`.
    pub fn start(
        cfg: SimBackendConfig,
        map: &TopologyMap,
        plan: WindowPlan,
        table: Table,
        timing: SimTiming,
    ) -> anyhow::Result<Self> {
        map.validate()?;
        let placement = Placement::build(cfg.policy, map, &plan, cfg.seed)?;
        Self::start_with_placement(cfg, map, plan, placement, table, timing)
    }

    /// Start with a prebuilt placement (fleet shards carry their own).
    pub fn start_with_placement(
        cfg: SimBackendConfig,
        map: &TopologyMap,
        plan: WindowPlan,
        placement: Placement,
        table: Table,
        timing: SimTiming,
    ) -> anyhow::Result<Self> {
        if table.rows != plan.total_rows {
            return Err(anyhow!(
                "table has {} rows but plan covers {}",
                table.rows,
                plan.total_rows
            ));
        }
        let metrics = Arc::new(Metrics::new());
        let plan = Arc::new(plan);
        let stats: Arc<Vec<GroupServeStats>> =
            Arc::new((0..map.groups.len()).map(|_| Default::default()).collect());

        let mut served_by_group: Vec<Vec<usize>> = vec![Vec::new(); map.groups.len()];
        for w in 0..plan.count() {
            for &g in placement.serving_groups(w) {
                served_by_group[g].push(w);
            }
        }
        let mut senders: Vec<Option<mpsc::Sender<WorkerMsg>>> =
            (0..map.groups.len()).map(|_| None).collect();
        let mut workers = Vec::new();
        for (g, served) in served_by_group.iter().enumerate() {
            if served.is_empty() {
                continue;
            }
            let (tx, rx) = mpsc::channel::<WorkerMsg>();
            senders[g] = Some(tx);
            let mut worker = SimWorker {
                group: g,
                sms: map.groups[g].clone(),
                machine: match &timing {
                    SimTiming::Machine(m) => Some(m.as_ref().clone()),
                    SimTiming::Probed => None,
                },
                solo_gbps: map.solo_gbps[g].max(1e-9),
                calib_accesses: cfg.calib_accesses_per_sm.max(1),
                plan: Arc::clone(&plan),
                table: table.clone(),
                metrics: Arc::clone(&metrics),
                stats: Arc::clone(&stats),
                ns_per_row: HashMap::new(),
            };
            let handle = std::thread::Builder::new()
                .name(format!("a100win-sim-g{g}"))
                .spawn(move || {
                    while let Ok(msg) = rx.recv() {
                        match msg {
                            WorkerMsg::Shutdown => break,
                            WorkerMsg::Job(job) => worker.execute(job),
                        }
                    }
                })
                .context("spawning sim worker")?;
            workers.push(handle);
        }

        let pipeline = Pipeline::start(
            cfg.batcher.clone(),
            Arc::clone(&plan),
            placement.clone(),
            Arc::clone(&metrics),
            table.d,
            senders,
            workers,
        )?;

        Ok(Self {
            pipeline,
            metrics,
            plan,
            table,
            placement,
            stats,
        })
    }

    pub fn plan(&self) -> &WindowPlan {
        &self.plan
    }

    pub fn table(&self) -> &Table {
        &self.table
    }

    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// What the simulated device did: per-group rows, device time, and the
    /// implied gather throughput under the active placement.
    pub fn sim_report(&self) -> Vec<GroupSimReport> {
        let row_bytes = self.plan.row_bytes as f64;
        self.stats
            .iter()
            .enumerate()
            .filter(|(_, s)| s.rows.load(Ordering::Relaxed) > 0)
            .map(|(group, s)| {
                let rows = s.rows.load(Ordering::Relaxed);
                let ns = s.sim_ns.load(Ordering::Relaxed).max(1) as f64;
                GroupSimReport {
                    group,
                    rows,
                    sim_ms: ns / 1e6,
                    simulated_gbps: rows as f64 * row_bytes / ns,
                }
            })
            .collect()
    }

    fn stop(&self) {
        self.pipeline.stop();
    }
}

impl Backend for SimBackend {
    fn submit(&self, batch: Batch) -> anyhow::Result<Ticket> {
        submit_ticketed(&self.pipeline.batcher, &self.metrics, self.table.rows, batch)
    }

    fn d(&self) -> usize {
        self.table.d
    }

    fn rows(&self) -> u64 {
        self.table.rows
    }

    fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    fn metrics_handle(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    fn shutdown(&self) {
        self.stop();
    }
}

impl Drop for SimBackend {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One group's worker: host gathers + simulated-device accounting.
struct SimWorker {
    group: usize,
    /// The probe map's smids for this group (filtered against the machine
    /// when calibrating).
    sms: Vec<SmId>,
    machine: Option<Machine>,
    solo_gbps: f64,
    calib_accesses: u64,
    plan: Arc<WindowPlan>,
    table: Table,
    metrics: Arc<Metrics>,
    stats: Arc<Vec<GroupServeStats>>,
    /// Memoized calibration results per window.
    ns_per_row: HashMap<usize, f64>,
}

impl SimWorker {
    fn execute(&mut self, job: Job) {
        let rate = self.ns_per_row(job.window);
        let w = self.plan.windows()[job.window];
        let d = self.table.d;
        let mut rows = Vec::with_capacity(job.local_rows.len() * d);
        for &local in &job.local_rows {
            let r = (w.start_row + local as u64) as usize;
            rows.extend_from_slice(&self.table.data[r * d..(r + 1) * d]);
        }
        let st = &self.stats[self.group];
        st.rows
            .fetch_add(job.local_rows.len() as u64, Ordering::Relaxed);
        st.sim_ns
            .fetch_add((job.local_rows.len() as f64 * rate) as u64, Ordering::Relaxed);
        job.acc.scatter(&job.positions, &rows, d);
        job.acc.finish_part(&self.metrics);
    }

    /// Simulated device cost of one row gathered from `window` by this
    /// group (ns).  GB/s ≡ bytes/ns, so `ns_per_row = row_bytes / gbps`.
    fn ns_per_row(&mut self, window: usize) -> f64 {
        if let Some(&r) = self.ns_per_row.get(&window) {
            return r;
        }
        let row_bytes = self.plan.row_bytes as f64;
        let rate = match &self.machine {
            Some(m) => {
                let sms: Vec<SmId> = self
                    .sms
                    .iter()
                    .copied()
                    .filter(|&s| s < m.topology().sm_count())
                    .collect();
                if sms.is_empty() {
                    row_bytes / self.solo_gbps
                } else {
                    let region = self.plan.region_of(&self.plan.windows()[window]);
                    let mut spec = MeasurementSpec::uniform_all(
                        &sms,
                        Pattern::Uniform(region),
                        self.calib_accesses,
                        0xCA11B ^ window as u64,
                    );
                    spec.txn_bytes = self.plan.row_bytes;
                    row_bytes / m.run(&spec).gbps.max(1e-9)
                }
            }
            None => row_bytes / self.solo_gbps,
        };
        self.ns_per_row.insert(window, rate);
        rate
    }
}

//! Fleet routing: one serving facade over several probed cards, with
//! live, zero-copy cross-card row migration.
//!
//! The paper stresses that the smid→group mapping "may vary card to card",
//! so a fleet deployment probes every card once and composes the per-card
//! [`TopologyMap`](crate::probe::TopologyMap)s.  [`FleetService`] wires
//! [`FleetPlan`]/[`CardShard`](crate::coordinator::CardShard) to the
//! ticketed facade: a request's rows are split by card shard, submitted to
//! each card's [`Service`] as ordinary tickets, and merged back **in
//! request order** when the [`FleetTicket`] is redeemed.
//!
//! ```text
//! global row ──► card shard (FleetPlan, generation-stamped) ──► window ──► SM group
//! ```
//!
//! The shard map is *live*: [`FleetService::control_epoch`] (or the
//! background thread enabled by [`FleetConfig::epoch`]) first drives each
//! card's own control plane (group re-deal, window re-split), then judges
//! the **per-card** load/capacity imbalance; when the fleet-scope
//! [`ControlPlane`] escalates to [`Lever::Migrate`], a
//! [`FleetRebalancer`] proposal re-cuts the card boundaries and the fleet
//! publishes a new generation whose re-sized cards serve fresh
//! [`TableView`] slices of the **same** shared `Arc<[f32]>` — refcount
//! bumps and worker re-spawns, never a row of memcpy.  In-flight
//! [`FleetTicket`]s pin their generation's `FleetState` (shard map *and*
//! card services), so they merge under the shard map they were split with
//! while new submissions route under the new one; a retired generation's
//! backends drain and stop when the last ticket drops.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use anyhow::{anyhow, Context};

use crate::coordinator::adaptive::AdaptiveConfig;
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::chunks::row_bytes_for_d;
use crate::coordinator::cluster::{CardSpec, FleetPlan};
use crate::coordinator::controlplane::{
    capacity_imbalance, committed_delta_atomic, load_shares, rebaseline_atomic, ControlPlane,
    ControlPlaneConfig, Decision, Lever,
};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::placement::PlacementPolicy;
use crate::coordinator::replan::SplitterConfig;
use crate::coordinator::table::{Table, TableView};

use crate::sim::FaultPlan;

use super::backend::{scatter_rows, Outcome, Ticket, TicketState};
use super::rebalance::{FleetRebalancer, RebalanceConfig};
use super::resilience::ResilienceConfig;
use super::ring::EpochGate;
use super::scatter::SlabPool;
use super::sim_backend::{SimBackend, SimBackendConfig, SimTiming};
use super::Service;

/// Fleet construction + repartitioning knobs (see
/// [`FleetService::build_sim_with`]).
#[derive(Clone)]
pub struct FleetConfig {
    pub batcher: BatcherConfig,
    pub seed: u64,
    /// Per-card group re-dealing, applied to every (re)built card backend.
    pub adaptive: Option<AdaptiveConfig>,
    /// Per-card window re-splitting (requires `adaptive`).
    pub resplit: Option<SplitterConfig>,
    /// Fleet-level migration tuning.
    pub rebalance: RebalanceConfig,
    /// Escalation policy of the fleet-scope control plane (its ladder runs
    /// per-card levers first).  `max_lever` is honored: `Migrate` by
    /// default, `Hold` pins the shard map (a static baseline arm).
    pub control: ControlPlaneConfig,
    /// Background control-epoch period; `None` = epochs are driven
    /// manually via [`FleetService::control_epoch`].
    pub epoch: Option<Duration>,
    /// Wall-clock pacing of simulated device time, applied to every card
    /// backend (see `SimBackendConfig::sim_timescale`); 0 = unpaced.
    pub sim_timescale: f64,
    /// Run every card on the pre-slab legacy request pipeline (the
    /// `benches/serve_hotpath.rs --legacy-path` oracle).
    pub legacy_path: bool,
    /// Per-card self-healing (retries, hedging, partials, breakers),
    /// applied to every card backend — including backends rebuilt by a
    /// migration.
    pub resilience: ResilienceConfig,
    /// Deterministic fault injection, decorrelated per card via
    /// [`FaultPlan::for_card`] (same schedule shape, independent draws).
    pub fault: Option<FaultPlan>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            seed: 0xF1EE7,
            adaptive: None,
            resplit: None,
            rebalance: RebalanceConfig::default(),
            control: ControlPlaneConfig {
                max_lever: Lever::Migrate,
                ..ControlPlaneConfig::default()
            },
            epoch: None,
            sim_timescale: 0.0,
            legacy_path: false,
            resilience: ResilienceConfig::default(),
            fault: None,
        }
    }
}

/// One card's share of an in-flight fleet request.
struct FleetPart {
    /// Index into the pinned generation's `cards` / `plan.shards`.
    shard: usize,
    ticket: Ticket,
    /// Original request positions of this card's rows.
    positions: Vec<u32>,
}

/// A claim on one in-flight fleet request; redeems to rows merged back in
/// request order.  Pins the generation it was split under: its shard map
/// and card services stay alive (and correct) even if the fleet migrates
/// rows and publishes a newer generation mid-flight.
pub struct FleetTicket {
    parts: Vec<FleetPart>,
    request_len: usize,
    d: usize,
    /// The submit-time generation: keeps its services alive until
    /// redemption, and routes redeemed per-card slabs back to their
    /// card's output pool.
    generation: Arc<FleetState>,
    /// Fleet-level pool the *merged* output buffer is drawn from
    /// (returned via [`FleetService::recycle`]).
    pool: Arc<SlabPool>,
}

impl FleetTicket {
    /// Non-blocking progress: Ready once every card is ready; Expired as
    /// soon as any card's deadline passed.
    pub fn poll(&mut self) -> TicketState {
        let mut all_ready = true;
        for p in &mut self.parts {
            match p.ticket.poll() {
                TicketState::Expired => return TicketState::Expired,
                TicketState::Pending => all_ready = false,
                TicketState::Ready => {}
            }
        }
        if all_ready {
            TicketState::Ready
        } else {
            TicketState::Pending
        }
    }

    /// Redeem: wait for every card and merge rows into request order.
    pub fn wait(self) -> anyhow::Result<Vec<f32>> {
        let d = self.d;
        // Pooled (stale prefix contents possible): the card split covers
        // every request position exactly once, so the scatters below
        // overwrite the whole buffer before it surfaces.
        let mut out = self.pool.get(self.request_len * d);
        for part in self.parts {
            let rows = part
                .ticket
                .wait()
                .with_context(|| format!("card shard {}", part.shard))?;
            scatter_rows(&mut out, &part.positions, &rows, d);
            // Return the card's slab to its pool: fleet steady state must
            // be as allocation-free per card as the single-card path.
            self.generation.cards[part.shard].recycle(rows);
        }
        Ok(out)
    }

    /// Redeem with graceful degradation: a card that failed or delivered
    /// only part of its shard contributes to the request-order validity
    /// mask instead of failing the whole request.  `Full` when every card
    /// delivered every row; `Err` only when *no* row was delivered (first
    /// card error, with its shard context).
    pub fn wait_outcome(self) -> anyhow::Result<Outcome> {
        let d = self.d;
        let mut out = self.pool.get(self.request_len * d);
        let mut valid = vec![false; self.request_len];
        let mut first_err: Option<anyhow::Error> = None;
        let mut degraded = false;
        for part in self.parts {
            match part.ticket.wait_outcome() {
                Ok(Outcome::Full(rows)) => {
                    scatter_rows(&mut out, &part.positions, &rows, d);
                    for &p in &part.positions {
                        valid[p as usize] = true;
                    }
                    self.generation.cards[part.shard].recycle(rows);
                }
                Ok(Outcome::Partial {
                    rows,
                    valid: card_valid,
                }) => {
                    degraded = true;
                    // `rows`/`card_valid` are in the card sub-request's
                    // order; scatter row-by-row through `positions`, zeroing
                    // invalid slots (the merged buffer is pooled — stale).
                    for (k, &p) in part.positions.iter().enumerate() {
                        let span = p as usize * d..(p as usize + 1) * d;
                        if card_valid[k] {
                            out[span].copy_from_slice(&rows[k * d..(k + 1) * d]);
                            valid[p as usize] = true;
                        } else {
                            out[span].fill(0.0);
                        }
                    }
                    self.generation.cards[part.shard].recycle(rows);
                }
                Err(e) => {
                    degraded = true;
                    for &p in &part.positions {
                        out[p as usize * d..(p as usize + 1) * d].fill(0.0);
                    }
                    if first_err.is_none() {
                        first_err = Some(e.context(format!("card shard {}", part.shard)));
                    }
                }
            }
        }
        if !degraded {
            return Ok(Outcome::Full(out));
        }
        if valid.iter().any(|&v| v) {
            return Ok(Outcome::Partial { rows: out, valid });
        }
        Err(first_err.unwrap_or_else(|| anyhow!("no rows delivered")))
    }
}

/// One published generation: the shard map and its position-matched card
/// services (plus, for sim-built fleets, the concrete backends so the
/// control plane can drive their per-card epochs and read their simulated
/// device accounting).
struct FleetState {
    plan: Arc<FleetPlan>,
    /// Position-matched to `plan.shards`.
    cards: Vec<Service>,
    /// Position-matched to `plan.shards`; `None` for externally composed
    /// services.
    sims: Vec<Option<Arc<SimBackend>>>,
}

/// Everything shared between the facade handle and the background epoch
/// thread.
struct FleetCore {
    state: RwLock<Arc<FleetState>>,
    d: usize,
    /// Pool for merged fleet outputs (cooperating callers return them via
    /// [`FleetService::recycle`], mirroring the single-card path).
    pool: Arc<SlabPool>,
    /// Zero-copy whole-table view (re-sliced per migration); `None` when
    /// the fleet was composed from external services — migration disabled.
    whole: Option<TableView>,
    /// Probe + timing per card (rebuild context); empty when external.
    specs: Vec<(CardSpec, SimTiming)>,
    cfg: FleetConfig,
    plane: ControlPlane,
    rebalancer: FleetRebalancer,
    /// Fleet-scope registry: migration counters live here (per-card
    /// counters live in each card's own registry).
    metrics: Arc<Metrics>,
    /// Serializes whole fleet epochs: the background thread and manual
    /// [`FleetService::control_epoch`] calls must not both migrate from
    /// the same stale state (two plans would claim the same generation).
    /// An atomic spin gate — epochs are rare and never on the request
    /// path.
    gate: EpochGate,
    /// Per-card routed-row totals at the previous committed epoch
    /// boundary, indexed by card id (atomics: epoch sampling takes no
    /// lock).
    last_card_rows: Vec<AtomicU64>,
    epoch_stop: AtomicBool,
    epoch_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl FleetCore {
    fn current(&self) -> Arc<FleetState> {
        Arc::clone(&self.state.read().unwrap())
    }

    /// One fleet control epoch: per-card levers first (each card's own
    /// control plane applies re-deals / re-splits), then the fleet ladder
    /// judges per-card imbalance and — once escalation reaches
    /// [`Lever::Migrate`] — applies a rebalancer proposal.  Returns the
    /// new *fleet* generation when a migration published.
    fn epoch(&self) -> Option<u64> {
        let _serialized = self.gate.lock();
        let state = self.current();
        let mut card_acted = false;
        for sim in state.sims.iter().flatten() {
            if sim.rebalance_epoch().is_some() {
                card_acted = true;
            }
        }
        if self.specs.is_empty() {
            // Externally composed fleet: nothing to migrate with.
            return None;
        }

        // Per-card load since the last committed epoch (indexed by card
        // id; a card rebuilt by a migration restarts its counters, which
        // the post-migration re-baseline absorbs).
        let n = self.specs.len();
        let mut totals = vec![0u64; n];
        for (shard, svc) in state.plan.shards.iter().zip(&state.cards) {
            totals[shard.card] = svc.metrics().rows;
        }
        let min_commit = self.rebalancer.cfg.min_epoch_rows;
        let delta = committed_delta_atomic(&self.last_card_rows, &totals, min_commit);

        let imbalance = match load_shares(&delta) {
            None => 0.0,
            Some(load) => {
                let total_cap: f64 = self.specs.iter().map(|(c, _)| c.capacity_gbps()).sum();
                let caps: Vec<f64> = self
                    .specs
                    .iter()
                    .map(|(c, _)| c.capacity_gbps() / total_cap)
                    .collect();
                capacity_imbalance(&load, &caps)
            }
        };

        let permitted = self.plane.permit(imbalance);
        if permitted < Lever::Migrate {
            self.plane.record(
                permitted,
                card_acted.then_some(Lever::Redeal),
                imbalance,
                None,
                if card_acted {
                    "per-card levers acted; fleet holds"
                } else {
                    "within tolerance or cooling down"
                },
            );
            return None;
        }

        let cards: Vec<CardSpec> = self.specs.iter().map(|(c, _)| c.clone()).collect();
        let Some(proposal) = self.rebalancer.propose(&state.plan, &cards, &delta) else {
            self.plane
                .record(permitted, None, imbalance, None, "rebalancer declined");
            return None;
        };
        match self.apply_migration(&state, &cards, &proposal.rows_of) {
            Ok((generation, moved)) => {
                self.metrics.migrate_epochs.fetch_add(1, Ordering::Relaxed);
                self.metrics.rows_migrated.fetch_add(moved, Ordering::Relaxed);
                self.metrics
                    .generations_published
                    .fetch_add(1, Ordering::Relaxed);
                self.plane.record(
                    permitted,
                    Some(Lever::Migrate),
                    imbalance,
                    Some(generation),
                    format!("migrated {moved} rows across cards (zero-copy)"),
                );
                Some(generation)
            }
            Err(why) => {
                self.plane.record(
                    permitted,
                    None,
                    imbalance,
                    None,
                    format!("migration aborted: {why:#}"),
                );
                None
            }
        }
    }

    /// Build and publish the next generation for `rows_of`: untouched
    /// cards keep their running services; re-sized cards get new backends
    /// over fresh zero-copy slices of the shared table storage.
    fn apply_migration(
        &self,
        old: &Arc<FleetState>,
        cards: &[CardSpec],
        rows_of: &[u64],
    ) -> anyhow::Result<(u64, u64)> {
        let whole = self
            .whole
            .as_ref()
            .ok_or_else(|| anyhow!("fleet has no rebuild context"))?;
        let next_plan = FleetPlan::with_ranges(
            cards,
            rows_of,
            old.plan.total_rows,
            old.plan.row_bytes,
            self.cfg.seed,
            old.plan.generation + 1,
        )?;
        let moved = old.plan.rows_moved(&next_plan);
        if moved < self.cfg.rebalance.min_move_rows {
            return Err(anyhow!("{moved} rows moved is below the migration floor"));
        }

        let mut services = Vec::with_capacity(next_plan.shards.len());
        let mut sims = Vec::with_capacity(next_plan.shards.len());
        for shard in &next_plan.shards {
            // Reuse a card whose range is untouched: its backend, queue,
            // metrics, and calibration all carry over.
            let unchanged = old
                .plan
                .shards
                .iter()
                .position(|s| {
                    s.card == shard.card
                        && s.start_row == shard.start_row
                        && s.rows == shard.rows
                });
            if let Some(i) = unchanged {
                services.push(old.cards[i].clone());
                sims.push(old.sims[i].clone());
                continue;
            }
            let (spec, timing) = &self.specs[shard.card];
            let backend = start_card_backend(&self.cfg, spec, timing, shard, whole)
                .with_context(|| format!("rebuilding card {}", shard.card))?;
            sims.push(Some(Arc::clone(&backend)));
            services.push(Service::new(backend));
        }

        let generation = next_plan.generation;
        let next = Arc::new(FleetState {
            plan: Arc::new(next_plan),
            cards: services,
            sims,
        });
        *self.state.write().unwrap() = Arc::clone(&next);
        // Re-baseline the per-card load counters under the new backends
        // (rebuilt cards restart their registries at zero).
        let mut totals = vec![0u64; self.specs.len()];
        for (shard, svc) in next.plan.shards.iter().zip(&next.cards) {
            totals[shard.card] = svc.metrics().rows;
        }
        rebaseline_atomic(&self.last_card_rows, &totals);
        Ok((generation, moved))
    }

    fn stop(&self) {
        self.epoch_stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.epoch_thread.lock().unwrap().take() {
            let _ = t.join();
        }
        for c in &self.current().cards {
            c.shutdown();
        }
    }
}

/// Build one card's backend over its shard — a zero-copy slice of the
/// shared table — wiring every fleet-level per-card setting.  The single
/// constructor both `build_sim_with` (startup) and `apply_migration`
/// (rebuild) use, so migrated cards can never silently run with different
/// settings than startup cards.
fn start_card_backend(
    cfg: &FleetConfig,
    spec: &CardSpec,
    timing: &SimTiming,
    shard: &crate::coordinator::cluster::CardShard,
    whole: &TableView,
) -> anyhow::Result<Arc<SimBackend>> {
    let local = whole.slice_rows(shard.start_row, shard.rows);
    let mut bcfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
    bcfg.batcher = cfg.batcher.clone();
    bcfg.seed = cfg.seed;
    bcfg.adaptive = cfg.adaptive.clone();
    bcfg.resplit = cfg.resplit.clone();
    bcfg.sim_timescale = cfg.sim_timescale;
    bcfg.legacy_path = cfg.legacy_path;
    bcfg.resilience = cfg.resilience.clone();
    bcfg.fault = cfg.fault.as_ref().map(|p| p.for_card(shard.card));
    Ok(Arc::new(SimBackend::start_with_placement(
        bcfg,
        &spec.map,
        shard.plan.clone(),
        shard.placement.clone(),
        local,
        timing.clone(),
    )?))
}

/// The fleet-level facade: two-level routing over per-card services, with
/// the card boundaries themselves under control-plane management.
pub struct FleetService {
    core: Arc<FleetCore>,
}

impl FleetService {
    /// Compose a fleet from an existing plan and per-card services (each
    /// serving exactly its shard's local row space).  Composed fleets have
    /// no rebuild context, so the migration lever is disabled.
    pub fn new(plan: FleetPlan, cards: Vec<Service>) -> anyhow::Result<Self> {
        let d = Self::validate(&plan, &cards)?;
        let sims = cards.iter().map(|_| None).collect();
        Ok(Self {
            core: Arc::new(FleetCore {
                state: RwLock::new(Arc::new(FleetState {
                    plan: Arc::new(plan),
                    cards,
                    sims,
                })),
                d,
                pool: SlabPool::new(),
                whole: None,
                specs: Vec::new(),
                cfg: FleetConfig::default(),
                plane: ControlPlane::new(ControlPlaneConfig {
                    max_lever: Lever::Migrate,
                    ..ControlPlaneConfig::default()
                }),
                rebalancer: FleetRebalancer::default(),
                metrics: Arc::new(Metrics::new()),
                gate: EpochGate::new(),
                last_card_rows: Vec::new(),
                epoch_stop: AtomicBool::new(false),
                epoch_thread: Mutex::new(None),
            }),
        })
    }

    fn validate(plan: &FleetPlan, cards: &[Service]) -> anyhow::Result<usize> {
        if plan.shards.len() != cards.len() {
            return Err(anyhow!(
                "{} shards but {} card services",
                plan.shards.len(),
                cards.len()
            ));
        }
        let mut d = None;
        for (shard, svc) in plan.shards.iter().zip(cards) {
            if svc.rows() != shard.rows {
                return Err(anyhow!(
                    "card {} serves {} rows but its shard has {}",
                    shard.card,
                    svc.rows(),
                    shard.rows
                ));
            }
            match d {
                None => d = Some(svc.d()),
                Some(d0) if d0 != svc.d() => {
                    return Err(anyhow!("cards disagree on row width"));
                }
                _ => {}
            }
        }
        d.ok_or_else(|| anyhow!("empty fleet"))
    }

    /// Build a hermetic fleet: shard `table` across simulated cards
    /// (capacity-weighted, reach-constrained — the plan comes from
    /// [`FleetPlan::build`]) and start one [`SimBackend`] per shard using
    /// that card's probed map, window plan, and group placement.
    ///
    /// **Zero-copy**: every card's backend receives a
    /// [`TableView`](crate::coordinator::TableView) into the one shared
    /// `Arc<[f32]>` — per-card memory is O(view metadata), so a >10 GiB
    /// host table costs refcount bumps, not per-shard copies.
    pub fn build_sim(
        specs: Vec<(CardSpec, SimTiming)>,
        table: &Table,
        batcher: BatcherConfig,
        seed: u64,
    ) -> anyhow::Result<Self> {
        Self::build_sim_with(
            specs,
            table,
            FleetConfig {
                batcher,
                seed,
                ..FleetConfig::default()
            },
        )
    }

    /// [`build_sim`](Self::build_sim) with full repartitioning control:
    /// per-card adaptive/re-split configs are applied to every card
    /// backend (and every backend rebuilt by a migration), and `cfg.epoch`
    /// optionally starts the background fleet control-epoch thread.
    pub fn build_sim_with(
        specs: Vec<(CardSpec, SimTiming)>,
        table: &Table,
        mut cfg: FleetConfig,
    ) -> anyhow::Result<Self> {
        // One epoch driver per card: when the fleet runs its own epoch
        // thread (which drives every card's control plane itself), strip
        // any per-card epoch timer — two concurrent drivers would halve
        // each card's hysteresis in wall time and race its plane state.
        if cfg.epoch.is_some() {
            if let Some(a) = cfg.adaptive.as_mut() {
                a.epoch = None;
            }
        }
        let cards: Vec<CardSpec> = specs.iter().map(|(c, _)| c.clone()).collect();
        let plan = FleetPlan::build(&cards, table.rows, row_bytes_for_d(table.d), cfg.seed)?;
        let whole = table.view();
        let mut services = Vec::new();
        let mut sims = Vec::new();
        for shard in &plan.shards {
            let (spec, timing) = &specs[shard.card];
            let backend = start_card_backend(&cfg, spec, timing, shard, &whole)
                .with_context(|| format!("starting card {}", shard.card))?;
            sims.push(Some(Arc::clone(&backend)));
            services.push(Service::new(backend));
        }
        let d = Self::validate(&plan, &services)?;

        // The fleet plane runs at whatever ceiling the config asks for:
        // `Migrate` by default (FleetConfig::default), `Hold` to pin the
        // shard map (e.g. a static baseline arm).
        let plane_cfg = cfg.control.clone();
        let n_cards = specs.len();
        let epoch = cfg.epoch;
        let core = Arc::new(FleetCore {
            state: RwLock::new(Arc::new(FleetState {
                plan: Arc::new(plan),
                cards: services,
                sims,
            })),
            d,
            pool: SlabPool::new(),
            whole: Some(whole),
            specs,
            rebalancer: FleetRebalancer::new(cfg.rebalance.clone()),
            plane: ControlPlane::new(plane_cfg),
            cfg,
            metrics: Arc::new(Metrics::new()),
            gate: EpochGate::new(),
            last_card_rows: (0..n_cards).map(|_| AtomicU64::new(0)).collect(),
            epoch_stop: AtomicBool::new(false),
            epoch_thread: Mutex::new(None),
        });

        if let Some(period) = epoch {
            let ctx = Arc::clone(&core);
            let tick = period
                .min(Duration::from_millis(5))
                .max(Duration::from_micros(100));
            let handle = std::thread::Builder::new()
                .name("a100win-fleet-controlplane".into())
                .spawn(move || {
                    let mut since = Duration::ZERO;
                    while !ctx.epoch_stop.load(Ordering::Relaxed) {
                        std::thread::sleep(tick);
                        since += tick;
                        if since >= period {
                            since = Duration::ZERO;
                            let _ = ctx.epoch();
                        }
                    }
                })
                .context("spawning fleet control plane")?;
            *core.epoch_thread.lock().unwrap() = Some(handle);
        }
        Ok(Self { core })
    }

    /// The current shard map (generation-stamped; migrations swap it).
    pub fn plan(&self) -> Arc<FleetPlan> {
        Arc::clone(&self.core.current().plan)
    }

    /// Per-card services of the current generation, position-matched to
    /// [`plan`](Self::plan)`.shards` (cheap clones of shared handles).
    pub fn cards(&self) -> Vec<Service> {
        self.core.current().cards.clone()
    }

    pub fn d(&self) -> usize {
        self.core.d
    }

    pub fn rows(&self) -> u64 {
        self.core.current().plan.total_rows
    }

    /// Run one fleet control epoch by hand (per-card levers, then the
    /// migration ladder).  Returns the new fleet generation when a
    /// migration published.  The background thread configured by
    /// [`FleetConfig::epoch`] calls exactly this.
    pub fn control_epoch(&self) -> Option<u64> {
        self.core.epoch()
    }

    /// The fleet control plane's audited decision trace, oldest first.
    pub fn control_decisions(&self) -> Vec<Decision> {
        self.core.plane.decisions()
    }

    /// Fleet-scope counters (migration epochs, rows migrated, generations).
    pub fn fleet_metrics(&self) -> MetricsSnapshot {
        self.core.metrics.snapshot()
    }

    /// Sum of per-card simulated aggregate GB/s (cards run in parallel).
    pub fn aggregate_sim_gbps(&self) -> f64 {
        self.core
            .current()
            .sims
            .iter()
            .flatten()
            .map(|s| s.aggregate_sim_gbps())
            .sum()
    }

    /// Split a request by card shard and submit each part; the returned
    /// [`FleetTicket`] merges rows back in request order under the shard
    /// map it was split with (migrations never disturb it).
    pub fn submit(
        &self,
        rows: Arc<Vec<u64>>,
        deadline: Option<Duration>,
    ) -> anyhow::Result<FleetTicket> {
        let state = self.core.current();
        let split = state.plan.split(&rows)?;
        let mut parts = Vec::new();
        for (si, (locals, positions)) in split.into_iter().enumerate() {
            if locals.is_empty() {
                continue;
            }
            let ticket = state.cards[si]
                .submit(Arc::new(locals), deadline)
                .with_context(|| format!("card shard {si}"))?;
            parts.push(FleetPart {
                shard: si,
                ticket,
                positions,
            });
        }
        Ok(FleetTicket {
            parts,
            request_len: rows.len(),
            d: self.core.d,
            generation: state,
            pool: Arc::clone(&self.core.pool),
        })
    }

    /// Blocking convenience: submit + merge.
    pub fn lookup(&self, rows: Arc<Vec<u64>>) -> anyhow::Result<Vec<f32>> {
        self.submit(rows, None)?.wait()
    }

    /// Return a redeemed merged buffer's capacity to the fleet's output
    /// pool (optional, like `Service::recycle`).
    pub fn recycle(&self, buf: Vec<f32>) {
        self.core.pool.put(buf);
    }

    /// Per-card metric snapshots of the current generation as
    /// `(card id, snapshot)`.  A card rebuilt by a migration restarts its
    /// registry (the fleet-scope counters in
    /// [`fleet_metrics`](Self::fleet_metrics) are continuous).
    pub fn per_card_metrics(&self) -> Vec<(usize, MetricsSnapshot)> {
        let state = self.core.current();
        state
            .plan
            .shards
            .iter()
            .zip(&state.cards)
            .map(|(shard, svc)| (shard.card, svc.metrics()))
            .collect()
    }

    pub fn shutdown(&self) {
        self.core.stop();
    }
}

impl Drop for FleetService {
    fn drop(&mut self) {
        // The background control-plane thread holds the core alive; an
        // un-shutdown fleet must not leak it (idempotent with shutdown()).
        self.core.stop();
    }
}

//! Fleet routing: one serving facade over several probed cards.
//!
//! The paper stresses that the smid→group mapping "may vary card to card",
//! so a fleet deployment probes every card once and composes the per-card
//! [`TopologyMap`](crate::probe::TopologyMap)s.  [`FleetService`] wires
//! [`FleetPlan`]/[`CardShard`](crate::coordinator::CardShard) to the
//! ticketed facade: a request's rows are split by card shard, submitted to
//! each card's [`Service`] as ordinary tickets, and merged back **in
//! request order** when the [`FleetTicket`] is redeemed.
//!
//! ```text
//! global row ──► card shard (FleetPlan) ──► window ──► SM group
//! ```

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Context};

use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::chunks::row_bytes_for_d;
use crate::coordinator::cluster::{CardSpec, FleetPlan};
use crate::coordinator::metrics::MetricsSnapshot;
use crate::coordinator::placement::PlacementPolicy;
use crate::coordinator::table::Table;

use super::backend::{scatter_rows, Ticket, TicketState};
use super::sim_backend::{SimBackend, SimBackendConfig, SimTiming};
use super::Service;

/// One card's share of an in-flight fleet request.
struct FleetPart {
    /// Index into `FleetService::cards` / `plan.shards`.
    shard: usize,
    ticket: Ticket,
    /// Original request positions of this card's rows.
    positions: Vec<u32>,
}

/// A claim on one in-flight fleet request; redeems to rows merged back in
/// request order.
pub struct FleetTicket {
    parts: Vec<FleetPart>,
    request_len: usize,
    d: usize,
}

impl FleetTicket {
    /// Non-blocking progress: Ready once every card is ready; Expired as
    /// soon as any card's deadline passed.
    pub fn poll(&mut self) -> TicketState {
        let mut all_ready = true;
        for p in &mut self.parts {
            match p.ticket.poll() {
                TicketState::Expired => return TicketState::Expired,
                TicketState::Pending => all_ready = false,
                TicketState::Ready => {}
            }
        }
        if all_ready {
            TicketState::Ready
        } else {
            TicketState::Pending
        }
    }

    /// Redeem: wait for every card and merge rows into request order.
    pub fn wait(self) -> anyhow::Result<Vec<f32>> {
        let d = self.d;
        let mut out = vec![0.0f32; self.request_len * d];
        for part in self.parts {
            let rows = part
                .ticket
                .wait()
                .with_context(|| format!("card shard {}", part.shard))?;
            scatter_rows(&mut out, &part.positions, &rows, d);
        }
        Ok(out)
    }
}

/// The fleet-level facade: two-level routing over per-card services.
pub struct FleetService {
    plan: FleetPlan,
    /// Position-matched to `plan.shards`.
    cards: Vec<Service>,
    d: usize,
}

impl FleetService {
    /// Compose a fleet from an existing plan and per-card services (each
    /// serving exactly its shard's local row space).
    pub fn new(plan: FleetPlan, cards: Vec<Service>) -> anyhow::Result<Self> {
        if plan.shards.len() != cards.len() {
            return Err(anyhow!(
                "{} shards but {} card services",
                plan.shards.len(),
                cards.len()
            ));
        }
        let mut d = None;
        for (shard, svc) in plan.shards.iter().zip(&cards) {
            if svc.rows() != shard.rows {
                return Err(anyhow!(
                    "card {} serves {} rows but its shard has {}",
                    shard.card,
                    svc.rows(),
                    shard.rows
                ));
            }
            match d {
                None => d = Some(svc.d()),
                Some(d0) if d0 != svc.d() => {
                    return Err(anyhow!("cards disagree on row width"));
                }
                _ => {}
            }
        }
        let d = d.ok_or_else(|| anyhow!("empty fleet"))?;
        Ok(Self { plan, cards, d })
    }

    /// Build a hermetic fleet: shard `table` across simulated cards
    /// (capacity-weighted, reach-constrained — the plan comes from
    /// [`FleetPlan::build`]) and start one [`SimBackend`] per shard using
    /// that card's probed map, window plan, and group placement.
    ///
    /// **Zero-copy**: every card's backend receives a
    /// [`TableView`](crate::coordinator::TableView) into the one shared
    /// `Arc<[f32]>` — per-card memory is O(view metadata), so a >10 GiB
    /// host table costs refcount bumps, not per-shard copies.
    pub fn build_sim(
        specs: Vec<(CardSpec, SimTiming)>,
        table: &Table,
        batcher: BatcherConfig,
        seed: u64,
    ) -> anyhow::Result<Self> {
        let cards: Vec<CardSpec> = specs.iter().map(|(c, _)| c.clone()).collect();
        let plan = FleetPlan::build(&cards, table.rows, row_bytes_for_d(table.d), seed)?;
        let whole = table.view();
        let mut services = Vec::new();
        for shard in &plan.shards {
            let (spec, timing) = &specs[shard.card];
            let local = whole.slice_rows(shard.start_row, shard.rows);
            let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
            cfg.batcher = batcher.clone();
            cfg.seed = seed;
            let backend = SimBackend::start_with_placement(
                cfg,
                &spec.map,
                shard.plan.clone(),
                shard.placement.clone(),
                local,
                timing.clone(),
            )
            .with_context(|| format!("starting card {}", shard.card))?;
            services.push(Service::new(Arc::new(backend)));
        }
        Self::new(plan, services)
    }

    pub fn plan(&self) -> &FleetPlan {
        &self.plan
    }

    /// Per-card services, position-matched to `plan().shards`.
    pub fn cards(&self) -> &[Service] {
        &self.cards
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn rows(&self) -> u64 {
        self.plan.total_rows
    }

    /// Split a request by card shard and submit each part; the returned
    /// [`FleetTicket`] merges rows back in request order.
    pub fn submit(
        &self,
        rows: Arc<Vec<u64>>,
        deadline: Option<Duration>,
    ) -> anyhow::Result<FleetTicket> {
        let split = self.plan.split(&rows)?;
        let mut parts = Vec::new();
        for (si, (locals, positions)) in split.into_iter().enumerate() {
            if locals.is_empty() {
                continue;
            }
            let ticket = self.cards[si]
                .submit(Arc::new(locals), deadline)
                .with_context(|| format!("card shard {si}"))?;
            parts.push(FleetPart {
                shard: si,
                ticket,
                positions,
            });
        }
        Ok(FleetTicket {
            parts,
            request_len: rows.len(),
            d: self.d,
        })
    }

    /// Blocking convenience: submit + merge.
    pub fn lookup(&self, rows: Arc<Vec<u64>>) -> anyhow::Result<Vec<f32>> {
        self.submit(rows, None)?.wait()
    }

    /// Per-card metric snapshots as `(card id, snapshot)`.
    pub fn per_card_metrics(&self) -> Vec<(usize, MetricsSnapshot)> {
        self.plan
            .shards
            .iter()
            .zip(&self.cards)
            .map(|(shard, svc)| (shard.card, svc.metrics()))
            .collect()
    }

    pub fn shutdown(&self) {
        for c in &self.cards {
            c.shutdown();
        }
    }
}

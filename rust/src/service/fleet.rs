//! Fleet routing: one serving facade over several probed cards, with
//! live, zero-copy cross-card row migration.
//!
//! The paper stresses that the smid→group mapping "may vary card to card",
//! so a fleet deployment probes every card once and composes the per-card
//! [`TopologyMap`](crate::probe::TopologyMap)s.  [`FleetService`] wires
//! [`FleetPlan`]/[`CardShard`](crate::coordinator::CardShard) to the
//! ticketed facade: a request's rows are split by card shard, submitted to
//! each card's [`Service`] as ordinary tickets, and merged back **in
//! request order** when the [`FleetTicket`] is redeemed.
//!
//! ```text
//! global row ──► card shard (FleetPlan, generation-stamped) ──► window ──► SM group
//! ```
//!
//! The shard map is *live*: [`FleetService::control_epoch`] (or the
//! background thread enabled by [`FleetConfig::epoch`]) first drives each
//! card's own control plane (group re-deal, window re-split), then judges
//! the **per-card** load/capacity imbalance; when the fleet-scope
//! [`ControlPlane`] escalates to [`Lever::Migrate`], a
//! [`FleetRebalancer`] proposal re-cuts the card boundaries and the fleet
//! publishes a new generation whose re-sized cards serve fresh
//! [`TableView`] slices of the **same** shared `Arc<[f32]>` — refcount
//! bumps and worker re-spawns, never a row of memcpy.  In-flight
//! [`FleetTicket`]s pin their generation's `FleetState` (shard map *and*
//! card services), so they merge under the shard map they were split with
//! while new submissions route under the new one; a retired generation's
//! backends drain and stop when the last ticket drops.
//!
//! Above migration sits the fifth lever, **replication**
//! ([`Lever::Replicate`]): when one shard's load exceeds what any single
//! card can serve (migration can only move the wall, not raise it), the
//! fleet publishes a generation-stamped
//! [`ReplicaSet`](crate::coordinator::ReplicaSet) whose replicas are
//! zero-copy views of the same shard range on additional cards, and
//! [`FleetService::submit`] routes each sub-batch by power-of-two-choices
//! over live per-card queue depth.  De-replication is the same swap in
//! reverse — tickets pinned to the old state drain naturally, no barrier.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context};

use crate::coordinator::adaptive::AdaptiveConfig;
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::chunks::{row_bytes_for_d, WindowPlan};
use crate::coordinator::cluster::{CardShard, CardSpec, FleetPlan};
use crate::coordinator::controlplane::{
    capacity_imbalance, committed_delta_atomic, load_shares, rebaseline_atomic, ControlPlane,
    ControlPlaneConfig, Decision, Lever,
};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::placement::{Placement, PlacementPolicy};
use crate::coordinator::remap::RemapConfig;
use crate::coordinator::replan::SplitterConfig;
use crate::coordinator::replicate::{Replica, ReplicaSet, ReplicateConfig};
use crate::coordinator::table::{Table, TableView};

use crate::sim::FaultPlan;

use super::backend::{scatter_rows, Backend, Outcome, Ticket, TicketState};
use super::rebalance::{FleetRebalancer, RebalanceConfig};
use super::resilience::ResilienceConfig;
use super::ring::EpochGate;
use super::scatter::SlabPool;
use super::sim_backend::{SimBackend, SimBackendConfig, SimTiming};
use super::Service;

/// Fleet construction + repartitioning knobs (see
/// [`FleetService::build_sim_with`]).
#[derive(Clone)]
pub struct FleetConfig {
    pub batcher: BatcherConfig,
    pub seed: u64,
    /// Per-card group re-dealing, applied to every (re)built card backend.
    pub adaptive: Option<AdaptiveConfig>,
    /// Per-card window re-splitting (requires `adaptive`).
    pub resplit: Option<SplitterConfig>,
    /// Fleet-level migration tuning.
    pub rebalance: RebalanceConfig,
    /// Escalation policy of the fleet-scope control plane (its ladder runs
    /// per-card levers first).  `max_lever` is honored: `Migrate` by
    /// default, `Hold` pins the shard map (a static baseline arm).
    pub control: ControlPlaneConfig,
    /// Background control-epoch period; `None` = epochs are driven
    /// manually via [`FleetService::control_epoch`].
    pub epoch: Option<Duration>,
    /// Wall-clock pacing of simulated device time, applied to every card
    /// backend (see `SimBackendConfig::sim_timescale`); 0 = unpaced.
    pub sim_timescale: f64,
    /// Run every card on the pre-slab legacy request pipeline (the
    /// `benches/serve_hotpath.rs --legacy-path` oracle).
    pub legacy_path: bool,
    /// Per-card self-healing (retries, hedging, partials, breakers),
    /// applied to every card backend — including backends rebuilt by a
    /// migration.
    pub resilience: ResilienceConfig,
    /// Deterministic fault injection, decorrelated per card via
    /// [`FaultPlan::for_card`] (same schedule shape, independent draws).
    pub fault: Option<FaultPlan>,
    /// Per-card TLB-aware hot-row repacking (the repack lever), applied to
    /// every (re)built card backend.  Requires `adaptive` (ignored without
    /// it, like the per-card `resplit`).
    pub remap: Option<RemapConfig>,
    /// Arm the fifth lever: hot-shard read replication routed by
    /// power-of-two-choices over live queue depth.  Note that
    /// `capacity_fraction == 0.0` disables the observed-demand gate —
    /// open-loop wall-clock demand can never meet a *simulated*-bandwidth
    /// bar, so CLI arms gate on hot-share alone (see
    /// [`ReplicateConfig`]).
    pub replicate: Option<ReplicateConfig>,
    /// Pin each card's simulation workers to distinct cores
    /// (`util::threads::pin_to_core`, Linux only); off by default.
    pub pin_cores: bool,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            seed: 0xF1EE7,
            adaptive: None,
            resplit: None,
            rebalance: RebalanceConfig::default(),
            control: ControlPlaneConfig {
                max_lever: Lever::Migrate,
                ..ControlPlaneConfig::default()
            },
            epoch: None,
            sim_timescale: 0.0,
            legacy_path: false,
            resilience: ResilienceConfig::default(),
            fault: None,
            remap: None,
            replicate: None,
            pin_cores: false,
        }
    }
}

/// One unit of a card's queue-depth gauge, held for the lifetime of an
/// in-flight part.  The decrement rides `Drop`, so every path — redeem,
/// per-card error, abandoned ticket — releases exactly once and the gauge
/// can never leak upward or go negative.
struct DepthGuard(Arc<AtomicU64>);

impl DepthGuard {
    fn acquire(gauge: &Arc<AtomicU64>) -> Self {
        // RELAXED: the depth gauge is a routing heuristic (the
        // power-of-two-choices sample), not a synchronization edge; the
        // increment here and the decrement in `Drop` pair on the same
        // atomic, so the value is exact, just not ordered.
        gauge.fetch_add(1, Ordering::Relaxed);
        Self(Arc::clone(gauge))
    }
}

impl Drop for DepthGuard {
    fn drop(&mut self) {
        // RELAXED: see `acquire` — paired decrement on a heuristic gauge.
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// One card's share of an in-flight fleet request.
struct FleetPart {
    /// Index into the pinned generation's `plan.shards`.
    shard: usize,
    /// Serving unit the part was routed to: the shard's owner
    /// (`unit == shard`) or a replica (`unit >= cards.len()` indexes
    /// `replica_units`).  Redeemed slabs recycle to this unit's pool.
    unit: usize,
    ticket: Ticket,
    /// Original request positions of this card's rows.
    positions: Vec<u32>,
    /// Held for the part's lifetime; dropping releases the routed card's
    /// queue-depth unit (see [`DepthGuard`]).
    _depth: DepthGuard,
}

/// A claim on one in-flight fleet request; redeems to rows merged back in
/// request order.  Pins the generation it was split under: its shard map
/// and card services stay alive (and correct) even if the fleet migrates
/// rows and publishes a newer generation mid-flight.
pub struct FleetTicket {
    parts: Vec<FleetPart>,
    request_len: usize,
    d: usize,
    /// The submit-time generation: keeps its services alive until
    /// redemption, and routes redeemed per-card slabs back to their
    /// card's output pool.
    generation: Arc<FleetState>,
    /// Fleet-level pool the *merged* output buffer is drawn from
    /// (returned via [`FleetService::recycle`]).
    pool: Arc<SlabPool>,
}

impl FleetTicket {
    /// Non-blocking progress: Ready once every card is ready; Expired as
    /// soon as any card's deadline passed.
    pub fn poll(&mut self) -> TicketState {
        let mut all_ready = true;
        for p in &mut self.parts {
            match p.ticket.poll() {
                TicketState::Expired => return TicketState::Expired,
                TicketState::Pending => all_ready = false,
                TicketState::Ready => {}
            }
        }
        if all_ready {
            TicketState::Ready
        } else {
            TicketState::Pending
        }
    }

    /// Redeem: wait for every card and merge rows into request order.
    pub fn wait(self) -> anyhow::Result<Vec<f32>> {
        let d = self.d;
        // Pooled (stale prefix contents possible): the card split covers
        // every request position exactly once, so the scatters below
        // overwrite the whole buffer before it surfaces.
        let mut out = self.pool.get(self.request_len * d);
        for part in self.parts {
            let rows = part
                .ticket
                .wait()
                .with_context(|| format!("card shard {}", part.shard))?;
            scatter_rows(&mut out, &part.positions, &rows, d);
            // Return the slab to the unit that served it (owner card or
            // replica): fleet steady state must be as allocation-free per
            // card as the single-card path, and a replica's slab in the
            // owner's pool would cross backends.
            self.generation.unit_service(part.unit).recycle(rows);
        }
        Ok(out)
    }

    /// Redeem with graceful degradation: a card that failed or delivered
    /// only part of its shard contributes to the request-order validity
    /// mask instead of failing the whole request.  `Full` when every card
    /// delivered every row; `Err` only when *no* row was delivered (first
    /// card error, with its shard context).
    pub fn wait_outcome(self) -> anyhow::Result<Outcome> {
        let d = self.d;
        let mut out = self.pool.get(self.request_len * d);
        let mut valid = vec![false; self.request_len];
        let mut first_err: Option<anyhow::Error> = None;
        let mut degraded = false;
        for part in self.parts {
            match part.ticket.wait_outcome() {
                Ok(Outcome::Full(rows)) => {
                    scatter_rows(&mut out, &part.positions, &rows, d);
                    for &p in &part.positions {
                        valid[p as usize] = true;
                    }
                    self.generation.unit_service(part.unit).recycle(rows);
                }
                Ok(Outcome::Partial {
                    rows,
                    valid: card_valid,
                }) => {
                    degraded = true;
                    // `rows`/`card_valid` are in the card sub-request's
                    // order; scatter row-by-row through `positions`, zeroing
                    // invalid slots (the merged buffer is pooled — stale).
                    for (k, &p) in part.positions.iter().enumerate() {
                        let span = p as usize * d..(p as usize + 1) * d;
                        if card_valid[k] {
                            out[span].copy_from_slice(&rows[k * d..(k + 1) * d]);
                            valid[p as usize] = true;
                        } else {
                            out[span].fill(0.0);
                        }
                    }
                    self.generation.unit_service(part.unit).recycle(rows);
                }
                Err(e) => {
                    degraded = true;
                    for &p in &part.positions {
                        out[p as usize * d..(p as usize + 1) * d].fill(0.0);
                    }
                    if first_err.is_none() {
                        first_err = Some(e.context(format!("card shard {}", part.shard)));
                    }
                }
            }
        }
        if !degraded {
            return Ok(Outcome::Full(out));
        }
        if valid.iter().any(|&v| v) {
            return Ok(Outcome::Partial { rows: out, valid });
        }
        Err(first_err.unwrap_or_else(|| anyhow!("no rows delivered")))
    }
}

/// One live read replica: an additional card serving a zero-copy view of
/// a shard's exact global row range (so card-local row ids are identical
/// to the owner's and no re-indexing is needed to route to it).
#[derive(Clone)]
struct ReplicaUnit {
    /// Index into `plan.shards` of the replicated shard.
    shard: usize,
    /// Host card id (never the shard's owner; see `ReplicaSet::check`).
    card: usize,
    svc: Service,
    /// `Some` for sim-built replicas (simulated-bandwidth accounting).
    sim: Option<Arc<SimBackend>>,
}

/// One published generation: the shard map and its position-matched card
/// services (plus, for sim-built fleets, the concrete backends so the
/// control plane can drive their per-card epochs and read their simulated
/// device accounting).
struct FleetState {
    plan: Arc<FleetPlan>,
    /// Position-matched to `plan.shards`.
    cards: Vec<Service>,
    /// Position-matched to `plan.shards`; `None` for externally composed
    /// services.
    sims: Vec<Option<Arc<SimBackend>>>,
    /// The published replica description (generation-stamped; swapped with
    /// the state exactly like the plan — see `coordinator::replicate`).
    replicas: Arc<ReplicaSet>,
    /// Live replica services, position-matched to `replicas.replicas()`.
    replica_units: Vec<ReplicaUnit>,
    /// Per-card in-flight depth gauges (the P2C routing signal), indexed
    /// by card id and *shared across generations* (each publish clones the
    /// `Arc`s), so a migration or replica swap never zeroes live depth.
    depth: Vec<Arc<AtomicU64>>,
}

impl FleetState {
    /// Resolve a serving unit id: `unit < cards.len()` is the owner of
    /// shard `unit`, anything beyond indexes `replica_units`.
    fn unit_service(&self, unit: usize) -> &Service {
        if unit < self.cards.len() {
            &self.cards[unit]
        } else {
            &self.replica_units[unit - self.cards.len()].svc
        }
    }

    // hotpath: begin — per-sub-batch routing; no allocation.
    /// Pick the serving unit for shard `si`: the owner when the shard is
    /// unreplicated, otherwise power-of-two-choices — sample two distinct
    /// candidates (owner + replicas) from the rotating counter and take
    /// the one whose card queue is shallower.
    fn pick_unit(&self, si: usize, rr: &AtomicU64) -> (usize, usize) {
        let owner = (si, self.plan.shards[si].card);
        if self.replicas.is_empty() {
            return owner;
        }
        let n = 1 + self.replicas.replicas_of(si);
        if n < 2 {
            return owner;
        }
        // RELAXED: the rotation only diversifies which two candidates get
        // sampled; any interleaving of concurrent increments is fine.
        let t = rr.fetch_add(1, Ordering::Relaxed) as usize;
        let a = t % n;
        let b = {
            let b = (t / n) % (n - 1);
            if b >= a {
                b + 1
            } else {
                b
            }
        };
        let (ua, ca) = self.candidate(si, a);
        let (ub, cb) = self.candidate(si, b);
        // RELAXED: depth reads are a heuristic snapshot — a stale value
        // costs one suboptimal pick, never correctness (both candidates
        // serve the identical row range).
        let da = self.depth[ca].load(Ordering::Relaxed);
        let db = self.depth[cb].load(Ordering::Relaxed);
        if db < da {
            (ub, cb)
        } else {
            (ua, ca)
        }
    }

    /// Candidate `j` for shard `si`: 0 is the owner, `k + 1` the shard's
    /// k-th replica unit (unit ids past `cards.len()` index
    /// `replica_units`).
    fn candidate(&self, si: usize, j: usize) -> (usize, usize) {
        if j == 0 {
            return (si, self.plan.shards[si].card);
        }
        let mut seen = 0;
        for (k, unit) in self.replica_units.iter().enumerate() {
            if unit.shard == si {
                seen += 1;
                if seen == j {
                    return (self.cards.len() + k, unit.card);
                }
            }
        }
        // Units are position-matched to the published set; a miss would be
        // a publish bug.  Fail safe to the owner.
        (si, self.plan.shards[si].card)
    }
    // hotpath: end
}

/// Everything shared between the facade handle and the background epoch
/// thread.
struct FleetCore {
    state: RwLock<Arc<FleetState>>,
    d: usize,
    /// Pool for merged fleet outputs (cooperating callers return them via
    /// [`FleetService::recycle`], mirroring the single-card path).
    pool: Arc<SlabPool>,
    /// Zero-copy whole-table view (re-sliced per migration); `None` when
    /// the fleet was composed from external services — migration disabled.
    whole: Option<TableView>,
    /// Probe + timing per card (rebuild context); empty when external.
    specs: Vec<(CardSpec, SimTiming)>,
    cfg: FleetConfig,
    plane: ControlPlane,
    rebalancer: FleetRebalancer,
    /// Fleet-scope registry: migration counters live here (per-card
    /// counters live in each card's own registry).
    metrics: Arc<Metrics>,
    /// Serializes whole fleet epochs: the background thread and manual
    /// [`FleetService::control_epoch`] calls must not both migrate from
    /// the same stale state (two plans would claim the same generation).
    /// An atomic spin gate — epochs are rare and never on the request
    /// path.
    gate: EpochGate,
    /// Per-card routed-row totals at the previous committed epoch
    /// boundary, indexed by card id (atomics: epoch sampling takes no
    /// lock).
    last_card_rows: Vec<AtomicU64>,
    /// Replica-unit routed-row totals at the previous committed epoch,
    /// indexed by *host* card id (a card hosts at most one replica).
    last_replica_rows: Vec<AtomicU64>,
    /// Wall-clock instant of the previous fleet epoch — the denominator
    /// of the replicate lever's observed-demand estimate.
    last_epoch_at: Mutex<Instant>,
    /// Rotation counter seeding the power-of-two-choices sample.
    rr: AtomicU64,
    epoch_stop: AtomicBool,
    epoch_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

/// What the replicate lever did with its turn (see `FleetCore::epoch`).
enum ReplicateOutcome {
    /// No single-shard hotspot (or no host with headroom): fall through to
    /// the migration path.
    Declined,
    /// The epoch was spent — a decision was recorded — but nothing
    /// published (e.g. the replica backend failed to build).
    Spent,
    /// A new replica set published at this generation.
    Published(u64),
}

impl FleetCore {
    fn current(&self) -> Arc<FleetState> {
        Arc::clone(&self.state.read().unwrap())
    }

    /// One fleet control epoch: per-card levers first (each card's own
    /// control plane applies re-deals / re-splits / repacks), then the
    /// fleet ladder judges per-card imbalance — [`Lever::Migrate`] applies
    /// a rebalancer proposal, [`Lever::Replicate`] (when armed) gives a
    /// single-shard hotspot a zero-copy replica first.  De-replication is
    /// judged every epoch regardless of the ladder: dropping a replica is
    /// de-escalation, not an escalation that must be earned.  Returns the
    /// published generation when anything published.
    fn epoch(&self) -> Option<u64> {
        let _serialized = self.gate.lock();
        let state = self.current();
        let mut card_acted = false;
        for sim in state.sims.iter().flatten() {
            if sim.rebalance_epoch().is_some() {
                card_acted = true;
            }
        }
        for unit in &state.replica_units {
            if let Some(sim) = &unit.sim {
                if sim.rebalance_epoch().is_some() {
                    card_acted = true;
                }
            }
        }
        if self.specs.is_empty() {
            // Externally composed fleet: nothing to migrate with.
            return None;
        }

        // Wall-clock span since the previous epoch: denominator of the
        // replicate lever's observed-demand gate.
        let dt = {
            let mut last = self.last_epoch_at.lock().unwrap();
            let now = Instant::now();
            let dt = now.duration_since(*last);
            *last = now;
            dt
        };

        // Per-card load since the last committed epoch (indexed by card
        // id; a card rebuilt by a migration restarts its counters, which
        // the post-migration re-baseline absorbs).
        let n = self.specs.len();
        let mut totals = vec![0u64; n];
        for (shard, svc) in state.plan.shards.iter().zip(&state.cards) {
            totals[shard.card] = svc.metrics().rows;
        }
        let min_commit = self.rebalancer.cfg.min_epoch_rows;
        let delta = committed_delta_atomic(&self.last_card_rows, &totals, min_commit);

        // Replica traffic keeps its own committed baseline, indexed by
        // host card (a card hosts at most one replica unit).
        let mut rtotals = vec![0u64; n];
        for unit in &state.replica_units {
            rtotals[unit.card] = unit.svc.metrics().rows;
        }
        let rdelta = committed_delta_atomic(&self.last_replica_rows, &rtotals, min_commit);

        // A card's load is everything it served this epoch — its own shard
        // plus any replica it hosts; that is what its HBM actually saw.
        let combined: Vec<u64> = delta.iter().zip(&rdelta).map(|(a, b)| a + b).collect();
        let total_delta: u64 = combined.iter().sum();

        let imbalance = match load_shares(&combined) {
            None => 0.0,
            Some(load) => {
                let total_cap: f64 = self.specs.iter().map(|(c, _)| c.capacity_gbps()).sum();
                let caps: Vec<f64> = self
                    .specs
                    .iter()
                    .map(|(c, _)| c.capacity_gbps() / total_cap)
                    .collect();
                capacity_imbalance(&load, &caps)
            }
        };

        let permitted = self.plane.permit(imbalance);

        // De-replication first, *before* the ladder's early return: a
        // fleet whose replicas absorbed the hotspot reads as healthy, and
        // healthy must not mean the replicas are retained forever.
        if let Some(generation) =
            self.try_dereplicate(&state, &delta, &rdelta, total_delta, permitted, imbalance)
        {
            return Some(generation);
        }

        if permitted < Lever::Migrate {
            self.plane.record(
                permitted,
                card_acted.then_some(Lever::Redeal),
                imbalance,
                None,
                if card_acted {
                    "per-card levers acted; fleet holds"
                } else {
                    "within tolerance or cooling down"
                },
            );
            return None;
        }

        if permitted >= Lever::Replicate {
            match self.try_replicate(&state, &delta, &rdelta, total_delta, dt, permitted, imbalance)
            {
                ReplicateOutcome::Published(generation) => return Some(generation),
                ReplicateOutcome::Spent => return None,
                ReplicateOutcome::Declined => {}
            }
        }

        let cards: Vec<CardSpec> = self.specs.iter().map(|(c, _)| c.clone()).collect();
        let Some(proposal) = self.rebalancer.propose(&state.plan, &cards, &combined) else {
            self.plane
                .record(permitted, None, imbalance, None, "rebalancer declined");
            return None;
        };
        match self.apply_migration(&state, &cards, &proposal.rows_of) {
            Ok((generation, moved)) => {
                self.metrics.migrate_epochs.fetch_add(1, Ordering::Relaxed);
                self.metrics.rows_migrated.fetch_add(moved, Ordering::Relaxed);
                self.metrics
                    .generations_published
                    .fetch_add(1, Ordering::Relaxed);
                self.plane.record(
                    permitted,
                    Some(Lever::Migrate),
                    imbalance,
                    Some(generation),
                    format!("migrated {moved} rows across cards (zero-copy)"),
                );
                Some(generation)
            }
            Err(why) => {
                self.plane.record(
                    permitted,
                    None,
                    imbalance,
                    None,
                    format!("migration aborted: {why:#}"),
                );
                None
            }
        }
    }

    /// Rows shard `si` routed this epoch, owner and replicas combined.
    fn shard_rows(&self, state: &FleetState, si: usize, delta: &[u64], rdelta: &[u64]) -> u64 {
        let mut rows = delta[state.plan.shards[si].card];
        for card in state.replicas.cards_of(si) {
            rows += rdelta[card];
        }
        rows
    }

    /// Drop every replica once the replicated shard's combined (owner +
    /// replicas) load share falls under the exit floor.  Returns the new
    /// replica-set generation when a drop published.
    fn try_dereplicate(
        &self,
        state: &Arc<FleetState>,
        delta: &[u64],
        rdelta: &[u64],
        total_delta: u64,
        permitted: Lever,
        imbalance: f64,
    ) -> Option<u64> {
        let rcfg = self.cfg.replicate.as_ref()?;
        if state.replicas.is_empty() || total_delta == 0 {
            return None;
        }
        // All published replicas cover one shard at a time (see
        // `try_replicate`).
        let si = state.replicas.replicas()[0].shard;
        let share = self.shard_rows(state, si, delta, rdelta) as f64 / total_delta as f64;
        if share >= rcfg.exit_share {
            return None;
        }
        let dropped = state.replicas.count() as u64;
        let generation = state.replicas.generation + 1;
        self.publish_replicas(state, ReplicaSet::with_replicas(generation, Vec::new()), Vec::new());
        self.metrics.replicate_epochs.fetch_add(1, Ordering::Relaxed);
        self.metrics.replicas_dropped.fetch_add(dropped, Ordering::Relaxed);
        self.metrics
            .generations_published
            .fetch_add(1, Ordering::Relaxed);
        self.plane.record(
            permitted,
            Some(Lever::Replicate),
            imbalance,
            Some(generation),
            format!(
                "dropped {dropped} replica(s) of shard {si}: hot share {share:.2} \
                 under exit floor {:.2}",
                rcfg.exit_share
            ),
        );
        Some(generation)
    }

    /// Give the hottest shard a zero-copy replica on the least-loaded
    /// other card, when the hotspot is genuinely single-window (share
    /// gate) and hot enough to be worth another card's bandwidth (demand
    /// gate, when enabled).
    #[allow(clippy::too_many_arguments)]
    fn try_replicate(
        &self,
        state: &Arc<FleetState>,
        delta: &[u64],
        rdelta: &[u64],
        total_delta: u64,
        dt: Duration,
        permitted: Lever,
        imbalance: f64,
    ) -> ReplicateOutcome {
        let Some(rcfg) = self.cfg.replicate.as_ref() else {
            return ReplicateOutcome::Declined;
        };
        let Some(whole) = self.whole.as_ref() else {
            return ReplicateOutcome::Declined;
        };
        let n = self.specs.len();
        if n < 2 || total_delta == 0 {
            return ReplicateOutcome::Declined;
        }
        let shares: Vec<f64> = (0..state.plan.shards.len())
            .map(|si| self.shard_rows(state, si, delta, rdelta) as f64 / total_delta as f64)
            .collect();
        let Some((si, &share)) = shares
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
        else {
            return ReplicateOutcome::Declined;
        };
        // Uniform traffic over n cards sits near 1/n and never clears the
        // share gate — replication is strictly for single-window hotspots.
        if share < rcfg.hot_share_min {
            return ReplicateOutcome::Declined;
        }
        if !state.replicas.is_empty() && state.replicas.replicas()[0].shard != si {
            // The hotspot moved off the replicated shard; the exit check
            // retires the stale replicas once their share collapses.
            return ReplicateOutcome::Declined;
        }
        if state.replicas.replicas_of(si) >= rcfg.max_replicas {
            return ReplicateOutcome::Declined;
        }
        let shard = &state.plan.shards[si];
        let owner = shard.card;
        // Observed demand on the hot shard vs the owner's calibrated
        // bandwidth.  `capacity_fraction == 0` disables this gate: wall
        // clock and simulated device time are different clocks, so
        // open-loop CLI traffic can never meet a simulated-bandwidth bar.
        let demand_gbps =
            self.shard_rows(state, si, delta, rdelta) as f64 * state.plan.row_bytes as f64
                / dt.as_secs_f64().max(1e-9)
                / 1e9;
        let cap = self.specs[owner].0.capacity_gbps();
        if rcfg.capacity_fraction > 0.0 && demand_gbps < rcfg.capacity_fraction * cap {
            return ReplicateOutcome::Declined;
        }
        // Host: the least-loaded card that is not the owner and not
        // already serving this shard, with room for the replica rows.
        let Some(host) = (0..n)
            .filter(|&c| c != owner && !state.replicas.cards_of(si).any(|r| r == c))
            .filter(|&c| shard.rows * state.plan.row_bytes <= self.specs[c].0.memory_bytes)
            .min_by_key(|&c| delta[c] + rdelta[c])
        else {
            return ReplicateOutcome::Declined;
        };
        let (spec, timing) = &self.specs[host];
        let backend = match start_replica_backend(
            &self.cfg,
            spec,
            timing,
            shard,
            state.plan.row_bytes,
            whole,
            host,
        ) {
            Ok(b) => b,
            Err(why) => {
                self.plane.record(
                    permitted,
                    None,
                    imbalance,
                    None,
                    format!("replication aborted: {why:#}"),
                );
                return ReplicateOutcome::Spent;
            }
        };
        let generation = state.replicas.generation + 1;
        let mut replicas = state.replicas.replicas().to_vec();
        replicas.push(Replica { shard: si, card: host });
        let set = ReplicaSet::with_replicas(generation, replicas);
        if let Err(why) = set.check(&state.plan, n) {
            backend.shutdown();
            self.plane.record(
                permitted,
                None,
                imbalance,
                None,
                format!("replication aborted: {why:#}"),
            );
            return ReplicateOutcome::Spent;
        }
        let mut units = state.replica_units.clone();
        units.push(ReplicaUnit {
            shard: si,
            card: host,
            svc: Service::new(Arc::clone(&backend)),
            sim: Some(backend),
        });
        self.publish_replicas(state, set, units);
        self.metrics.replicate_epochs.fetch_add(1, Ordering::Relaxed);
        self.metrics.replicas_created.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .generations_published
            .fetch_add(1, Ordering::Relaxed);
        self.plane.record(
            permitted,
            Some(Lever::Replicate),
            imbalance,
            Some(generation),
            format!(
                "replicated shard {si} (rows [{}, {})) onto card {host}: \
                 share {share:.2}, {demand_gbps:.1} GB/s offered (zero-copy)",
                shard.start_row,
                shard.end_row()
            ),
        );
        ReplicateOutcome::Published(generation)
    }

    /// Publish a new replica set + units over the current plan and cards
    /// (the replica analog of `apply_migration`'s swap), then re-baseline
    /// the replica load counters for the new unit set.
    fn publish_replicas(&self, old: &Arc<FleetState>, set: ReplicaSet, units: Vec<ReplicaUnit>) {
        let next = Arc::new(FleetState {
            plan: Arc::clone(&old.plan),
            cards: old.cards.clone(),
            sims: old.sims.clone(),
            replicas: Arc::new(set),
            replica_units: units,
            depth: old.depth.clone(),
        });
        *self.state.write().unwrap() = Arc::clone(&next);
        let mut rtotals = vec![0u64; self.specs.len()];
        for unit in &next.replica_units {
            rtotals[unit.card] = unit.svc.metrics().rows;
        }
        rebaseline_atomic(&self.last_replica_rows, &rtotals);
    }

    /// Build and publish the next generation for `rows_of`: untouched
    /// cards keep their running services; re-sized cards get new backends
    /// over fresh zero-copy slices of the shared table storage.
    fn apply_migration(
        &self,
        old: &Arc<FleetState>,
        cards: &[CardSpec],
        rows_of: &[u64],
    ) -> anyhow::Result<(u64, u64)> {
        let whole = self
            .whole
            .as_ref()
            .ok_or_else(|| anyhow!("fleet has no rebuild context"))?;
        let next_plan = FleetPlan::with_ranges(
            cards,
            rows_of,
            old.plan.total_rows,
            old.plan.row_bytes,
            self.cfg.seed,
            old.plan.generation + 1,
        )?;
        let moved = old.plan.rows_moved(&next_plan);
        if moved < self.cfg.rebalance.min_move_rows {
            return Err(anyhow!("{moved} rows moved is below the migration floor"));
        }

        let mut services = Vec::with_capacity(next_plan.shards.len());
        let mut sims = Vec::with_capacity(next_plan.shards.len());
        for shard in &next_plan.shards {
            // Reuse a card whose range is untouched: its backend, queue,
            // metrics, and calibration all carry over.
            let unchanged = old
                .plan
                .shards
                .iter()
                .position(|s| {
                    s.card == shard.card
                        && s.start_row == shard.start_row
                        && s.rows == shard.rows
                });
            if let Some(i) = unchanged {
                services.push(old.cards[i].clone());
                sims.push(old.sims[i].clone());
                continue;
            }
            let (spec, timing) = &self.specs[shard.card];
            let backend = start_card_backend(&self.cfg, spec, timing, shard, whole)
                .with_context(|| format!("rebuilding card {}", shard.card))?;
            sims.push(Some(Arc::clone(&backend)));
            services.push(Service::new(backend));
        }

        let generation = next_plan.generation;
        // Migration re-cuts shard boundaries, so any replica's row range
        // is stale by construction: the new generation publishes an empty
        // replica set (counted as dropped; if the hotspot survives the
        // rebalance it re-escalates and re-replicates under the new cuts).
        let dropped = old.replicas.count() as u64;
        let next = Arc::new(FleetState {
            plan: Arc::new(next_plan),
            cards: services,
            sims,
            replicas: Arc::new(ReplicaSet::with_replicas(
                old.replicas.generation + u64::from(dropped > 0),
                Vec::new(),
            )),
            replica_units: Vec::new(),
            depth: old.depth.clone(),
        });
        *self.state.write().unwrap() = Arc::clone(&next);
        if dropped > 0 {
            self.metrics.replicas_dropped.fetch_add(dropped, Ordering::Relaxed);
        }
        // Re-baseline the per-card load counters under the new backends
        // (rebuilt cards restart their registries at zero).
        let mut totals = vec![0u64; self.specs.len()];
        for (shard, svc) in next.plan.shards.iter().zip(&next.cards) {
            totals[shard.card] = svc.metrics().rows;
        }
        rebaseline_atomic(&self.last_card_rows, &totals);
        rebaseline_atomic(&self.last_replica_rows, &vec![0u64; self.specs.len()]);
        Ok((generation, moved))
    }

    fn stop(&self) {
        self.epoch_stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.epoch_thread.lock().unwrap().take() {
            let _ = t.join();
        }
        let state = self.current();
        for c in &state.cards {
            c.shutdown();
        }
        for unit in &state.replica_units {
            unit.svc.shutdown();
        }
    }
}

/// Build one card's backend over its shard — a zero-copy slice of the
/// shared table — wiring every fleet-level per-card setting.  The single
/// constructor both `build_sim_with` (startup) and `apply_migration`
/// (rebuild) use, so migrated cards can never silently run with different
/// settings than startup cards.
fn start_card_backend(
    cfg: &FleetConfig,
    spec: &CardSpec,
    timing: &SimTiming,
    shard: &CardShard,
    whole: &TableView,
) -> anyhow::Result<Arc<SimBackend>> {
    let local = whole.slice_rows(shard.start_row, shard.rows);
    Ok(Arc::new(SimBackend::start_with_placement(
        card_backend_config(cfg, shard.card),
        &spec.map,
        shard.plan.clone(),
        shard.placement.clone(),
        local,
        timing.clone(),
    )?))
}

/// The per-card [`SimBackendConfig`] every fleet backend — startup,
/// migration rebuild, or replica — is started with, so no path can
/// silently diverge on a setting.
fn card_backend_config(cfg: &FleetConfig, card: usize) -> SimBackendConfig {
    let mut bcfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
    bcfg.batcher = cfg.batcher.clone();
    bcfg.seed = cfg.seed;
    bcfg.adaptive = cfg.adaptive.clone();
    bcfg.resplit = cfg.resplit.clone();
    bcfg.remap = cfg.remap.clone();
    bcfg.sim_timescale = cfg.sim_timescale;
    bcfg.legacy_path = cfg.legacy_path;
    bcfg.resilience = cfg.resilience.clone();
    bcfg.fault = cfg.fault.as_ref().map(|p| p.for_card(card));
    bcfg.pin_cores = cfg.pin_cores;
    bcfg
}

/// Build a replica backend on `host`: the same zero-copy slice as the
/// owner (same global row range, so card-local row ids match and routing
/// needs no re-indexing), but with windows and placement rebuilt for the
/// *host* card's probed map — reach and group count vary card to card,
/// per the paper, so the owner's plan would mis-window the replica.
fn start_replica_backend(
    cfg: &FleetConfig,
    spec: &CardSpec,
    timing: &SimTiming,
    shard: &CardShard,
    row_bytes: u64,
    whole: &TableView,
    host: usize,
) -> anyhow::Result<Arc<SimBackend>> {
    let local = whole.slice_rows(shard.start_row, shard.rows);
    let plan = WindowPlan::for_reach(
        shard.rows,
        row_bytes,
        spec.map.reach_bytes,
        spec.map.groups.len(),
    )
    .with_context(|| format!("replica window plan on card {host}"))?;
    let placement = Placement::build(PlacementPolicy::GroupToChunk, &spec.map, &plan, cfg.seed)
        .with_context(|| format!("replica placement on card {host}"))?;
    Ok(Arc::new(SimBackend::start_with_placement(
        card_backend_config(cfg, host),
        &spec.map,
        plan,
        placement,
        local,
        timing.clone(),
    )?))
}

/// The fleet-level facade: two-level routing over per-card services, with
/// the card boundaries themselves under control-plane management.
pub struct FleetService {
    core: Arc<FleetCore>,
}

impl FleetService {
    /// Compose a fleet from an existing plan and per-card services (each
    /// serving exactly its shard's local row space).  Composed fleets have
    /// no rebuild context, so the migration lever is disabled.
    pub fn new(plan: FleetPlan, cards: Vec<Service>) -> anyhow::Result<Self> {
        let d = Self::validate(&plan, &cards)?;
        let sims = cards.iter().map(|_| None).collect();
        let n_gauges = plan.shards.iter().map(|s| s.card + 1).max().unwrap_or(0);
        Ok(Self {
            core: Arc::new(FleetCore {
                state: RwLock::new(Arc::new(FleetState {
                    plan: Arc::new(plan),
                    cards,
                    sims,
                    replicas: Arc::new(ReplicaSet::identity()),
                    replica_units: Vec::new(),
                    depth: (0..n_gauges).map(|_| Arc::new(AtomicU64::new(0))).collect(),
                })),
                d,
                pool: SlabPool::new(),
                whole: None,
                specs: Vec::new(),
                cfg: FleetConfig::default(),
                plane: ControlPlane::new(ControlPlaneConfig {
                    max_lever: Lever::Migrate,
                    ..ControlPlaneConfig::default()
                }),
                rebalancer: FleetRebalancer::default(),
                metrics: Arc::new(Metrics::new()),
                gate: EpochGate::new(),
                last_card_rows: Vec::new(),
                last_replica_rows: Vec::new(),
                last_epoch_at: Mutex::new(Instant::now()),
                rr: AtomicU64::new(0),
                epoch_stop: AtomicBool::new(false),
                epoch_thread: Mutex::new(None),
            }),
        })
    }

    fn validate(plan: &FleetPlan, cards: &[Service]) -> anyhow::Result<usize> {
        if plan.shards.len() != cards.len() {
            return Err(anyhow!(
                "{} shards but {} card services",
                plan.shards.len(),
                cards.len()
            ));
        }
        let mut d = None;
        for (shard, svc) in plan.shards.iter().zip(cards) {
            if svc.rows() != shard.rows {
                return Err(anyhow!(
                    "card {} serves {} rows but its shard has {}",
                    shard.card,
                    svc.rows(),
                    shard.rows
                ));
            }
            match d {
                None => d = Some(svc.d()),
                Some(d0) if d0 != svc.d() => {
                    return Err(anyhow!("cards disagree on row width"));
                }
                _ => {}
            }
        }
        d.ok_or_else(|| anyhow!("empty fleet"))
    }

    /// Build a hermetic fleet: shard `table` across simulated cards
    /// (capacity-weighted, reach-constrained — the plan comes from
    /// [`FleetPlan::build`]) and start one [`SimBackend`] per shard using
    /// that card's probed map, window plan, and group placement.
    ///
    /// **Zero-copy**: every card's backend receives a
    /// [`TableView`](crate::coordinator::TableView) into the one shared
    /// `Arc<[f32]>` — per-card memory is O(view metadata), so a >10 GiB
    /// host table costs refcount bumps, not per-shard copies.
    pub fn build_sim(
        specs: Vec<(CardSpec, SimTiming)>,
        table: &Table,
        batcher: BatcherConfig,
        seed: u64,
    ) -> anyhow::Result<Self> {
        Self::build_sim_with(
            specs,
            table,
            FleetConfig {
                batcher,
                seed,
                ..FleetConfig::default()
            },
        )
    }

    /// [`build_sim`](Self::build_sim) with full repartitioning control:
    /// per-card adaptive/re-split configs are applied to every card
    /// backend (and every backend rebuilt by a migration), and `cfg.epoch`
    /// optionally starts the background fleet control-epoch thread.
    pub fn build_sim_with(
        specs: Vec<(CardSpec, SimTiming)>,
        table: &Table,
        mut cfg: FleetConfig,
    ) -> anyhow::Result<Self> {
        // One epoch driver per card: when the fleet runs its own epoch
        // thread (which drives every card's control plane itself), strip
        // any per-card epoch timer — two concurrent drivers would halve
        // each card's hysteresis in wall time and race its plane state.
        if cfg.epoch.is_some() {
            if let Some(a) = cfg.adaptive.as_mut() {
                a.epoch = None;
            }
        }
        let cards: Vec<CardSpec> = specs.iter().map(|(c, _)| c.clone()).collect();
        let plan = FleetPlan::build(&cards, table.rows, row_bytes_for_d(table.d), cfg.seed)?;
        let whole = table.view();
        let mut services = Vec::new();
        let mut sims = Vec::new();
        for shard in &plan.shards {
            let (spec, timing) = &specs[shard.card];
            let backend = start_card_backend(&cfg, spec, timing, shard, &whole)
                .with_context(|| format!("starting card {}", shard.card))?;
            sims.push(Some(Arc::clone(&backend)));
            services.push(Service::new(backend));
        }
        let d = Self::validate(&plan, &services)?;

        // The fleet plane runs at whatever ceiling the config asks for:
        // `Migrate` by default (FleetConfig::default), `Hold` to pin the
        // shard map (e.g. a static baseline arm).  Arming replication
        // raises a migration-capable ceiling to the fifth rung — a plane
        // pinned below `Migrate` stays pinned.
        let mut plane_cfg = cfg.control.clone();
        if cfg.replicate.is_some() && plane_cfg.max_lever >= Lever::Migrate {
            plane_cfg.max_lever = Lever::Replicate;
        }
        let n_cards = specs.len();
        let epoch = cfg.epoch;
        let core = Arc::new(FleetCore {
            state: RwLock::new(Arc::new(FleetState {
                plan: Arc::new(plan),
                cards: services,
                sims,
                replicas: Arc::new(ReplicaSet::identity()),
                replica_units: Vec::new(),
                depth: (0..n_cards).map(|_| Arc::new(AtomicU64::new(0))).collect(),
            })),
            d,
            pool: SlabPool::new(),
            whole: Some(whole),
            specs,
            rebalancer: FleetRebalancer::new(cfg.rebalance.clone()),
            plane: ControlPlane::new(plane_cfg),
            cfg,
            metrics: Arc::new(Metrics::new()),
            gate: EpochGate::new(),
            last_card_rows: (0..n_cards).map(|_| AtomicU64::new(0)).collect(),
            last_replica_rows: (0..n_cards).map(|_| AtomicU64::new(0)).collect(),
            last_epoch_at: Mutex::new(Instant::now()),
            rr: AtomicU64::new(0),
            epoch_stop: AtomicBool::new(false),
            epoch_thread: Mutex::new(None),
        });

        if let Some(period) = epoch {
            let ctx = Arc::clone(&core);
            let tick = period
                .min(Duration::from_millis(5))
                .max(Duration::from_micros(100));
            let handle = std::thread::Builder::new()
                .name("a100win-fleet-controlplane".into())
                .spawn(move || {
                    let mut since = Duration::ZERO;
                    while !ctx.epoch_stop.load(Ordering::Relaxed) {
                        std::thread::sleep(tick);
                        since += tick;
                        if since >= period {
                            since = Duration::ZERO;
                            let _ = ctx.epoch();
                        }
                    }
                })
                .context("spawning fleet control plane")?;
            *core.epoch_thread.lock().unwrap() = Some(handle);
        }
        Ok(Self { core })
    }

    /// The current shard map (generation-stamped; migrations swap it).
    pub fn plan(&self) -> Arc<FleetPlan> {
        Arc::clone(&self.core.current().plan)
    }

    /// Per-card services of the current generation, position-matched to
    /// [`plan`](Self::plan)`.shards` (cheap clones of shared handles).
    pub fn cards(&self) -> Vec<Service> {
        self.core.current().cards.clone()
    }

    pub fn d(&self) -> usize {
        self.core.d
    }

    pub fn rows(&self) -> u64 {
        self.core.current().plan.total_rows
    }

    /// Run one fleet control epoch by hand (per-card levers, then the
    /// migration ladder).  Returns the new fleet generation when a
    /// migration published.  The background thread configured by
    /// [`FleetConfig::epoch`] calls exactly this.
    pub fn control_epoch(&self) -> Option<u64> {
        self.core.epoch()
    }

    /// The fleet control plane's audited decision trace, oldest first.
    pub fn control_decisions(&self) -> Vec<Decision> {
        self.core.plane.decisions()
    }

    /// Fleet-scope counters (migration epochs, rows migrated, generations).
    pub fn fleet_metrics(&self) -> MetricsSnapshot {
        self.core.metrics.snapshot()
    }

    /// Sum of per-card simulated aggregate GB/s (cards run in parallel).
    /// Replicas are priced as parallel devices: a replicated shard's
    /// bandwidth is the owner's plus every replica's.
    pub fn aggregate_sim_gbps(&self) -> f64 {
        let state = self.core.current();
        let owners: f64 = state
            .sims
            .iter()
            .flatten()
            .map(|s| s.aggregate_sim_gbps())
            .sum();
        let replicas: f64 = state
            .replica_units
            .iter()
            .filter_map(|u| u.sim.as_ref())
            .map(|s| s.aggregate_sim_gbps())
            .sum();
        owners + replicas
    }

    /// Fleet makespan throughput: units run in parallel, so the slowest
    /// unit's simulated device time bounds the fleet — total routed bytes
    /// over that bound.  Unlike [`aggregate_sim_gbps`]
    /// (Self::aggregate_sim_gbps), which prices per-device achieved
    /// bandwidth, this collapses under imbalance: a fleet whose hot card
    /// serves everything scores roughly one card's bandwidth.
    pub fn makespan_sim_gbps(&self) -> f64 {
        let state = self.core.current();
        let mut total_rows = 0u64;
        let mut max_ns = 0f64;
        let sims = state
            .sims
            .iter()
            .flatten()
            .chain(state.replica_units.iter().filter_map(|u| u.sim.as_ref()));
        for sim in sims {
            let report = sim.sim_report();
            total_rows += report.iter().map(|r| r.rows).sum::<u64>();
            let ns = report.iter().map(|r| r.sim_ms * 1e6).fold(0.0f64, f64::max);
            max_ns = max_ns.max(ns);
        }
        if max_ns <= 0.0 {
            return 0.0;
        }
        total_rows as f64 * state.plan.row_bytes as f64 / max_ns
    }

    /// Zero every unit's simulated-device accounting (benchmark harness
    /// hook: measure a steady state without the convergence phase).
    pub fn reset_sim_stats(&self) {
        let state = self.core.current();
        for sim in state.sims.iter().flatten() {
            sim.reset_sim_stats();
        }
        for unit in &state.replica_units {
            if let Some(sim) = &unit.sim {
                sim.reset_sim_stats();
            }
        }
    }

    /// The published replica set of the current generation (empty until
    /// the replicate lever fires; see [`ReplicaSet`]).
    pub fn replica_set(&self) -> Arc<ReplicaSet> {
        Arc::clone(&self.core.current().replicas)
    }

    /// Live replica services of the current generation as
    /// `(shard index, host card, service)` — cheap handle clones,
    /// position-matched to [`replica_set`](Self::replica_set).
    pub fn replica_cards(&self) -> Vec<(usize, usize, Service)> {
        self.core
            .current()
            .replica_units
            .iter()
            .map(|u| (u.shard, u.card, u.svc.clone()))
            .collect()
    }

    /// Per-card in-flight queue depths (the power-of-two-choices routing
    /// signal), indexed by card id.
    pub fn queue_depths(&self) -> Vec<u64> {
        // RELAXED: monitoring snapshot of a heuristic gauge.
        self.core
            .current()
            .depth
            .iter()
            .map(|g| g.load(Ordering::Relaxed))
            .collect()
    }

    /// Split a request by card shard and submit each part; the returned
    /// [`FleetTicket`] merges rows back in request order under the shard
    /// map it was split with (migrations never disturb it).
    pub fn submit(
        &self,
        rows: Arc<Vec<u64>>,
        deadline: Option<Duration>,
    ) -> anyhow::Result<FleetTicket> {
        let state = self.core.current();
        let split = state.plan.split(&rows)?;
        let mut parts = Vec::new();
        for (si, (locals, positions)) in split.into_iter().enumerate() {
            if locals.is_empty() {
                continue;
            }
            // Owner unless the shard is replicated; then the shallower of
            // two sampled candidate queues (power-of-two-choices).  The
            // depth unit is acquired before submission so concurrent picks
            // see this part immediately, and its guard releases on every
            // exit path (including the `?` below).
            let (unit, card) = state.pick_unit(si, &self.core.rr);
            let depth = DepthGuard::acquire(&state.depth[card]);
            let ticket = state
                .unit_service(unit)
                .submit(Arc::new(locals), deadline)
                .with_context(|| format!("card shard {si} (unit {unit})"))?;
            parts.push(FleetPart {
                shard: si,
                unit,
                ticket,
                positions,
                _depth: depth,
            });
        }
        Ok(FleetTicket {
            parts,
            request_len: rows.len(),
            d: self.core.d,
            generation: state,
            pool: Arc::clone(&self.core.pool),
        })
    }

    /// Blocking convenience: submit + merge.
    pub fn lookup(&self, rows: Arc<Vec<u64>>) -> anyhow::Result<Vec<f32>> {
        self.submit(rows, None)?.wait()
    }

    /// Return a redeemed merged buffer's capacity to the fleet's output
    /// pool (optional, like `Service::recycle`).
    pub fn recycle(&self, buf: Vec<f32>) {
        self.core.pool.put(buf);
    }

    /// Per-card metric snapshots of the current generation as
    /// `(card id, snapshot)`.  A card rebuilt by a migration restarts its
    /// registry (the fleet-scope counters in
    /// [`fleet_metrics`](Self::fleet_metrics) are continuous).
    pub fn per_card_metrics(&self) -> Vec<(usize, MetricsSnapshot)> {
        let state = self.core.current();
        state
            .plan
            .shards
            .iter()
            .zip(&state.cards)
            .map(|(shard, svc)| (shard.card, svc.metrics()))
            .collect()
    }

    pub fn shutdown(&self) {
        self.core.stop();
    }
}

impl Drop for FleetService {
    fn drop(&mut self) {
        // The background control-plane thread holds the core alive; an
        // un-shutdown fleet must not leak it (idempotent with shutdown()).
        self.core.stop();
    }
}

//! Fleet-level row rebalancing: recompute the card shard boundaries from
//! observed per-card load — the control plane's most expensive lever.
//!
//! A [`FleetPlan`](crate::coordinator::FleetPlan) shards the row space
//! across cards proportionally to *probed capacity*; under skewed traffic
//! a card holding a hot row range saturates while its peers idle, and no
//! amount of intra-card repartitioning (re-deal, re-split) can shed load a
//! card simply *owns*.  [`FleetRebalancer`] re-cuts the card boundaries at
//! capacity-share quantiles of the observed load density (the same
//! construction [`PlanSplitter`](crate::coordinator::PlanSplitter) uses
//! one level down, with per-card memory and reach-coverage clamps instead
//! of the per-window reach bound).
//!
//! Applying a proposal is **zero-copy**: the fleet re-slices the one
//! shared `Arc<[f32]>` into new per-card
//! [`TableView`](crate::coordinator::TableView)s — migration costs
//! refcount bumps and worker re-spawns, never a row of memcpy (pointer
//! identity asserted in `tests/repartition.rs`).

use crate::coordinator::cluster::{CardSpec, FleetPlan};
use crate::coordinator::controlplane::{capacity_imbalance, load_shares};
use crate::coordinator::replan::LoadDensity;

/// Tuning for [`FleetRebalancer`].
#[derive(Debug, Clone)]
pub struct RebalanceConfig {
    /// Minimum per-card load/capacity share deviation before a migration
    /// is proposed (migrations are expensive: higher floor than the
    /// intra-card levers).
    pub min_imbalance: f64,
    /// Minimum rows observed fleet-wide in an epoch before proposing.
    pub min_epoch_rows: u64,
    /// Proposals moving fewer rows than this are dropped (a dribble of
    /// boundary rows is not worth a card rebuild).
    pub min_move_rows: u64,
}

impl Default for RebalanceConfig {
    fn default() -> Self {
        Self {
            min_imbalance: 0.15,
            min_epoch_rows: 1_024,
            min_move_rows: 64,
        }
    }
}

/// A proposed re-sharding: rows per card (card order) plus the imbalance
/// that motivated it.  Turn it into a plan with
/// [`FleetPlan::with_ranges`]; the implied volume is
/// [`FleetPlan::rows_moved`].
#[derive(Debug, Clone)]
pub struct MigrationProposal {
    pub rows_of: Vec<u64>,
    pub imbalance: f64,
}

/// The fleet-level boundary re-cutter (see module docs).
#[derive(Debug, Clone, Default)]
pub struct FleetRebalancer {
    pub cfg: RebalanceConfig,
}

impl FleetRebalancer {
    pub fn new(cfg: RebalanceConfig) -> Self {
        Self { cfg }
    }

    /// Propose new per-card row counts from one epoch's per-card routed
    /// rows (`card_rows[i]` = rows card `i` served this epoch).  `None`
    /// keeps the current shards: signal too thin, the imbalance is within
    /// tolerance, or geometry (memory / reach coverage) forbids a better
    /// cut.
    pub fn propose(
        &self,
        plan: &FleetPlan,
        cards: &[CardSpec],
        card_rows: &[u64],
    ) -> Option<MigrationProposal> {
        let n = cards.len();
        if n == 0 || card_rows.len() != n || plan.shards.is_empty() {
            return None;
        }
        let total: u64 = card_rows.iter().sum();
        if total < self.cfg.min_epoch_rows.max(1) {
            return None;
        }
        let total_cap: f64 = cards.iter().map(|c| c.capacity_gbps()).sum();
        if !total_cap.is_finite() || total_cap <= 0.0 {
            return None;
        }

        let load = load_shares(card_rows)?;
        let caps: Vec<f64> = cards
            .iter()
            .map(|c| c.capacity_gbps() / total_cap)
            .collect();
        let imbalance = capacity_imbalance(&load, &caps);
        if imbalance < self.cfg.min_imbalance {
            return None;
        }

        // Piecewise-constant load density over the current shards in
        // global row order (the same smoothed-quantile machinery the
        // window re-splitter uses one level down).
        let density = LoadDensity::smoothed(
            plan.shards.iter().map(|s| (s.rows, card_rows[s.card])),
            plan.total_rows,
        );

        // Geometry: a card may hold at most min(memory, groups * reach)
        // worth of rows (beyond groups * reach no valid window plan
        // exists).
        let max_rows: Vec<u64> = cards
            .iter()
            .map(|c| {
                let mem = c.memory_bytes / plan.row_bytes;
                let reach = (c.map.reach_bytes / plan.row_bytes)
                    .saturating_mul(c.map.groups.len() as u64);
                mem.min(reach)
            })
            .collect();
        if max_rows.iter().sum::<u64>() < plan.total_rows {
            return None;
        }

        // Cut card boundaries (card order = global row order) at
        // capacity-share load quantiles, clamped so every suffix of cards
        // can still absorb the remainder.
        let mut rows_of = vec![0u64; n];
        let mut cursor = 0u64;
        let mut want = 0.0f64;
        for i in 0..n - 1 {
            want += caps[i];
            let tail_max: u64 = max_rows[i + 1..].iter().sum();
            let lo = cursor.max(plan.total_rows.saturating_sub(tail_max));
            let hi = (cursor + max_rows[i]).min(plan.total_rows);
            if lo > hi {
                return None; // defensive: infeasible geometry
            }
            let cut = density.row_at_load(want).clamp(lo, hi);
            rows_of[i] = cut - cursor;
            cursor = cut;
        }
        rows_of[n - 1] = plan.total_rows - cursor;
        if rows_of[n - 1] > max_rows[n - 1] {
            return None; // defensive: the lo bounds should prevent this
        }
        if rows_of == plan.rows_per_card(n) {
            return None;
        }
        Some(MigrationProposal { rows_of, imbalance })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GIB;
    use crate::probe::TopologyMap;

    fn card(groups: usize, gbps: f64, mem_gib: u64) -> CardSpec {
        CardSpec {
            map: TopologyMap {
                groups: (0..groups).map(|g| vec![g * 2, g * 2 + 1]).collect(),
                reach_bytes: 64 * GIB,
                solo_gbps: vec![gbps; groups],
                independent: true,
                card_id: format!("rb-{groups}x{gbps}"),
            },
            memory_bytes: mem_gib * GIB,
        }
    }

    #[test]
    fn hot_card_sheds_rows_to_its_peer() {
        let cards = vec![card(4, 100.0, 80), card(4, 100.0, 80)];
        let rows = 64 * GIB / 128;
        let plan = FleetPlan::build(&cards, rows, 128, 0).unwrap();
        // Card 0 serves 90% of the traffic: it must shrink.
        let prop = FleetRebalancer::default()
            .propose(&plan, &cards, &[9_000, 1_000])
            .expect("90/10 over equal cards must migrate");
        assert!(prop.imbalance > 0.35);
        assert!(
            prop.rows_of[0] < plan.shards[0].rows,
            "hot card kept {} of {} rows",
            prop.rows_of[0],
            plan.shards[0].rows
        );
        assert_eq!(prop.rows_of.iter().sum::<u64>(), rows);
        // The proposal builds into a valid next-generation plan.
        let next =
            FleetPlan::with_ranges(&cards, &prop.rows_of, rows, 128, 0, plan.generation + 1)
                .unwrap();
        assert!(next.fits_reach(&cards));
        assert!(plan.rows_moved(&next) > 0);
    }

    #[test]
    fn balanced_load_and_thin_signal_hold() {
        let cards = vec![card(4, 100.0, 80), card(4, 100.0, 80)];
        let rows = 64 * GIB / 128;
        let plan = FleetPlan::build(&cards, rows, 128, 0).unwrap();
        let rb = FleetRebalancer::default();
        assert!(rb.propose(&plan, &cards, &[5_100, 4_900]).is_none());
        assert!(rb.propose(&plan, &cards, &[9, 1]).is_none(), "starved epoch");
        assert!(rb.propose(&plan, &cards, &[5_000]).is_none(), "wrong arity");
    }

    #[test]
    fn memory_clamp_bounds_the_receiving_card() {
        // The cold card is tiny: it cannot absorb the hot card's surplus.
        let cards = vec![card(4, 100.0, 80), card(4, 100.0, 4)];
        let rows = 66 * GIB / 128;
        let plan = FleetPlan::build(&cards, rows, 128, 0).unwrap();
        // Load says card 1 should grow far beyond its 4 GiB.
        if let Some(prop) = FleetRebalancer::default().propose(&plan, &cards, &[9_500, 500]) {
            assert!(prop.rows_of[1] * 128 <= 4 * GIB);
            assert!(
                FleetPlan::with_ranges(&cards, &prop.rows_of, rows, 128, 0, 1).is_ok()
            );
        }
    }

    #[test]
    fn proposal_is_deterministic() {
        let cards = vec![card(4, 120.0, 80), card(4, 80.0, 80)];
        let rows = 64 * GIB / 128;
        let plan = FleetPlan::build(&cards, rows, 128, 3).unwrap();
        let rb = FleetRebalancer::default();
        let a = rb.propose(&plan, &cards, &[9_000, 1_000]).unwrap();
        let b = rb.propose(&plan, &cards, &[9_000, 1_000]).unwrap();
        assert_eq!(a.rows_of, b.rows_of);
    }
}

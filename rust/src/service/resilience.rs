//! Self-healing serving: retries, straggler hedging, partial results, and
//! per-group circuit breakers.
//!
//! The reach constraint makes failure recovery *routing*: a failed
//! sub-batch's rows live in exactly one window, so the only way to retry
//! them is to re-split against the **live** [`PlacementCell`] — after a
//! health epoch evicted the failing group, the retry lands on a healthy
//! sibling holding the same window.  Everything here feeds that loop:
//!
//! * [`RetryPolicy`] — per-sub-batch retry with a budget and exponential
//!   backoff; the retried rows go back through the dispatcher (the job
//!   rings are single-producer, so workers never re-enqueue directly —
//!   they post a [`ResMsg`] on one mpsc channel the dispatcher drains).
//! * [`HedgeConfig`] — sub-batches outstanding past a latency-quantile
//!   watermark are speculatively re-dispatched to a sibling group serving
//!   the same window; a [`PartToken`] makes completion first-wins, and the
//!   scatter claim bitmap keeps duplicate writes detectable.
//! * [`BreakerConfig`] — per-group closed→open→half-open breaker.  Open
//!   maps to `GroupHealth::Failed` (evicted by the next health epoch),
//!   half-open to `Degraded` (re-included at half weight — its live
//!   traffic *is* the probe stream).  Transitions fire a hook into the
//!   control plane so they appear in the decision trace.
//! * Partial results ride on the scatter layer's per-slot state (see
//!   [`super::scatter::ScatterBuf::take_partial`]) and surface as
//!   [`super::backend::Outcome::Partial`].
//!
//! All of it is off by default and allocation-free when off: the hot path
//! (PR 5) is untouched unless a [`ResilienceConfig`] turns a feature on.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU8, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;

use super::backend::RequestAcc;

/// Per-sub-batch retry: up to `budget` re-dispatches with exponential
/// backoff (`backoff * 2^attempt`), each re-routed through the live
/// placement.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    pub budget: u32,
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            budget: 3,
            backoff: Duration::from_micros(200),
        }
    }
}

/// Straggler hedging: a sub-batch outstanding longer than
/// `max(min_after, latency quantile)` is speculatively duplicated onto a
/// sibling group serving the same window; first completion wins.
#[derive(Debug, Clone)]
pub struct HedgeConfig {
    /// Floor on the hedge watermark — never hedge sooner than this (keeps
    /// cold-start quantiles from hedging everything).
    pub min_after: Duration,
    /// Latency quantile (of the request latency histogram) used as the
    /// straggler watermark, e.g. 0.99.
    pub quantile: f64,
}

impl Default for HedgeConfig {
    fn default() -> Self {
        Self {
            min_after: Duration::from_millis(3),
            quantile: 0.99,
        }
    }
}

/// Per-group circuit breaker: `failure_threshold` consecutive failures
/// open the breaker (group evicted); after `open_for` it half-opens
/// (re-included at half weight — real traffic probes it);
/// `probe_successes` consecutive successes close it again.
#[derive(Debug, Clone)]
pub struct BreakerConfig {
    pub failure_threshold: u32,
    pub open_for: Duration,
    pub probe_successes: u32,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        Self {
            failure_threshold: 5,
            open_for: Duration::from_millis(20),
            probe_successes: 3,
        }
    }
}

/// The resilience feature set.  `Default` is everything off — the serving
/// hot path is bit-identical to the non-resilient build until a feature
/// is enabled.
#[derive(Debug, Clone, Default)]
pub struct ResilienceConfig {
    pub retry: Option<RetryPolicy>,
    pub hedge: Option<HedgeConfig>,
    pub breaker: Option<BreakerConfig>,
    /// Deliver completed rows + a per-row validity mask
    /// ([`super::backend::Outcome::Partial`]) instead of failing the whole
    /// ticket, via [`super::backend::Ticket::wait_outcome`].
    pub partials: bool,
}

impl ResilienceConfig {
    /// Everything on, at default settings (the chaos-soak posture).
    pub fn full() -> Self {
        Self {
            retry: Some(RetryPolicy::default()),
            hedge: Some(HedgeConfig::default()),
            breaker: Some(BreakerConfig::default()),
            partials: true,
        }
    }

    /// Any feature enabled at all.
    pub fn enabled(&self) -> bool {
        self.needs_ctx() || self.partials
    }

    /// Features that need the runtime context (retry/hedge/breaker);
    /// partials ride on the scatter layer alone.
    pub fn needs_ctx(&self) -> bool {
        self.retry.is_some() || self.hedge.is_some() || self.breaker.is_some()
    }
}

/// Breaker states, in the classic closed→open→half-open cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: traffic flows, consecutive failures counted.
    Closed,
    /// Tripped: the group is evicted from serving until `open_for` passes.
    Open,
    /// Probation: re-included at reduced weight; its live traffic is the
    /// probe stream.
    HalfOpen,
}

const ST_CLOSED: u8 = 0;
const ST_OPEN: u8 = 1;
const ST_HALF_OPEN: u8 = 2;

fn state_of(v: u8) -> BreakerState {
    match v {
        ST_OPEN => BreakerState::Open,
        ST_HALF_OPEN => BreakerState::HalfOpen,
        _ => BreakerState::Closed,
    }
}

struct GroupBreaker {
    state: AtomicU8,
    consec_failures: AtomicU32,
    probe_successes: AtomicU32,
    opened_at: Mutex<Option<Instant>>,
}

/// Per-group breaker bank.  Lock-free on the success/failure hot path;
/// the `opened_at` mutex is only touched on transitions and ticks.
pub(crate) struct CircuitBreaker {
    cfg: BreakerConfig,
    groups: Vec<GroupBreaker>,
}

impl CircuitBreaker {
    fn new(cfg: BreakerConfig, groups: usize) -> Self {
        Self {
            cfg,
            groups: (0..groups)
                .map(|_| GroupBreaker {
                    state: AtomicU8::new(ST_CLOSED),
                    consec_failures: AtomicU32::new(0),
                    probe_successes: AtomicU32::new(0),
                    opened_at: Mutex::new(None),
                })
                .collect(),
        }
    }

    pub(crate) fn state(&self, group: usize) -> BreakerState {
        state_of(self.groups[group].state.load(Ordering::Acquire))
    }

    /// Record a failure; `Some(new_state)` on a transition.
    fn on_failure(&self, group: usize) -> Option<BreakerState> {
        let g = &self.groups[group];
        match g.state.load(Ordering::Acquire) {
            ST_CLOSED => {
                let n = g.consec_failures.fetch_add(1, Ordering::AcqRel) + 1;
                if n >= self.cfg.failure_threshold
                    && g.state
                        .compare_exchange(ST_CLOSED, ST_OPEN, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                {
                    *g.opened_at.lock().unwrap() = Some(Instant::now());
                    return Some(BreakerState::Open);
                }
                None
            }
            ST_HALF_OPEN => {
                // A probe failed: straight back to open.
                if g.state
                    .compare_exchange(ST_HALF_OPEN, ST_OPEN, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    g.probe_successes.store(0, Ordering::Release);
                    *g.opened_at.lock().unwrap() = Some(Instant::now());
                    return Some(BreakerState::Open);
                }
                None
            }
            _ => None,
        }
    }

    /// Record a success; `Some(new_state)` on a transition.
    fn on_success(&self, group: usize) -> Option<BreakerState> {
        let g = &self.groups[group];
        match g.state.load(Ordering::Acquire) {
            ST_CLOSED => {
                g.consec_failures.store(0, Ordering::Release);
                None
            }
            ST_HALF_OPEN => {
                let n = g.probe_successes.fetch_add(1, Ordering::AcqRel) + 1;
                if n >= self.cfg.probe_successes
                    && g.state
                        .compare_exchange(
                            ST_HALF_OPEN,
                            ST_CLOSED,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_ok()
                {
                    g.consec_failures.store(0, Ordering::Release);
                    return Some(BreakerState::Closed);
                }
                None
            }
            _ => None,
        }
    }

    /// Age open breakers into half-open; returns the groups that moved.
    fn tick(&self, now: Instant) -> Vec<usize> {
        let mut moved = Vec::new();
        for (i, g) in self.groups.iter().enumerate() {
            if g.state.load(Ordering::Acquire) != ST_OPEN {
                continue;
            }
            let due = g
                .opened_at
                .lock()
                .unwrap()
                .is_some_and(|t| now.duration_since(t) >= self.cfg.open_for);
            if due
                && g.state
                    .compare_exchange(ST_OPEN, ST_HALF_OPEN, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
            {
                g.probe_successes.store(0, Ordering::Release);
                moved.push(i);
            }
        }
        moved
    }
}

/// First-completion-wins token shared by a sub-batch and its hedge
/// copies.  `copies` counts outstanding copies so that *failure* only
/// propagates when every copy has failed (the last failing copy claims
/// the token and owns the part's fate).
pub(crate) struct PartToken {
    claimed: AtomicBool,
    copies: AtomicU32,
}

impl PartToken {
    pub(crate) fn new() -> Self {
        Self {
            claimed: AtomicBool::new(false),
            copies: AtomicU32::new(1),
        }
    }

    /// Claim the part.  The winner (and only the winner) scatters its rows
    /// and finishes the part.
    pub(crate) fn claim(&self) -> bool {
        !self.claimed.swap(true, Ordering::AcqRel)
    }

    pub(crate) fn is_claimed(&self) -> bool {
        self.claimed.load(Ordering::Acquire)
    }

    /// Another copy is being dispatched (called by the dispatcher before
    /// the hedge job is sent).
    pub(crate) fn add_copy(&self) {
        self.copies.fetch_add(1, Ordering::AcqRel);
    }

    /// This copy failed.  True iff the failure must propagate: it was the
    /// last outstanding copy *and* no copy had succeeded — in which case
    /// this call claims the token and the caller owns the retry/fail path.
    pub(crate) fn copy_failed(&self) -> bool {
        self.copies.fetch_sub(1, Ordering::AcqRel) == 1 && self.claim()
    }
}

/// A recovery work item posted back to the dispatcher (the only job-ring
/// producer).  Rows are global row ids — the dispatcher re-splits them
/// against the *current* placement generation.
pub(crate) struct ResMsg {
    /// Global row ids to re-dispatch.
    pub rows: Vec<u64>,
    /// Final output positions, parallel to `rows`.
    pub positions: Vec<u32>,
    pub acc: Arc<RequestAcc>,
    /// Retry attempt this message carries (0 for hedges).
    pub attempt: u32,
    /// Dispatch no earlier than this (backoff; hedges are immediate).
    pub due: Instant,
    pub hedge: bool,
    /// Hedge only: the token shared with the original copy.
    pub token: Option<Arc<PartToken>>,
    /// Hedge only: prefer a sibling group other than this one.
    pub exclude: Option<usize>,
}

/// One outstanding hedge-eligible sub-batch, watched by the monitor.
struct HedgeEntry {
    token: Arc<PartToken>,
    started: Instant,
    group: usize,
    rows: Vec<u64>,
    positions: Vec<u32>,
    acc: Arc<RequestAcc>,
}

type BreakerHook = Arc<dyn Fn(usize, BreakerState) + Send + Sync>;

/// The shared resilience runtime: breaker bank, retry/hedge channel back
/// to the dispatcher, hedge registry, and the monitor thread that ages
/// breakers and fires hedges.
pub(crate) struct ResilienceCtx {
    pub(crate) cfg: ResilienceConfig,
    metrics: Arc<Metrics>,
    breaker: Option<CircuitBreaker>,
    // `mpsc::Sender` is !Sync on older toolchains; the mutex makes the ctx
    // shareable.  Workers clone their own sender at construction, so this
    // lock is off the per-job path.
    tx: Mutex<mpsc::Sender<ResMsg>>,
    rx: Mutex<Option<mpsc::Receiver<ResMsg>>>,
    registry: Mutex<Vec<HedgeEntry>>,
    hook: Mutex<Option<BreakerHook>>,
    stop: AtomicBool,
    monitor: Mutex<Option<thread::JoinHandle<()>>>,
}

impl ResilienceCtx {
    pub(crate) fn new(cfg: ResilienceConfig, metrics: Arc<Metrics>, groups: usize) -> Arc<Self> {
        let (tx, rx) = mpsc::channel();
        let breaker = cfg
            .breaker
            .clone()
            .map(|bcfg| CircuitBreaker::new(bcfg, groups));
        Arc::new(Self {
            cfg,
            metrics,
            breaker,
            tx: Mutex::new(tx),
            rx: Mutex::new(Some(rx)),
            registry: Mutex::new(Vec::new()),
            hook: Mutex::new(None),
            stop: AtomicBool::new(false),
            monitor: Mutex::new(None),
        })
    }

    pub(crate) fn hedge_enabled(&self) -> bool {
        self.cfg.hedge.is_some()
    }

    /// A sender for a worker thread (each worker owns its clone).
    pub(crate) fn sender(&self) -> mpsc::Sender<ResMsg> {
        self.tx.lock().unwrap().clone()
    }

    /// The dispatcher takes the single receiver at pipeline start.
    pub(crate) fn take_receiver(&self) -> Option<mpsc::Receiver<ResMsg>> {
        self.rx.lock().unwrap().take()
    }

    pub(crate) fn breaker_state(&self, group: usize) -> Option<BreakerState> {
        self.breaker.as_ref().map(|b| b.state(group))
    }

    /// Wire breaker transitions into the control plane (health epoch +
    /// decision trace).  Installed once the control context exists.
    pub(crate) fn install_hook(&self, hook: BreakerHook) {
        *self.hook.lock().unwrap() = Some(hook);
    }

    fn fire_hook(&self, group: usize, state: BreakerState) {
        let hook = self.hook.lock().unwrap().clone();
        if let Some(h) = hook {
            h(group, state);
        }
    }

    fn count_transition(&self, state: BreakerState) {
        let counter = match state {
            BreakerState::Open => &self.metrics.breaker_opens,
            BreakerState::HalfOpen => &self.metrics.breaker_half_opens,
            BreakerState::Closed => &self.metrics.breaker_closes,
        };
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// A job on `group` completed cleanly.
    pub(crate) fn note_success(&self, group: usize) {
        if let Some(b) = &self.breaker {
            if let Some(state) = b.on_success(group) {
                self.count_transition(state);
                self.fire_hook(group, state);
            }
        }
    }

    /// A job on `group` failed (injected or structural).
    pub(crate) fn note_failure(&self, group: usize) {
        if let Some(b) = &self.breaker {
            if let Some(state) = b.on_failure(group) {
                self.count_transition(state);
                self.fire_hook(group, state);
            }
        }
    }

    /// Whether a failure at `attempt` still has retry budget.
    pub(crate) fn can_retry(&self, attempt: u32) -> bool {
        self.cfg.retry.as_ref().is_some_and(|p| attempt < p.budget)
    }

    /// Post a retry for `rows` back to the dispatcher.  False if the
    /// pipeline is gone (caller fails the part instead).
    pub(crate) fn send_retry(
        &self,
        rows: Vec<u64>,
        positions: Vec<u32>,
        acc: Arc<RequestAcc>,
        attempt: u32,
    ) -> bool {
        let Some(policy) = &self.cfg.retry else {
            return false;
        };
        let backoff = policy.backoff * 2u32.saturating_pow(attempt.min(16));
        let msg = ResMsg {
            rows,
            positions,
            acc,
            attempt: attempt + 1,
            due: Instant::now() + backoff,
            hedge: false,
            token: None,
            exclude: None,
        };
        if self.tx.lock().unwrap().send(msg).is_ok() {
            self.metrics.retries.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    /// Register a freshly dispatched sub-batch as hedge-eligible.  Called
    /// by the dispatcher (hedge mode only — the extra clones are the price
    /// of speculation, never paid when hedging is off).
    pub(crate) fn register_hedge(
        &self,
        token: Arc<PartToken>,
        group: usize,
        rows: Vec<u64>,
        positions: Vec<u32>,
        acc: Arc<RequestAcc>,
    ) {
        self.registry.lock().unwrap().push(HedgeEntry {
            token,
            started: Instant::now(),
            group,
            rows,
            positions,
            acc,
        });
    }

    /// Current hedge watermark: the configured latency quantile, floored
    /// at `min_after`.
    fn hedge_watermark(&self) -> Option<Duration> {
        let h = self.cfg.hedge.as_ref()?;
        let q = Duration::from_micros(self.metrics.latency.quantile_us(h.quantile));
        Some(h.min_after.max(q))
    }

    /// One monitor pass: prune settled hedge entries, hedge stragglers,
    /// age open breakers.  Public-in-crate so tests can drive it directly.
    pub(crate) fn monitor_pass(&self, now: Instant) {
        if let Some(watermark) = self.hedge_watermark() {
            let mut due = Vec::new();
            {
                let mut reg = self.registry.lock().unwrap();
                let mut i = 0;
                while i < reg.len() {
                    if reg[i].token.is_claimed() {
                        reg.swap_remove(i);
                    } else if now.duration_since(reg[i].started) >= watermark {
                        due.push(reg.swap_remove(i));
                    } else {
                        i += 1;
                    }
                }
            }
            for e in due {
                e.token.add_copy();
                let msg = ResMsg {
                    rows: e.rows,
                    positions: e.positions,
                    acc: e.acc,
                    attempt: 0,
                    due: now,
                    hedge: true,
                    token: Some(Arc::clone(&e.token)),
                    exclude: Some(e.group),
                };
                if self.tx.lock().unwrap().send(msg).is_ok() {
                    self.metrics.hedges.fetch_add(1, Ordering::Relaxed);
                } else {
                    // Pipeline gone: the copy never dispatches.
                    e.token.copy_failed();
                }
            }
        }
        if let Some(b) = &self.breaker {
            for group in b.tick(now) {
                self.count_transition(BreakerState::HalfOpen);
                self.fire_hook(group, BreakerState::HalfOpen);
            }
        }
    }

    /// Start the monitor thread (hedge aging + breaker ticks).  No-op when
    /// neither feature needs one.
    pub(crate) fn start_monitor(self: &Arc<Self>) {
        if self.cfg.hedge.is_none() && self.cfg.breaker.is_none() {
            return;
        }
        let ctx = Arc::clone(self);
        let handle = thread::Builder::new()
            .name("a100win-resilience".into())
            .spawn(move || {
                while !ctx.stop.load(Ordering::Acquire) {
                    ctx.monitor_pass(Instant::now());
                    thread::sleep(Duration::from_micros(500));
                }
            })
            // PANIC: thread-spawn failure at startup is unrecoverable
            // resource exhaustion; there is no degraded mode to fall to.
            .expect("spawn resilience monitor");
        *self.monitor.lock().unwrap() = Some(handle);
    }

    /// Stop the monitor thread (idempotent).
    pub(crate) fn stop_monitor(&self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.monitor.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for ResilienceCtx {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn breaker(threshold: u32, probes: u32) -> CircuitBreaker {
        CircuitBreaker::new(
            BreakerConfig {
                failure_threshold: threshold,
                open_for: Duration::from_millis(5),
                probe_successes: probes,
            },
            2,
        )
    }

    #[test]
    fn breaker_full_cycle() {
        let b = breaker(3, 2);
        assert_eq!(b.state(0), BreakerState::Closed);
        assert_eq!(b.on_failure(0), None);
        assert_eq!(b.on_failure(0), None);
        assert_eq!(b.on_failure(0), Some(BreakerState::Open));
        assert_eq!(b.state(0), BreakerState::Open);
        // Other group untouched.
        assert_eq!(b.state(1), BreakerState::Closed);
        // Open ignores further traffic outcomes.
        assert_eq!(b.on_failure(0), None);
        assert_eq!(b.on_success(0), None);
        // Not due yet.
        assert!(b.tick(Instant::now()).is_empty());
        std::thread::sleep(Duration::from_millis(6));
        assert_eq!(b.tick(Instant::now()), vec![0]);
        assert_eq!(b.state(0), BreakerState::HalfOpen);
        // Two probe successes close it.
        assert_eq!(b.on_success(0), None);
        assert_eq!(b.on_success(0), Some(BreakerState::Closed));
        assert_eq!(b.state(0), BreakerState::Closed);
    }

    #[test]
    fn half_open_failure_reopens() {
        let b = breaker(1, 2);
        assert_eq!(b.on_failure(0), Some(BreakerState::Open));
        std::thread::sleep(Duration::from_millis(6));
        assert_eq!(b.tick(Instant::now()), vec![0]);
        assert_eq!(b.on_failure(0), Some(BreakerState::Open));
        assert_eq!(b.state(0), BreakerState::Open);
    }

    #[test]
    fn success_resets_consecutive_failures() {
        let b = breaker(3, 1);
        b.on_failure(0);
        b.on_failure(0);
        b.on_success(0);
        assert_eq!(b.on_failure(0), None);
        assert_eq!(b.on_failure(0), None);
        assert_eq!(b.on_failure(0), Some(BreakerState::Open));
    }

    #[test]
    fn part_token_first_completion_wins() {
        let t = PartToken::new();
        assert!(t.claim());
        assert!(!t.claim());
        assert!(t.is_claimed());
    }

    #[test]
    fn part_token_failure_propagates_only_when_all_copies_fail() {
        // Single copy fails -> propagate.
        let t = PartToken::new();
        assert!(t.copy_failed());
        // Two copies: first failure is silent, second propagates.
        let t = PartToken::new();
        t.add_copy();
        assert!(!t.copy_failed());
        assert!(t.copy_failed());
        // A success before the last failure suppresses propagation.
        let t = PartToken::new();
        t.add_copy();
        assert!(t.claim());
        assert!(!t.copy_failed());
        assert!(!t.copy_failed());
    }
}

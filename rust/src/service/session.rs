//! Per-tenant sessions: admission control and backpressure on top of a
//! shared [`Service`](super::Service).
//!
//! Every session owns an in-flight budget.  A submission beyond the budget
//! is either **rejected** immediately ([`OverloadPolicy::Reject`], the
//! heavy-traffic default: shed load at the front door) or **queued** by
//! blocking the caller until a slot frees ([`OverloadPolicy::Queue`],
//! closed-loop clients).  Both outcomes are surfaced in the backend's
//! [`Metrics`] (`admission_rejected` / `throttled`) and in per-session
//! [`SessionStats`].
//!
//! Slots are released by RAII: the [`SlotGuard`] rides inside the
//! [`Ticket`] and frees the slot when the ticket resolves or is dropped —
//! a tenant cannot leak budget by abandoning tickets.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::anyhow;

use crate::coordinator::metrics::Metrics;

use super::backend::Ticket;
use super::Service;

/// What to do with a submission beyond the in-flight budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Fail the submission immediately (load shedding).
    Reject,
    /// Block the caller until a slot frees (backpressure).
    Queue,
}

#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Maximum unresolved tickets this tenant may hold.
    pub max_in_flight: usize,
    pub overload: OverloadPolicy,
    /// Deadline attached to every submission (None = unbounded).
    pub deadline: Option<Duration>,
}

impl Default for SessionConfig {
    fn default() -> Self {
        Self {
            max_in_flight: 64,
            overload: OverloadPolicy::Reject,
            deadline: None,
        }
    }
}

/// Per-tenant counters (the backend-wide view lives in [`Metrics`]).
#[derive(Debug, Default)]
pub struct SessionStats {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub throttled: AtomicU64,
}

/// The in-flight gauge: a counting semaphore with RAII release.
#[derive(Debug)]
pub(crate) struct Slots {
    cap: usize,
    used: Mutex<usize>,
    freed: Condvar,
}

impl Slots {
    fn new(cap: usize) -> Arc<Self> {
        Arc::new(Self {
            cap,
            used: Mutex::new(0),
            freed: Condvar::new(),
        })
    }

    fn try_acquire(slots: &Arc<Self>) -> Option<SlotGuard> {
        let mut used = slots.used.lock().unwrap();
        if *used >= slots.cap {
            return None;
        }
        *used += 1;
        Some(SlotGuard {
            slots: Arc::clone(slots),
        })
    }

    /// Block until a slot frees; reports whether the caller had to wait.
    fn acquire_blocking(slots: &Arc<Self>) -> (SlotGuard, bool) {
        let mut used = slots.used.lock().unwrap();
        let mut blocked = false;
        while *used >= slots.cap {
            blocked = true;
            used = slots.freed.wait(used).unwrap();
        }
        *used += 1;
        (
            SlotGuard {
                slots: Arc::clone(slots),
            },
            blocked,
        )
    }

    fn used(&self) -> usize {
        *self.used.lock().unwrap()
    }
}

/// Releases one in-flight slot on drop.
#[derive(Debug)]
pub struct SlotGuard {
    slots: Arc<Slots>,
}

impl Drop for SlotGuard {
    fn drop(&mut self) {
        let mut used = self.slots.used.lock().unwrap();
        *used -= 1;
        drop(used);
        self.slots.freed.notify_one();
    }
}

/// One tenant's handle on the service.
pub struct Session {
    tenant: String,
    cfg: SessionConfig,
    service: Service,
    slots: Arc<Slots>,
    stats: Arc<SessionStats>,
    metrics: Arc<Metrics>,
}

impl Session {
    pub(crate) fn new(service: Service, tenant: &str, cfg: SessionConfig) -> Self {
        assert!(cfg.max_in_flight >= 1, "in-flight budget must be >= 1");
        let metrics = service.metrics_handle();
        Self {
            tenant: tenant.to_string(),
            slots: Slots::new(cfg.max_in_flight),
            cfg,
            service,
            stats: Arc::new(SessionStats::default()),
            metrics,
        }
    }

    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    pub fn stats(&self) -> &SessionStats {
        &self.stats
    }

    /// Unresolved tickets currently held by this tenant.
    pub fn in_flight(&self) -> usize {
        self.slots.used()
    }

    /// Admission-controlled submit: acquires an in-flight slot per the
    /// overload policy, then forwards to the service with the session's
    /// default deadline.  The slot rides inside the ticket and frees when
    /// the ticket resolves or is dropped.
    pub fn submit(&self, rows: Arc<Vec<u64>>) -> anyhow::Result<Ticket> {
        let guard = match self.cfg.overload {
            OverloadPolicy::Reject => Slots::try_acquire(&self.slots).ok_or_else(|| {
                self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                self.metrics.admission_rejected.fetch_add(1, Ordering::Relaxed);
                anyhow!(
                    "tenant '{}' over its in-flight budget ({})",
                    self.tenant,
                    self.cfg.max_in_flight
                )
            })?,
            OverloadPolicy::Queue => {
                let (guard, blocked) = Slots::acquire_blocking(&self.slots);
                if blocked {
                    self.stats.throttled.fetch_add(1, Ordering::Relaxed);
                    self.metrics.throttled.fetch_add(1, Ordering::Relaxed);
                }
                guard
            }
        };
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        let mut ticket = self.service.submit(rows, self.cfg.deadline)?;
        ticket.slot = Some(guard);
        Ok(ticket)
    }

    /// Blocking convenience: submit + wait.
    pub fn lookup(&self, rows: Arc<Vec<u64>>) -> anyhow::Result<Vec<f32>> {
        self.submit(rows)?.wait()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slots_count_and_release() {
        let s = Slots::new(2);
        let a = Slots::try_acquire(&s).unwrap();
        let b = Slots::try_acquire(&s).unwrap();
        assert!(Slots::try_acquire(&s).is_none());
        assert_eq!(s.used(), 2);
        drop(a);
        assert_eq!(s.used(), 1);
        let c = Slots::try_acquire(&s).unwrap();
        assert!(Slots::try_acquire(&s).is_none());
        drop(b);
        drop(c);
        assert_eq!(s.used(), 0);
    }

    #[test]
    fn blocking_acquire_wakes_on_release() {
        let s = Slots::new(1);
        let held = Slots::try_acquire(&s).unwrap();
        let s2 = Arc::clone(&s);
        let t = std::thread::spawn(move || {
            let (g, blocked) = Slots::acquire_blocking(&s2);
            drop(g);
            blocked
        });
        std::thread::sleep(Duration::from_millis(30));
        drop(held);
        assert!(t.join().unwrap(), "second acquire must have blocked");
        assert_eq!(s.used(), 0);
    }

    #[test]
    fn unblocked_acquire_reports_no_wait() {
        let s = Slots::new(1);
        let (g, blocked) = Slots::acquire_blocking(&s);
        assert!(!blocked);
        drop(g);
    }
}

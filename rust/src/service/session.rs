//! Per-tenant sessions: admission control and backpressure on top of a
//! shared [`Service`](super::Service).
//!
//! Every session owns an in-flight budget.  A submission beyond the budget
//! is either **rejected** immediately ([`OverloadPolicy::Reject`], the
//! heavy-traffic default: shed load at the front door) or **queued** by
//! blocking the caller until a slot frees ([`OverloadPolicy::Queue`],
//! closed-loop clients).  Both outcomes are surfaced in the backend's
//! [`Metrics`] (`admission_rejected` / `throttled`) and in per-session
//! [`SessionStats`].
//!
//! Slots are released by RAII: the [`SlotGuard`] rides inside the
//! [`Ticket`] and frees the slot when the ticket resolves or is dropped —
//! a tenant cannot leak budget by abandoning tickets.
//!
//! On top of the per-tenant budgets, a [`GlobalAdmission`] bounds the
//! *fleet-wide* in-flight total with **weighted fair sharing**: each
//! tenant's weight reserves it a guaranteed slice of the global budget
//! (non-preemptive, so reservations are never lent out — a granted slot
//! cannot be reclaimed), and un-reserved slack is first-come.  A noisy
//! neighbor can exhaust the slack but never a quiet tenant's reservation.
//!
//! All synchronization here comes from the `util::sync` shim: under
//! `--features model` the CAS admission core and the parked-waiter
//! handshake run inside the `interleave` checker (`verify::admission_*`),
//! where `wait_timeout` never times out — so a passing model proves the
//! wakeup protocol sound without its latency backstop.

use std::sync::Arc;
use std::time::Duration;

use crate::util::sync::{AtomicU64, AtomicUsize, Condvar, Mutex, Ordering};

use anyhow::anyhow;

use crate::coordinator::metrics::Metrics;

use super::backend::Ticket;
use super::Service;

/// Condvar backstop so a lost wakeup costs bounded latency, never a hang
/// (the waiter-count handshake makes it rare).
const WAIT_BACKSTOP: Duration = Duration::from_millis(50);

/// The one parked blocking-acquire protocol both budget layers share:
/// register as a waiter, re-try (closing the race with a release that ran
/// between the caller's failed fast path and the registration), then wait
/// with the timeout backstop.  Pair with [`wake_parked`] on release.
fn acquire_parked<T>(
    waiters: &AtomicUsize,
    wait_lock: &Mutex<()>,
    freed: &Condvar,
    mut try_acquire: impl FnMut() -> Option<T>,
) -> (T, bool) {
    if let Some(g) = try_acquire() {
        return (g, false);
    }
    waiters.fetch_add(1, Ordering::SeqCst);
    let mut guard = wait_lock.lock().unwrap();
    loop {
        if let Some(g) = try_acquire() {
            waiters.fetch_sub(1, Ordering::SeqCst);
            return (g, true);
        }
        let (g, _timeout) = freed.wait_timeout(guard, WAIT_BACKSTOP).unwrap();
        guard = g;
    }
}

/// Release-side half of [`acquire_parked`]: notify only when someone is
/// actually registered, so the uncontended release never locks.  `all`
/// selects the wake breadth: per-session [`Slots`] waiters all share one
/// predicate, so a single freed slot wakes one of them; the global budget
/// wakes everyone because its waiters' predicates differ per tenant (the
/// freed slot may be admissible to any of them).
fn wake_parked(waiters: &AtomicUsize, wait_lock: &Mutex<()>, freed: &Condvar, all: bool) {
    if waiters.load(Ordering::SeqCst) > 0 {
        let _g = wait_lock.lock().unwrap();
        if all {
            freed.notify_all();
        } else {
            freed.notify_one();
        }
    }
}

/// Increment `gauge` only while it stays below `limit` (CAS loop).
///
/// Unlike fetch_add-then-undo, a *failed* attempt never perturbs the
/// gauge — so one tenant hammering a full budget can never transiently
/// inflate a shared counter and spuriously reject another tenant that is
/// within its own bound.  The admission invariants stay exact, not
/// statistical, without a lock.
fn try_bump(gauge: &AtomicUsize, limit: usize) -> bool {
    let mut cur = gauge.load(Ordering::Acquire);
    loop {
        if cur >= limit {
            return false;
        }
        match gauge.compare_exchange_weak(cur, cur + 1, Ordering::AcqRel, Ordering::Acquire) {
            Ok(_) => return true,
            Err(actual) => cur = actual,
        }
    }
}

/// What to do with a submission beyond the in-flight budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Fail the submission immediately (load shedding).
    Reject,
    /// Block the caller until a slot frees (backpressure).
    Queue,
}

#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Maximum unresolved tickets this tenant may hold.
    pub max_in_flight: usize,
    pub overload: OverloadPolicy,
    /// Deadline attached to every submission (None = unbounded).
    pub deadline: Option<Duration>,
}

impl Default for SessionConfig {
    fn default() -> Self {
        Self {
            max_in_flight: 64,
            overload: OverloadPolicy::Reject,
            deadline: None,
        }
    }
}

/// Per-tenant counters (the backend-wide view lives in [`Metrics`]).
#[derive(Debug, Default)]
pub struct SessionStats {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub throttled: AtomicU64,
}

/// The in-flight gauge: a counting semaphore with RAII release.
///
/// The fast path — an under-budget tenant acquiring or releasing a slot —
/// is a single atomic add/sub, no mutex.  The mutex + condvar pair exists
/// only for [`OverloadPolicy::Queue`] waiters, and the release side locks
/// it only when the waiter counter says someone is actually parked.
#[derive(Debug)]
pub(crate) struct Slots {
    cap: usize,
    used: AtomicUsize,
    waiters: AtomicUsize,
    wait_lock: Mutex<()>,
    freed: Condvar,
}

impl Slots {
    fn new(cap: usize) -> Arc<Self> {
        Arc::new(Self {
            cap,
            used: AtomicUsize::new(0),
            waiters: AtomicUsize::new(0),
            wait_lock: Mutex::new(()),
            freed: Condvar::new(),
        })
    }

    /// Lock-free acquire: a bounded CAS increment, so `used` is always an
    /// exact occupancy count (failed attempts leave no trace).
    fn try_acquire(slots: &Arc<Self>) -> Option<SlotGuard> {
        if try_bump(&slots.used, slots.cap) {
            Some(SlotGuard {
                slots: Arc::clone(slots),
            })
        } else {
            None
        }
    }

    /// Block until a slot frees; reports whether the caller had to wait.
    fn acquire_blocking(slots: &Arc<Self>) -> (SlotGuard, bool) {
        acquire_parked(&slots.waiters, &slots.wait_lock, &slots.freed, || {
            Self::try_acquire(slots)
        })
    }

    fn release(&self) {
        self.used.fetch_sub(1, Ordering::AcqRel);
        wake_parked(&self.waiters, &self.wait_lock, &self.freed, false);
    }

    fn used(&self) -> usize {
        self.used.load(Ordering::Acquire)
    }
}

/// Releases one in-flight slot on drop.
#[derive(Debug)]
pub struct SlotGuard {
    slots: Arc<Slots>,
}

impl Drop for SlotGuard {
    fn drop(&mut self) {
        self.slots.release();
    }
}

// ---------------------------------------------------------------------------
// Cross-tenant budget with weighted fair sharing.
// ---------------------------------------------------------------------------

/// One tenant's live admission counters — shared by the registry, every
/// session of the tenant, and every outstanding slot guard, so acquire
/// and release never need the registry lock.
#[derive(Debug, Default)]
pub(crate) struct TenantCounters {
    /// Slots held within the tenant's guaranteed share.
    reserved: AtomicUsize,
    /// Slots borrowed from the shared slack.
    borrowed: AtomicUsize,
    /// `floor(capacity * w / Σw)` over active tenants; recomputed under
    /// the registry lock whenever the weight table changes.
    guaranteed: AtomicUsize,
}

impl TenantCounters {
    fn used(&self) -> usize {
        self.reserved.load(Ordering::Acquire) + self.borrowed.load(Ordering::Acquire)
    }
}

#[derive(Debug)]
struct TenantState {
    name: String,
    weight: f64,
    /// Live sessions sharing this tenant id; the reservation stays active
    /// until the last one deregisters (in-flight slots still drain
    /// through the shared counters afterwards).
    sessions: usize,
    active: bool,
    counters: Arc<TenantCounters>,
}

/// One tenant's slice of the global budget, for reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantShare {
    pub tenant: String,
    pub weight: f64,
    /// Reserved in-flight slots (`floor(capacity * w / Σw)` over active
    /// tenants — floors, so reservations never overcommit the budget; a
    /// tiny-weight tenant may have guarantee 0 and live off slack).
    pub guaranteed: usize,
    pub used: usize,
}

/// The fleet-wide in-flight budget, shared by many [`Session`]s.
///
/// Admission rule for tenant *i*:
///
/// * always deny when the budget is full;
/// * grant while the tenant is within its guaranteed share;
/// * beyond the share, grant only from *slack* — capacity not reserved for
///   tenants' guarantees — so a flood by one tenant can never consume
///   another's reservation.
///
/// **The whole acquire/release path is lock-free**: every grant is a
/// pair of CAS-bounded increments (the tenant's `reserved` against its
/// cached guarantee — or the shared `slack_used` against `slack_cap` —
/// then `total_used` against the capacity), so no interleaving can exceed
/// the capacity or a reservation, denied attempts leave no trace on any
/// shared gauge, and an under-budget tenant admits with two atomic RMWs.
/// The registry lock is taken only by `register`/`deregister`/`report`,
/// which recompute the per-tenant guarantee caches and the slack bound.
///
/// Shares are recomputed from the live weight table, so registering a new
/// tenant shrinks everyone's guarantee proportionally from the next
/// admission decision on (slots already granted under the old shares
/// drain naturally; until they do, a freshly shrunk guarantee can be
/// temporarily unmeetable).  Guarantees use floors, so their sum never
/// exceeds the capacity — a tenant within its reported guarantee is never
/// denied by other tenants' traffic.
#[derive(Debug)]
pub struct GlobalAdmission {
    capacity: usize,
    /// Σ slots held, all tenants (reserved + borrowed).
    total_used: AtomicUsize,
    /// Slots currently borrowed from the slack.
    slack_used: AtomicUsize,
    /// `capacity - Σ guaranteed(active)` — the borrowable pool.
    slack_cap: AtomicUsize,
    tenants: Mutex<Vec<TenantState>>,
    waiters: AtomicUsize,
    wait_lock: Mutex<()>,
    freed: Condvar,
}

impl GlobalAdmission {
    pub fn new(capacity: usize) -> Arc<Self> {
        assert!(capacity >= 1, "global budget must be >= 1");
        Arc::new(Self {
            capacity,
            total_used: AtomicUsize::new(0),
            slack_used: AtomicUsize::new(0),
            slack_cap: AtomicUsize::new(capacity),
            tenants: Mutex::new(Vec::new()),
            waiters: AtomicUsize::new(0),
            wait_lock: Mutex::new(()),
            freed: Condvar::new(),
        })
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Recompute every active tenant's guarantee and the slack bound
    /// (called under the registry lock on any weight-table change).
    fn recompute_shares(&self, ts: &[TenantState]) {
        let total_w: f64 = ts.iter().filter(|t| t.active).map(|t| t.weight).sum();
        let mut guaranteed_sum = 0usize;
        for t in ts {
            let g = if t.active && total_w > 0.0 {
                (self.capacity as f64 * t.weight / total_w) as usize
            } else {
                0
            };
            guaranteed_sum += g;
            t.counters.guaranteed.store(g, Ordering::Release);
        }
        self.slack_cap
            .store(self.capacity.saturating_sub(guaranteed_sum), Ordering::Release);
    }

    /// Register a tenant (or update its weight / add a session to it);
    /// returns its id.  Fully drained dead tenants' slots are reused, so
    /// the table is bounded by the peak number of concurrently live (or
    /// still-draining) tenants, not by process lifetime.
    pub fn register(&self, tenant: &str, weight: f64) -> usize {
        assert!(weight > 0.0, "tenant weight must be positive");
        let mut ts = self.tenants.lock().unwrap();
        let id = if let Some(i) = ts.iter().position(|t| t.name == tenant) {
            ts[i].weight = weight;
            ts[i].sessions += 1;
            ts[i].active = true;
            i
        } else {
            let state = TenantState {
                name: tenant.to_string(),
                weight,
                sessions: 1,
                active: true,
                counters: Arc::new(TenantCounters::default()),
            };
            // Reuse a fully dead slot (no sessions, nothing in flight):
            // live guards hold the counters Arc, so only a drained slot is
            // safe to rename (its counters are replaced wholesale).
            if let Some(i) = ts
                .iter()
                .position(|t| !t.active && t.sessions == 0 && t.counters.used() == 0)
            {
                ts[i] = state;
                i
            } else {
                ts.push(state);
                ts.len() - 1
            }
        };
        self.recompute_shares(&ts);
        drop(ts);
        self.wake_waiters();
        id
    }

    /// Drop one session's claim on a tenant (called by [`Session`] on
    /// drop); the reservation is released when the last session goes.
    /// In-flight slots keep counting against the budget until their
    /// guards drop; a freed reservation is redistributable immediately.
    pub fn deregister(&self, i: usize) {
        let mut ts = self.tenants.lock().unwrap();
        if let Some(t) = ts.get_mut(i) {
            t.sessions = t.sessions.saturating_sub(1);
            if t.sessions == 0 {
                t.active = false;
            }
        }
        self.recompute_shares(&ts);
        drop(ts);
        self.wake_waiters();
    }

    /// This tenant's shared counters (sessions cache the Arc so their
    /// submit path never touches the registry lock).
    pub(crate) fn counters(&self, i: usize) -> Arc<TenantCounters> {
        Arc::clone(&self.tenants.lock().unwrap()[i].counters)
    }

    /// The lock-free admission core: CAS-bounded increments, tenant-local
    /// gauge first.  Every success leaves `total_used <= capacity`, each
    /// tenant's `reserved <= guaranteed` (modulo live guarantee shrinks),
    /// and `slack_used <= slack_cap` — so reservations are never eaten by
    /// borrowers under any interleaving.  Ordering matters for isolation:
    /// a tenant beyond its guarantee fails on its *own* reserved gauge and
    /// (with no slack) on the slack gauge — which within-guarantee grants
    /// never consult — so a flood of denied attempts cannot perturb any
    /// counter a quiet tenant's admission reads.
    fn acquire_with(
        global: &Arc<Self>,
        counters: &Arc<TenantCounters>,
    ) -> Option<GlobalSlotGuard> {
        // Within the guarantee: tenant-local reservation, then the hard
        // capacity bound (which only real grants ever bump).
        if try_bump(&counters.reserved, counters.guaranteed.load(Ordering::Acquire)) {
            if try_bump(&global.total_used, global.capacity) {
                return Some(GlobalSlotGuard {
                    global: Arc::clone(global),
                    counters: Arc::clone(counters),
                    borrowed: false,
                });
            }
            // Full despite Σ guarantees <= capacity: only possible while
            // old grants drain after a live guarantee shrink.
            counters.reserved.fetch_sub(1, Ordering::AcqRel);
            return None;
        }
        // Beyond the share: borrow from the slack pool.
        if try_bump(&global.slack_used, global.slack_cap.load(Ordering::Acquire)) {
            if try_bump(&global.total_used, global.capacity) {
                counters.borrowed.fetch_add(1, Ordering::AcqRel);
                return Some(GlobalSlotGuard {
                    global: Arc::clone(global),
                    counters: Arc::clone(counters),
                    borrowed: true,
                });
            }
            global.slack_used.fetch_sub(1, Ordering::AcqRel);
        }
        None
    }

    /// Non-blocking acquire for tenant id `i` (Reject overload policy).
    pub fn try_acquire(global: &Arc<Self>, i: usize) -> Option<GlobalSlotGuard> {
        let counters = global.counters(i);
        Self::acquire_with(global, &counters)
    }

    /// Non-blocking acquire via a session's cached counters — the
    /// fully lock-free fast path.
    pub(crate) fn try_acquire_cached(
        global: &Arc<Self>,
        counters: &Arc<TenantCounters>,
    ) -> Option<GlobalSlotGuard> {
        Self::acquire_with(global, counters)
    }

    /// Blocking acquire (Queue overload policy); reports whether the
    /// caller had to wait.
    pub fn acquire_blocking(global: &Arc<Self>, i: usize) -> (GlobalSlotGuard, bool) {
        let counters = global.counters(i);
        Self::acquire_blocking_cached(global, &counters)
    }

    /// Blocking acquire via cached counters.
    pub(crate) fn acquire_blocking_cached(
        global: &Arc<Self>,
        counters: &Arc<TenantCounters>,
    ) -> (GlobalSlotGuard, bool) {
        acquire_parked(&global.waiters, &global.wait_lock, &global.freed, || {
            Self::acquire_with(global, counters)
        })
    }

    fn wake_waiters(&self) {
        wake_parked(&self.waiters, &self.wait_lock, &self.freed, true);
    }

    /// Total in-flight slots across all tenants.
    pub fn used_total(&self) -> usize {
        self.total_used.load(Ordering::Acquire)
    }

    /// Per-tenant weights, guarantees, and usage for active tenants (the
    /// multi-tenant view next to [`Metrics`]'s aggregate counters).
    pub fn report(&self) -> Vec<TenantShare> {
        let ts = self.tenants.lock().unwrap();
        ts.iter()
            .filter(|t| t.active)
            .map(|t| TenantShare {
                tenant: t.name.clone(),
                weight: t.weight,
                guaranteed: t.counters.guaranteed.load(Ordering::Acquire),
                used: t.counters.used(),
            })
            .collect()
    }
}

/// Releases one global in-flight slot on drop (lock-free: the guard
/// carries its tenant's counters and its reserved/borrowed class).
#[derive(Debug)]
pub struct GlobalSlotGuard {
    global: Arc<GlobalAdmission>,
    counters: Arc<TenantCounters>,
    /// Granted from the slack pool (beyond the guarantee) rather than the
    /// tenant's reservation: the class is fixed at grant time so releases
    /// stay consistent even if guarantees were re-dealt in between.
    borrowed: bool,
}

impl Drop for GlobalSlotGuard {
    fn drop(&mut self) {
        if self.borrowed {
            self.counters.borrowed.fetch_sub(1, Ordering::AcqRel);
            self.global.slack_used.fetch_sub(1, Ordering::AcqRel);
        } else {
            self.counters.reserved.fetch_sub(1, Ordering::AcqRel);
        }
        self.global.total_used.fetch_sub(1, Ordering::AcqRel);
        self.global.wake_waiters();
    }
}

/// One tenant's handle on the service.
pub struct Session {
    tenant: String,
    cfg: SessionConfig,
    service: Service,
    slots: Arc<Slots>,
    /// Cross-tenant budget, this tenant's id in it, and the cached counter
    /// block — the submit fast path acquires global slots without ever
    /// touching the registry lock.
    global: Option<(Arc<GlobalAdmission>, usize, Arc<TenantCounters>)>,
    stats: Arc<SessionStats>,
    metrics: Arc<Metrics>,
}

impl Session {
    pub(crate) fn new(service: Service, tenant: &str, cfg: SessionConfig) -> Self {
        assert!(cfg.max_in_flight >= 1, "in-flight budget must be >= 1");
        let metrics = service.metrics_handle();
        Self {
            tenant: tenant.to_string(),
            slots: Slots::new(cfg.max_in_flight),
            cfg,
            service,
            global: None,
            stats: Arc::new(SessionStats::default()),
            metrics,
        }
    }

    /// A session that additionally answers to a cross-tenant
    /// [`GlobalAdmission`] budget with the given fair-sharing weight.
    pub(crate) fn with_global(
        service: Service,
        tenant: &str,
        cfg: SessionConfig,
        global: &Arc<GlobalAdmission>,
        weight: f64,
    ) -> Self {
        let id = global.register(tenant, weight);
        let counters = global.counters(id);
        let mut s = Self::new(service, tenant, cfg);
        s.global = Some((Arc::clone(global), id, counters));
        s
    }

    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    pub fn stats(&self) -> &SessionStats {
        &self.stats
    }

    /// Unresolved tickets currently held by this tenant.
    pub fn in_flight(&self) -> usize {
        self.slots.used()
    }

    /// Admission-controlled submit: acquires an in-flight slot per the
    /// overload policy — first from the session budget, then (when the
    /// session shares a [`GlobalAdmission`]) from the weighted cross-tenant
    /// budget — then forwards to the service with the session's default
    /// deadline.  Both slots ride inside the ticket and free when the
    /// ticket resolves or is dropped.
    pub fn submit(&self, rows: Arc<Vec<u64>>) -> anyhow::Result<Ticket> {
        self.submit_with_deadline(rows, self.cfg.deadline)
    }

    /// [`Session::submit`] with a per-request deadline override — the
    /// network edge maps each wire request's deadline onto its tenant's
    /// admission budgets through this entry point.
    pub fn submit_with_deadline(
        &self,
        rows: Arc<Vec<u64>>,
        deadline: Option<Duration>,
    ) -> anyhow::Result<Ticket> {
        // `throttled` counts *submissions* that blocked, not budgets: a
        // Queue-mode submission that waits on both the session and the
        // global budget still increments once.
        let mut blocked_any = false;
        let guard = match self.cfg.overload {
            OverloadPolicy::Reject => Slots::try_acquire(&self.slots).ok_or_else(|| {
                self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                self.metrics.admission_rejected.fetch_add(1, Ordering::Relaxed);
                anyhow!(
                    "tenant '{}' over its in-flight budget ({})",
                    self.tenant,
                    self.cfg.max_in_flight
                )
            })?,
            OverloadPolicy::Queue => {
                let (guard, blocked) = Slots::acquire_blocking(&self.slots);
                blocked_any |= blocked;
                guard
            }
        };
        // The local guard is held across the global acquire: a tenant
        // queued on the shared budget still counts against its own cap.
        let global_guard = match &self.global {
            None => None,
            Some((global, _id, counters)) => Some(match self.cfg.overload {
                OverloadPolicy::Reject => GlobalAdmission::try_acquire_cached(global, counters)
                    .ok_or_else(|| {
                        self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                        self.metrics.admission_rejected.fetch_add(1, Ordering::Relaxed);
                        self.metrics.global_rejected.fetch_add(1, Ordering::Relaxed);
                        anyhow!(
                            "tenant '{}' denied by the global admission budget ({})",
                            self.tenant,
                            global.capacity()
                        )
                    })?,
                OverloadPolicy::Queue => {
                    let (g, blocked) = GlobalAdmission::acquire_blocking_cached(global, counters);
                    blocked_any |= blocked;
                    g
                }
            }),
        };
        if blocked_any {
            self.stats.throttled.fetch_add(1, Ordering::Relaxed);
            self.metrics.throttled.fetch_add(1, Ordering::Relaxed);
        }
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        let mut ticket = self.service.submit(rows, deadline)?;
        ticket.slot = Some(guard);
        ticket.global_slot = global_guard;
        Ok(ticket)
    }

    /// Blocking convenience: submit + wait.
    pub fn lookup(&self, rows: Arc<Vec<u64>>) -> anyhow::Result<Vec<f32>> {
        self.submit(rows)?.wait()
    }
}

impl Drop for Session {
    /// Release this tenant's global reservation: dead tenants must not
    /// keep capacity reserved forever (in-flight tickets still drain
    /// through their guards).
    fn drop(&mut self) {
        if let Some((global, id, _counters)) = &self.global {
            global.deregister(*id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slots_count_and_release() {
        let s = Slots::new(2);
        let a = Slots::try_acquire(&s).unwrap();
        let b = Slots::try_acquire(&s).unwrap();
        assert!(Slots::try_acquire(&s).is_none());
        assert_eq!(s.used(), 2);
        drop(a);
        assert_eq!(s.used(), 1);
        let c = Slots::try_acquire(&s).unwrap();
        assert!(Slots::try_acquire(&s).is_none());
        drop(b);
        drop(c);
        assert_eq!(s.used(), 0);
    }

    #[test]
    fn blocking_acquire_wakes_on_release() {
        let s = Slots::new(1);
        let held = Slots::try_acquire(&s).unwrap();
        let s2 = Arc::clone(&s);
        let t = std::thread::spawn(move || {
            let (g, blocked) = Slots::acquire_blocking(&s2);
            drop(g);
            blocked
        });
        std::thread::sleep(Duration::from_millis(30));
        drop(held);
        assert!(t.join().unwrap(), "second acquire must have blocked");
        assert_eq!(s.used(), 0);
    }

    #[test]
    fn unblocked_acquire_reports_no_wait() {
        let s = Slots::new(1);
        let (g, blocked) = Slots::acquire_blocking(&s);
        assert!(!blocked);
        drop(g);
    }

    #[test]
    fn global_budget_reserves_weighted_shares() {
        // capacity 8, weights 3:1 -> guarantees 6 and 2.
        let ga = GlobalAdmission::new(8);
        let a = ga.register("a", 3.0);
        let b = ga.register("b", 1.0);
        let shares = ga.report();
        assert_eq!(shares[a].guaranteed, 6);
        assert_eq!(shares[b].guaranteed, 2);

        // A floods: it gets exactly its guarantee (no slack to borrow —
        // the rest is reserved for B).
        let mut held = Vec::new();
        while let Some(g) = GlobalAdmission::try_acquire(&ga, a) {
            held.push(g);
            assert!(held.len() <= 8, "runaway grant");
        }
        assert_eq!(held.len(), 6);

        // B's reservation survives the flood.
        let b1 = GlobalAdmission::try_acquire(&ga, b).unwrap();
        let b2 = GlobalAdmission::try_acquire(&ga, b).unwrap();
        assert!(GlobalAdmission::try_acquire(&ga, b).is_none(), "full");
        assert_eq!(ga.used_total(), 8);
        drop((b1, b2, held));
        assert_eq!(ga.used_total(), 0);
    }

    #[test]
    fn global_budget_slack_is_borrowable() {
        // capacity 10, weights 1:1 over capacity 10 -> guarantees 5 and 5
        // (no slack); with weights 2:1 guarantees are 6 and 3, slack 1 —
        // the over-share tenant may take its guarantee plus the slack.
        let ga = GlobalAdmission::new(10);
        let a = ga.register("a", 2.0);
        let _b = ga.register("b", 1.0);
        let mut held = Vec::new();
        while let Some(g) = GlobalAdmission::try_acquire(&ga, a) {
            held.push(g);
            assert!(held.len() <= 10, "runaway grant");
        }
        assert_eq!(held.len(), 7, "guarantee 6 + slack 1");
    }

    #[test]
    fn single_tenant_uses_whole_budget() {
        let ga = GlobalAdmission::new(4);
        let a = ga.register("only", 1.0);
        let held: Vec<_> = (0..4)
            .map(|_| GlobalAdmission::try_acquire(&ga, a).unwrap())
            .collect();
        assert!(GlobalAdmission::try_acquire(&ga, a).is_none());
        drop(held);
        assert!(GlobalAdmission::try_acquire(&ga, a).is_some());
    }

    #[test]
    fn global_blocking_acquire_wakes_on_release() {
        let ga = GlobalAdmission::new(1);
        let a = ga.register("a", 1.0);
        let held = GlobalAdmission::try_acquire(&ga, a).unwrap();
        let ga2 = Arc::clone(&ga);
        let t = std::thread::spawn(move || {
            let (g, blocked) = GlobalAdmission::acquire_blocking(&ga2, a);
            drop(g);
            blocked
        });
        std::thread::sleep(Duration::from_millis(30));
        drop(held);
        assert!(t.join().unwrap(), "second acquire must have blocked");
        assert_eq!(ga.used_total(), 0);
    }

    #[test]
    fn skewed_weights_never_overcommit_guarantees() {
        // Floors: Σ guarantees ≤ capacity even under extreme weight skew,
        // so a quiet tenant within its guarantee is never denied.
        let ga = GlobalAdmission::new(8);
        let a = ga.register("a", 50.0);
        let b = ga.register("b", 1.0);
        let c = ga.register("c", 1.0);
        let shares = ga.report();
        let sum: usize = shares.iter().map(|s| s.guaranteed).sum();
        assert!(sum <= 8, "guarantees overcommit: {shares:?}");
        // A floods, B takes a slot; C must still get its guarantee (if
        // any) — and with guarantee 0 it simply has no reservation.
        let mut held = Vec::new();
        while let Some(g) = GlobalAdmission::try_acquire(&ga, a) {
            held.push(g);
        }
        let _b1 = GlobalAdmission::try_acquire(&ga, b);
        for _ in 0..shares[c].guaranteed {
            assert!(
                GlobalAdmission::try_acquire(&ga, c).is_some(),
                "guaranteed slot denied"
            );
        }
    }

    #[test]
    fn deregister_releases_reservation() {
        // capacity 8, weights 1:1 -> 4 each; after B deregisters, A owns
        // the whole budget again.
        let ga = GlobalAdmission::new(8);
        let a = ga.register("a", 1.0);
        let b = ga.register("b", 1.0);
        let mut held = Vec::new();
        while let Some(g) = GlobalAdmission::try_acquire(&ga, a) {
            held.push(g);
        }
        assert_eq!(held.len(), 4, "half the budget while B is active");
        ga.deregister(b);
        while let Some(g) = GlobalAdmission::try_acquire(&ga, a) {
            held.push(g);
        }
        assert_eq!(held.len(), 8, "B's reservation must be released");
        assert_eq!(ga.report().len(), 1, "report lists active tenants only");
        // Re-registering reactivates the same slot.
        assert_eq!(ga.register("b", 1.0), b);
        assert_eq!(ga.report().len(), 2);
    }

    #[test]
    fn session_refcount_and_slot_reuse() {
        let ga = GlobalAdmission::new(8);
        let t = ga.register("t", 2.0);
        assert_eq!(ga.register("t", 2.0), t, "same-name session shares the id");
        ga.deregister(t);
        assert_eq!(ga.report().len(), 1, "one session still live");
        ga.deregister(t);
        assert_eq!(ga.report().len(), 0, "last session released the tenant");
        // A drained dead slot is renamed for the next new tenant, bounding
        // the table by concurrent tenants rather than process lifetime.
        let u = ga.register("u", 1.0);
        assert_eq!(u, t, "dead slot must be reused");
        assert_eq!(ga.report()[0].tenant, "u");
    }

    #[test]
    fn concurrent_lock_free_admission_holds_invariants() {
        // Hammer the lock-free reserve-then-check path from many threads:
        // the budget must never overshoot, a tenant's reserved grants must
        // never exceed its guarantee, and everything must drain to zero.
        let ga = GlobalAdmission::new(16);
        let a = ga.register("a", 1.0);
        let b = ga.register("b", 1.0);
        let over = Arc::new(AtomicU64::new(0));
        // Miri's interpreter makes each CAS ~1000x slower; a short hammer
        // still drives the reserve-then-check interleavings it can catch.
        let (threads, iters): (&[usize], usize) = if cfg!(miri) {
            (&[a, b, a], 50)
        } else {
            (&[a, b, a, b, a, b], 2_000)
        };
        std::thread::scope(|s| {
            for &tid in threads {
                let ga = Arc::clone(&ga);
                let over = Arc::clone(&over);
                s.spawn(move || {
                    let c = ga.counters(tid);
                    for _ in 0..iters {
                        if let Some(g) = GlobalAdmission::try_acquire_cached(&ga, &c) {
                            if ga.used_total() > 16 {
                                over.fetch_add(1, Ordering::Relaxed);
                            }
                            drop(g);
                        }
                    }
                });
            }
        });
        assert_eq!(over.load(Ordering::Relaxed), 0, "budget overshot");
        assert_eq!(ga.used_total(), 0, "slots leaked");
        let shares = ga.report();
        assert!(shares.iter().all(|t| t.used == 0), "{shares:?}");
    }

    #[test]
    fn re_registering_updates_weight() {
        let ga = GlobalAdmission::new(8);
        let a = ga.register("a", 1.0);
        let _b = ga.register("b", 1.0);
        assert_eq!(ga.report()[a].guaranteed, 4);
        assert_eq!(ga.register("a", 3.0), a, "same id on re-register");
        assert_eq!(ga.report()[a].guaranteed, 6);
    }
}

//! Per-tenant sessions: admission control and backpressure on top of a
//! shared [`Service`](super::Service).
//!
//! Every session owns an in-flight budget.  A submission beyond the budget
//! is either **rejected** immediately ([`OverloadPolicy::Reject`], the
//! heavy-traffic default: shed load at the front door) or **queued** by
//! blocking the caller until a slot frees ([`OverloadPolicy::Queue`],
//! closed-loop clients).  Both outcomes are surfaced in the backend's
//! [`Metrics`] (`admission_rejected` / `throttled`) and in per-session
//! [`SessionStats`].
//!
//! Slots are released by RAII: the [`SlotGuard`] rides inside the
//! [`Ticket`] and frees the slot when the ticket resolves or is dropped —
//! a tenant cannot leak budget by abandoning tickets.
//!
//! On top of the per-tenant budgets, a [`GlobalAdmission`] bounds the
//! *fleet-wide* in-flight total with **weighted fair sharing**: each
//! tenant's weight reserves it a guaranteed slice of the global budget
//! (non-preemptive, so reservations are never lent out — a granted slot
//! cannot be reclaimed), and un-reserved slack is first-come.  A noisy
//! neighbor can exhaust the slack but never a quiet tenant's reservation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::anyhow;

use crate::coordinator::metrics::Metrics;

use super::backend::Ticket;
use super::Service;

/// What to do with a submission beyond the in-flight budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Fail the submission immediately (load shedding).
    Reject,
    /// Block the caller until a slot frees (backpressure).
    Queue,
}

#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Maximum unresolved tickets this tenant may hold.
    pub max_in_flight: usize,
    pub overload: OverloadPolicy,
    /// Deadline attached to every submission (None = unbounded).
    pub deadline: Option<Duration>,
}

impl Default for SessionConfig {
    fn default() -> Self {
        Self {
            max_in_flight: 64,
            overload: OverloadPolicy::Reject,
            deadline: None,
        }
    }
}

/// Per-tenant counters (the backend-wide view lives in [`Metrics`]).
#[derive(Debug, Default)]
pub struct SessionStats {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub throttled: AtomicU64,
}

/// The in-flight gauge: a counting semaphore with RAII release.
#[derive(Debug)]
pub(crate) struct Slots {
    cap: usize,
    used: Mutex<usize>,
    freed: Condvar,
}

impl Slots {
    fn new(cap: usize) -> Arc<Self> {
        Arc::new(Self {
            cap,
            used: Mutex::new(0),
            freed: Condvar::new(),
        })
    }

    fn try_acquire(slots: &Arc<Self>) -> Option<SlotGuard> {
        let mut used = slots.used.lock().unwrap();
        if *used >= slots.cap {
            return None;
        }
        *used += 1;
        Some(SlotGuard {
            slots: Arc::clone(slots),
        })
    }

    /// Block until a slot frees; reports whether the caller had to wait.
    fn acquire_blocking(slots: &Arc<Self>) -> (SlotGuard, bool) {
        let mut used = slots.used.lock().unwrap();
        let mut blocked = false;
        while *used >= slots.cap {
            blocked = true;
            used = slots.freed.wait(used).unwrap();
        }
        *used += 1;
        (
            SlotGuard {
                slots: Arc::clone(slots),
            },
            blocked,
        )
    }

    fn used(&self) -> usize {
        *self.used.lock().unwrap()
    }
}

/// Releases one in-flight slot on drop.
#[derive(Debug)]
pub struct SlotGuard {
    slots: Arc<Slots>,
}

impl Drop for SlotGuard {
    fn drop(&mut self) {
        let mut used = self.slots.used.lock().unwrap();
        *used -= 1;
        drop(used);
        self.slots.freed.notify_one();
    }
}

// ---------------------------------------------------------------------------
// Cross-tenant budget with weighted fair sharing.
// ---------------------------------------------------------------------------

#[derive(Debug)]
struct TenantState {
    name: String,
    weight: f64,
    used: usize,
    /// Live sessions sharing this tenant id; the reservation stays active
    /// until the last one deregisters (in-flight slots still drain
    /// through `used` afterwards).
    sessions: usize,
    active: bool,
}

/// One tenant's slice of the global budget, for reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantShare {
    pub tenant: String,
    pub weight: f64,
    /// Reserved in-flight slots (`floor(capacity * w / Σw)` over active
    /// tenants — floors, so reservations never overcommit the budget; a
    /// tiny-weight tenant may have guarantee 0 and live off slack).
    pub guaranteed: usize,
    pub used: usize,
}

/// The fleet-wide in-flight budget, shared by many [`Session`]s.
///
/// Admission rule for tenant *i* (all under one lock, so the invariant is
/// exact, not statistical):
///
/// * always deny when the budget is full;
/// * grant while the tenant is within its guaranteed share;
/// * beyond the share, grant only from *slack* — capacity not reserved for
///   other tenants' unused guarantees — so a flood by one tenant can never
///   consume another's reservation.
///
/// Shares are recomputed from the live weight table, so registering a new
/// tenant shrinks everyone's guarantee proportionally from the next
/// admission decision on (slots already granted under the old shares
/// drain naturally; until they do, a freshly shrunk guarantee can be
/// temporarily unmeetable).  Guarantees use floors, so their sum never
/// exceeds the capacity — a tenant within its reported guarantee is never
/// denied by other tenants' traffic.
#[derive(Debug)]
pub struct GlobalAdmission {
    capacity: usize,
    tenants: Mutex<Vec<TenantState>>,
    freed: Condvar,
}

impl GlobalAdmission {
    pub fn new(capacity: usize) -> Arc<Self> {
        assert!(capacity >= 1, "global budget must be >= 1");
        Arc::new(Self {
            capacity,
            tenants: Mutex::new(Vec::new()),
            freed: Condvar::new(),
        })
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Register a tenant (or update its weight / add a session to it);
    /// returns its id.  Fully drained dead tenants' slots are reused, so
    /// the table is bounded by the peak number of concurrently live (or
    /// still-draining) tenants, not by process lifetime.
    pub fn register(&self, tenant: &str, weight: f64) -> usize {
        assert!(weight > 0.0, "tenant weight must be positive");
        let mut ts = self.tenants.lock().unwrap();
        if let Some(i) = ts.iter().position(|t| t.name == tenant) {
            ts[i].weight = weight;
            ts[i].sessions += 1;
            ts[i].active = true;
            self.freed.notify_all();
            return i;
        }
        let state = TenantState {
            name: tenant.to_string(),
            weight,
            used: 0,
            sessions: 1,
            active: true,
        };
        // Reuse a fully dead slot (no sessions, nothing in flight): live
        // guards index by id, so only a drained slot is safe to rename.
        if let Some(i) = ts
            .iter()
            .position(|t| !t.active && t.sessions == 0 && t.used == 0)
        {
            ts[i] = state;
            return i;
        }
        ts.push(state);
        ts.len() - 1
    }

    /// Drop one session's claim on a tenant (called by [`Session`] on
    /// drop); the reservation is released when the last session goes.
    /// In-flight slots keep counting against the budget until their
    /// guards drop; a freed reservation is redistributable immediately.
    pub fn deregister(&self, i: usize) {
        let mut ts = self.tenants.lock().unwrap();
        if let Some(t) = ts.get_mut(i) {
            t.sessions = t.sessions.saturating_sub(1);
            if t.sessions == 0 {
                t.active = false;
            }
        }
        drop(ts);
        self.freed.notify_all();
    }

    fn total_active_weight(ts: &[TenantState]) -> f64 {
        ts.iter().filter(|t| t.active).map(|t| t.weight).sum()
    }

    fn guaranteed_with(&self, ts: &[TenantState], i: usize, total_w: f64) -> usize {
        if !ts[i].active {
            return 0;
        }
        (self.capacity as f64 * ts[i].weight / total_w) as usize
    }

    fn guaranteed(&self, ts: &[TenantState], i: usize) -> usize {
        self.guaranteed_with(ts, i, Self::total_active_weight(ts))
    }

    fn allowed(&self, ts: &[TenantState], i: usize) -> bool {
        let total_used: usize = ts.iter().map(|t| t.used).sum();
        if total_used >= self.capacity {
            return false;
        }
        // One weight pass shared by every guarantee below: admission stays
        // O(tenants) under the lock.
        let total_w = Self::total_active_weight(ts);
        if ts[i].used < self.guaranteed_with(ts, i, total_w) {
            return true;
        }
        // Beyond the share: only slack not reserved for others.
        let reserved_others: usize = (0..ts.len())
            .filter(|&j| j != i)
            .map(|j| self.guaranteed_with(ts, j, total_w).saturating_sub(ts[j].used))
            .sum();
        total_used + reserved_others < self.capacity
    }

    /// Non-blocking acquire for tenant id `i` (Reject overload policy).
    pub fn try_acquire(global: &Arc<Self>, i: usize) -> Option<GlobalSlotGuard> {
        let mut ts = global.tenants.lock().unwrap();
        if !global.allowed(&ts, i) {
            return None;
        }
        ts[i].used += 1;
        Some(GlobalSlotGuard {
            global: Arc::clone(global),
            tenant: i,
        })
    }

    /// Blocking acquire (Queue overload policy); reports whether the
    /// caller had to wait.
    pub fn acquire_blocking(global: &Arc<Self>, i: usize) -> (GlobalSlotGuard, bool) {
        let mut ts = global.tenants.lock().unwrap();
        let mut blocked = false;
        while !global.allowed(&ts, i) {
            blocked = true;
            ts = global.freed.wait(ts).unwrap();
        }
        ts[i].used += 1;
        (
            GlobalSlotGuard {
                global: Arc::clone(global),
                tenant: i,
            },
            blocked,
        )
    }

    /// Total in-flight slots across all tenants.
    pub fn used_total(&self) -> usize {
        self.tenants.lock().unwrap().iter().map(|t| t.used).sum()
    }

    /// Per-tenant weights, guarantees, and usage for active tenants (the
    /// multi-tenant view next to [`Metrics`]'s aggregate counters).
    pub fn report(&self) -> Vec<TenantShare> {
        let ts = self.tenants.lock().unwrap();
        (0..ts.len())
            .filter(|&i| ts[i].active)
            .map(|i| TenantShare {
                tenant: ts[i].name.clone(),
                weight: ts[i].weight,
                guaranteed: self.guaranteed(&ts, i),
                used: ts[i].used,
            })
            .collect()
    }
}

/// Releases one global in-flight slot on drop.
#[derive(Debug)]
pub struct GlobalSlotGuard {
    global: Arc<GlobalAdmission>,
    tenant: usize,
}

impl Drop for GlobalSlotGuard {
    fn drop(&mut self) {
        let mut ts = self.global.tenants.lock().unwrap();
        ts[self.tenant].used -= 1;
        drop(ts);
        self.global.freed.notify_all();
    }
}

/// One tenant's handle on the service.
pub struct Session {
    tenant: String,
    cfg: SessionConfig,
    service: Service,
    slots: Arc<Slots>,
    /// Cross-tenant budget and this tenant's id in it, when shared.
    global: Option<(Arc<GlobalAdmission>, usize)>,
    stats: Arc<SessionStats>,
    metrics: Arc<Metrics>,
}

impl Session {
    pub(crate) fn new(service: Service, tenant: &str, cfg: SessionConfig) -> Self {
        assert!(cfg.max_in_flight >= 1, "in-flight budget must be >= 1");
        let metrics = service.metrics_handle();
        Self {
            tenant: tenant.to_string(),
            slots: Slots::new(cfg.max_in_flight),
            cfg,
            service,
            global: None,
            stats: Arc::new(SessionStats::default()),
            metrics,
        }
    }

    /// A session that additionally answers to a cross-tenant
    /// [`GlobalAdmission`] budget with the given fair-sharing weight.
    pub(crate) fn with_global(
        service: Service,
        tenant: &str,
        cfg: SessionConfig,
        global: &Arc<GlobalAdmission>,
        weight: f64,
    ) -> Self {
        let id = global.register(tenant, weight);
        let mut s = Self::new(service, tenant, cfg);
        s.global = Some((Arc::clone(global), id));
        s
    }

    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    pub fn stats(&self) -> &SessionStats {
        &self.stats
    }

    /// Unresolved tickets currently held by this tenant.
    pub fn in_flight(&self) -> usize {
        self.slots.used()
    }

    /// Admission-controlled submit: acquires an in-flight slot per the
    /// overload policy — first from the session budget, then (when the
    /// session shares a [`GlobalAdmission`]) from the weighted cross-tenant
    /// budget — then forwards to the service with the session's default
    /// deadline.  Both slots ride inside the ticket and free when the
    /// ticket resolves or is dropped.
    pub fn submit(&self, rows: Arc<Vec<u64>>) -> anyhow::Result<Ticket> {
        // `throttled` counts *submissions* that blocked, not budgets: a
        // Queue-mode submission that waits on both the session and the
        // global budget still increments once.
        let mut blocked_any = false;
        let guard = match self.cfg.overload {
            OverloadPolicy::Reject => Slots::try_acquire(&self.slots).ok_or_else(|| {
                self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                self.metrics.admission_rejected.fetch_add(1, Ordering::Relaxed);
                anyhow!(
                    "tenant '{}' over its in-flight budget ({})",
                    self.tenant,
                    self.cfg.max_in_flight
                )
            })?,
            OverloadPolicy::Queue => {
                let (guard, blocked) = Slots::acquire_blocking(&self.slots);
                blocked_any |= blocked;
                guard
            }
        };
        // The local guard is held across the global acquire: a tenant
        // queued on the shared budget still counts against its own cap.
        let global_guard = match &self.global {
            None => None,
            Some((global, id)) => Some(match self.cfg.overload {
                OverloadPolicy::Reject => {
                    GlobalAdmission::try_acquire(global, *id).ok_or_else(|| {
                        self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                        self.metrics.admission_rejected.fetch_add(1, Ordering::Relaxed);
                        self.metrics.global_rejected.fetch_add(1, Ordering::Relaxed);
                        anyhow!(
                            "tenant '{}' denied by the global admission budget ({})",
                            self.tenant,
                            global.capacity()
                        )
                    })?
                }
                OverloadPolicy::Queue => {
                    let (g, blocked) = GlobalAdmission::acquire_blocking(global, *id);
                    blocked_any |= blocked;
                    g
                }
            }),
        };
        if blocked_any {
            self.stats.throttled.fetch_add(1, Ordering::Relaxed);
            self.metrics.throttled.fetch_add(1, Ordering::Relaxed);
        }
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        let mut ticket = self.service.submit(rows, self.cfg.deadline)?;
        ticket.slot = Some(guard);
        ticket.global_slot = global_guard;
        Ok(ticket)
    }

    /// Blocking convenience: submit + wait.
    pub fn lookup(&self, rows: Arc<Vec<u64>>) -> anyhow::Result<Vec<f32>> {
        self.submit(rows)?.wait()
    }
}

impl Drop for Session {
    /// Release this tenant's global reservation: dead tenants must not
    /// keep capacity reserved forever (in-flight tickets still drain
    /// through their guards).
    fn drop(&mut self) {
        if let Some((global, id)) = &self.global {
            global.deregister(*id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slots_count_and_release() {
        let s = Slots::new(2);
        let a = Slots::try_acquire(&s).unwrap();
        let b = Slots::try_acquire(&s).unwrap();
        assert!(Slots::try_acquire(&s).is_none());
        assert_eq!(s.used(), 2);
        drop(a);
        assert_eq!(s.used(), 1);
        let c = Slots::try_acquire(&s).unwrap();
        assert!(Slots::try_acquire(&s).is_none());
        drop(b);
        drop(c);
        assert_eq!(s.used(), 0);
    }

    #[test]
    fn blocking_acquire_wakes_on_release() {
        let s = Slots::new(1);
        let held = Slots::try_acquire(&s).unwrap();
        let s2 = Arc::clone(&s);
        let t = std::thread::spawn(move || {
            let (g, blocked) = Slots::acquire_blocking(&s2);
            drop(g);
            blocked
        });
        std::thread::sleep(Duration::from_millis(30));
        drop(held);
        assert!(t.join().unwrap(), "second acquire must have blocked");
        assert_eq!(s.used(), 0);
    }

    #[test]
    fn unblocked_acquire_reports_no_wait() {
        let s = Slots::new(1);
        let (g, blocked) = Slots::acquire_blocking(&s);
        assert!(!blocked);
        drop(g);
    }

    #[test]
    fn global_budget_reserves_weighted_shares() {
        // capacity 8, weights 3:1 -> guarantees 6 and 2.
        let ga = GlobalAdmission::new(8);
        let a = ga.register("a", 3.0);
        let b = ga.register("b", 1.0);
        let shares = ga.report();
        assert_eq!(shares[a].guaranteed, 6);
        assert_eq!(shares[b].guaranteed, 2);

        // A floods: it gets exactly its guarantee (no slack to borrow —
        // the rest is reserved for B).
        let mut held = Vec::new();
        while let Some(g) = GlobalAdmission::try_acquire(&ga, a) {
            held.push(g);
            assert!(held.len() <= 8, "runaway grant");
        }
        assert_eq!(held.len(), 6);

        // B's reservation survives the flood.
        let b1 = GlobalAdmission::try_acquire(&ga, b).unwrap();
        let b2 = GlobalAdmission::try_acquire(&ga, b).unwrap();
        assert!(GlobalAdmission::try_acquire(&ga, b).is_none(), "full");
        assert_eq!(ga.used_total(), 8);
        drop((b1, b2, held));
        assert_eq!(ga.used_total(), 0);
    }

    #[test]
    fn global_budget_slack_is_borrowable() {
        // capacity 10, weights 1:1 over capacity 10 -> guarantees 5 and 5
        // (no slack); with weights 2:1 guarantees are 6 and 3, slack 1 —
        // the over-share tenant may take its guarantee plus the slack.
        let ga = GlobalAdmission::new(10);
        let a = ga.register("a", 2.0);
        let _b = ga.register("b", 1.0);
        let mut held = Vec::new();
        while let Some(g) = GlobalAdmission::try_acquire(&ga, a) {
            held.push(g);
            assert!(held.len() <= 10, "runaway grant");
        }
        assert_eq!(held.len(), 7, "guarantee 6 + slack 1");
    }

    #[test]
    fn single_tenant_uses_whole_budget() {
        let ga = GlobalAdmission::new(4);
        let a = ga.register("only", 1.0);
        let held: Vec<_> = (0..4)
            .map(|_| GlobalAdmission::try_acquire(&ga, a).unwrap())
            .collect();
        assert!(GlobalAdmission::try_acquire(&ga, a).is_none());
        drop(held);
        assert!(GlobalAdmission::try_acquire(&ga, a).is_some());
    }

    #[test]
    fn global_blocking_acquire_wakes_on_release() {
        let ga = GlobalAdmission::new(1);
        let a = ga.register("a", 1.0);
        let held = GlobalAdmission::try_acquire(&ga, a).unwrap();
        let ga2 = Arc::clone(&ga);
        let t = std::thread::spawn(move || {
            let (g, blocked) = GlobalAdmission::acquire_blocking(&ga2, a);
            drop(g);
            blocked
        });
        std::thread::sleep(Duration::from_millis(30));
        drop(held);
        assert!(t.join().unwrap(), "second acquire must have blocked");
        assert_eq!(ga.used_total(), 0);
    }

    #[test]
    fn skewed_weights_never_overcommit_guarantees() {
        // Floors: Σ guarantees ≤ capacity even under extreme weight skew,
        // so a quiet tenant within its guarantee is never denied.
        let ga = GlobalAdmission::new(8);
        let a = ga.register("a", 50.0);
        let b = ga.register("b", 1.0);
        let c = ga.register("c", 1.0);
        let shares = ga.report();
        let sum: usize = shares.iter().map(|s| s.guaranteed).sum();
        assert!(sum <= 8, "guarantees overcommit: {shares:?}");
        // A floods, B takes a slot; C must still get its guarantee (if
        // any) — and with guarantee 0 it simply has no reservation.
        let mut held = Vec::new();
        while let Some(g) = GlobalAdmission::try_acquire(&ga, a) {
            held.push(g);
        }
        let _b1 = GlobalAdmission::try_acquire(&ga, b);
        for _ in 0..shares[c].guaranteed {
            assert!(
                GlobalAdmission::try_acquire(&ga, c).is_some(),
                "guaranteed slot denied"
            );
        }
    }

    #[test]
    fn deregister_releases_reservation() {
        // capacity 8, weights 1:1 -> 4 each; after B deregisters, A owns
        // the whole budget again.
        let ga = GlobalAdmission::new(8);
        let a = ga.register("a", 1.0);
        let b = ga.register("b", 1.0);
        let mut held = Vec::new();
        while let Some(g) = GlobalAdmission::try_acquire(&ga, a) {
            held.push(g);
        }
        assert_eq!(held.len(), 4, "half the budget while B is active");
        ga.deregister(b);
        while let Some(g) = GlobalAdmission::try_acquire(&ga, a) {
            held.push(g);
        }
        assert_eq!(held.len(), 8, "B's reservation must be released");
        assert_eq!(ga.report().len(), 1, "report lists active tenants only");
        // Re-registering reactivates the same slot.
        assert_eq!(ga.register("b", 1.0), b);
        assert_eq!(ga.report().len(), 2);
    }

    #[test]
    fn session_refcount_and_slot_reuse() {
        let ga = GlobalAdmission::new(8);
        let t = ga.register("t", 2.0);
        assert_eq!(ga.register("t", 2.0), t, "same-name session shares the id");
        ga.deregister(t);
        assert_eq!(ga.report().len(), 1, "one session still live");
        ga.deregister(t);
        assert_eq!(ga.report().len(), 0, "last session released the tenant");
        // A drained dead slot is renamed for the next new tenant, bounding
        // the table by concurrent tenants rather than process lifetime.
        let u = ga.register("u", 1.0);
        assert_eq!(u, t, "dead slot must be reused");
        assert_eq!(ga.report()[0].tenant, "u");
    }

    #[test]
    fn re_registering_updates_weight() {
        let ga = GlobalAdmission::new(8);
        let a = ga.register("a", 1.0);
        let _b = ga.register("b", 1.0);
        assert_eq!(ga.report()[a].guaranteed, 4);
        assert_eq!(ga.register("a", 3.0), a, "same id on re-register");
        assert_eq!(ga.report()[a].guaranteed, 6);
    }
}

//! Coordinator state: window->group assignment epochs, group health, and
//! rebalancing when groups degrade or fail.
//!
//! The placement computed at startup is not static: if a resource group is
//! taken out (simulated XID error, thermal throttle, preemption), its
//! windows must move to surviving groups — ideally keeping every group's
//! window set small enough to stay under TLB reach, and otherwise
//! *admitting* that a group now straddles two windows (degraded mode, the
//! Fig-1 regime) rather than failing the table.

use std::collections::BTreeMap;

use crate::probe::TopologyMap;

use super::chunks::WindowPlan;
use super::placement::{Placement, PlacementPolicy};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupHealth {
    Healthy,
    /// Serving, but deprioritized (e.g. thermal).
    Degraded,
    /// Not serving.
    Failed,
}

/// Versioned assignment state.
#[derive(Debug, Clone)]
pub struct CoordinatorState {
    pub epoch: u64,
    /// window id -> serving group indices (ordered by priority).
    pub assignment: Vec<Vec<usize>>,
    pub health: Vec<GroupHealth>,
    /// True when some group serves more than one window (TLB reach may be
    /// exceeded; throughput follows the paper's Fig-1 cliff).
    pub degraded_reach: bool,
}

impl CoordinatorState {
    /// Initial state from a placement.
    pub fn new(placement: &Placement, group_count: usize) -> Self {
        Self {
            epoch: 0,
            assignment: placement.groups_of_window.clone(),
            health: vec![GroupHealth::Healthy; group_count],
            degraded_reach: false,
        }
    }

    /// Serving groups of a window, healthiest first.
    pub fn serving(&self, window: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self.assignment[window]
            .iter()
            .copied()
            .filter(|&g| self.health[g] != GroupHealth::Failed)
            .collect();
        v.sort_by_key(|&g| match self.health[g] {
            GroupHealth::Healthy => 0,
            GroupHealth::Degraded => 1,
            GroupHealth::Failed => 2,
        });
        v
    }

    /// Mark a group and rebalance: every window must end with >= 1 serving
    /// group.  Windows orphaned by failures are taken over by the
    /// least-loaded surviving groups; a group serving >1 window flips
    /// `degraded_reach` (its combined footprint may exceed TLB reach).
    pub fn set_health(
        &mut self,
        group: usize,
        health: GroupHealth,
        map: &TopologyMap,
    ) -> anyhow::Result<()> {
        if group >= self.health.len() {
            anyhow::bail!("group {group} out of range");
        }
        self.health[group] = health;
        self.epoch += 1;

        // Count load (windows served) per surviving group.
        let mut load: BTreeMap<usize, usize> = BTreeMap::new();
        for g in 0..self.health.len() {
            if self.health[g] != GroupHealth::Failed {
                load.insert(g, 0);
            }
        }
        if load.is_empty() {
            anyhow::bail!("all groups failed");
        }
        for wss in &self.assignment {
            for &g in wss {
                if let Some(l) = load.get_mut(&g) {
                    *l += 1;
                }
            }
        }

        // Re-home orphaned windows.
        for w in 0..self.assignment.len() {
            let alive = self
                .assignment[w]
                .iter()
                .any(|&g| self.health[g] != GroupHealth::Failed);
            if !alive {
                // Prefer healthy, low-load, high-capacity groups.
                let (&best, _) = load
                    .iter()
                    .min_by(|(&ga, &la), (&gb, &lb)| {
                        let ha = self.health[ga] == GroupHealth::Degraded;
                        let hb = self.health[gb] == GroupHealth::Degraded;
                        ha.cmp(&hb)
                            .then(la.cmp(&lb))
                            .then(
                                map.solo_gbps[gb]
                                    .partial_cmp(&map.solo_gbps[ga])
                                    // PANIC: throughputs are finite, never NaN.
                                    .unwrap(),
                            )
                            .then(ga.cmp(&gb))
                    })
                    // PANIC: at least one group survives (checked upstream),
                    // so the load map is non-empty.
                    .unwrap();
                self.assignment[w].push(best);
                // PANIC: `best` was drawn from this map's own keys.
                *load.get_mut(&best).unwrap() += 1;
            }
        }

        // Reach degradation: any surviving group on >1 window?
        let mut per_group = vec![0usize; self.health.len()];
        for (w, wss) in self.assignment.iter().enumerate() {
            let _ = w;
            for &g in wss {
                if self.health[g] != GroupHealth::Failed {
                    per_group[g] += 1;
                }
            }
        }
        self.degraded_reach = per_group.iter().any(|&c| c > 1);
        Ok(())
    }

    /// Do all windows still have a serving group?
    pub fn all_windows_served(&self) -> bool {
        (0..self.assignment.len()).all(|w| !self.serving(w).is_empty())
    }
}

/// Build placement + state in one step (startup path).
pub fn bootstrap(
    policy: PlacementPolicy,
    map: &TopologyMap,
    plan: &WindowPlan,
    seed: u64,
) -> anyhow::Result<(Placement, CoordinatorState)> {
    let placement = Placement::build(policy, map, plan, seed)?;
    let state = CoordinatorState::new(&placement, map.groups.len());
    Ok((placement, state))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map4() -> TopologyMap {
        TopologyMap {
            groups: (0..4).map(|g| vec![g * 2, g * 2 + 1]).collect(),
            reach_bytes: 1 << 30,
            solo_gbps: vec![120.0, 118.0, 90.0, 91.0],
            independent: true,
            card_id: "t".into(),
        }
    }

    fn state2() -> (TopologyMap, CoordinatorState) {
        let map = map4();
        let plan = WindowPlan::split(1 << 16, 128, 2);
        let (_p, st) =
            bootstrap(PlacementPolicy::GroupToChunk, &map, &plan, 0).unwrap();
        (map, st)
    }

    #[test]
    fn bootstrap_serves_all_windows() {
        let (_map, st) = state2();
        assert!(st.all_windows_served());
        assert_eq!(st.epoch, 0);
        assert!(!st.degraded_reach);
    }

    #[test]
    fn failed_group_windows_rehomed() {
        let (map, mut st) = state2();
        // Fail every group on window 0.
        let victims = st.serving(0);
        for g in victims {
            st.set_health(g, GroupHealth::Failed, &map).unwrap();
        }
        assert!(st.all_windows_served(), "window 0 must be re-homed");
        assert!(st.epoch >= 1);
        // The takeover group now serves two windows -> reach degraded.
        assert!(st.degraded_reach);
    }

    #[test]
    fn degraded_groups_sort_last() {
        let (map, mut st) = state2();
        let serving = st.serving(0);
        assert!(serving.len() >= 2, "need 2 groups on window 0");
        let first = serving[0];
        st.set_health(first, GroupHealth::Degraded, &map).unwrap();
        let after = st.serving(0);
        assert_eq!(*after.last().unwrap(), first);
        assert!(!st.degraded_reach, "degraded (not failed) keeps its window");
    }

    #[test]
    fn recovery_clears_priority() {
        let (map, mut st) = state2();
        let g = st.serving(0)[0];
        st.set_health(g, GroupHealth::Failed, &map).unwrap();
        assert!(!st.serving(0).contains(&g));
        st.set_health(g, GroupHealth::Healthy, &map).unwrap();
        assert!(st.serving(0).contains(&g));
    }

    #[test]
    fn all_failed_errors() {
        let (map, mut st) = state2();
        for g in 0..3 {
            st.set_health(g, GroupHealth::Failed, &map).unwrap();
        }
        assert!(st.set_health(3, GroupHealth::Failed, &map).is_err());
    }

    #[test]
    fn epoch_increments_per_change() {
        let (map, mut st) = state2();
        let e0 = st.epoch;
        st.set_health(0, GroupHealth::Degraded, &map).unwrap();
        st.set_health(0, GroupHealth::Healthy, &map).unwrap();
        assert_eq!(st.epoch, e0 + 2);
    }
}

#[cfg(test)]
mod prop_tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn property_random_failures_never_orphan_windows() {
        prop::check("state-failure-injection", 50, |g| {
            let n_groups = g.usize(2, 8);
            let n_windows = g.usize(1, n_groups);
            let map = TopologyMap {
                groups: (0..n_groups).map(|q| vec![q * 2, q * 2 + 1]).collect(),
                reach_bytes: 1 << 30,
                solo_gbps: (0..n_groups).map(|q| 90.0 + q as f64).collect(),
                independent: true,
                card_id: "prop".into(),
            };
            let plan = WindowPlan::split(1 << 16, 128, n_windows);
            let (_p, mut st) =
                bootstrap(PlacementPolicy::GroupToChunk, &map, &plan, g.u64(0, 999)).unwrap();

            // Random health transitions; keep at least one group alive.
            for _ in 0..g.usize(1, 20) {
                let victim = g.usize(0, n_groups - 1);
                let health = *g.pick(&[
                    GroupHealth::Healthy,
                    GroupHealth::Degraded,
                    GroupHealth::Failed,
                ]);
                let alive_after = (0..n_groups)
                    .filter(|&q| {
                        if q == victim {
                            health != GroupHealth::Failed
                        } else {
                            st.health[q] != GroupHealth::Failed
                        }
                    })
                    .count();
                if alive_after == 0 {
                    continue; // would kill the last group; skip
                }
                st.set_health(victim, health, &map).unwrap();
                assert!(st.all_windows_served(), "window orphaned");
                // serving() never returns failed groups.
                for w in 0..n_windows {
                    for &q in &st.serving(w) {
                        assert_ne!(st.health[q], GroupHealth::Failed);
                    }
                }
            }
        });
    }
}

//! The embedding-lookup server: the paper's group-to-chunk placement as a
//! serving system, and the PJRT implementation of the serving facade's
//! [`Backend`] trait.
//!
//! Topology (one process, vLLM-router-like):
//!
//! ```text
//! clients ─submit()─► Ticket   Batcher ──► dispatcher thread ──► per-group worker
//!    ▲                           ▲            (Router::split)        threads
//!    └────────── ticket channel ─┴────── last sub-batch ◄────── PJRT gather
//! ```
//!
//! * Each **worker** owns one SM resource group's execution domain: its own
//!   PJRT client, the compiled gather executables, and the device buffer of
//!   the window shard(s) it serves.  Under `GroupToChunk` that is exactly
//!   one window smaller than TLB reach — the paper's construction.
//! * The **dispatcher** splits every batched request by owning window and
//!   fans sub-batches to the pinned groups.
//! * Sub-batches are padded to the executable's static batch size (XLA
//!   static shapes); padding is dropped before merging.
//!
//! Python never runs here: workers execute AOT artifacts from `artifacts/`.
//!
//! Callers should usually wrap the server in a
//! [`Service`](crate::service::Service) — the front door documented in
//! `service/` — rather than driving it directly; the hermetic sibling is
//! [`SimBackend`](crate::service::SimBackend).

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{anyhow, Context};

use crate::probe::TopologyMap;
use crate::runtime::Runtime;
use crate::service::backend::{
    submit_ticketed, AccPool, Backend, Batch, DataPath, Job, Pipeline, Shells, Ticket, WorkQueue,
    WorkSender, JOB_RING_CAP, SHELL_RING_CAP,
};
use crate::service::ring;
use crate::service::scatter::SlabPool;

use super::batcher::BatcherConfig;
use super::chunks::WindowPlan;
use super::metrics::{Metrics, MetricsSnapshot};
use super::placement::{Placement, PlacementCell, PlacementPolicy, Placer, StaticPlacer};
use super::router::pad_indices;
use super::state::{CoordinatorState, GroupHealth};
use super::table::TableView;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifacts_dir: std::path::PathBuf,
    pub policy: PlacementPolicy,
    pub batcher: BatcherConfig,
    pub seed: u64,
}

impl ServerConfig {
    pub fn new(artifacts_dir: std::path::PathBuf) -> Self {
        Self {
            artifacts_dir,
            policy: PlacementPolicy::GroupToChunk,
            batcher: BatcherConfig::default(),
            seed: 0xC0FFEE,
        }
    }
}

/// The running server.
pub struct EmbeddingServer {
    pipeline: Pipeline,
    metrics: Arc<Metrics>,
    plan: Arc<WindowPlan>,
    view: TableView,
    /// The request pipeline `submit` runs (always the slab path here,
    /// carrying the output pool workers scatter PJRT gather results into;
    /// cached so submit pays no per-request construction).
    path: DataPath,
    placement: Arc<PlacementCell>,
    /// The startup placement: the widest group↔window assignment this
    /// server can honor (each worker uploaded only its startup windows'
    /// shards), so live swaps are validated against it.
    startup: Placement,
    /// The probe map the server was started against (health transitions
    /// re-deal with its capacities).
    map: TopologyMap,
    /// Versioned group-health view; [`set_group_health`] transitions drive
    /// immediate placement swaps (ROADMAP item (a)).
    ///
    /// [`set_group_health`]: EmbeddingServer::set_group_health
    state: Mutex<CoordinatorState>,
}

impl EmbeddingServer {
    /// Start the server: probe map + zero-copy table view in, worker
    /// threads out.
    ///
    /// `plan` must slice the view into windows whose row count matches an
    /// available artifact `n` (XLA static shapes).
    pub fn start(
        cfg: ServerConfig,
        map: &TopologyMap,
        plan: WindowPlan,
        view: TableView,
    ) -> anyhow::Result<Self> {
        if view.rows() != plan.total_rows {
            return Err(anyhow!(
                "table view has {} rows but plan covers {}",
                view.rows(),
                plan.total_rows
            ));
        }
        let placement = StaticPlacer(cfg.policy).place(map, &plan, cfg.seed)?;
        let metrics = Arc::new(Metrics::for_windows(plan.count()));
        let plan = Arc::new(plan);

        // --- workers: one per group that serves at least one window ------
        // Jobs arrive over a bounded SPSC ring; emptied index shells ride
        // a return ring back to the dispatcher's router pool.
        let pool = SlabPool::new();
        let accs = AccPool::new();
        let mut senders: Vec<Option<WorkSender>> = (0..map.groups.len()).map(|_| None).collect();
        let mut shell_returns: Vec<ring::Consumer<Shells>> = Vec::new();
        let mut workers = Vec::new();
        let mut served_by_group: Vec<Vec<usize>> = vec![Vec::new(); map.groups.len()];
        for w in 0..plan.count() {
            for &g in placement.serving_groups(w) {
                served_by_group[g].push(w);
            }
        }
        for (g, served) in served_by_group.iter().enumerate() {
            if served.is_empty() {
                continue;
            }
            let (tx, rx) = ring::spsc::<Job>(JOB_RING_CAP);
            let (shell_tx, shell_rx) = ring::spsc::<Shells>(SHELL_RING_CAP);
            senders[g] = Some(WorkSender::Ring(tx));
            shell_returns.push(shell_rx);
            let worker = WorkerInit {
                group: g,
                windows: served.clone(),
                artifacts_dir: cfg.artifacts_dir.clone(),
                plan: Arc::clone(&plan),
                view: view.clone(),
                metrics: Arc::clone(&metrics),
            };
            // Startup errors must fail `start`, not the thread: hand the
            // result back over a one-shot channel.
            let (ready_tx, ready_rx) = mpsc::sync_channel::<anyhow::Result<()>>(1);
            let handle = std::thread::Builder::new()
                .name(format!("a100win-worker-g{g}"))
                .spawn(move || worker.run(WorkQueue::Ring(rx), shell_tx, ready_tx))
                .context("spawning worker")?;
            ready_rx
                .recv()
                .map_err(|_| anyhow!("worker {g} died during startup"))?
                .with_context(|| format!("worker {g} startup"))?;
            workers.push(handle);
        }

        // --- dispatcher + queue (shared scaffolding) ----------------------
        let cell = Arc::new(PlacementCell::new(Arc::clone(&plan), placement.clone()));
        let pipeline = Pipeline::start(
            cfg.batcher.clone(),
            Arc::clone(&cell),
            Arc::clone(&metrics),
            view.d(),
            senders,
            shell_returns,
            Some(Arc::clone(&accs)),
            workers,
            // No resilience runtime on the PJRT path yet: device-side
            // recovery semantics (re-executing a partially-run HLO gather)
            // need real-hardware validation first.
            None,
        )?;

        let state = CoordinatorState::new(&placement, map.groups.len());
        Ok(Self {
            pipeline,
            metrics,
            plan,
            view,
            path: DataPath::Slab { pool, accs },
            placement: cell,
            startup: placement,
            map: map.clone(),
            state: Mutex::new(state),
        })
    }

    /// Blocking convenience over [`Backend::submit`]: returns the gathered
    /// rows (len = rows.len() * d).  Indices are shared, not cloned.
    pub fn lookup(&self, rows: Arc<Vec<u64>>) -> anyhow::Result<Vec<f32>> {
        Backend::submit(self, Batch::new(rows))?.wait()
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    pub fn plan(&self) -> &WindowPlan {
        &self.plan
    }

    pub fn table_view(&self) -> &TableView {
        &self.view
    }

    /// The current live placement (generation-stamped).
    pub fn placement(&self) -> Arc<Placement> {
        self.placement.load()
    }

    /// Swap the live placement without draining in-flight tickets; the
    /// next formed batch routes under it.  PJRT workers hold only the
    /// window shards they uploaded at startup, so the new placement may
    /// only assign a window to groups that already served it (subsets /
    /// reorders — e.g. dropping a degraded group); anything wider needs a
    /// restart.  Returns the new generation.
    pub fn swap_placement(&self, placement: Placement) -> anyhow::Result<u64> {
        placement
            .check_servable(self.plan.count(), self.startup.window_of_group.len())
            .map_err(|why| anyhow!("placement is unservable: {why}"))?;
        for (w, groups) in placement.groups_of_window.iter().enumerate() {
            for &g in groups {
                if !self.startup.groups_of_window[w].contains(&g) {
                    return Err(anyhow!(
                        "group {g} holds no shard for window {w} (not in the startup placement)"
                    ));
                }
            }
        }
        Ok(self.placement.store(placement))
    }

    /// Report a group health transition and swap the placement
    /// *immediately* — no timer, no drain (ROADMAP item (a)).  Each window
    /// keeps its startup groups minus Failed ones, ordered healthy-first,
    /// so the swap always stays within the shards the workers uploaded.  A
    /// window whose startup groups have *all* failed cannot be served
    /// without re-uploading — that errors (restart required) rather than
    /// silently routing to a group with no shard.  Returns the published
    /// generation.
    pub fn set_group_health(&self, group: usize, health: GroupHealth) -> anyhow::Result<u64> {
        // Build AND publish under the state lock: two concurrent health
        // transitions must publish in the order they updated the health
        // table, or the later (staler) placement could re-include a group
        // the earlier call just failed.  `swap_placement` never takes this
        // lock, so holding it across the publish cannot deadlock.
        let mut st = self.state.lock().unwrap();
        // Pre-validate BEFORE committing the transition: an unservable
        // outcome must leave both the health table and the placement
        // untouched, never a health table that disagrees with what is
        // actually being served.
        let hypothetical = |g: usize| {
            if g == group {
                health
            } else {
                st.health.get(g).copied().unwrap_or(health)
            }
        };
        for (w, startup_groups) in self.startup.groups_of_window.iter().enumerate() {
            if startup_groups
                .iter()
                .all(|&g| hypothetical(g) == GroupHealth::Failed)
            {
                return Err(anyhow!(
                    "every startup group of window {w} would be failed; \
                     restart required to re-upload its shard"
                ));
            }
        }
        st.set_health(group, health, &self.map)?;
        let mut groups_of_window = Vec::with_capacity(self.startup.groups_of_window.len());
        for startup_groups in &self.startup.groups_of_window {
            let mut live: Vec<usize> = startup_groups
                .iter()
                .copied()
                .filter(|&g| st.health[g] != GroupHealth::Failed)
                .collect();
            debug_assert!(!live.is_empty(), "pre-validated above");
            live.sort_by_key(|&g| match st.health[g] {
                GroupHealth::Healthy => 0,
                GroupHealth::Degraded => 1,
                GroupHealth::Failed => 2,
            });
            groups_of_window.push(live);
        }
        let mut window_of_group = self.startup.window_of_group.clone();
        for (w, gs) in groups_of_window.iter().enumerate() {
            for &g in gs {
                window_of_group[g] = w;
            }
        }
        let placement = Placement {
            policy: self.startup.policy,
            generation: 0, // stamped by the cell
            groups_of_window,
            window_of_group,
        };
        self.swap_placement(placement)
    }

    /// The coordinator's versioned health view (epoch per transition,
    /// degraded-reach flag).
    pub fn health_state(&self) -> CoordinatorState {
        self.state.lock().unwrap().clone()
    }

    /// Drain and stop all threads (idempotent; also runs on drop).
    pub fn shutdown(&self) {
        self.pipeline.stop();
    }
}

impl Backend for EmbeddingServer {
    fn submit(&self, batch: Batch) -> anyhow::Result<Ticket> {
        submit_ticketed(
            &self.pipeline.batcher,
            &self.metrics,
            self.view.rows(),
            self.view.d(),
            &self.path,
            false,
            batch,
        )
    }

    fn d(&self) -> usize {
        self.view.d()
    }

    fn rows(&self) -> u64 {
        self.view.rows()
    }

    fn view(&self) -> Option<&TableView> {
        Some(&self.view)
    }

    fn recycle(&self, buf: Vec<f32>) {
        if let DataPath::Slab { pool, .. } = &self.path {
            pool.put(buf);
        }
    }

    fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    fn metrics_handle(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    fn shutdown(&self) {
        EmbeddingServer::shutdown(self);
    }
}

impl Drop for EmbeddingServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Everything a worker thread needs at startup.
struct WorkerInit {
    group: usize,
    windows: Vec<usize>,
    artifacts_dir: std::path::PathBuf,
    plan: Arc<WindowPlan>,
    /// Zero-copy view of the served table; the worker uploads only its
    /// windows' row slices to the device.
    view: TableView,
    metrics: Arc<Metrics>,
}

impl WorkerInit {
    fn run(
        self,
        queue: WorkQueue,
        shells: ring::Producer<Shells>,
        ready: mpsc::SyncSender<anyhow::Result<()>>,
    ) {
        let mut ctx = match self.setup() {
            Ok(ctx) => {
                let _ = ready.send(Ok(()));
                ctx
            }
            Err(e) => {
                let _ = ready.send(Err(e));
                return;
            }
        };
        queue.for_each_job(|job| ctx.execute(job, &shells));
    }

    fn setup(self) -> anyhow::Result<WorkerCtx> {
        let mut rt = Runtime::new(&self.artifacts_dir)?;
        // Pick the lookup artifacts whose table shape matches the window
        // shard shape (static shapes: window rows must equal artifact n).
        let lookups: Vec<(usize, String)> = rt
            .manifest()
            .by_entry("lookup")
            .iter()
            .filter(|a| a.d == self.view.d())
            .map(|a| (a.b, a.name.clone()))
            .collect();
        if lookups.is_empty() {
            return Err(anyhow!("no lookup artifacts for d={}", self.view.d()));
        }
        let n_required = rt
            .manifest()
            .by_entry("lookup")
            .first()
            .map(|a| a.n)
            // PANIC: guarded — the emptiness bail above proves at least one
            // lookup artifact exists in the manifest.
            .unwrap();
        let mut shards = std::collections::HashMap::new();
        for &w in &self.windows {
            let win = self.plan.windows()[w];
            if win.rows != n_required as u64 {
                return Err(anyhow!(
                    "window {w} has {} rows but artifacts were lowered for n={n_required}; \
                     re-run aot.py or resize the table",
                    win.rows
                ));
            }
            let host = self.view.rows_slice(win.start_row, win.rows);
            let buf = rt.upload_f32(host, &[win.rows as usize, self.view.d()])?;
            shards.insert(w, buf);
        }
        for (_b, name) in &lookups {
            rt.ensure_compiled(name)?;
        }
        Ok(WorkerCtx {
            group: self.group,
            rt,
            lookups,
            shards,
            metrics: self.metrics,
            d: self.view.d(),
        })
    }
}

/// Live worker state (owns PJRT handles; never leaves its thread).
struct WorkerCtx {
    #[allow(dead_code)]
    group: usize,
    rt: Runtime,
    /// (batch, artifact name), ascending batch.
    lookups: Vec<(usize, String)>,
    shards: std::collections::HashMap<usize, xla::PjRtBuffer>,
    metrics: Arc<Metrics>,
    d: usize,
}

/// Decompose `len` rows into executable batch sizes minimizing padded
/// slots: greedily take the largest batch that fits, then round the
/// remainder up to the smallest batch that covers it.  With the standard
/// 256/1024/4096 artifact set this at least halves padding vs rounding the
/// whole sub-batch up (EXPERIMENTS.md §Perf iteration 2).
fn plan_batches(len: usize, sizes: &[usize]) -> Vec<usize> {
    debug_assert!(!sizes.is_empty() && sizes.windows(2).all(|w| w[0] < w[1]));
    let mut plan = Vec::new();
    let mut rem = len;
    for &b in sizes.iter().rev() {
        while rem >= b {
            plan.push(b);
            rem -= b;
        }
    }
    if rem > 0 {
        let b = sizes.iter().copied().find(|&b| b >= rem).unwrap_or(sizes[sizes.len() - 1]);
        plan.push(b);
    }
    plan
}

impl WorkerCtx {
    /// Artifact name for an exact batch size.
    fn artifact_for(&self, b: usize) -> &str {
        &self
            .lookups
            .iter()
            .find(|(ab, _)| *ab == b)
            // PANIC: invariant — the planner only chooses batch sizes that
            // exist in this worker's lookup table.
            .expect("plan_batches only emits available sizes")
            .1
    }

    fn execute(&mut self, job: Job, shells: &ring::Producer<Shells>) {
        let result = self.gather_scatter(&job);
        let done = match result {
            Ok(()) => job.acc.finish_part(&self.metrics),
            Err(e) => job.acc.fail_part(&self.metrics, &format!("{e:#}")),
        };
        job.recycle_shells(Some(shells), done);
    }

    /// Gather `job.local_rows` from the job's window shard, decomposed into
    /// padding-minimal executable batches, scattering each executed chunk
    /// *directly* into the request's output buffer — the PJRT readback is
    /// the only host copy left on this path (the old per-job accumulation
    /// `Vec` + second locked copy are gone).
    fn gather_scatter(&mut self, job: &Job) -> anyhow::Result<()> {
        let shard = self
            .shards
            .get(&job.window)
            .ok_or_else(|| anyhow!("group has no shard for window {}", job.window))?;
        let sizes: Vec<usize> = self.lookups.iter().map(|(b, _)| *b).collect();
        let plan = plan_batches(job.local_rows.len(), &sizes);
        let mut cursor = 0usize;
        for b in plan {
            let chunk = &job.local_rows[cursor..job.local_rows.len().min(cursor + b)];
            let positions = &job.positions[cursor..cursor + chunk.len()];
            cursor += chunk.len();
            let name = self.artifact_for(b).to_string();
            let (padded, real) = pad_indices(chunk, b);
            self.metrics
                .padded_rows
                .fetch_add((b - real) as u64, Ordering::Relaxed);
            // NB: execution needs &mut self for the compile cache, but
            // shards are disjoint borrows; clone the name to end the
            // manifest borrow.
            let full = {
                let rt = &mut self.rt;
                let exe_name: &str = &name;
                rt.ensure_compiled(exe_name)?;
                let idx = rt.upload_i32(&padded, &[b])?;
                let outs = rt.execute(exe_name, &[&idx, shard])?;
                outs[0]
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("gather result: {e:?}"))?
            };
            // Padding never leaks: only the real rows are scattered.
            job.acc.scatter(positions, &full[..real * self.d], self.d);
        }
        Ok(())
    }
}

// Integration tests (requiring artifacts) live in
// rust/tests/coordinator_integration.rs and rust/tests/end_to_end.rs; the
// hermetic facade tests (sim backend) in rust/tests/service_facade.rs.

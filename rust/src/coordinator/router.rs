//! Request routing: split a lookup batch by owning window, dispatch to the
//! groups pinned there, and merge results back in request order.
//!
//! Pure logic (no threads, no PJRT) so the invariants are property-testable:
//!
//! * every index is routed to the window that contains it,
//! * every routed index is localized to its window's row space,
//! * the merge restores exactly the request's order,
//! * padding (to the executable's static batch) never leaks into results.

use crate::util::rng::Rng;

use super::chunks::WindowPlan;
use super::placement::Placement;

/// A sub-batch destined for one window.
#[derive(Debug, Clone)]
pub struct SubBatch {
    pub window: usize,
    /// Group chosen to execute this sub-batch.
    pub group: usize,
    /// Window-local row indices.
    pub local_rows: Vec<u32>,
    /// For each entry, its position in the original request.
    pub positions: Vec<u32>,
}

/// Split plan for one request.
#[derive(Debug, Clone)]
pub struct SplitBatch {
    pub sub_batches: Vec<SubBatch>,
    pub request_len: usize,
}

/// Sentinel for "window has no sub-batch yet in this split".
const NO_SLOT: u32 = u32::MAX;

/// Stateless router (the RNG for group load-spreading is caller-owned).
///
/// The per-window scratch (`window_slot`) and a pool of recycled
/// [`SubBatch`] shells persist across [`Router::split`] calls, so the
/// request hot path performs no per-request window-map allocation and —
/// when callers return finished splits via [`Router::recycle`] — no
/// sub-batch allocations either (EXPERIMENTS.md §Perf L3, serving path).
///
/// Neither the plan nor the placement is captured at construction:
/// [`Router::split`] reads both per call, so dispatchers route each formed
/// batch under the current generation of a live
/// [`PlacementCell`](super::placement::PlacementCell) — re-*dealt*
/// placements *and* re-*split* window plans take effect at the next batch
/// with no drain and no router rebuild (the scratch grows on demand when a
/// re-split raises the window count).
#[derive(Debug, Default)]
pub struct Router {
    /// Round-robin cursors per window for group selection.
    cursors: Vec<usize>,
    /// Scratch: window id -> index into the split being built (`NO_SLOT`
    /// when untouched).  Reset lazily after each split by walking only the
    /// touched windows.
    window_slot: Vec<u32>,
    /// Recycled sub-batch shells (emptied, capacity retained).
    pool: Vec<SubBatch>,
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow the per-window scratch to cover `count` windows (no-op once
    /// sized; cursors of shrunk plans keep their history harmlessly).
    fn ensure_windows(&mut self, count: usize) {
        if self.window_slot.len() < count {
            self.window_slot.resize(count, NO_SLOT);
            self.cursors.resize(count, 0);
        }
    }

    /// Split a request's global row indices into per-window sub-batches
    /// under `plan` + `placement` (the placement must cover the plan's
    /// windows).  Each sub-batch is assigned a serving group round-robin
    /// (cheap load spreading; the probed capacities are balanced by
    /// construction).
    pub fn split(&mut self, rows: &[u64], plan: &WindowPlan, placement: &Placement) -> SplitBatch {
        debug_assert_eq!(plan.count(), placement.groups_of_window.len());
        self.ensure_windows(plan.count());
        let mut sub_batches: Vec<SubBatch> = Vec::new();
        for (pos, &row) in rows.iter().enumerate() {
            let w = plan.window_of(row);
            let sb_idx = match self.window_slot[w.id] {
                NO_SLOT => {
                    let serving = placement.serving_groups(w.id);
                    let cursor = &mut self.cursors[w.id];
                    let group = serving[*cursor % serving.len()];
                    *cursor = cursor.wrapping_add(1);
                    let mut sb = self.pool.pop().unwrap_or_else(|| SubBatch {
                        window: 0,
                        group: 0,
                        local_rows: Vec::new(),
                        positions: Vec::new(),
                    });
                    sb.window = w.id;
                    sb.group = group;
                    sub_batches.push(sb);
                    let idx = sub_batches.len() - 1;
                    self.window_slot[w.id] = idx as u32;
                    idx
                }
                i => i as usize,
            };
            sub_batches[sb_idx].local_rows.push(w.localize(row) as u32);
            sub_batches[sb_idx].positions.push(pos as u32);
        }
        // Reset only the touched scratch entries (O(sub-batches), not
        // O(windows)).
        for sb in &sub_batches {
            self.window_slot[sb.window] = NO_SLOT;
        }
        SplitBatch {
            sub_batches,
            request_len: rows.len(),
        }
    }

    /// Return a finished split's sub-batch shells for reuse by later
    /// [`Router::split`] calls.  Purely an optimization — splits that
    /// escape (e.g. into worker jobs) simply aren't recycled.
    pub fn recycle(&mut self, split: SplitBatch) {
        for mut sb in split.sub_batches {
            sb.local_rows.clear();
            sb.positions.clear();
            self.pool.push(sb);
        }
    }

    /// Adopt a pair of emptied (capacity-retaining) index vectors as a
    /// pooled shell.  This is how shells that escaped into worker jobs
    /// come home: workers clear them and send them back over their
    /// return ring; the dispatcher drains the rings into this pool, so at
    /// steady state [`Router::split`] allocates nothing per sub-batch.
    pub fn adopt_shells(&mut self, mut local_rows: Vec<u32>, mut positions: Vec<u32>) {
        local_rows.clear();
        positions.clear();
        self.pool.push(SubBatch {
            window: 0,
            group: 0,
            local_rows,
            positions,
        });
    }
}

/// Pad `local_rows` (i32 cast) up to `batch` entries, repeating index 0.
/// Returns (padded indices, real length).
pub fn pad_indices(local_rows: &[u32], batch: usize) -> (Vec<i32>, usize) {
    assert!(
        local_rows.len() <= batch,
        "sub-batch {} exceeds executable batch {batch}",
        local_rows.len()
    );
    let mut v: Vec<i32> = local_rows.iter().map(|&r| r as i32).collect();
    v.resize(batch, 0);
    (v, local_rows.len())
}

/// Merge per-sub-batch gathered rows (each `d` wide, padding already
/// dropped) back into request order.  `parts[i]` corresponds to
/// `split.sub_batches[i]`.
pub fn merge_rows(split: &SplitBatch, parts: &[Vec<f32>], d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; split.request_len * d];
    for (sb, rows) in split.sub_batches.iter().zip(parts) {
        assert_eq!(
            rows.len(),
            sb.local_rows.len() * d,
            "sub-batch result size mismatch"
        );
        crate::service::backend::scatter_rows(&mut out, &sb.positions, rows, d);
    }
    out
}

/// Generate a random batch of global rows (bench/test helper).
pub fn random_rows(rng: &mut Rng, total_rows: u64, len: usize) -> Vec<u64> {
    (0..len).map(|_| rng.gen_range(total_rows)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::placement::PlacementPolicy;
    use crate::probe::TopologyMap;
    use crate::util::prop;

    fn setup(windows: usize) -> (WindowPlan, Placement) {
        let map = TopologyMap {
            groups: (0..4).map(|g| vec![g * 2, g * 2 + 1]).collect(),
            reach_bytes: 1 << 30,
            solo_gbps: vec![100.0, 100.0, 100.0, 100.0],
            independent: true,
            card_id: "t".into(),
        };
        let plan = WindowPlan::split(10_000, 128, windows);
        let placement =
            Placement::build(PlacementPolicy::GroupToChunk, &map, &plan, 0).unwrap();
        (plan, placement)
    }

    #[test]
    fn split_routes_every_index_to_owning_window() {
        let (plan, placement) = setup(4);
        let mut router = Router::new();
        let rows: Vec<u64> = vec![0, 9_999, 2_500, 5_000, 7_499, 1, 2_500];
        let split = router.split(&rows, &plan, &placement);
        let mut covered = 0;
        for sb in &split.sub_batches {
            let w = &plan.windows()[sb.window];
            for (k, &local) in sb.local_rows.iter().enumerate() {
                let global = w.start_row + local as u64;
                assert_eq!(global, rows[sb.positions[k] as usize]);
                covered += 1;
            }
            // The chosen group must actually serve the window.
            assert!(placement.serving_groups(sb.window).contains(&sb.group));
        }
        assert_eq!(covered, rows.len());
    }

    #[test]
    fn merge_restores_request_order() {
        let (plan, placement) = setup(4);
        let mut router = Router::new();
        let rows: Vec<u64> = vec![42, 9_000, 3, 7_777, 2_500, 42];
        let split = router.split(&rows, &plan, &placement);
        // Fake per-row payload: row value replicated d times.
        let d = 4;
        let parts: Vec<Vec<f32>> = split
            .sub_batches
            .iter()
            .map(|sb| {
                let w = &plan.windows()[sb.window];
                sb.local_rows
                    .iter()
                    .flat_map(|&l| {
                        let g = (w.start_row + l as u64) as f32;
                        std::iter::repeat(g).take(d)
                    })
                    .collect()
            })
            .collect();
        let merged = merge_rows(&split, &parts, d);
        for (i, &row) in rows.iter().enumerate() {
            for j in 0..d {
                assert_eq!(merged[i * d + j], row as f32);
            }
        }
    }

    #[test]
    fn pad_indices_pads_and_reports_len() {
        let (idx, real) = pad_indices(&[5, 6, 7], 8);
        assert_eq!(real, 3);
        assert_eq!(idx, vec![5, 6, 7, 0, 0, 0, 0, 0]);
    }

    #[test]
    #[should_panic(expected = "exceeds executable batch")]
    fn pad_indices_rejects_oversize() {
        pad_indices(&[1, 2, 3], 2);
    }

    #[test]
    fn round_robin_spreads_groups() {
        // One window served by several groups (Naive policy): consecutive
        // splits should rotate through them.
        let map = TopologyMap {
            groups: (0..4).map(|g| vec![g]).collect(),
            reach_bytes: 1 << 30,
            solo_gbps: vec![1.0; 4],
            independent: true,
            card_id: "t".into(),
        };
        let plan = WindowPlan::split(100, 128, 1);
        let placement = Placement::build(PlacementPolicy::Naive, &map, &plan, 0).unwrap();
        let mut router = Router::new();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4 {
            let split = router.split(&[1, 2, 3], &plan, &placement);
            seen.insert(split.sub_batches[0].group);
        }
        assert_eq!(seen.len(), 4, "round robin must cycle all groups");
    }

    #[test]
    fn recycled_splits_reuse_shells_and_stay_correct() {
        let (plan, placement) = setup(4);
        let mut router = Router::new();
        let rows: Vec<u64> = vec![0, 9_999, 2_500, 5_000, 7_499, 1, 2_500];
        let first = router.split(&rows, &plan, &placement);
        let sub_count = first.sub_batches.len();
        router.recycle(first);
        // Subsequent splits must produce identical routing out of the
        // recycled shells (cursors advanced round-robin, data reset).
        for _ in 0..3 {
            let split = router.split(&rows, &plan, &placement);
            assert_eq!(split.sub_batches.len(), sub_count);
            let mut covered = 0;
            for sb in &split.sub_batches {
                let w = &plan.windows()[sb.window];
                for (k, &local) in sb.local_rows.iter().enumerate() {
                    assert_eq!(
                        w.start_row + local as u64,
                        rows[sb.positions[k] as usize]
                    );
                    covered += 1;
                }
            }
            assert_eq!(covered, rows.len());
            router.recycle(split);
        }
    }

    #[test]
    fn split_follows_swapped_placement() {
        // The placement is read per split: handing the router a different
        // placement reroutes the very next call, no rebuild, no drain.
        let (plan, placement) = setup(2);
        let mut router = Router::new();
        let rows: Vec<u64> = vec![1, 2, 9_999];
        let before = router.split(&rows, &plan, &placement);
        for sb in &before.sub_batches {
            assert!(placement.serving_groups(sb.window).contains(&sb.group));
        }
        // Swap: reverse which groups serve which window.
        let swapped = Placement {
            policy: placement.policy,
            generation: placement.generation + 1,
            groups_of_window: placement.groups_of_window.iter().rev().cloned().collect(),
            window_of_group: placement
                .window_of_group
                .iter()
                .map(|&w| 1 - w)
                .collect(),
        };
        let after = router.split(&rows, &plan, &swapped);
        for sb in &after.sub_batches {
            assert!(swapped.serving_groups(sb.window).contains(&sb.group));
        }
    }

    #[test]
    fn property_split_merge_identity() {
        prop::check("split-merge-identity", 50, |g| {
            let windows = g.usize(1, 4);
            let (plan, placement) = setup(windows);
            let mut router = Router::new();
            let len = g.usize(1, 300);
            let rows: Vec<u64> = (0..len).map(|_| g.u64(0, 9_999)).collect();
            let split = router.split(&rows, &plan, &placement);

            // Sub-batch sizes sum to the request.
            let total: usize = split.sub_batches.iter().map(|s| s.local_rows.len()).sum();
            assert_eq!(total, len);

            // Identity payload merge reproduces the request.
            let d = 2;
            let parts: Vec<Vec<f32>> = split
                .sub_batches
                .iter()
                .map(|sb| {
                    let w = &plan.windows()[sb.window];
                    sb.local_rows
                        .iter()
                        .flat_map(|&l| {
                            let v = (w.start_row + l as u64) as f32;
                            [v, v]
                        })
                        .collect()
                })
                .collect();
            let merged = merge_rows(&split, &parts, d);
            for (i, &row) in rows.iter().enumerate() {
                assert_eq!(merged[i * d], row as f32, "position {i}");
            }

            // No duplicate positions.
            let mut pos: Vec<u32> = split
                .sub_batches
                .iter()
                .flat_map(|s| s.positions.iter().copied())
                .collect();
            pos.sort_unstable();
            pos.dedup();
            assert_eq!(pos.len(), len);
        });
    }
}

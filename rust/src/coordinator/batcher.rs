//! Dynamic batching: accumulate lookup requests until a size or deadline
//! trigger, then emit one batch (vLLM-router-style continuous batching,
//! scoped to the lookup workload).
//!
//! Thread-safe: producers call [`Batcher::submit`], the serving loop calls
//! [`Batcher::next_batch`].  Backpressure: a bounded queue; `submit` blocks
//! when `max_pending` requests are waiting (tests cover the non-blocking
//! `try_submit` too).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One enqueued request: shared global row indices + an opaque ticket the
/// server uses to respond + an optional completion deadline the dispatcher
/// may cull on.  Rows travel by `Arc` so enqueueing never copies indices.
#[derive(Debug)]
pub struct PendingRequest<T> {
    pub rows: Arc<Vec<u64>>,
    pub ticket: T,
    pub enqueued: Instant,
    pub deadline: Option<Instant>,
}

/// A formed batch.
#[derive(Debug)]
pub struct Batch<T> {
    pub requests: Vec<PendingRequest<T>>,
}

impl<T> Batch<T> {
    pub fn total_rows(&self) -> usize {
        self.requests.iter().map(|r| r.rows.len()).sum()
    }
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Emit when this many rows are pending...
    pub max_batch_rows: usize,
    /// ...or when the oldest request has waited this long.
    pub max_wait: Duration,
    /// Bound on queued requests (backpressure).
    pub max_pending: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch_rows: 4096,
            max_wait: Duration::from_millis(2),
            max_pending: 1024,
        }
    }
}

/// Outcome of [`Batcher::next_batch_or_timeout`].
#[derive(Debug)]
pub enum BatchWait<T> {
    Batch(Batch<T>),
    /// `max_idle` elapsed with no batch ready.
    TimedOut,
    /// Closed and drained (terminal, like `next_batch() -> None`).
    Closed,
}

struct State<T> {
    queue: VecDeque<PendingRequest<T>>,
    closed: bool,
}

/// The batching queue.
pub struct Batcher<T> {
    cfg: BatcherConfig,
    state: Mutex<State<T>>,
    /// Rows queued across pending requests, mirrored outside the lock so
    /// the control plane's epoch sampling ([`Batcher::pending_rows`])
    /// never contends with submitters on the queue mutex.
    pending_rows: AtomicUsize,
    /// Signals consumers (batch ready / closed) and producers (space freed).
    cv: Condvar,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch_rows > 0 && cfg.max_pending > 0);
        Self {
            cfg,
            state: Mutex::new(State {
                queue: VecDeque::new(),
                closed: false,
            }),
            pending_rows: AtomicUsize::new(0),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request, blocking while the queue is full.  Returns Err if
    /// the batcher is closed.
    pub fn submit(
        &self,
        rows: Arc<Vec<u64>>,
        deadline: Option<Instant>,
        ticket: T,
    ) -> Result<(), T> {
        let mut st = self.state.lock().unwrap();
        while st.queue.len() >= self.cfg.max_pending && !st.closed {
            st = self.cv.wait(st).unwrap();
        }
        if st.closed {
            return Err(ticket);
        }
        self.pending_rows.fetch_add(rows.len(), Ordering::Relaxed);
        st.queue.push_back(PendingRequest {
            rows,
            ticket,
            enqueued: Instant::now(),
            deadline,
        });
        self.cv.notify_all();
        Ok(())
    }

    /// Non-blocking submit; Err(ticket) when full or closed.
    pub fn try_submit(
        &self,
        rows: Arc<Vec<u64>>,
        deadline: Option<Instant>,
        ticket: T,
    ) -> Result<(), T> {
        let mut st = self.state.lock().unwrap();
        if st.closed || st.queue.len() >= self.cfg.max_pending {
            return Err(ticket);
        }
        self.pending_rows.fetch_add(rows.len(), Ordering::Relaxed);
        st.queue.push_back(PendingRequest {
            rows,
            ticket,
            enqueued: Instant::now(),
            deadline,
        });
        self.cv.notify_all();
        Ok(())
    }

    /// Block until a batch is ready (size or deadline trigger) or the
    /// batcher is closed and drained.  Returns None on closed+empty.
    pub fn next_batch(&self) -> Option<Batch<T>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.queue.is_empty() {
                // PANIC: guarded by the emptiness check on the line above.
                let oldest_wait = st.queue.front().unwrap().enqueued.elapsed();
                if self.pending_rows.load(Ordering::Relaxed) >= self.cfg.max_batch_rows
                    || oldest_wait >= self.cfg.max_wait
                    || st.closed
                {
                    return Some(self.drain_batch(&mut st));
                }
                // Wait out the remaining deadline (or a new submit).
                let remaining = self.cfg.max_wait - oldest_wait;
                let (guard, _timeout) = self.cv.wait_timeout(st, remaining).unwrap();
                st = guard;
            } else if st.closed {
                return None;
            } else {
                st = self.cv.wait(st).unwrap();
            }
        }
    }

    /// Like [`next_batch`](Self::next_batch), but give up after `max_idle`
    /// without a formed batch — for dispatch loops that interleave other
    /// work (retry/hedge re-dispatch) with batch formation and cannot park
    /// indefinitely.
    pub fn next_batch_or_timeout(&self, max_idle: Duration) -> BatchWait<T> {
        let idle_deadline = Instant::now() + max_idle;
        let mut st = self.state.lock().unwrap();
        loop {
            let now = Instant::now();
            if !st.queue.is_empty() {
                // PANIC: guarded by the emptiness check on the line above.
                let oldest_wait = st.queue.front().unwrap().enqueued.elapsed();
                if self.pending_rows.load(Ordering::Relaxed) >= self.cfg.max_batch_rows
                    || oldest_wait >= self.cfg.max_wait
                    || st.closed
                {
                    return BatchWait::Batch(self.drain_batch(&mut st));
                }
                if now >= idle_deadline {
                    return BatchWait::TimedOut;
                }
                let remaining = (self.cfg.max_wait - oldest_wait).min(idle_deadline - now);
                let (guard, _timeout) = self.cv.wait_timeout(st, remaining).unwrap();
                st = guard;
            } else if st.closed {
                return BatchWait::Closed;
            } else if now >= idle_deadline {
                return BatchWait::TimedOut;
            } else {
                let (guard, _timeout) = self.cv.wait_timeout(st, idle_deadline - now).unwrap();
                st = guard;
            }
        }
    }

    fn drain_batch(&self, st: &mut State<T>) -> Batch<T> {
        let mut requests = Vec::new();
        let mut rows = 0usize;
        while let Some(front) = st.queue.front() {
            let next = front.rows.len();
            // Always take at least one request; stop before exceeding the
            // cap (oversized single requests still pass through whole).
            if !requests.is_empty() && rows + next > self.cfg.max_batch_rows {
                break;
            }
            rows += next;
            // PANIC: the `while let Some(front)` peek proved non-empty.
            let req = st.queue.pop_front().unwrap();
            requests.push(req);
            if rows >= self.cfg.max_batch_rows {
                break;
            }
        }
        self.pending_rows.fetch_sub(rows, Ordering::Relaxed);
        self.cv.notify_all(); // wake blocked producers
        Batch { requests }
    }

    /// Close: further submits fail; queued requests still drain.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.cv.notify_all();
    }

    pub fn pending(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    /// Rows queued across all pending requests — the queue-depth signal the
    /// adaptive placer samples at epoch boundaries.  Lock-free: epoch
    /// sampling must not contend with submitters.
    pub fn pending_rows(&self) -> usize {
        self.pending_rows.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn cfg(rows: usize, wait_ms: u64, pending: usize) -> BatcherConfig {
        BatcherConfig {
            max_batch_rows: rows,
            max_wait: Duration::from_millis(wait_ms),
            max_pending: pending,
        }
    }

    fn rows(v: Vec<u64>) -> Arc<Vec<u64>> {
        Arc::new(v)
    }

    #[test]
    fn size_trigger_forms_batch() {
        let b: Batcher<u32> = Batcher::new(cfg(8, 10_000, 100));
        b.submit(rows(vec![1, 2, 3, 4]), None, 0).unwrap();
        b.submit(rows(vec![5, 6, 7, 8]), None, 1).unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(batch.total_rows(), 8);
    }

    #[test]
    fn deadline_trigger_fires_for_small_batch() {
        let b: Batcher<u32> = Batcher::new(cfg(1_000_000, 5, 100));
        b.submit(rows(vec![1, 2]), None, 7).unwrap();
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert!(t.elapsed() >= Duration::from_millis(4));
        assert!(t.elapsed() < Duration::from_secs(2));
    }

    #[test]
    fn batch_respects_row_cap() {
        let b: Batcher<u32> = Batcher::new(cfg(6, 10_000, 100));
        for i in 0..4 {
            b.submit(rows(vec![0, 1, 2]), None, i).unwrap(); // 3 rows each
        }
        let batch = b.next_batch().unwrap();
        // 3+3=6 hits the cap exactly; third request stays queued.
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(b.pending(), 2);
    }

    #[test]
    fn oversized_request_passes_whole() {
        let b: Batcher<u32> = Batcher::new(cfg(4, 10_000, 100));
        b.submit(rows((0..10).collect()), None, 0).unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.total_rows(), 10);
    }

    #[test]
    fn pending_rows_tracks_queue() {
        let b: Batcher<u32> = Batcher::new(cfg(4, 10_000, 100));
        assert_eq!(b.pending_rows(), 0);
        b.submit(rows(vec![1, 2, 3]), None, 0).unwrap();
        b.submit(rows(vec![4]), None, 1).unwrap();
        assert_eq!(b.pending_rows(), 4);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.total_rows(), 4);
        assert_eq!(b.pending_rows(), 0);
    }

    #[test]
    fn close_drains_then_none() {
        let b: Batcher<u32> = Batcher::new(cfg(1_000, 10_000, 100));
        b.submit(rows(vec![1]), None, 0).unwrap();
        b.close();
        assert!(b.submit(rows(vec![2]), None, 1).is_err());
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn try_submit_backpressure() {
        let b: Batcher<u32> = Batcher::new(cfg(1_000, 10_000, 2));
        assert!(b.try_submit(rows(vec![1]), None, 0).is_ok());
        assert!(b.try_submit(rows(vec![2]), None, 1).is_ok());
        assert!(b.try_submit(rows(vec![3]), None, 2).is_err()); // full
    }

    #[test]
    fn deadline_rides_along() {
        let b: Batcher<u32> = Batcher::new(cfg(8, 10_000, 100));
        let dl = Instant::now() + Duration::from_secs(5);
        b.submit(rows(vec![1]), Some(dl), 0).unwrap();
        b.submit(rows(vec![2, 3, 4, 5, 6, 7, 8]), None, 1).unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests[0].deadline, Some(dl));
        assert_eq!(batch.requests[1].deadline, None);
    }

    #[test]
    fn timeout_variant_times_out_batches_and_closes() {
        let b: Batcher<u32> = Batcher::new(cfg(8, 10_000, 100));
        // Empty queue: times out after max_idle.
        let t = Instant::now();
        assert!(matches!(
            b.next_batch_or_timeout(Duration::from_millis(5)),
            BatchWait::TimedOut
        ));
        assert!(t.elapsed() >= Duration::from_millis(4));
        // A ready batch (size trigger) is returned immediately.
        b.submit(rows(vec![1, 2, 3, 4]), None, 0).unwrap();
        b.submit(rows(vec![5, 6, 7, 8]), None, 1).unwrap();
        match b.next_batch_or_timeout(Duration::from_millis(5)) {
            BatchWait::Batch(batch) => assert_eq!(batch.total_rows(), 8),
            other => panic!("expected batch, got {other:?}"),
        }
        // A pending-but-untriggered request times out without draining...
        b.submit(rows(vec![9]), None, 2).unwrap();
        assert!(matches!(
            b.next_batch_or_timeout(Duration::from_millis(2)),
            BatchWait::TimedOut
        ));
        assert_eq!(b.pending(), 1);
        // ...then drains on close, and the variant reports Closed after.
        b.close();
        assert!(matches!(
            b.next_batch_or_timeout(Duration::from_millis(2)),
            BatchWait::Batch(_)
        ));
        assert!(matches!(
            b.next_batch_or_timeout(Duration::from_millis(2)),
            BatchWait::Closed
        ));
    }

    #[test]
    fn producer_consumer_threads() {
        let b: Arc<Batcher<usize>> = Arc::new(Batcher::new(cfg(64, 1, 16)));
        let n_requests = 200;
        let producer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                for i in 0..n_requests {
                    b.submit(rows(vec![i as u64; 4]), None, i).unwrap();
                }
                b.close();
            })
        };
        let mut seen = Vec::new();
        while let Some(batch) = b.next_batch() {
            for r in batch.requests {
                seen.push(r.ticket);
            }
        }
        producer.join().unwrap();
        seen.sort_unstable();
        assert_eq!(seen, (0..n_requests).collect::<Vec<_>>());
    }
}

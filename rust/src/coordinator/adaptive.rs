//! Skew-aware placement: rebalance the group↔window assignment from
//! observed per-window load, epoch by epoch.
//!
//! The paper pins each SM resource group to one ≤reach window and shows
//! that restores full-speed random access — but a *static* pin sizes each
//! window's serving capacity for uniform traffic.  Under zipfian or
//! hot-spot skew a hot window's groups saturate while cold windows idle.
//! [`AdaptivePlacer`] keeps the paper's invariant (every group serves
//! exactly one ≤reach window, every window covered) and re-deals groups so
//! each window's share of probed capacity tracks its share of observed
//! load: hot windows earn more groups.  Cf. TileLens (arXiv 2607.04031) on
//! transparent layout adaptation over large-granularity memory.
//!
//! Deterministic: same signals + capacities → same placement, so the
//! rebalance path is property-testable (`property_rebalance_keeps_invariant`).

use std::time::Duration;

use crate::probe::TopologyMap;

use super::chunks::WindowPlan;
use super::placement::{Placement, PlacementPolicy, Placer, WindowSignals};

/// Tuning for [`AdaptivePlacer`] and the backend's rebalance driver.
#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    /// Background rebalance period for backends that drive their own
    /// epochs; `None` = epochs are ticked manually
    /// (e.g. [`SimBackend::rebalance_epoch`](crate::service::SimBackend::rebalance_epoch)).
    pub epoch: Option<Duration>,
    /// Hysteresis: minimum |load share − capacity share| on some window
    /// before a swap is proposed (keeps uniform traffic at generation 0).
    /// Queue backlog ([`WindowSignals`](super::placement::WindowSignals)
    /// `queued_rows`) tightens the effective threshold down to half.
    pub min_imbalance: f64,
    /// Minimum rows observed in an epoch before rebalancing (starvation of
    /// signal must not cause thrashing swaps).
    pub min_epoch_rows: u64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        Self {
            epoch: None,
            min_imbalance: 0.10,
            min_epoch_rows: 256,
        }
    }
}

/// The skew-aware [`Placer`]: starts from the paper's group-to-chunk deal,
/// then re-deals groups to windows proportionally to observed load.
#[derive(Debug, Clone, Default)]
pub struct AdaptivePlacer {
    pub cfg: AdaptiveConfig,
}

impl AdaptivePlacer {
    pub fn new(cfg: AdaptiveConfig) -> Self {
        Self { cfg }
    }

    /// Greedy capacity-proportional deal: groups (fastest first) go to the
    /// window with the largest remaining capacity deficit against its load
    /// target; empty windows then steal the slowest group from the most
    /// over-provisioned multi-group window so coverage always holds.
    ///
    /// Shared with the window re-splitter
    /// ([`PlanSplitter`](super::replan::PlanSplitter)): re-split plans deal
    /// groups over their new windows with exactly this logic, so re-deal
    /// and re-split produce placements with identical balancing semantics.
    pub(crate) fn deal(map: &TopologyMap, load_share: &[f64]) -> (Vec<Vec<usize>>, Vec<usize>) {
        let w = load_share.len();
        let g = map.groups.len();
        debug_assert!(g >= w);
        let total_cap: f64 = map.solo_gbps.iter().sum();
        let target: Vec<f64> = load_share.iter().map(|s| s * total_cap).collect();

        let mut order: Vec<usize> = (0..g).collect();
        order.sort_by(|&a, &b| {
            map.solo_gbps[b]
                .partial_cmp(&map.solo_gbps[a])
                // PANIC: probed throughputs are finite, never NaN.
                .unwrap()
                .then(a.cmp(&b))
        });

        let mut groups_of_window = vec![Vec::new(); w];
        let mut assigned = vec![0.0f64; w];
        let mut window_of_group = vec![0usize; g];
        for &gi in &order {
            let wid = (0..w)
                .max_by(|&a, &b| {
                    (target[a] - assigned[a])
                        .partial_cmp(&(target[b] - assigned[b]))
                        // PANIC: targets and assignments are finite sums.
                        .unwrap()
                        .then(b.cmp(&a)) // ties: lower window id wins
                })
                // PANIC: w >= 1, so the candidate range is non-empty.
                .unwrap();
            groups_of_window[wid].push(gi);
            assigned[wid] += map.solo_gbps[gi];
            window_of_group[gi] = wid;
        }

        // Coverage fix-up: a cold window may have been starved entirely.
        while let Some(empty) = groups_of_window.iter().position(Vec::is_empty) {
            let donor = (0..w)
                .filter(|&i| groups_of_window[i].len() > 1)
                .max_by(|&a, &b| {
                    (assigned[a] - target[a])
                        .partial_cmp(&(assigned[b] - target[b]))
                        // PANIC: targets and assignments are finite sums.
                        .unwrap()
                        .then(b.cmp(&a))
                })
                // PANIC: invariant — with g >= w, some window holds >1 group
                // whenever another is empty.
                .expect("g >= w guarantees a multi-group donor");
            // Move the donor's slowest group.
            let k = (0..groups_of_window[donor].len())
                .min_by(|&a, &b| {
                    let ga = groups_of_window[donor][a];
                    let gb = groups_of_window[donor][b];
                    map.solo_gbps[ga]
                        .partial_cmp(&map.solo_gbps[gb])
                        // PANIC: probed throughputs are finite, never NaN.
                        .unwrap()
                        .then(ga.cmp(&gb))
                })
                // PANIC: the donor was selected for holding >1 group.
                .unwrap();
            let moved = groups_of_window[donor].remove(k);
            assigned[donor] -= map.solo_gbps[moved];
            groups_of_window[empty].push(moved);
            assigned[empty] += map.solo_gbps[moved];
            window_of_group[moved] = empty;
        }
        (groups_of_window, window_of_group)
    }
}

impl Placer for AdaptivePlacer {
    fn name(&self) -> &'static str {
        "adaptive"
    }

    /// Initial placement: the paper's static group-to-chunk deal (uniform
    /// prior — no load observed yet).
    fn place(&self, map: &TopologyMap, plan: &WindowPlan, seed: u64) -> anyhow::Result<Placement> {
        Placement::build(PlacementPolicy::GroupToChunk, map, plan, seed)
    }

    fn rebalance(
        &self,
        current: &Placement,
        map: &TopologyMap,
        plan: &WindowPlan,
        signals: &WindowSignals,
    ) -> Option<Placement> {
        let w = plan.count();
        let total = signals.total_rows();
        // `total == 0` guards division even when `min_epoch_rows` is 0.
        if signals.rows.len() != w
            || total == 0
            || total < self.cfg.min_epoch_rows
            || map.groups.len() < w
        {
            return None;
        }
        let load_share: Vec<f64> = signals
            .rows
            .iter()
            .map(|&r| r as f64 / total as f64)
            .collect();

        // Hysteresis against the *current* capacity shares.  Queue
        // pressure (batcher depth vs the epoch's served rows) tightens the
        // threshold down to half: when requests are backing up, a smaller
        // mismatch is worth correcting; an unpressured system leaves the
        // placement alone at the same mismatch.
        let total_cap: f64 = map.solo_gbps.iter().sum();
        let imbalance = (0..w)
            .map(|wid| {
                let cap: f64 = current.groups_of_window[wid]
                    .iter()
                    .map(|&g| map.solo_gbps[g])
                    .sum();
                (load_share[wid] - cap / total_cap).abs()
            })
            .fold(0.0f64, f64::max);
        let pressure = (signals.queued_rows as f64 / total as f64).min(1.0);
        if imbalance < self.cfg.min_imbalance * (1.0 - 0.5 * pressure) {
            return None;
        }

        let (groups_of_window, window_of_group) = Self::deal(map, &load_share);
        if groups_of_window == current.groups_of_window {
            return None;
        }
        Some(Placement {
            policy: PlacementPolicy::GroupToChunk,
            generation: current.generation, // stamped by PlacementCell::store
            groups_of_window,
            window_of_group,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map(solo: &[f64]) -> TopologyMap {
        TopologyMap {
            groups: (0..solo.len()).map(|g| vec![g * 2, g * 2 + 1]).collect(),
            reach_bytes: 1 << 30,
            solo_gbps: solo.to_vec(),
            independent: true,
            card_id: "adaptive-test".into(),
        }
    }

    fn signals(rows: &[u64]) -> WindowSignals {
        WindowSignals {
            rows: rows.to_vec(),
            ..Default::default()
        }
    }

    fn start(map: &TopologyMap, plan: &WindowPlan) -> Placement {
        AdaptivePlacer::default().place(map, plan, 0).unwrap()
    }

    #[test]
    fn hot_window_earns_more_groups() {
        let m = map(&[100.0; 4]);
        let plan = WindowPlan::split(1 << 16, 128, 2);
        let current = start(&m, &plan);
        assert_eq!(current.groups_of_window[0].len(), 2);
        let next = AdaptivePlacer::default()
            .rebalance(&current, &m, &plan, &signals(&[9_000, 1_000]))
            .expect("skew must trigger a swap");
        assert_eq!(next.groups_of_window[0].len(), 3, "{next:?}");
        assert_eq!(next.groups_of_window[1].len(), 1);
        assert_eq!(next.check_windowed_invariant(&m, &plan), Ok(()));
    }

    #[test]
    fn uniform_load_keeps_current_placement() {
        let m = map(&[100.0; 4]);
        let plan = WindowPlan::split(1 << 16, 128, 2);
        let current = start(&m, &plan);
        assert!(AdaptivePlacer::default()
            .rebalance(&current, &m, &plan, &signals(&[5_050, 4_950]))
            .is_none());
    }

    #[test]
    fn starved_epoch_never_swaps() {
        let m = map(&[100.0; 4]);
        let plan = WindowPlan::split(1 << 16, 128, 2);
        let current = start(&m, &plan);
        let placer = AdaptivePlacer::default();
        assert!(placer.rebalance(&current, &m, &plan, &signals(&[10, 0])).is_none());
        assert!(placer.rebalance(&current, &m, &plan, &signals(&[0, 0])).is_none());
    }

    #[test]
    fn queue_pressure_tightens_hysteresis() {
        // Unequal groups: w0={g0,g2} holds 220/400 = 0.55 of capacity.
        // A 0.47/0.53 load is a 0.08 mismatch — inside the idle threshold
        // (0.10), outside the fully-pressured one (0.05).
        let m = map(&[120.0, 100.0, 100.0, 80.0]);
        let plan = WindowPlan::split(1 << 16, 128, 2);
        let current = start(&m, &plan);
        let placer = AdaptivePlacer::default();
        let idle = WindowSignals {
            rows: vec![4_700, 5_300],
            ..Default::default()
        };
        assert!(placer.rebalance(&current, &m, &plan, &idle).is_none());
        let pressured = WindowSignals {
            queued_rows: 10_000,
            ..idle
        };
        let next = placer
            .rebalance(&current, &m, &plan, &pressured)
            .expect("backlog must lower the swap threshold");
        assert_eq!(next.check_windowed_invariant(&m, &plan), Ok(()));
    }

    #[test]
    fn cold_windows_keep_one_group() {
        // Extreme skew: all load on window 0 — windows 1 and 2 must still
        // be covered (the table must stay servable everywhere).
        let m = map(&[120.0, 110.0, 100.0, 90.0, 80.0]);
        let plan = WindowPlan::split(1 << 16, 128, 3);
        let current = start(&m, &plan);
        let next = AdaptivePlacer::default()
            .rebalance(&current, &m, &plan, &signals(&[10_000, 0, 0]))
            .expect("skew must trigger a swap");
        assert_eq!(next.check_windowed_invariant(&m, &plan), Ok(()));
        for wid in 1..3 {
            assert_eq!(next.groups_of_window[wid].len(), 1, "{next:?}");
        }
        assert_eq!(next.groups_of_window[0].len(), 3);
    }

    #[test]
    fn rebalance_is_deterministic() {
        let m = map(&[100.0, 99.0, 98.0, 97.0]);
        let plan = WindowPlan::split(1 << 16, 128, 2);
        let current = start(&m, &plan);
        let placer = AdaptivePlacer::default();
        let s = signals(&[8_000, 2_000]);
        let a = placer.rebalance(&current, &m, &plan, &s).unwrap();
        let b = placer.rebalance(&current, &m, &plan, &s).unwrap();
        assert_eq!(a.groups_of_window, b.groups_of_window);
        assert_eq!(a.window_of_group, b.window_of_group);
    }
}

#[cfg(test)]
mod prop_tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn property_rebalance_keeps_invariant() {
        prop::check("adaptive-invariant", 80, |g| {
            let n_windows = g.usize(1, 6);
            let n_groups = g.usize(n_windows, 14);
            let map = TopologyMap {
                groups: (0..n_groups).map(|q| vec![q * 2, q * 2 + 1]).collect(),
                reach_bytes: 1 << 30,
                solo_gbps: (0..n_groups).map(|_| g.f64(60.0, 140.0)).collect(),
                independent: true,
                card_id: "prop".into(),
            };
            // Windows sized well under reach so fits_reach holds.
            let plan = WindowPlan::split(1 << 16, 128, n_windows);
            let placer = AdaptivePlacer::default();
            let mut current = placer.place(&map, &plan, g.u64(0, 99)).unwrap();
            assert_eq!(current.check_windowed_invariant(&map, &plan), Ok(()));

            // A run of epochs with arbitrary (possibly degenerate) loads:
            // the invariant must hold after every accepted swap.
            for _ in 0..g.usize(1, 8) {
                let rows: Vec<u64> =
                    (0..n_windows).map(|_| g.u64(0, 50_000)).collect();
                let sig = WindowSignals {
                    rows,
                    ..Default::default()
                };
                if let Some(next) = placer.rebalance(&current, &map, &plan, &sig) {
                    assert_eq!(
                        next.check_windowed_invariant(&map, &plan),
                        Ok(()),
                        "signals {sig:?}"
                    );
                    current = next;
                }
            }
        });
    }
}

//! Serving metrics: lock-free counters + log-bucketed latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log2-bucketed latency histogram, 1 µs .. ~1 s.
const BUCKETS: usize = 22;

/// Recording shards: every group worker records a latency per completed
/// request, so a single counter line would be the one cache line the whole
/// fleet of workers fights over.  Threads hash to a shard
/// (thread-local, assigned round-robin) and record with relaxed adds;
/// readers sum the shards (acquire loads, so a snapshot observes every
/// count recorded before it).
const SHARDS: usize = 8;

/// Cache-line aligned so adjacent shards never share a boundary line —
/// otherwise neighboring threads would still bounce one line per record
/// and partially undo the sharding.
#[derive(Debug, Default)]
#[repr(align(64))]
struct LatencyShard {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

#[derive(Debug, Default)]
pub struct LatencyHistogram {
    shards: [LatencyShard; SHARDS],
}

/// This thread's shard index (round-robin at first use).
fn shard_index() -> usize {
    use std::sync::atomic::AtomicUsize;
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SHARD: usize = NEXT.fetch_add(1, Ordering::Relaxed) % SHARDS;
    }
    SHARD.with(|s| *s)
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn bucket_of(us: u64) -> usize {
        (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1)
    }

    pub fn record(&self, latency: Duration) {
        let us = latency.as_micros() as u64;
        let shard = &self.shards[shard_index()];
        // Count is added LAST with Release: a snapshot that acquires a
        // count has the matching bucket/sum/max contributions too.
        shard.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        shard.sum_us.fetch_add(us, Ordering::Relaxed);
        shard.max_us.fetch_max(us, Ordering::Relaxed);
        shard.count.fetch_add(1, Ordering::Release);
    }

    pub fn count(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.count.load(Ordering::Acquire))
            .sum()
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        let sum: u64 = self
            .shards
            .iter()
            .map(|s| s.sum_us.load(Ordering::Relaxed))
            .sum();
        sum as f64 / c as f64
    }

    pub fn max_us(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.max_us.load(Ordering::Relaxed))
            .max()
            .unwrap_or(0)
    }

    /// Approximate quantile from the log buckets (upper bucket edge).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let want = ((total as f64) * q).ceil() as u64;
        let mut acc = 0;
        for i in 0..BUCKETS {
            for s in &self.shards {
                acc += s.buckets[i].load(Ordering::Relaxed);
            }
            if acc >= want {
                return 1u64 << (i + 1);
            }
        }
        self.max_us()
    }
}

/// Aggregate serving metrics.  One registry per backend; the service
/// facade, sessions, and tickets all record into the backend's registry so
/// admission-control outcomes (`admission_rejected` / `throttled`) and
/// deadline expiries (`expired`) show up next to the serving counters.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub rows: AtomicU64,
    pub batches: AtomicU64,
    pub padded_rows: AtomicU64,
    pub errors: AtomicU64,
    /// Requests refused for invalid input (row index out of table).
    pub rejected: AtomicU64,
    /// Requests shed by session admission control (over the in-flight
    /// budget under the Reject overload policy) — kept separate from
    /// `rejected` so overload is distinguishable from client bugs.
    pub admission_rejected: AtomicU64,
    /// Tickets whose deadline passed before the result arrived (counted at
    /// `Ticket::wait` timeout or dispatcher-side culling).
    pub expired: AtomicU64,
    /// Session submissions that blocked on the in-flight budget (Queue
    /// overload policy).
    pub throttled: AtomicU64,
    /// Submissions denied by the cross-tenant
    /// [`GlobalAdmission`](crate::service::GlobalAdmission) budget (always
    /// also counted in `admission_rejected`; kept separate so per-tenant
    /// overload is distinguishable from fleet-wide overload).
    pub global_rejected: AtomicU64,
    /// Rows routed per window (index = window id; the adaptive placer's
    /// load signal).  Sized by [`Metrics::for_windows`]; empty when the
    /// owner tracks no placement.  Sized to the *maximum* window count
    /// (one per SM group): a re-split may raise the live plan's count.
    pub window_rows: Vec<AtomicU64>,
    /// Control-plane epochs that re-*dealt* groups under fixed window
    /// boundaries (the cheapest repartitioning lever).
    pub redeal_epochs: AtomicU64,
    /// Control-plane epochs that re-*split* the window boundaries.
    pub resplit_epochs: AtomicU64,
    /// Control-plane epochs that migrated row ranges across cards (fleet
    /// registries only).
    pub migrate_epochs: AtomicU64,
    /// Rows whose owning card changed across all migrations (zero-copy:
    /// view re-slices, never data copies).
    pub rows_migrated: AtomicU64,
    /// Plan/placement generations published by the control plane (every
    /// redeal, resplit, or migration bumps exactly one generation).
    pub generations_published: AtomicU64,
    /// Sub-batches re-dispatched by the resilience layer after a failure
    /// (each retry attempt counts once).
    pub retries: AtomicU64,
    /// Speculative duplicate sub-batches dispatched for stragglers.
    pub hedges: AtomicU64,
    /// Hedged duplicates that completed before the original copy.
    pub hedge_wins: AtomicU64,
    /// Tickets resolved as [`Outcome::Partial`](crate::service::Outcome)
    /// (completed rows + validity mask) instead of failing outright.
    pub partials: AtomicU64,
    /// Circuit-breaker transitions into `Open` (group evicted).
    pub breaker_opens: AtomicU64,
    /// Circuit-breaker transitions into `HalfOpen` (probation probing).
    pub breaker_half_opens: AtomicU64,
    /// Circuit-breaker transitions back to `Closed` (group recovered).
    pub breaker_closes: AtomicU64,
    pub latency: LatencyHistogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// A registry that additionally tracks per-window routed rows.
    pub fn for_windows(windows: usize) -> Self {
        Self {
            window_rows: (0..windows).map(|_| AtomicU64::new(0)).collect(),
            ..Self::default()
        }
    }

    /// Record rows routed to a window (no-op for unsized registries).
    pub fn record_window_rows(&self, window: usize, rows: u64) {
        if let Some(c) = self.window_rows.get(window) {
            c.fetch_add(rows, Ordering::Relaxed);
        }
    }

    /// Lifetime per-window routed-row totals (epoch deltas are the
    /// caller's subtraction).
    pub fn window_rows_snapshot(&self) -> Vec<u64> {
        self.window_rows
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            rows: self.rows.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            padded_rows: self.padded_rows.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            admission_rejected: self.admission_rejected.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            throttled: self.throttled.load(Ordering::Relaxed),
            global_rejected: self.global_rejected.load(Ordering::Relaxed),
            window_rows: self.window_rows_snapshot(),
            redeal_epochs: self.redeal_epochs.load(Ordering::Relaxed),
            resplit_epochs: self.resplit_epochs.load(Ordering::Relaxed),
            migrate_epochs: self.migrate_epochs.load(Ordering::Relaxed),
            rows_migrated: self.rows_migrated.load(Ordering::Relaxed),
            generations_published: self.generations_published.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            hedges: self.hedges.load(Ordering::Relaxed),
            hedge_wins: self.hedge_wins.load(Ordering::Relaxed),
            partials: self.partials.load(Ordering::Relaxed),
            breaker_opens: self.breaker_opens.load(Ordering::Relaxed),
            breaker_half_opens: self.breaker_half_opens.load(Ordering::Relaxed),
            breaker_closes: self.breaker_closes.load(Ordering::Relaxed),
            mean_latency_us: self.latency.mean_us(),
            p50_latency_us: self.latency.quantile_us(0.50),
            p99_latency_us: self.latency.quantile_us(0.99),
            max_latency_us: self.latency.max_us(),
        }
    }
}

/// Point-in-time copy for reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub rows: u64,
    pub batches: u64,
    pub padded_rows: u64,
    pub errors: u64,
    pub rejected: u64,
    pub admission_rejected: u64,
    pub expired: u64,
    pub throttled: u64,
    pub global_rejected: u64,
    /// Rows routed per window (empty when the backend sizes no windows).
    pub window_rows: Vec<u64>,
    pub redeal_epochs: u64,
    pub resplit_epochs: u64,
    pub migrate_epochs: u64,
    pub rows_migrated: u64,
    pub generations_published: u64,
    pub retries: u64,
    pub hedges: u64,
    pub hedge_wins: u64,
    pub partials: u64,
    pub breaker_opens: u64,
    pub breaker_half_opens: u64,
    pub breaker_closes: u64,
    pub mean_latency_us: f64,
    pub p50_latency_us: u64,
    pub p99_latency_us: u64,
    pub max_latency_us: u64,
}

impl MetricsSnapshot {
    pub fn report(&self) -> String {
        format!(
            "requests={} rows={} batches={} padded={} errors={} rejected={} \
             shed={} shed_global={} expired={} throttled={} \
             repartition(redeal/resplit/migrate)={}/{}/{} gens={} rows_migrated={} \
             resilience(retry/hedge/hedgewin/partial)={}/{}/{}/{} \
             breaker(open/half/close)={}/{}/{} \
             latency(mean/p50/p99/max µs)={:.0}/{}/{}/{}",
            self.requests,
            self.rows,
            self.batches,
            self.padded_rows,
            self.errors,
            self.rejected,
            self.admission_rejected,
            self.global_rejected,
            self.expired,
            self.throttled,
            self.redeal_epochs,
            self.resplit_epochs,
            self.migrate_epochs,
            self.generations_published,
            self.rows_migrated,
            self.retries,
            self.hedges,
            self.hedge_wins,
            self.partials,
            self.breaker_opens,
            self.breaker_half_opens,
            self.breaker_closes,
            self.mean_latency_us,
            self.p50_latency_us,
            self.p99_latency_us,
            self.max_latency_us
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_and_quantiles() {
        let h = LatencyHistogram::new();
        for us in [10u64, 20, 30, 40, 1000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_us() - 220.0).abs() < 1.0);
        assert!(h.quantile_us(0.5) >= 16 && h.quantile_us(0.5) <= 64);
        assert!(h.quantile_us(0.99) >= 1000);
        assert_eq!(h.max_us(), 1000);
    }

    #[test]
    fn bucket_mapping_monotone() {
        let mut last = 0;
        for us in [1u64, 2, 5, 100, 10_000, 1_000_000, u64::MAX / 2] {
            let b = LatencyHistogram::bucket_of(us);
            assert!(b >= last);
            last = b;
            assert!(b < BUCKETS);
        }
    }

    #[test]
    fn snapshot_roundtrip() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.rows.fetch_add(300, Ordering::Relaxed);
        m.latency.record(Duration::from_micros(50));
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.rows, 300);
        assert!(s.report().contains("requests=3"));
    }

    #[test]
    fn window_rows_tracked_when_sized() {
        let m = Metrics::for_windows(3);
        m.record_window_rows(0, 5);
        m.record_window_rows(2, 7);
        m.record_window_rows(2, 1);
        m.record_window_rows(9, 100); // out of range: ignored
        assert_eq!(m.window_rows_snapshot(), vec![5, 0, 8]);
        assert_eq!(m.snapshot().window_rows, vec![5, 0, 8]);
        // Unsized registries ignore window recording entirely.
        let plain = Metrics::new();
        plain.record_window_rows(0, 5);
        assert!(plain.window_rows_snapshot().is_empty());
    }

    #[test]
    fn concurrent_recording() {
        let m = std::sync::Arc::new(Metrics::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = std::sync::Arc::clone(&m);
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.rows.fetch_add(1, Ordering::Relaxed);
                        m.latency.record(Duration::from_micros(7));
                    }
                });
            }
        });
        assert_eq!(m.rows.load(Ordering::Relaxed), 8000);
        assert_eq!(m.latency.count(), 8000);
    }
}

//! Serving metrics: lock-free counters, log-bucketed latency histogram,
//! and a space-bounded row-frequency sketch feeding the repack lever.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Log2-bucketed latency histogram, 1 µs .. ~1 s.
const BUCKETS: usize = 22;

/// Recording shards: every group worker records a latency per completed
/// request, so a single counter line would be the one cache line the whole
/// fleet of workers fights over.  Threads hash to a shard
/// (thread-local, assigned round-robin) and record with relaxed adds;
/// readers sum the shards (acquire loads, so a snapshot observes every
/// count recorded before it).
const SHARDS: usize = 8;

/// Cache-line aligned so adjacent shards never share a boundary line —
/// otherwise neighboring threads would still bounce one line per record
/// and partially undo the sharding.
#[derive(Debug, Default)]
#[repr(align(64))]
struct LatencyShard {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

#[derive(Debug, Default)]
pub struct LatencyHistogram {
    shards: [LatencyShard; SHARDS],
}

/// This thread's shard index (round-robin at first use).
fn shard_index() -> usize {
    use std::sync::atomic::AtomicUsize;
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SHARD: usize = NEXT.fetch_add(1, Ordering::Relaxed) % SHARDS;
    }
    SHARD.with(|s| *s)
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn bucket_of(us: u64) -> usize {
        (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1)
    }

    pub fn record(&self, latency: Duration) {
        let us = latency.as_micros() as u64;
        let shard = &self.shards[shard_index()];
        // Count is added LAST with Release: a snapshot that acquires a
        // count has the matching bucket/sum/max contributions too.
        shard.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        shard.sum_us.fetch_add(us, Ordering::Relaxed);
        shard.max_us.fetch_max(us, Ordering::Relaxed);
        shard.count.fetch_add(1, Ordering::Release);
    }

    pub fn count(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.count.load(Ordering::Acquire))
            .sum()
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        let sum: u64 = self
            .shards
            .iter()
            .map(|s| s.sum_us.load(Ordering::Relaxed))
            .sum();
        sum as f64 / c as f64
    }

    pub fn max_us(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.max_us.load(Ordering::Relaxed))
            .max()
            .unwrap_or(0)
    }

    /// Approximate quantile from the log buckets (upper bucket edge).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let want = ((total as f64) * q).ceil() as u64;
        let mut acc = 0;
        for i in 0..BUCKETS {
            for s in &self.shards {
                acc += s.buckets[i].load(Ordering::Relaxed);
            }
            if acc >= want {
                return 1u64 << (i + 1);
            }
        }
        self.max_us()
    }
}

/// One tracked row in the frequency sketch: the SpaceSaving estimate and
/// its error bound (`count - err` is a guaranteed lower bound on the true
/// frequency — the quantity hot-set decisions trust).
#[derive(Debug, Clone, Copy)]
struct FreqSlot {
    count: u64,
    err: u64,
}

#[derive(Debug)]
struct SketchInner {
    cap: usize,
    counts: HashMap<u64, FreqSlot>,
    /// Raw rows recorded (post-sampling), the share denominator.
    observed: u64,
}

/// Space-bounded decayed row-frequency sketch (SpaceSaving) over *global*
/// row ids — keyed globally so re-splits that move window boundaries never
/// invalidate the learned hot set.  The dispatcher records a 1-in-8 sample
/// of routed rows where `record_window_rows` already fires; the sketch is
/// `None` unless the owner enables the repack lever, so non-remap backends
/// pay nothing.  Writers are the (single) dispatcher thread; the epoch
/// thread reads and decays — one uncontended mutex, off the scatter path.
#[derive(Debug)]
pub struct RowFreqSketch {
    inner: Mutex<SketchInner>,
    /// Rolling row counter driving the 1-in-`SAMPLE` stride.
    sampled: AtomicU64,
}

/// Sampling stride for routed-row recording.
const SAMPLE: u64 = 8;

impl RowFreqSketch {
    pub fn new(cap: usize) -> Self {
        Self {
            inner: Mutex::new(SketchInner {
                cap: cap.max(1),
                counts: HashMap::with_capacity(cap.max(1) + 1),
                observed: 0,
            }),
            sampled: AtomicU64::new(0),
        }
    }

    /// Record one observed row (SpaceSaving insert/evict).
    fn record_locked(inner: &mut SketchInner, row: u64) {
        inner.observed += 1;
        if let Some(slot) = inner.counts.get_mut(&row) {
            slot.count += 1;
            return;
        }
        if inner.counts.len() < inner.cap {
            inner.counts.insert(row, FreqSlot { count: 1, err: 0 });
            return;
        }
        // Evict the minimum-estimate entry; the newcomer inherits its
        // estimate as the classic SpaceSaving error bound.
        let (&victim, &slot) = match inner.counts.iter().min_by_key(|(_, s)| s.count) {
            Some(kv) => kv,
            None => return,
        };
        inner.counts.remove(&victim);
        inner.counts.insert(
            row,
            FreqSlot {
                count: slot.count + 1,
                err: slot.count,
            },
        );
    }

    /// Record a 1-in-[`SAMPLE`] stride of a routed sub-batch's rows
    /// (`start_row` lifts window-local ids to global row space).
    pub fn record_routed(&self, start_row: u64, local_rows: &[u32]) {
        let base = self.sampled.fetch_add(local_rows.len() as u64, Ordering::Relaxed);
        // First sampled offset in this batch: the next multiple of SAMPLE.
        let first = (SAMPLE - base % SAMPLE) % SAMPLE;
        if first >= local_rows.len() as u64 {
            return;
        }
        let Ok(mut inner) = self.inner.lock() else {
            return;
        };
        let mut k = first as usize;
        while k < local_rows.len() {
            Self::record_locked(&mut inner, start_row + local_rows[k] as u64);
            k += SAMPLE as usize;
        }
    }

    /// Halve every estimate (and the denominator), dropping emptied rows —
    /// called once per control-plane epoch so drifted-away hot sets fade.
    pub fn decay(&self) {
        let Ok(mut inner) = self.inner.lock() else {
            return;
        };
        inner.observed /= 2;
        inner.counts.retain(|_, s| {
            s.count /= 2;
            s.err /= 2;
            s.count > s.err
        });
    }

    /// Guaranteed-frequency top rows, most frequent first:
    /// `(global_row, guaranteed_count)` with `guaranteed = count - err`.
    pub fn top(&self) -> Vec<(u64, u64)> {
        let Ok(inner) = self.inner.lock() else {
            return Vec::new();
        };
        let mut out: Vec<(u64, u64)> = inner
            .counts
            .iter()
            .filter(|(_, s)| s.count > s.err)
            .map(|(&row, s)| (row, s.count - s.err))
            .collect();
        out.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        out
    }

    /// Rows recorded since the last decay halvings (share denominator).
    pub fn observed(&self) -> u64 {
        self.inner.lock().map(|i| i.observed).unwrap_or(0)
    }
}

/// Aggregate serving metrics.  One registry per backend; the service
/// facade, sessions, and tickets all record into the backend's registry so
/// admission-control outcomes (`admission_rejected` / `throttled`) and
/// deadline expiries (`expired`) show up next to the serving counters.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub rows: AtomicU64,
    pub batches: AtomicU64,
    pub padded_rows: AtomicU64,
    pub errors: AtomicU64,
    /// Requests refused for invalid input (row index out of table).
    pub rejected: AtomicU64,
    /// Requests shed by session admission control (over the in-flight
    /// budget under the Reject overload policy) — kept separate from
    /// `rejected` so overload is distinguishable from client bugs.
    pub admission_rejected: AtomicU64,
    /// Tickets whose deadline passed before the result arrived (counted at
    /// `Ticket::wait` timeout or dispatcher-side culling).
    pub expired: AtomicU64,
    /// Session submissions that blocked on the in-flight budget (Queue
    /// overload policy).
    pub throttled: AtomicU64,
    /// Submissions denied by the cross-tenant
    /// [`GlobalAdmission`](crate::service::GlobalAdmission) budget (always
    /// also counted in `admission_rejected`; kept separate so per-tenant
    /// overload is distinguishable from fleet-wide overload).
    pub global_rejected: AtomicU64,
    /// Rows routed per window (index = window id; the adaptive placer's
    /// load signal).  Sized by [`Metrics::for_windows`]; empty when the
    /// owner tracks no placement.  Sized to the *maximum* window count
    /// (one per SM group): a re-split may raise the live plan's count.
    pub window_rows: Vec<AtomicU64>,
    /// Control-plane epochs that re-*dealt* groups under fixed window
    /// boundaries (the cheapest repartitioning lever).
    pub redeal_epochs: AtomicU64,
    /// Control-plane epochs that re-*split* the window boundaries.
    pub resplit_epochs: AtomicU64,
    /// Control-plane epochs that migrated row ranges across cards (fleet
    /// registries only).
    pub migrate_epochs: AtomicU64,
    /// Rows whose owning card changed across all migrations (zero-copy:
    /// view re-slices, never data copies).
    pub rows_migrated: AtomicU64,
    /// Control-plane epochs that re*pack*ed a window's hot rows into a
    /// page-aligned prefix (the fourth, layout-changing lever).
    pub repack_epochs: AtomicU64,
    /// Rows copied into packed hot prefixes across all repacks (unlike
    /// migration this lever *does* move data — exactly these rows, once).
    pub rows_repacked: AtomicU64,
    /// Control-plane epochs that changed the replica set — created *or*
    /// dropped replicas (the fifth lever; fleet registries only).
    pub replicate_epochs: AtomicU64,
    /// Read replicas brought up across all replicate epochs (each is a
    /// zero-copy `TableView` slice on an extra card, never a data copy).
    pub replicas_created: AtomicU64,
    /// Read replicas retired after load subsided (de-replication).
    pub replicas_dropped: AtomicU64,
    /// Plan/placement generations published by the control plane (every
    /// redeal, resplit, or migration bumps exactly one generation).
    pub generations_published: AtomicU64,
    /// Sub-batches re-dispatched by the resilience layer after a failure
    /// (each retry attempt counts once).
    pub retries: AtomicU64,
    /// Speculative duplicate sub-batches dispatched for stragglers.
    pub hedges: AtomicU64,
    /// Hedged duplicates that completed before the original copy.
    pub hedge_wins: AtomicU64,
    /// Tickets resolved as [`Outcome::Partial`](crate::service::Outcome)
    /// (completed rows + validity mask) instead of failing outright.
    pub partials: AtomicU64,
    /// Circuit-breaker transitions into `Open` (group evicted).
    pub breaker_opens: AtomicU64,
    /// Circuit-breaker transitions into `HalfOpen` (probation probing).
    pub breaker_half_opens: AtomicU64,
    /// Circuit-breaker transitions back to `Closed` (group recovered).
    pub breaker_closes: AtomicU64,
    pub latency: LatencyHistogram,
    /// Row-frequency sketch for hot-set learning; `None` (and zero-cost)
    /// unless the owner enables the repack lever.
    pub row_freq: Option<RowFreqSketch>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// A registry that additionally tracks per-window routed rows.
    pub fn for_windows(windows: usize) -> Self {
        Self {
            window_rows: (0..windows).map(|_| AtomicU64::new(0)).collect(),
            ..Self::default()
        }
    }

    /// Enable hot-set learning: attach a row-frequency sketch of `cap`
    /// tracked rows (builder-style, used at backend construction).
    pub fn with_row_sketch(mut self, cap: usize) -> Self {
        self.row_freq = Some(RowFreqSketch::new(cap));
        self
    }

    /// Record rows routed to a window (no-op for unsized registries).
    pub fn record_window_rows(&self, window: usize, rows: u64) {
        if let Some(c) = self.window_rows.get(window) {
            c.fetch_add(rows, Ordering::Relaxed);
        }
    }

    /// Feed the row-frequency sketch from a routed sub-batch (no-op unless
    /// the repack lever enabled the sketch).
    pub fn record_routed_rows(&self, start_row: u64, local_rows: &[u32]) {
        if let Some(s) = &self.row_freq {
            s.record_routed(start_row, local_rows);
        }
    }

    /// Lifetime per-window routed-row totals (epoch deltas are the
    /// caller's subtraction).
    pub fn window_rows_snapshot(&self) -> Vec<u64> {
        self.window_rows
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            rows: self.rows.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            padded_rows: self.padded_rows.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            admission_rejected: self.admission_rejected.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            throttled: self.throttled.load(Ordering::Relaxed),
            global_rejected: self.global_rejected.load(Ordering::Relaxed),
            window_rows: self.window_rows_snapshot(),
            redeal_epochs: self.redeal_epochs.load(Ordering::Relaxed),
            resplit_epochs: self.resplit_epochs.load(Ordering::Relaxed),
            migrate_epochs: self.migrate_epochs.load(Ordering::Relaxed),
            rows_migrated: self.rows_migrated.load(Ordering::Relaxed),
            repack_epochs: self.repack_epochs.load(Ordering::Relaxed),
            rows_repacked: self.rows_repacked.load(Ordering::Relaxed),
            replicate_epochs: self.replicate_epochs.load(Ordering::Relaxed),
            replicas_created: self.replicas_created.load(Ordering::Relaxed),
            replicas_dropped: self.replicas_dropped.load(Ordering::Relaxed),
            generations_published: self.generations_published.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            hedges: self.hedges.load(Ordering::Relaxed),
            hedge_wins: self.hedge_wins.load(Ordering::Relaxed),
            partials: self.partials.load(Ordering::Relaxed),
            breaker_opens: self.breaker_opens.load(Ordering::Relaxed),
            breaker_half_opens: self.breaker_half_opens.load(Ordering::Relaxed),
            breaker_closes: self.breaker_closes.load(Ordering::Relaxed),
            mean_latency_us: self.latency.mean_us(),
            p50_latency_us: self.latency.quantile_us(0.50),
            p99_latency_us: self.latency.quantile_us(0.99),
            max_latency_us: self.latency.max_us(),
        }
    }
}

/// Point-in-time copy for reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub rows: u64,
    pub batches: u64,
    pub padded_rows: u64,
    pub errors: u64,
    pub rejected: u64,
    pub admission_rejected: u64,
    pub expired: u64,
    pub throttled: u64,
    pub global_rejected: u64,
    /// Rows routed per window (empty when the backend sizes no windows).
    pub window_rows: Vec<u64>,
    pub redeal_epochs: u64,
    pub resplit_epochs: u64,
    pub migrate_epochs: u64,
    pub rows_migrated: u64,
    pub repack_epochs: u64,
    pub rows_repacked: u64,
    pub replicate_epochs: u64,
    pub replicas_created: u64,
    pub replicas_dropped: u64,
    pub generations_published: u64,
    pub retries: u64,
    pub hedges: u64,
    pub hedge_wins: u64,
    pub partials: u64,
    pub breaker_opens: u64,
    pub breaker_half_opens: u64,
    pub breaker_closes: u64,
    pub mean_latency_us: f64,
    pub p50_latency_us: u64,
    pub p99_latency_us: u64,
    pub max_latency_us: u64,
}

impl MetricsSnapshot {
    pub fn report(&self) -> String {
        format!(
            "requests={} rows={} batches={} padded={} errors={} rejected={} \
             shed={} shed_global={} expired={} throttled={} \
             repartition(redeal/resplit/migrate/repack/replicate)={}/{}/{}/{}/{} gens={} \
             rows_migrated={} rows_repacked={} replicas(up/down)={}/{} \
             resilience(retry/hedge/hedgewin/partial)={}/{}/{}/{} \
             breaker(open/half/close)={}/{}/{} \
             latency(mean/p50/p99/max µs)={:.0}/{}/{}/{}",
            self.requests,
            self.rows,
            self.batches,
            self.padded_rows,
            self.errors,
            self.rejected,
            self.admission_rejected,
            self.global_rejected,
            self.expired,
            self.throttled,
            self.redeal_epochs,
            self.resplit_epochs,
            self.migrate_epochs,
            self.repack_epochs,
            self.replicate_epochs,
            self.generations_published,
            self.rows_migrated,
            self.rows_repacked,
            self.replicas_created,
            self.replicas_dropped,
            self.retries,
            self.hedges,
            self.hedge_wins,
            self.partials,
            self.breaker_opens,
            self.breaker_half_opens,
            self.breaker_closes,
            self.mean_latency_us,
            self.p50_latency_us,
            self.p99_latency_us,
            self.max_latency_us
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_and_quantiles() {
        let h = LatencyHistogram::new();
        for us in [10u64, 20, 30, 40, 1000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_us() - 220.0).abs() < 1.0);
        assert!(h.quantile_us(0.5) >= 16 && h.quantile_us(0.5) <= 64);
        assert!(h.quantile_us(0.99) >= 1000);
        assert_eq!(h.max_us(), 1000);
    }

    #[test]
    fn bucket_mapping_monotone() {
        let mut last = 0;
        for us in [1u64, 2, 5, 100, 10_000, 1_000_000, u64::MAX / 2] {
            let b = LatencyHistogram::bucket_of(us);
            assert!(b >= last);
            last = b;
            assert!(b < BUCKETS);
        }
    }

    #[test]
    fn snapshot_roundtrip() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.rows.fetch_add(300, Ordering::Relaxed);
        m.latency.record(Duration::from_micros(50));
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.rows, 300);
        assert!(s.report().contains("requests=3"));
    }

    #[test]
    fn window_rows_tracked_when_sized() {
        let m = Metrics::for_windows(3);
        m.record_window_rows(0, 5);
        m.record_window_rows(2, 7);
        m.record_window_rows(2, 1);
        m.record_window_rows(9, 100); // out of range: ignored
        assert_eq!(m.window_rows_snapshot(), vec![5, 0, 8]);
        assert_eq!(m.snapshot().window_rows, vec![5, 0, 8]);
        // Unsized registries ignore window recording entirely.
        let plain = Metrics::new();
        plain.record_window_rows(0, 5);
        assert!(plain.window_rows_snapshot().is_empty());
    }

    #[test]
    fn sketch_is_space_bounded_and_ranks_hot_rows() {
        let s = RowFreqSketch::new(8);
        // A skewed stream: rows 0..4 hot, a long tail of cold singletons.
        // Record unsampled via the locked path-equivalent: feed each row as
        // a single-element batch at stride-aligned offsets.
        for round in 0..200u64 {
            for hot in 0..4u64 {
                s.record_routed(0, &[(hot * SAMPLE) as u32; SAMPLE as usize]);
            }
            s.record_routed(0, &[((100 + round) * SAMPLE) as u32; SAMPLE as usize]);
        }
        let top = s.top();
        assert!(top.len() <= 8, "sketch exceeded its capacity");
        // The four hot rows dominate the guaranteed-frequency ranking.
        let head: Vec<u64> = top.iter().take(4).map(|(r, _)| *r).collect();
        for hot in 0..4u64 {
            assert!(head.contains(&(hot * SAMPLE)), "hot row {hot} missing: {top:?}");
        }
        assert!(s.observed() > 0);
    }

    #[test]
    fn sketch_guarantees_are_small_under_uniform_traffic() {
        let s = RowFreqSketch::new(16);
        // Uniform stream over many distinct rows: every guaranteed count
        // stays near 1, so the "hot share" signal correctly reads as cold.
        for row in 0..2000u64 {
            s.record_routed(0, &[(row * SAMPLE) as u32; SAMPLE as usize]);
        }
        let observed = s.observed();
        let guaranteed: u64 = s.top().iter().map(|(_, g)| g).sum();
        assert!(
            (guaranteed as f64) < 0.2 * observed as f64,
            "uniform traffic produced a fake hot set: {guaranteed}/{observed}"
        );
    }

    #[test]
    fn sketch_decay_halves_and_drops() {
        let s = RowFreqSketch::new(8);
        for _ in 0..16 {
            s.record_routed(0, &[0u32; SAMPLE as usize]);
        }
        let before = s.top();
        assert_eq!(before[0].0, 0);
        let g_before = before[0].1;
        s.decay();
        let after = s.top();
        assert_eq!(after[0].1, g_before / 2);
        // Repeated decay fades the entry out entirely.
        for _ in 0..8 {
            s.decay();
        }
        assert!(s.top().is_empty());
        assert_eq!(s.observed(), 0);
    }

    #[test]
    fn sampling_records_a_fixed_stride() {
        let s = RowFreqSketch::new(64);
        // 8 batches of SAMPLE rows: exactly one row sampled per batch.
        for b in 0..8u64 {
            s.record_routed(1000, &[b as u32; SAMPLE as usize]);
        }
        assert_eq!(s.observed(), 8);
        // Rows land in global space (start_row offset applied).
        assert!(s.top().iter().all(|&(r, _)| r >= 1000));
        // Sketchless metrics ignore the feed entirely.
        let plain = Metrics::new();
        plain.record_routed_rows(0, &[1, 2, 3]);
        assert!(plain.row_freq.is_none());
        let sized = Metrics::for_windows(2).with_row_sketch(4);
        sized.record_routed_rows(0, &[1; 16]);
        assert!(sized.row_freq.as_ref().map(|f| f.observed() > 0) == Some(true));
    }

    #[test]
    fn concurrent_recording() {
        let m = std::sync::Arc::new(Metrics::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = std::sync::Arc::clone(&m);
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.rows.fetch_add(1, Ordering::Relaxed);
                        m.latency.record(Duration::from_micros(7));
                    }
                });
            }
        });
        assert_eq!(m.rows.load(Ordering::Relaxed), 8000);
        assert_eq!(m.latency.count(), 8000);
    }
}

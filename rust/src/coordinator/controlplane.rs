//! The repartitioning control plane: one escalation policy over the five
//! rebalancing levers, cheapest data movement first —
//!
//! ```text
//!   re-deal groups        (AdaptivePlacer::rebalance — swap the deal)
//!     └─ not enough? → re-split window boundaries   (PlanSplitter::replan)
//!           └─ not enough? → migrate rows across cards (FleetRebalancer)
//!                 └─ not enough? → repack hot rows in-window (RowRemap)
//!                       └─ not enough? → replicate the hot shard (ReplicaSet)
//! ```
//!
//! Repack is the only lever that *copies row data* (into a packed
//! page-aligned slab) rather than re-pointing zero-copy views — the routing
//! levers must have had their chance first.  Replicate sits above even
//! that: it *spends another card's capacity* (a zero-copy read replica of
//! the hot shard, routed by power-of-two-choices over queue depth), the one
//! lever left when a single window is hotter than one card's bandwidth and
//! no amount of re-layout on the owning card can help.
//!
//! [`ControlPlane`] owns the *policy* (when is each lever permitted), not
//! the levers themselves: a per-card epoch loop
//! ([`SimBackend`](crate::service::SimBackend)) drives deal/re-split, the
//! fleet epoch loop ([`FleetService`](crate::service::FleetService)) adds
//! migration on top.  Each epoch the driver reports the observed capacity/
//! load imbalance; [`permit`](ControlPlane::permit) answers with the
//! strongest lever allowed right now (hysteresis per level: an imbalance
//! must *persist* for `patience` epochs beyond what the cheaper lever fixed
//! before the next one unlocks, and every action is followed by `cooldown`
//! quiet epochs so fresh signals accumulate under the new layout).  The
//! driver then tries levers cheapest-to-permitted and records what actually
//! happened; the resulting [`Decision`] trace is the control plane's
//! audit log (`a100win bench-serve` prints its tail).

use std::collections::VecDeque;
use std::sync::Mutex;

/// The repartitioning levers, cheapest first.  `Ord` follows cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Lever {
    /// Leave the layout alone this epoch.
    Hold,
    /// Re-deal SM groups across fixed window boundaries.
    Redeal,
    /// Re-split the window boundaries themselves.
    Resplit,
    /// Move row ranges across cards (fleet scope only).
    Migrate,
    /// Repack a window's hot rows into a page-aligned prefix (the only
    /// lever that copies data; see `coordinator::remap`).
    Repack,
    /// Give a saturated shard zero-copy read replicas on additional cards
    /// (fleet scope only; see `coordinator::replicate`).  The most
    /// expensive lever: it spends another card's bandwidth.
    Replicate,
}

impl std::fmt::Display for Lever {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Lever::Hold => "hold",
            Lever::Redeal => "redeal",
            Lever::Resplit => "resplit",
            Lever::Migrate => "migrate",
            Lever::Repack => "repack",
            Lever::Replicate => "replicate",
        })
    }
}

#[derive(Debug, Clone)]
pub struct ControlPlaneConfig {
    /// Load-share vs capacity-share deviation below which the layout is
    /// considered healthy (streaks reset; nothing is permitted).
    pub min_imbalance: f64,
    /// Over-threshold epochs required per escalation step: the first
    /// `patience` failing epochs permit only a re-deal, the next
    /// `patience` unlock re-splitting, then migration, then repacking.
    pub patience: u32,
    /// Quiet epochs after any applied lever, so the new layout collects
    /// signal before being judged.
    pub cooldown: u32,
    /// The strongest lever this scope may use (`Resplit` for one card,
    /// `Migrate` for a fleet, `Repack` when the card also owns a hot-row
    /// remap layer, `Replicate` for a fleet armed with read replication —
    /// a per-card scope without migration simply declines the `Migrate`
    /// rung and escalates past it on the next epoch).
    pub max_lever: Lever,
    /// Decisions retained in the audit trace.
    pub trace_len: usize,
}

impl Default for ControlPlaneConfig {
    fn default() -> Self {
        Self {
            min_imbalance: 0.10,
            patience: 1,
            cooldown: 1,
            max_lever: Lever::Resplit,
            trace_len: 64,
        }
    }
}

/// One epoch's audited outcome.
#[derive(Debug, Clone)]
pub struct Decision {
    pub epoch: u64,
    /// The strongest lever the policy permitted this epoch.
    pub permitted: Lever,
    /// The lever that actually published a new generation (None: no-op —
    /// healthy, cooling down, or every permitted lever declined).
    pub acted: Option<Lever>,
    /// The imbalance the epoch was judged on.
    pub imbalance: f64,
    /// Generation published by the acted lever.
    pub generation: Option<u64>,
    pub why: String,
}

#[derive(Debug)]
struct PlaneState {
    epoch: u64,
    /// Consecutive over-threshold epochs (excluding cooldowns).
    streak: u32,
    cooldown_left: u32,
    trace: VecDeque<Decision>,
}

/// The escalation policy + audit trace (see module docs).
#[derive(Debug)]
pub struct ControlPlane {
    cfg: ControlPlaneConfig,
    state: Mutex<PlaneState>,
}

impl ControlPlane {
    pub fn new(cfg: ControlPlaneConfig) -> Self {
        Self {
            cfg,
            state: Mutex::new(PlaneState {
                epoch: 0,
                streak: 0,
                cooldown_left: 0,
                trace: VecDeque::new(),
            }),
        }
    }

    pub fn config(&self) -> &ControlPlaneConfig {
        &self.cfg
    }

    /// Open an epoch: given the observed imbalance, return the strongest
    /// lever permitted right now.  The driver should attempt levers
    /// cheapest-to-permitted (a permitted `Resplit` means "try the re-deal
    /// first; if it declines or cannot help, re-split").
    pub fn permit(&self, imbalance: f64) -> Lever {
        let mut st = self.state.lock().unwrap();
        st.epoch += 1;
        if st.cooldown_left > 0 {
            st.cooldown_left -= 1;
            return Lever::Hold;
        }
        if imbalance.is_nan() || imbalance < self.cfg.min_imbalance {
            // NaN-safe: an unmeasurable imbalance never escalates.
            st.streak = 0;
            return Lever::Hold;
        }
        st.streak += 1;
        let step = (st.streak - 1) / self.cfg.patience.max(1);
        let lever = match step {
            0 => Lever::Redeal,
            1 => Lever::Resplit,
            2 => Lever::Migrate,
            3 => Lever::Repack,
            _ => Lever::Replicate,
        };
        lever.min(self.cfg.max_lever)
    }

    /// Record the outcome of the epoch opened by the matching
    /// [`permit`](Self::permit) call.  An applied lever starts the
    /// cooldown; the streak is *not* reset — only a healthy epoch resets
    /// it, so a lever that failed to fix the imbalance escalates.
    pub fn record(
        &self,
        permitted: Lever,
        acted: Option<Lever>,
        imbalance: f64,
        generation: Option<u64>,
        why: impl Into<String>,
    ) {
        let mut st = self.state.lock().unwrap();
        if acted.is_some() {
            st.cooldown_left = self.cfg.cooldown;
        }
        let d = Decision {
            epoch: st.epoch,
            permitted,
            acted,
            imbalance,
            generation,
            why: why.into(),
        };
        if st.trace.len() >= self.cfg.trace_len.max(1) {
            st.trace.pop_front();
        }
        st.trace.push_back(d);
    }

    /// Open an epoch *outside* the escalation ladder — health transitions
    /// act immediately, bypassing hysteresis — advancing the epoch counter
    /// (so the decision trace stays strictly ordered) without touching
    /// streaks or cooldowns.  Record the outcome with
    /// [`record`](Self::record) as usual.
    pub fn open_unladdered(&self) -> u64 {
        let mut st = self.state.lock().unwrap();
        st.epoch += 1;
        st.epoch
    }

    /// Append a pure audit entry: an unladdered epoch that held (no lever,
    /// no imbalance judgment) but whose `why` belongs in the trace — e.g. a
    /// circuit-breaker transition that will drive the *next* health epoch.
    pub fn note(&self, why: impl Into<String>) {
        self.open_unladdered();
        self.record(Lever::Hold, None, 0.0, None, why);
    }

    /// Epochs opened so far.
    pub fn epoch(&self) -> u64 {
        self.state.lock().unwrap().epoch
    }

    /// The retained decision trace, oldest first.
    pub fn decisions(&self) -> Vec<Decision> {
        self.state.lock().unwrap().trace.iter().cloned().collect()
    }
}

/// The imbalance every scope is judged on: the largest deviation between a
/// partition's observed load share and its provisioned capacity share.
/// (Used per-window against the placement's group capacities, and per-card
/// against the fleet's probed card capacities.)
pub fn capacity_imbalance(load_share: &[f64], capacity_share: &[f64]) -> f64 {
    debug_assert_eq!(load_share.len(), capacity_share.len());
    load_share
        .iter()
        .zip(capacity_share)
        .map(|(l, c)| (l - c).abs())
        .fold(0.0f64, f64::max)
}

/// Normalize per-partition observed rows into load shares; `None` when the
/// epoch carried no signal at all.
pub fn load_shares(rows: &[u64]) -> Option<Vec<f64>> {
    let total: u64 = rows.iter().sum();
    if total == 0 {
        return None;
    }
    Some(rows.iter().map(|&r| r as f64 / total as f64).collect())
}

/// Delta observed counters against a committed atomic baseline — the one
/// epoch-signal rule every scope shares: the baseline only advances when
/// the epoch carried at least `min_commit` total, so a starved epoch
/// rolls its signal into the next one and persistent low-rate skew still
/// accumulates to a decision instead of being dropped.
///
/// The epoch drivers keep their committed-baseline registers as plain
/// atomics (relaxed-counter writes, acquire/release at the epoch
/// boundary) instead of a `Mutex<Vec<u64>>`, so reading an epoch signal
/// never takes a lock the request path could ever see.  The baseline's
/// length is fixed at construction (sized for the maximum counter set,
/// like [`Metrics::for_windows`](crate::coordinator::Metrics::for_windows));
/// shorter `totals` are treated as zero-extended.  Callers serialize
/// epochs (they already hold the epoch gate), so the read-then-store pair
/// is not racing other committers.
pub fn committed_delta_atomic(
    last: &[std::sync::atomic::AtomicU64],
    totals: &[u64],
    min_commit: u64,
) -> Vec<u64> {
    use std::sync::atomic::Ordering;
    // A counter beyond the baseline's fixed size never panics mid-epoch
    // (the epoch gate would be poisoned for the process): its baseline
    // reads as zero and never advances, so that counter's "delta"
    // degrades to its lifetime total — recent-skew detection is muted for
    // it, identically in debug and release.  Current callers size the
    // baseline to the registry's maximum, so this is a guard rail, not a
    // supported mode.
    let delta: Vec<u64> = totals
        .iter()
        .enumerate()
        .map(|(i, t)| t.saturating_sub(last.get(i).map_or(0, |a| a.load(Ordering::Acquire))))
        .collect();
    if delta.iter().sum::<u64>() >= min_commit {
        for (i, &t) in totals.iter().enumerate() {
            if let Some(slot) = last.get(i) {
                slot.store(t, Ordering::Release);
            }
        }
    }
    delta
}

/// Reset an atomic committed baseline to `totals` (re-baselining after a
/// re-split or migration invalidates the old counter meanings).
pub fn rebaseline_atomic(last: &[std::sync::atomic::AtomicU64], totals: &[u64]) {
    use std::sync::atomic::Ordering;
    for (i, slot) in last.iter().enumerate() {
        slot.store(totals.get(i).copied().unwrap_or(0), Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plane(max: Lever) -> ControlPlane {
        ControlPlane::new(ControlPlaneConfig {
            max_lever: max,
            ..Default::default()
        })
    }

    #[test]
    fn healthy_epochs_hold_and_reset_streaks() {
        let cp = plane(Lever::Migrate);
        assert_eq!(cp.permit(0.02), Lever::Hold);
        assert_eq!(cp.permit(0.5), Lever::Redeal);
        cp.record(Lever::Redeal, None, 0.5, None, "placer declined");
        // Healthy again: streak resets, so the next failure starts cheap.
        assert_eq!(cp.permit(0.01), Lever::Hold);
        assert_eq!(cp.permit(0.5), Lever::Redeal);
    }

    #[test]
    fn persistent_imbalance_escalates_cheapest_first() {
        let cp = plane(Lever::Migrate);
        // Epoch 1: first failure — only the cheap lever is permitted; the
        // re-deal applies and cooldown begins.
        assert_eq!(cp.permit(0.4), Lever::Redeal);
        cp.record(Lever::Redeal, Some(Lever::Redeal), 0.4, Some(1), "re-dealt");
        // Epoch 2: cooling down.
        assert_eq!(cp.permit(0.4), Lever::Hold);
        cp.record(Lever::Hold, None, 0.4, None, "cooldown");
        // Epoch 3: the re-deal did not fix it — re-split unlocks.
        assert_eq!(cp.permit(0.4), Lever::Resplit);
        cp.record(Lever::Resplit, Some(Lever::Resplit), 0.4, Some(2), "re-split");
        assert_eq!(cp.permit(0.4), Lever::Hold); // cooldown again
        // Epoch 5: still broken — migration unlocks.
        assert_eq!(cp.permit(0.4), Lever::Migrate);
    }

    #[test]
    fn repack_is_the_last_rung() {
        let cp = plane(Lever::Repack);
        // Streaks 1..=3 walk the routing levers (all declining, so no
        // cooldown intervenes); streak 4 reaches the copying lever.
        assert_eq!(cp.permit(0.4), Lever::Redeal);
        cp.record(Lever::Redeal, None, 0.4, None, "declined");
        assert_eq!(cp.permit(0.4), Lever::Resplit);
        cp.record(Lever::Resplit, None, 0.4, None, "declined");
        assert_eq!(cp.permit(0.4), Lever::Migrate);
        cp.record(Lever::Migrate, None, 0.4, None, "no fleet scope: declined");
        assert_eq!(cp.permit(0.4), Lever::Repack);
        cp.record(Lever::Repack, Some(Lever::Repack), 0.4, Some(1), "repacked");
        // Applied lever cools down, then the ladder stays at the top.
        assert_eq!(cp.permit(0.4), Lever::Hold);
        assert_eq!(cp.permit(0.4), Lever::Repack);
        // A healthy epoch resets all the way down.
        assert_eq!(cp.permit(0.0), Lever::Hold);
        assert_eq!(cp.permit(0.4), Lever::Redeal);
    }

    #[test]
    fn replicate_is_the_fifth_rung() {
        let cp = plane(Lever::Replicate);
        // Four declining rungs, then the ladder tops out at replication.
        assert_eq!(cp.permit(0.4), Lever::Redeal);
        cp.record(Lever::Redeal, None, 0.4, None, "declined");
        assert_eq!(cp.permit(0.4), Lever::Resplit);
        cp.record(Lever::Resplit, None, 0.4, None, "declined");
        assert_eq!(cp.permit(0.4), Lever::Migrate);
        cp.record(Lever::Migrate, None, 0.4, None, "declined");
        assert_eq!(cp.permit(0.4), Lever::Repack);
        cp.record(Lever::Repack, None, 0.4, None, "declined");
        assert_eq!(cp.permit(0.4), Lever::Replicate);
        cp.record(
            Lever::Replicate,
            Some(Lever::Replicate),
            0.4,
            Some(1),
            "replicated",
        );
        // Cooldown, then the ladder stays at the top until healthy.
        assert_eq!(cp.permit(0.4), Lever::Hold);
        assert_eq!(cp.permit(0.4), Lever::Replicate);
        assert_eq!(cp.permit(0.0), Lever::Hold);
        assert_eq!(cp.permit(0.4), Lever::Redeal);
    }

    #[test]
    fn migrate_cap_never_permits_repack() {
        let cp = plane(Lever::Migrate);
        for _ in 0..10 {
            let lever = cp.permit(0.4);
            assert!(lever <= Lever::Migrate);
            cp.record(lever, None, 0.4, None, "declined");
        }
    }

    #[test]
    fn declined_levers_escalate_without_cooldown() {
        let cp = plane(Lever::Migrate);
        assert_eq!(cp.permit(0.4), Lever::Redeal);
        cp.record(Lever::Redeal, None, 0.4, None, "placer declined");
        // No action → no cooldown → next epoch escalates immediately.
        assert_eq!(cp.permit(0.4), Lever::Resplit);
    }

    #[test]
    fn max_lever_caps_the_ladder() {
        let cp = plane(Lever::Resplit);
        for _ in 0..10 {
            let lever = cp.permit(0.4);
            assert!(lever <= Lever::Resplit);
            cp.record(lever, None, 0.4, None, "declined");
        }
        assert_eq!(cp.permit(0.4), Lever::Resplit);
    }

    #[test]
    fn trace_is_bounded_and_ordered() {
        let cp = ControlPlane::new(ControlPlaneConfig {
            trace_len: 4,
            ..Default::default()
        });
        for i in 0..10 {
            let lever = cp.permit(0.3);
            cp.record(lever, None, 0.3, None, format!("epoch {i}"));
        }
        let trace = cp.decisions();
        assert_eq!(trace.len(), 4);
        assert!(trace.windows(2).all(|w| w[0].epoch < w[1].epoch));
        assert_eq!(trace.last().unwrap().epoch, cp.epoch());
    }

    #[test]
    fn unladdered_epochs_keep_the_trace_ordered() {
        let cp = plane(Lever::Resplit);
        assert_eq!(cp.permit(0.4), Lever::Redeal);
        cp.record(Lever::Redeal, None, 0.4, None, "laddered");
        // A health-path epoch advances the counter without a permit...
        let e = cp.open_unladdered();
        assert_eq!(e, 2);
        cp.record(Lever::Redeal, Some(Lever::Redeal), 0.0, Some(1), "health");
        // ...its applied action still starts the normal cooldown...
        assert_eq!(cp.permit(0.4), Lever::Hold);
        // ...and the ladder's streak survives intact: the next failing
        // epoch escalates exactly as if the health epoch were regular.
        assert_eq!(cp.permit(0.4), Lever::Resplit);
        let trace = cp.decisions();
        assert!(trace.windows(2).all(|w| w[0].epoch < w[1].epoch));
    }

    #[test]
    fn nan_imbalance_is_held_not_escalated() {
        let cp = plane(Lever::Migrate);
        assert_eq!(cp.permit(f64::NAN), Lever::Hold);
        assert_eq!(cp.permit(0.4), Lever::Redeal);
    }

    #[test]
    fn capacity_imbalance_is_max_deviation() {
        let im = capacity_imbalance(&[0.9, 0.1], &[0.5, 0.5]);
        assert!((im - 0.4).abs() < 1e-12);
        assert_eq!(capacity_imbalance(&[], &[]), 0.0);
    }
}

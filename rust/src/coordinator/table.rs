//! Table storage: one shared, immutable f32 buffer and zero-copy views
//! over row ranges of it.
//!
//! The serving stack never copies table data after construction.  A
//! [`Table`] owns the backing storage (`Arc<[f32]>`); every consumer — a
//! card shard in a fleet, a window shard uploaded by a PJRT worker, a sim
//! worker's gather source — holds a [`TableView`]: `(storage, start_row,
//! rows)` metadata over the same allocation.  Sharding a 10 GiB host
//! table across 8 cards costs 8 refcount bumps, not 10 GiB of memcpy
//! (ROADMAP ">10 GiB hosts" item; verified by a shared-`Arc` pointer
//! identity test in `tests/adaptive_serving.rs`).

use std::sync::Arc;

/// Host-side table (synthetic or user-provided): the storage owner.
#[derive(Debug, Clone)]
pub struct Table {
    pub rows: u64,
    pub d: usize,
    pub data: Arc<[f32]>,
}

impl Table {
    /// Deterministic synthetic table: row r, column j holds
    /// `r as f32 + j as f32 / 100.0` — lets tests verify any gather against
    /// closed-form expectations without storing golden data.
    pub fn synthetic(rows: u64, d: usize) -> Self {
        let mut data = Vec::with_capacity(rows as usize * d);
        for r in 0..rows {
            for j in 0..d {
                data.push(r as f32 + j as f32 / 100.0);
            }
        }
        Self {
            rows,
            d,
            data: data.into(),
        }
    }

    /// Wrap an existing buffer (`data.len()` must be `rows * d`).
    pub fn from_data(data: Vec<f32>, rows: u64, d: usize) -> anyhow::Result<Self> {
        if data.len() as u64 != rows * d as u64 {
            anyhow::bail!("{} f32s cannot hold {rows} rows x {d}", data.len());
        }
        Ok(Self {
            rows,
            d,
            data: data.into(),
        })
    }

    pub fn expected(&self, row: u64, j: usize) -> f32 {
        self.data[row as usize * self.d + j]
    }

    /// Zero-copy view of the whole table (shares the storage `Arc`).
    pub fn view(&self) -> TableView {
        TableView {
            storage: Arc::clone(&self.data),
            start_row: 0,
            rows: self.rows,
            d: self.d,
        }
    }
}

/// A zero-copy window onto a [`Table`]'s rows: offset + length metadata
/// over the shared storage.  Cloning or re-slicing a view never touches
/// the f32 data.  Row indices on a view are *view-local* (0-based); the
/// view remembers where it starts in the backing storage.
#[derive(Debug, Clone)]
pub struct TableView {
    storage: Arc<[f32]>,
    /// First row of this view in the storage's row space.
    start_row: u64,
    rows: u64,
    d: usize,
}

impl TableView {
    /// Rows visible through this view.
    pub fn rows(&self) -> u64 {
        self.rows
    }

    /// Row width (f32 elements per row).
    pub fn d(&self) -> usize {
        self.d
    }

    /// This view's first row in the backing storage's row space.
    pub fn start_row(&self) -> u64 {
        self.start_row
    }

    /// The shared backing storage — pointer identity across views proves
    /// zero-copy sharding (`Arc::ptr_eq`).
    pub fn storage(&self) -> &Arc<[f32]> {
        &self.storage
    }

    /// One view-local row as a slice of `d` f32s.
    pub fn row(&self, local_row: u64) -> &[f32] {
        assert!(
            local_row < self.rows,
            "row {local_row} out of view ({} rows)",
            self.rows
        );
        let a = (self.start_row + local_row) as usize * self.d;
        &self.storage[a..a + self.d]
    }

    /// A contiguous view-local row range `[start_row, start_row + rows)` as
    /// one slice (device-upload path: a window shard is always contiguous).
    pub fn rows_slice(&self, start_row: u64, rows: u64) -> &[f32] {
        assert!(
            start_row + rows <= self.rows,
            "rows [{start_row}, {}) out of view ({} rows)",
            start_row + rows,
            self.rows
        );
        let a = (self.start_row + start_row) as usize * self.d;
        let b = (self.start_row + start_row + rows) as usize * self.d;
        &self.storage[a..b]
    }

    /// Zero-copy sub-view of `rows` rows starting at view-local
    /// `start_row`.  Offsets compose: a slice of a slice still indexes the
    /// original storage directly.
    pub fn slice_rows(&self, start_row: u64, rows: u64) -> TableView {
        assert!(
            start_row + rows <= self.rows,
            "slice [{start_row}, {}) out of view ({} rows)",
            start_row + rows,
            self.rows
        );
        TableView {
            storage: Arc::clone(&self.storage),
            start_row: self.start_row + start_row,
            rows,
            d: self.d,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn view_shares_storage_without_copying() {
        let t = Table::synthetic(100, 4);
        let v = t.view();
        assert_eq!(v.rows(), 100);
        assert_eq!(v.d(), 4);
        assert!(Arc::ptr_eq(v.storage(), &t.data));
        // Clones and slices alias the same allocation.
        let s = v.slice_rows(25, 50);
        assert!(Arc::ptr_eq(s.storage(), &t.data));
        assert!(Arc::ptr_eq(s.clone().storage(), &t.data));
    }

    #[test]
    fn slice_offsets_compose() {
        let t = Table::synthetic(100, 4);
        let a = t.view().slice_rows(10, 80); // storage rows [10, 90)
        let b = a.slice_rows(5, 20); // storage rows [15, 35)
        assert_eq!(b.start_row(), 15);
        assert_eq!(b.rows(), 20);
        for local in 0..20u64 {
            let global = 15 + local;
            assert_eq!(b.row(local), t.view().row(global));
            assert_eq!(b.row(local)[0], t.expected(global, 0));
        }
    }

    #[test]
    fn rows_slice_matches_row_concatenation() {
        let t = Table::synthetic(64, 3);
        let v = t.view().slice_rows(16, 32);
        let s = v.rows_slice(4, 8); // storage rows [20, 28)
        assert_eq!(s.len(), 8 * 3);
        for (k, row) in (20..28u64).enumerate() {
            assert_eq!(&s[k * 3..(k + 1) * 3], t.view().row(row));
        }
    }

    #[test]
    fn overlapping_views_agree() {
        let t = Table::synthetic(50, 2);
        let a = t.view().slice_rows(0, 30);
        let b = t.view().slice_rows(20, 30);
        // Overlap rows [20, 30): both views read identical data.
        for k in 0..10u64 {
            assert_eq!(a.row(20 + k), b.row(k));
        }
    }

    #[test]
    #[should_panic(expected = "out of view")]
    fn row_out_of_bounds_panics() {
        Table::synthetic(10, 2).view().row(10);
    }

    #[test]
    #[should_panic(expected = "out of view")]
    fn slice_out_of_bounds_panics() {
        Table::synthetic(10, 2).view().slice_rows(5, 6);
    }

    #[test]
    #[should_panic(expected = "out of view")]
    fn sub_view_cannot_escape_parent() {
        // A sub-view must not reach rows of the storage outside itself.
        let t = Table::synthetic(100, 2);
        let v = t.view().slice_rows(0, 10);
        v.row(11); // storage row 11 exists, view row 11 does not
    }

    #[test]
    fn from_data_validates_shape() {
        assert!(Table::from_data(vec![0.0; 12], 4, 3).is_ok());
        assert!(Table::from_data(vec![0.0; 11], 4, 3).is_err());
    }
}

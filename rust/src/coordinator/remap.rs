//! TLB-aware hot-row packing: live logical→physical row remaps per window.
//!
//! The paper's reach constraint makes *packing density inside a window* the
//! remaining layout lever: every gathered row costs a translation, so the
//! fewer distinct pages the hot rows straddle, the fewer uTLB misses and
//! page walks per request (TileLens, arxiv 2607.04031, measures the same
//! effect on real silicon).  A [`WindowRemap`] is a per-window permutation
//! of *local* row ids — the hot set, learned from the decayed row-frequency
//! sketch in `coordinator::metrics`, is packed contiguously into a
//! page-granule-aligned prefix of a freshly copied slab; cold rows keep
//! their original slots except for the ones displaced out of the prefix,
//! which take the slots the hot rows vacated.  A [`RemapPlan`] collects the
//! per-window remaps (`None` = identity fast path) and is published through
//! the `PlacementCell` exactly like a re-split: generation-stamped, picked
//! up by the dispatcher at the next formed batch, no drain — in-flight jobs
//! pin the old packed slab through its `Arc` until they finish.
//!
//! Nothing here allocates on the serving hot path: `row()` is one index
//! through the permutation into the packed slab.  All copying happens once,
//! on the control-plane epoch thread, when a repack is published.

use std::sync::Arc;

use crate::coordinator::chunks::{Window, WindowPlan};
use crate::coordinator::table::TableView;

/// Tuning for the repack lever.
#[derive(Debug, Clone)]
pub struct RemapConfig {
    /// Translation granule the hot prefix is aligned to (the simulated
    /// card's TLB page; clamped per window by [`granule_rows`]).
    ///
    /// [`granule_rows`]: RemapConfig::granule_rows
    pub page_bytes: u64,
    /// Cap on the packed prefix as a fraction of the window's rows.
    pub max_hot_fraction: f64,
    /// Minimum guaranteed traffic share the candidate hot set must carry
    /// before a repack is worth the copy (uniform traffic never qualifies).
    pub min_hot_share: f64,
    /// Hysteresis: skip republishing when the new hot set overlaps the
    /// live remap's hot set by at least this fraction.
    pub min_overlap_to_hold: f64,
    /// Capacity of the row-frequency sketch feeding hot-set learning.
    pub sketch_rows: usize,
}

impl Default for RemapConfig {
    fn default() -> Self {
        Self {
            page_bytes: 2 << 20,
            max_hot_fraction: 0.25,
            min_hot_share: 0.3,
            min_overlap_to_hold: 0.875,
            sketch_rows: 1024,
        }
    }
}

impl RemapConfig {
    /// Packing granule in *rows* for a window: the TLB page, halved until
    /// the window holds at least four granules (a window that cannot fit
    /// several granules has nothing to densify), never below one row.
    pub fn granule_rows(&self, row_bytes: u64, window_rows: u64) -> u64 {
        let mut rows = (self.page_bytes / row_bytes.max(1)).max(1);
        while rows > 1 && rows * 4 > window_rows {
            rows /= 2;
        }
        rows
    }
}

/// A packed layout for one window: a true permutation of the window's local
/// rows plus the packed copy of the window's data in physical order.
#[derive(Debug)]
pub struct WindowRemap {
    /// The window this remap was built for (geometry is re-checked at
    /// dispatch so a stale remap never crosses a re-split boundary).
    window: Window,
    /// Logical local row -> physical local row; a full permutation.
    perm: Box<[u32]>,
    /// Rows in the packed hot prefix (a multiple of `page_rows`).
    hot_rows: u32,
    /// Packing granule in rows the prefix is aligned to.
    page_rows: u32,
    /// Traffic share the hot set carried when the remap was planned.
    hot_share: f64,
    /// Packed copy of the window's rows, physical order.  Fresh allocation;
    /// the original table storage is untouched (mirrors the PR-4 zero-copy
    /// migration: swap by `Arc`, never mutate shared slabs).
    storage: Arc<[f32]>,
    d: usize,
}

impl WindowRemap {
    /// Build a packed remap for `window` over the full-table `view`.
    ///
    /// `hot_candidates` are window-local row ids, most frequent first
    /// (duplicates and out-of-range ids are ignored); `hot_share` is the
    /// traffic share they carry.  Returns `None` when there is nothing
    /// worth packing (no candidates, granule cap zero, or the prefix would
    /// swallow the whole window — identity is already optimal then).
    pub fn pack(
        view: &TableView,
        window: &Window,
        hot_candidates: &[u32],
        hot_share: f64,
        cfg: &RemapConfig,
    ) -> Option<Arc<WindowRemap>> {
        let rows = window.rows as usize;
        let d = view.d();
        let row_bytes = crate::coordinator::chunks::row_bytes_for_d(d);
        let page_rows = cfg.granule_rows(row_bytes, window.rows);

        // Dedup + bounds-filter the candidates, order preserved.
        let mut is_hot = vec![false; rows];
        let mut hot: Vec<u32> = Vec::with_capacity(hot_candidates.len().min(rows));
        for &c in hot_candidates {
            if (c as usize) < rows && !is_hot[c as usize] {
                is_hot[c as usize] = true;
                hot.push(c);
            }
        }
        if hot.is_empty() {
            return None;
        }

        // Prefix size: candidates rounded UP to a granule multiple, capped
        // at max_hot_fraction of the window (floored to a granule multiple).
        let cap = ((window.rows as f64 * cfg.max_hot_fraction) as u64 / page_rows) * page_rows;
        let hot_n = (hot.len() as u64)
            .div_ceil(page_rows)
            .saturating_mul(page_rows)
            .min(cap);
        if hot_n == 0 || hot_n >= window.rows {
            return None;
        }
        let hot_n = hot_n as usize;
        if hot.len() > hot_n {
            for &h in &hot[hot_n..] {
                is_hot[h as usize] = false;
            }
            hot.truncate(hot_n);
        } else {
            // Pad with the lowest-id cold rows so the prefix fills whole
            // granules (they were about to live there anyway).
            let mut l = 0u32;
            while hot.len() < hot_n {
                if !is_hot[l as usize] {
                    is_hot[l as usize] = true;
                    hot.push(l);
                }
                l += 1;
            }
        }

        // Permutation: hot row i -> physical slot i; cold rows displaced
        // from the prefix take (in order) the slots vacated by hot rows
        // that lived beyond the prefix; everything else stays put.
        let mut perm: Vec<u32> = (0..rows as u32).collect();
        for (i, &h) in hot.iter().enumerate() {
            perm[h as usize] = i as u32;
        }
        let mut vacated: Vec<u32> = hot.iter().copied().filter(|&h| h as usize >= hot_n).collect();
        vacated.sort_unstable();
        let mut next_slot = vacated.into_iter();
        for l in 0..hot_n {
            if !is_hot[l] {
                // Counts match by construction: #cold-in-prefix == #hot-beyond.
                let slot = next_slot.next()?;
                perm[l] = slot;
            }
        }

        // Packed slab: physical order, one pass over the inverse.
        let mut inv = vec![0u32; rows];
        for (l, &p) in perm.iter().enumerate() {
            inv[p as usize] = l as u32;
        }
        let mut packed: Vec<f32> = Vec::with_capacity(rows * d);
        for &l in &inv {
            packed.extend_from_slice(view.row(window.start_row + l as u64));
        }

        Some(Arc::new(WindowRemap {
            window: *window,
            perm: perm.into_boxed_slice(),
            hot_rows: hot_n as u32,
            page_rows: page_rows as u32,
            hot_share: hot_share.clamp(0.0, 1.0),
            storage: packed.into(),
            d,
        }))
    }

    /// The window geometry this remap was built for.
    pub fn window(&self) -> &Window {
        &self.window
    }

    /// Does this remap still describe `w` (same id, start, rows)?  The
    /// dispatcher drops remaps whose geometry a re-split invalidated.
    pub fn matches(&self, w: &Window) -> bool {
        self.window.id == w.id && self.window.start_row == w.start_row && self.window.rows == w.rows
    }

    /// Rows in the packed hot prefix.
    pub fn hot_rows(&self) -> u32 {
        self.hot_rows
    }

    /// Packing granule (rows).
    pub fn page_rows(&self) -> u32 {
        self.page_rows
    }

    /// Traffic share the hot set carried at planning time.
    pub fn hot_share(&self) -> f64 {
        self.hot_share
    }

    /// The hot set as logical local ids (prefix of the inverse permutation).
    pub fn hot_logical_rows(&self) -> Vec<u32> {
        let mut out = vec![0u32; self.hot_rows as usize];
        for (l, &p) in self.perm.iter().enumerate() {
            if (p as usize) < out.len() {
                out[p as usize] = l as u32;
            }
        }
        out
    }

    /// The packed slab (for `Arc::ptr_eq` pinning tests).
    pub fn storage(&self) -> &Arc<[f32]> {
        &self.storage
    }

    // hotpath: begin
    /// Physical local slot of a logical local row.
    #[inline]
    pub fn physical_of(&self, logical_local: u32) -> u32 {
        self.perm[logical_local as usize]
    }

    /// One logical local row, read through the permutation from the packed
    /// slab.  Content-identical to the unpacked `TableView` row.
    #[inline]
    pub fn row(&self, logical_local: u32) -> &[f32] {
        let p = self.perm[logical_local as usize] as usize * self.d;
        &self.storage[p..p + self.d]
    }
    // hotpath: end

    /// Full invariant check: true permutation, geometry matches the plan,
    /// granule-aligned prefix, packed slab the right shape.
    pub fn check(&self, plan: &WindowPlan) -> anyhow::Result<()> {
        let w = plan
            .windows()
            .iter()
            .find(|w| w.id == self.window.id)
            .ok_or_else(|| anyhow::anyhow!("remap window {} not in plan", self.window.id))?;
        if !self.matches(w) {
            anyhow::bail!(
                "remap geometry [{}, +{}) disagrees with plan window {} [{}, +{})",
                self.window.start_row,
                self.window.rows,
                w.id,
                w.start_row,
                w.rows
            );
        }
        let rows = self.window.rows as usize;
        if self.perm.len() != rows {
            anyhow::bail!("perm len {} != window rows {rows}", self.perm.len());
        }
        let mut seen = vec![false; rows];
        for &p in self.perm.iter() {
            let p = p as usize;
            if p >= rows || seen[p] {
                anyhow::bail!("perm is not a permutation (slot {p})");
            }
            seen[p] = true;
        }
        if self.page_rows == 0 || self.hot_rows == 0 {
            anyhow::bail!("degenerate remap: page_rows or hot_rows is zero");
        }
        if self.hot_rows as u64 >= self.window.rows {
            anyhow::bail!("hot prefix swallows the window");
        }
        if self.hot_rows % self.page_rows != 0 {
            anyhow::bail!(
                "hot prefix of {} rows not aligned to {}-row granule",
                self.hot_rows,
                self.page_rows
            );
        }
        if self.storage.len() != rows * self.d {
            anyhow::bail!(
                "packed slab holds {} f32s, window needs {}",
                self.storage.len(),
                rows * self.d
            );
        }
        if !(0.0..=1.0).contains(&self.hot_share) {
            anyhow::bail!("hot_share {} outside [0, 1]", self.hot_share);
        }
        Ok(())
    }
}

/// The published per-window remap set.  `None` entries (and windows beyond
/// the vec) are identity — the dispatcher and workers read straight from
/// the shared table storage for those.
#[derive(Debug, Clone, Default)]
pub struct RemapPlan {
    /// Generation stamped by the `PlacementCell` at publication.
    pub generation: u64,
    windows: Vec<Option<Arc<WindowRemap>>>,
}

impl RemapPlan {
    /// The identity remap: every window unpacked.
    pub fn identity() -> Self {
        Self::default()
    }

    /// Identity over `count` windows (slots ready for `set_window`).
    pub fn with_windows(count: usize) -> Self {
        Self {
            generation: 0,
            windows: vec![None; count],
        }
    }

    /// No window is packed.
    pub fn is_identity(&self) -> bool {
        self.windows.iter().all(|w| w.is_none())
    }

    /// The remap for a window, if it is packed.
    pub fn window_remap(&self, window: usize) -> Option<&Arc<WindowRemap>> {
        self.windows.get(window).and_then(|w| w.as_ref())
    }

    /// Install (or clear) one window's remap, growing the slot vec.
    pub fn set_window(&mut self, window: usize, remap: Option<Arc<WindowRemap>>) {
        if self.windows.len() <= window {
            self.windows.resize(window + 1, None);
        }
        self.windows[window] = remap;
    }

    /// Number of packed windows.
    pub fn packed_windows(&self) -> usize {
        self.windows.iter().filter(|w| w.is_some()).count()
    }

    /// Total rows living in packed hot prefixes.
    pub fn total_hot_rows(&self) -> u64 {
        self.windows
            .iter()
            .flatten()
            .map(|r| r.hot_rows() as u64)
            .sum()
    }

    /// Check every packed window against the plan it serves.
    pub fn check(&self, plan: &WindowPlan) -> anyhow::Result<()> {
        for (i, remap) in self.windows.iter().enumerate() {
            if let Some(r) = remap {
                if r.window().id != i {
                    anyhow::bail!("slot {i} holds remap for window {}", r.window().id);
                }
                r.check(plan)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::table::Table;

    fn plan_one(rows: u64) -> (Table, WindowPlan) {
        let t = Table::synthetic(rows, 8);
        let p = WindowPlan::split(rows, crate::coordinator::chunks::row_bytes_for_d(8), 1);
        (t, p)
    }

    fn small_cfg() -> RemapConfig {
        RemapConfig {
            page_bytes: 8 * 32, // 8-row granule at d=8 (row_bytes 32)
            ..RemapConfig::default()
        }
    }

    #[test]
    fn identity_plan_is_identity() {
        let (_, p) = plan_one(128);
        let id = RemapPlan::identity();
        assert!(id.is_identity());
        assert_eq!(id.packed_windows(), 0);
        assert!(id.window_remap(0).is_none());
        id.check(&p).unwrap();
    }

    #[test]
    fn pack_builds_a_checked_permutation() {
        let (t, p) = plan_one(128);
        let w = p.windows()[0];
        let cfg = small_cfg();
        // Hot rows scattered through the window, deliberately unsorted.
        let hot = [100u32, 3, 77, 12, 99, 5];
        let r = WindowRemap::pack(&t.view(), &w, &hot, 0.8, &cfg).unwrap();
        r.check(&p).unwrap();
        // 6 candidates round up to one 8-row granule.
        assert_eq!(r.hot_rows(), 8);
        assert_eq!(r.page_rows(), 8);
        // The named hot rows land in the prefix, in frequency order.
        for (i, &h) in hot.iter().enumerate() {
            assert_eq!(r.physical_of(h), i as u32);
        }
        // Every row's content survives the permutation.
        for l in 0..128u32 {
            assert_eq!(r.row(l), t.view().row(l as u64), "row {l}");
        }
    }

    #[test]
    fn pack_caps_prefix_at_max_hot_fraction() {
        let (t, p) = plan_one(128);
        let w = p.windows()[0];
        let cfg = small_cfg();
        // 64 candidates, but the cap is 0.25 * 128 = 32 rows.
        let hot: Vec<u32> = (0..64).map(|i| (i * 2) as u32).collect();
        let r = WindowRemap::pack(&t.view(), &w, &hot, 0.9, &cfg).unwrap();
        r.check(&p).unwrap();
        assert_eq!(r.hot_rows(), 32);
        // Truncation keeps the most frequent candidates.
        for (i, &h) in hot[..32].iter().enumerate() {
            assert_eq!(r.physical_of(h), i as u32);
        }
    }

    #[test]
    fn pack_declines_when_nothing_to_pack() {
        let (t, p) = plan_one(128);
        let w = p.windows()[0];
        let cfg = small_cfg();
        // No candidates at all.
        assert!(WindowRemap::pack(&t.view(), &w, &[], 0.5, &cfg).is_none());
        // Candidates all out of range are filtered to nothing.
        assert!(WindowRemap::pack(&t.view(), &w, &[500, 900], 0.5, &cfg).is_none());
        // A window too small to hold a granule-aligned prefix under the cap.
        let tiny = Window {
            id: 0,
            start_row: 0,
            rows: 8,
        };
        assert!(WindowRemap::pack(&t.view(), &tiny, &[1], 0.5, &cfg).is_none());
    }

    #[test]
    fn stale_geometry_is_detected() {
        let (t, p) = plan_one(128);
        let w = p.windows()[0];
        let cfg = small_cfg();
        let r = WindowRemap::pack(&t.view(), &w, &[1, 2, 3], 0.7, &cfg).unwrap();
        assert!(r.matches(&w));
        // A re-split moved the boundary: same id, different rows.
        let moved = Window {
            id: 0,
            start_row: 0,
            rows: 64,
        };
        assert!(!r.matches(&moved));
        let replan = WindowPlan::split(128, 32, 2);
        assert!(r.check(&replan).is_err());
    }

    #[test]
    fn plan_slots_grow_and_check() {
        let (t, p2) = {
            let t = Table::synthetic(256, 8);
            let p = WindowPlan::split(256, 32, 2);
            (t, p)
        };
        let cfg = small_cfg();
        let w1 = p2.windows()[1];
        let r = WindowRemap::pack(&t.view(), &w1, &[9, 4, 40], 0.6, &cfg).unwrap();
        let mut plan = RemapPlan::identity();
        plan.set_window(1, Some(Arc::clone(&r)));
        assert!(!plan.is_identity());
        assert_eq!(plan.packed_windows(), 1);
        assert_eq!(plan.total_hot_rows(), r.hot_rows() as u64);
        plan.check(&p2).unwrap();
        // A remap parked in the wrong slot fails the plan check.
        let mut wrong = RemapPlan::identity();
        wrong.set_window(0, Some(r));
        assert!(wrong.check(&p2).is_err());
    }

    #[test]
    fn granule_clamps_to_small_windows() {
        let cfg = RemapConfig::default();
        // 2 MiB page over 128-byte rows = 16384 rows; a 32768-row window
        // holds only 2 of those, so the granule halves until >= 4 fit.
        let g = cfg.granule_rows(128, 32_768);
        assert!(g <= 32_768 / 4);
        assert!(g.is_power_of_two());
        // Huge windows keep the full page granule.
        assert_eq!(cfg.granule_rows(128, 1 << 20), 16_384);
        // Degenerate windows clamp to one row.
        assert_eq!(cfg.granule_rows(128, 2), 1);
    }
}

#[cfg(test)]
mod prop_tests {
    use super::*;
    use crate::coordinator::table::Table;
    use crate::util::prop;

    #[test]
    fn property_packed_remaps_hold_every_invariant() {
        prop::check("remap-invariants", 60, |g| {
            let d = *g.pick(&[4usize, 8, 32]);
            let windows = g.usize(1, 3);
            let rows_per = g.u64(32, 1024);
            let total = rows_per * windows as u64;
            let t = Table::synthetic(total, d);
            let row_bytes = crate::coordinator::chunks::row_bytes_for_d(d);
            let plan = WindowPlan::split(total, row_bytes, windows);
            let cfg = RemapConfig {
                page_bytes: row_bytes * (1 << g.usize(0, 4)),
                max_hot_fraction: g.f64(0.1, 0.5),
                ..RemapConfig::default()
            };
            let mut rplan = RemapPlan::with_windows(windows);
            for w in plan.windows() {
                let n_hot = g.usize(1, (w.rows as usize / 2).max(1));
                // Candidates may repeat and run out of range; pack filters.
                let hot: Vec<u32> = (0..n_hot)
                    .map(|_| g.u64(0, w.rows + w.rows / 4) as u32)
                    .collect();
                let share = g.f64(0.0, 1.0);
                if let Some(r) = WindowRemap::pack(&t.view(), w, &hot, share, &cfg) {
                    // Invariants: permutation, alignment, geometry, shape.
                    r.check(&plan).unwrap();
                    assert_eq!(r.hot_rows() % r.page_rows(), 0);
                    assert!((r.hot_rows() as u64) < w.rows);
                    assert!(
                        r.hot_rows() as u64
                            <= ((w.rows as f64 * cfg.max_hot_fraction) as u64
                                / r.page_rows() as u64
                                + 1)
                                * r.page_rows() as u64
                    );
                    // Logical<->physical round-trip is exact.
                    let mut seen = vec![false; w.rows as usize];
                    for l in 0..w.rows as u32 {
                        let p = r.physical_of(l);
                        assert!(!seen[p as usize]);
                        seen[p as usize] = true;
                    }
                    // Content identity: packed rows == source rows.
                    for l in 0..w.rows as u32 {
                        assert_eq!(r.row(l), t.view().row(w.start_row + l as u64));
                    }
                    rplan.set_window(w.id, Some(r));
                }
            }
            rplan.check(&plan).unwrap();
        });
    }

    #[test]
    fn property_hot_candidates_land_in_prefix() {
        prop::check("remap-hot-prefix", 40, |g| {
            let t = Table::synthetic(512, 8);
            let plan = WindowPlan::split(512, 32, 1);
            let w = plan.windows()[0];
            let cfg = RemapConfig {
                page_bytes: 32 * 8,
                max_hot_fraction: 0.25,
                ..RemapConfig::default()
            };
            let n = g.usize(1, 100);
            let mut hot: Vec<u32> = (0..n).map(|_| g.u64(0, 511) as u32).collect();
            hot.dedup();
            if let Some(r) = WindowRemap::pack(&t.view(), &w, &hot, 0.5, &cfg) {
                let prefix = r.hot_rows();
                let mut uniq = std::collections::HashSet::new();
                for &h in &hot {
                    if uniq.insert(h) && (uniq.len() as u32) <= prefix {
                        assert!(
                            r.physical_of(h) < prefix,
                            "hot row {h} fell outside the {prefix}-row prefix"
                        );
                    }
                }
            }
        });
    }
}

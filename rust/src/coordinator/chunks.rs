//! Window (chunk) management: slice a huge random-access table into
//! windows no larger than the probed TLB reach.
//!
//! The table lives in *row* space: `rows x d` f32 rows, one row = one
//! 128-byte line when d = 32.  A [`WindowPlan`] cuts the row space into
//! equal windows; the paper's requirement is `window_bytes <= reach` so
//! that any SM group confined to one window never thrashes its TLB.

/// One window of table rows `[start_row, start_row + rows)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Window {
    pub id: usize,
    pub start_row: u64,
    pub rows: u64,
}

impl Window {
    pub fn end_row(&self) -> u64 {
        self.start_row + self.rows
    }

    pub fn contains(&self, row: u64) -> bool {
        row >= self.start_row && row < self.end_row()
    }

    /// Row index local to the window.
    pub fn localize(&self, row: u64) -> u64 {
        debug_assert!(self.contains(row));
        row - self.start_row
    }
}

/// The full partition of a table's row space into windows.
///
/// Windows need not be equal-width: [`WindowPlan::from_boundaries`] builds
/// load-proportional plans (the re-splitting control plane's output), where
/// a hot row range gets a narrow window and cold ranges are merged into
/// wide ones.  Equal-width plans keep an O(1) `window_of`; boundary plans
/// fall back to binary search over the (few, ≤ group-count) windows.
#[derive(Debug, Clone)]
pub struct WindowPlan {
    pub total_rows: u64,
    pub row_bytes: u64,
    windows: Vec<Window>,
    /// Row width of all non-final windows (for O(1) lookup); 0 when the
    /// plan is non-uniform (`from_boundaries`) and lookup binary-searches.
    stride: u64,
}

impl WindowPlan {
    /// Cut `total_rows` into `count` near-equal windows.
    pub fn split(total_rows: u64, row_bytes: u64, count: usize) -> Self {
        assert!(count >= 1);
        assert!(
            total_rows >= count as u64,
            "fewer rows ({total_rows}) than windows ({count})"
        );
        let stride = total_rows.div_ceil(count as u64);
        let mut windows = Vec::with_capacity(count);
        let mut start = 0;
        for id in 0..count {
            let rows = stride.min(total_rows - start);
            assert!(rows > 0, "window {id} would be empty");
            windows.push(Window {
                id,
                start_row: start,
                rows,
            });
            start += rows;
        }
        assert_eq!(start, total_rows);
        Self {
            total_rows,
            row_bytes,
            windows,
            stride,
        }
    }

    /// Build a (possibly non-uniform) plan from explicit window start rows.
    /// `starts[0]` must be 0 and starts must be strictly increasing below
    /// `total_rows`; window `i` spans `[starts[i], starts[i+1])` (the last
    /// runs to `total_rows`).  This is the re-splitting control plane's
    /// constructor: boundaries land wherever the observed load density says.
    pub fn from_boundaries(
        total_rows: u64,
        row_bytes: u64,
        starts: &[u64],
    ) -> anyhow::Result<Self> {
        if starts.first() != Some(&0) {
            anyhow::bail!("boundary plan must start at row 0");
        }
        let mut windows = Vec::with_capacity(starts.len());
        for (id, &start) in starts.iter().enumerate() {
            let end = starts.get(id + 1).copied().unwrap_or(total_rows);
            if end <= start || end > total_rows {
                anyhow::bail!(
                    "boundary {id} spans [{start}, {end}) over {total_rows} rows: \
                     starts must be strictly increasing and below the table end"
                );
            }
            windows.push(Window {
                id,
                start_row: start,
                rows: end - start,
            });
        }
        // Keep the O(1) stride path when the boundaries happen to be the
        // uniform split (all non-final windows equal, final no larger).
        let stride = match windows.split_last() {
            Some((last, rest))
                if rest
                    .iter()
                    .all(|w| w.rows == windows[0].rows)
                    && last.rows <= windows[0].rows
                    && !rest.is_empty() =>
            {
                windows[0].rows
            }
            Some((_only, [])) => total_rows,
            _ => 0,
        };
        Ok(Self {
            total_rows,
            row_bytes,
            windows,
            stride,
        })
    }

    /// The start rows of every window (inverse of
    /// [`from_boundaries`](Self::from_boundaries)).
    pub fn boundaries(&self) -> Vec<u64> {
        self.windows.iter().map(|w| w.start_row).collect()
    }

    /// Cut a table into as few windows as possible subject to the probed
    /// reach (the paper's construction: windows <= reach, one per group,
    /// group count permitting).
    pub fn for_reach(
        total_rows: u64,
        row_bytes: u64,
        reach_bytes: u64,
        max_windows: usize,
    ) -> anyhow::Result<Self> {
        let total_bytes = total_rows * row_bytes;
        let need = total_bytes.div_ceil(reach_bytes).max(1) as usize;
        if need > max_windows {
            anyhow::bail!(
                "table of {total_bytes} bytes needs {need} windows of <= {reach_bytes} bytes, \
                 but only {max_windows} groups are available"
            );
        }
        Ok(Self::split(total_rows, row_bytes, need))
    }

    pub fn windows(&self) -> &[Window] {
        &self.windows
    }

    pub fn count(&self) -> usize {
        self.windows.len()
    }

    /// Window containing a global row (O(1) for uniform plans, O(log W)
    /// for boundary plans — W never exceeds the group count).
    pub fn window_of(&self, row: u64) -> &Window {
        assert!(row < self.total_rows, "row {row} out of table");
        let idx = if self.stride > 0 {
            // Final window may be shorter than stride; idx can overshoot by
            // one only when stride divides unevenly — clamp.
            ((row / self.stride) as usize).min(self.windows.len() - 1)
        } else {
            self.windows.partition_point(|w| w.end_row() <= row)
        };
        debug_assert!(self.windows[idx].contains(row));
        &self.windows[idx]
    }

    /// Are these the same window boundaries (ignoring ids/derived state)?
    pub fn same_boundaries(&self, other: &WindowPlan) -> bool {
        self.total_rows == other.total_rows
            && self.windows.len() == other.windows.len()
            && self
                .windows
                .iter()
                .zip(&other.windows)
                .all(|(a, b)| a.start_row == b.start_row)
    }

    /// Bytes spanned by one window.
    pub fn window_bytes(&self, w: &Window) -> u64 {
        w.rows * self.row_bytes
    }

    /// Are all windows within `reach` bytes?  (The paper's invariant.)
    pub fn fits_reach(&self, reach_bytes: u64) -> bool {
        self.windows
            .iter()
            .all(|w| self.window_bytes(w) <= reach_bytes)
    }

    /// The device byte region of a window (rows scaled by row_bytes) — for
    /// driving the simulator with window-constrained access patterns.
    pub fn region_of(&self, w: &Window) -> crate::sim::MemRegion {
        crate::sim::MemRegion::new(w.start_row * self.row_bytes, w.rows * self.row_bytes)
    }
}

/// Row width in bytes for a `d`-wide f32 table (d=32 -> one 128 B line).
pub fn row_bytes_for_d(d: usize) -> u64 {
    (d * 4) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_covers_rows_exactly() {
        let p = WindowPlan::split(1000, 128, 3);
        assert_eq!(p.count(), 3);
        let total: u64 = p.windows().iter().map(|w| w.rows).sum();
        assert_eq!(total, 1000);
        assert_eq!(p.windows()[0].start_row, 0);
        for w in p.windows().windows(2) {
            assert_eq!(w[0].end_row(), w[1].start_row);
        }
    }

    #[test]
    fn window_of_is_consistent_with_contains() {
        let p = WindowPlan::split(1000, 128, 7);
        for row in 0..1000 {
            let w = p.window_of(row);
            assert!(w.contains(row));
            assert_eq!(w.localize(row), row - w.start_row);
        }
    }

    #[test]
    fn for_reach_minimizes_window_count() {
        // 1 GiB of rows at 128 B, reach 256 MiB -> 4 windows.
        let rows = (1u64 << 30) / 128;
        let p = WindowPlan::for_reach(rows, 128, 256 << 20, 14).unwrap();
        assert_eq!(p.count(), 4);
        assert!(p.fits_reach(256 << 20));
    }

    #[test]
    fn for_reach_single_window_when_table_fits() {
        let p = WindowPlan::for_reach(1024, 128, 1 << 30, 14).unwrap();
        assert_eq!(p.count(), 1);
    }

    #[test]
    fn for_reach_fails_when_groups_insufficient() {
        // 100 windows needed, only 14 groups.
        let rows = (100u64 << 20) / 128;
        assert!(WindowPlan::for_reach(rows, 128, 1 << 20, 14).is_err());
    }

    #[test]
    fn line_rows() {
        assert_eq!(row_bytes_for_d(32), crate::config::LINE_BYTES);
    }

    #[test]
    fn uneven_final_window() {
        let p = WindowPlan::split(10, 128, 3);
        let sizes: Vec<u64> = p.windows().iter().map(|w| w.rows).collect();
        assert_eq!(sizes, vec![4, 4, 2]);
        assert_eq!(p.window_of(9).id, 2);
        assert_eq!(p.window_of(8).id, 2);
        assert_eq!(p.window_of(7).id, 1);
    }

    #[test]
    #[should_panic(expected = "out of table")]
    fn window_of_out_of_range_panics() {
        WindowPlan::split(10, 128, 2).window_of(10);
    }

    #[test]
    fn from_boundaries_builds_non_uniform_plans() {
        let p = WindowPlan::from_boundaries(1000, 128, &[0, 100, 150, 900]).unwrap();
        assert_eq!(p.count(), 4);
        let sizes: Vec<u64> = p.windows().iter().map(|w| w.rows).collect();
        assert_eq!(sizes, vec![100, 50, 750, 100]);
        // Lookup agrees with containment at every boundary edge.
        for row in [0u64, 99, 100, 149, 150, 899, 900, 999] {
            let w = p.window_of(row);
            assert!(w.contains(row), "row {row} -> window {}", w.id);
        }
        assert_eq!(p.window_of(99).id, 0);
        assert_eq!(p.window_of(100).id, 1);
        assert_eq!(p.window_of(999).id, 3);
        assert_eq!(p.boundaries(), vec![0, 100, 150, 900]);
    }

    #[test]
    fn from_boundaries_uniform_keeps_stride_semantics() {
        let split = WindowPlan::split(10, 128, 3);
        let rebuilt = WindowPlan::from_boundaries(10, 128, &split.boundaries()).unwrap();
        assert!(split.same_boundaries(&rebuilt));
        for row in 0..10 {
            assert_eq!(split.window_of(row).id, rebuilt.window_of(row).id);
        }
        // Single-window plans work through both constructors.
        let one = WindowPlan::from_boundaries(10, 128, &[0]).unwrap();
        assert_eq!(one.count(), 1);
        assert_eq!(one.window_of(9).id, 0);
        assert!(!one.same_boundaries(&split));
    }

    #[test]
    fn from_boundaries_rejects_malformed_starts() {
        assert!(WindowPlan::from_boundaries(100, 128, &[1, 50]).is_err());
        assert!(WindowPlan::from_boundaries(100, 128, &[0, 50, 50]).is_err());
        assert!(WindowPlan::from_boundaries(100, 128, &[0, 120]).is_err());
        assert!(WindowPlan::from_boundaries(100, 128, &[]).is_err());
    }

    #[test]
    fn region_of_maps_rows_to_bytes() {
        let p = WindowPlan::split(1000, 128, 2);
        let r = p.region_of(&p.windows()[1]);
        assert_eq!(r.base, 500 * 128);
        assert_eq!(r.len, 500 * 128);
    }
}

#[cfg(test)]
mod prop_tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn property_split_partitions_and_localizes() {
        prop::check("windowplan-partition", 60, |g| {
            let rows = g.u64(1, 100_000);
            let count = g.usize(1, 16.min(rows as usize));
            let plan = WindowPlan::split(rows, 128, count);

            // Windows tile the row space exactly.
            assert_eq!(plan.windows()[0].start_row, 0);
            assert_eq!(plan.windows().last().unwrap().end_row(), rows);
            for w in plan.windows().windows(2) {
                assert_eq!(w[0].end_row(), w[1].start_row);
                assert!(w[0].rows > 0 && w[1].rows > 0);
            }

            // window_of + localize round-trip for random rows.
            for _ in 0..50 {
                let row = g.u64(0, rows - 1);
                let w = plan.window_of(row);
                assert!(w.contains(row));
                assert_eq!(w.start_row + w.localize(row), row);
            }
        });
    }

    #[test]
    fn property_boundary_plans_partition_and_localize() {
        prop::check("windowplan-boundaries", 60, |g| {
            let rows = g.u64(16, 100_000);
            let count = g.usize(1, 12.min(rows as usize));
            // Random strictly-increasing starts beginning at 0.
            let mut starts: Vec<u64> = vec![0];
            let mut used = std::collections::BTreeSet::new();
            used.insert(0u64);
            while starts.len() < count {
                let s = g.u64(1, rows - 1);
                if used.insert(s) {
                    starts.push(s);
                }
            }
            starts.sort_unstable();
            let plan = WindowPlan::from_boundaries(rows, 128, &starts).unwrap();
            assert_eq!(plan.count(), starts.len());
            assert_eq!(plan.windows().last().unwrap().end_row(), rows);
            for w in plan.windows().windows(2) {
                assert_eq!(w[0].end_row(), w[1].start_row);
            }
            for _ in 0..60 {
                let row = g.u64(0, rows - 1);
                let w = plan.window_of(row);
                assert!(w.contains(row));
                assert_eq!(w.start_row + w.localize(row), row);
            }
        });
    }

    #[test]
    fn property_for_reach_respects_invariant() {
        prop::check("windowplan-reach", 40, |g| {
            let rows = g.u64(1024, 1 << 22);
            let reach = g.u64(1 << 17, 1 << 26);
            match WindowPlan::for_reach(rows, 128, reach, 14) {
                Ok(plan) => {
                    assert!(plan.fits_reach(reach), "window exceeds reach");
                    assert!(plan.count() <= 14);
                    // Minimality: one fewer window would violate reach
                    // (unless a single window already fits).
                    if plan.count() > 1 {
                        let fewer = WindowPlan::split(rows, 128, plan.count() - 1);
                        assert!(!fewer.fits_reach(reach));
                    }
                }
                Err(_) => {
                    // Only legal when even 14 windows cannot satisfy reach.
                    assert!(rows * 128 > reach * 14);
                }
            }
        });
    }
}

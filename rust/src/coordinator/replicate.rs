//! Hot-shard read replication: the fifth (and most expensive) control-plane
//! lever.
//!
//! Every other lever rearranges *one* card's bandwidth — re-deal, re-split,
//! and repack re-shape a card's TLB windows, migration re-homes whole row
//! ranges.  None of them helps when a single window's offered load exceeds
//! one card's calibrated bandwidth: per-channel HBM ceilings are a hard
//! wall only aggregation across copies can move (cf. *Benchmarking High
//! Bandwidth Memory on FPGAs*, arXiv 2005.04324).  A [`ReplicaSet`] is the
//! published description of that aggregation: for each replicated shard, a
//! list of *additional* cards serving a zero-copy replica — the replica's
//! backend is another `TableView::slice_rows` over the same shared
//! `Arc<[f32]>` (a refcount bump, not a copy), covering exactly the owner's
//! global row range so local row ids are identical on every copy.
//!
//! The set is generation-stamped and published exactly like a plan /
//! placement / remap swap through the fleet's state cell (the fleet-scope
//! analog of the single-card `PlacementCell`): in-flight `FleetTicket`s pin
//! their submit-time state — replica services included — through its `Arc`,
//! so de-replication needs no drain; a retired replica's backend stops when
//! the last pinned ticket redeems.
//!
//! Reads route by power-of-two-choices over live per-card queue depth
//! (`service::fleet` owns the gauges); this module owns only the published
//! *description* and its invariants.

use crate::coordinator::cluster::FleetPlan;

/// Tuning for the replicate lever.
#[derive(Debug, Clone)]
pub struct ReplicateConfig {
    /// Minimum share of an epoch's routed rows the hottest shard must carry
    /// before it counts as a single-window hotspot (uniform traffic over
    /// `n` cards sits near `1/n` and never qualifies).
    pub hot_share_min: f64,
    /// Demand threshold: the hot shard's observed row rate, in bytes/s,
    /// must exceed this fraction of the owning card's calibrated aggregate
    /// bandwidth before a replica is worth another card's capacity.
    pub capacity_fraction: f64,
    /// Hysteresis floor: when the replicated shard's combined load share
    /// (owner + replicas) falls below this, the replicas are dropped.
    pub exit_share: f64,
    /// Cap on replicas per shard (each costs one extra card's bandwidth).
    pub max_replicas: usize,
}

impl Default for ReplicateConfig {
    fn default() -> Self {
        Self {
            hot_share_min: 0.5,
            capacity_fraction: 0.5,
            exit_share: 0.35,
            max_replicas: 2,
        }
    }
}

/// One read replica: `shard`'s row range served (additionally) by `card`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Replica {
    /// Index into the fleet plan's shard list.
    pub shard: usize,
    /// The card hosting the replica (never the shard's owning card).
    pub card: usize,
}

/// The published replica description: generation-stamped, immutable once
/// published (a change is a fresh `ReplicaSet` and a generation bump, never
/// a mutation — the same publish discipline as `RemapPlan`).
#[derive(Debug, Clone, Default)]
pub struct ReplicaSet {
    /// Generation stamped at publication (fleet plan generation space).
    pub generation: u64,
    replicas: Vec<Replica>,
}

impl ReplicaSet {
    /// The empty set: every shard served only by its owner.
    pub fn identity() -> Self {
        Self::default()
    }

    /// A set holding `replicas` (validate with [`check`](Self::check)
    /// before publishing).
    pub fn with_replicas(generation: u64, replicas: Vec<Replica>) -> Self {
        Self {
            generation,
            replicas,
        }
    }

    /// No shard is replicated.
    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    /// Total replicas across all shards.
    pub fn count(&self) -> usize {
        self.replicas.len()
    }

    /// All replicas, publication order.
    pub fn replicas(&self) -> &[Replica] {
        &self.replicas
    }

    /// The replica cards serving `shard` (besides its owner).
    pub fn cards_of(&self, shard: usize) -> impl Iterator<Item = usize> + '_ {
        self.replicas
            .iter()
            .filter(move |r| r.shard == shard)
            .map(|r| r.card)
    }

    /// Replica count for one shard.
    pub fn replicas_of(&self, shard: usize) -> usize {
        self.replicas.iter().filter(|r| r.shard == shard).count()
    }

    /// Invariants against the plan the set serves: every replica names a
    /// real shard and a real card, never the shard's own owner, and no
    /// (shard, card) pair repeats — a duplicate would double-count a queue
    /// in the power-of-two-choices sample.
    pub fn check(&self, plan: &FleetPlan, n_cards: usize) -> anyhow::Result<()> {
        for (i, r) in self.replicas.iter().enumerate() {
            let shard = plan
                .shards
                .get(r.shard)
                .ok_or_else(|| anyhow::anyhow!("replica {i} names shard {} not in plan", r.shard))?;
            if r.card >= n_cards {
                anyhow::bail!("replica {i} names card {} of {n_cards}", r.card);
            }
            if r.card == shard.card {
                anyhow::bail!(
                    "replica {i} of shard {} lives on its owner card {}",
                    r.shard,
                    shard.card
                );
            }
            if self.replicas[..i]
                .iter()
                .any(|p| p.shard == r.shard && p.card == r.card)
            {
                anyhow::bail!("duplicate replica: shard {} on card {}", r.shard, r.card);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cluster::CardSpec;
    use crate::probe::TopologyMap;

    fn plan3() -> FleetPlan {
        let specs: Vec<CardSpec> = (0..3)
            .map(|i| CardSpec {
                map: TopologyMap {
                    groups: (0..4).map(|g| vec![g]).collect(),
                    reach_bytes: 1 << 30,
                    solo_gbps: vec![100.0; 4],
                    independent: true,
                    card_id: format!("replica-test-{i}"),
                },
                memory_bytes: 1 << 30,
            })
            .collect();
        FleetPlan::build(&specs, 3 * 1024, 128, 7).unwrap()
    }

    #[test]
    fn identity_is_empty_and_checks() {
        let plan = plan3();
        let set = ReplicaSet::identity();
        assert!(set.is_empty());
        assert_eq!(set.count(), 0);
        assert_eq!(set.replicas_of(0), 0);
        set.check(&plan, 3).unwrap();
    }

    #[test]
    fn replicas_resolve_per_shard() {
        let plan = plan3();
        let owner0 = plan.shards[0].card;
        let others: Vec<usize> = (0..3).filter(|&c| c != owner0).collect();
        let set = ReplicaSet::with_replicas(
            5,
            others
                .iter()
                .map(|&card| Replica { shard: 0, card })
                .collect(),
        );
        set.check(&plan, 3).unwrap();
        assert_eq!(set.count(), 2);
        assert_eq!(set.replicas_of(0), 2);
        assert_eq!(set.replicas_of(1), 0);
        let cards: Vec<usize> = set.cards_of(0).collect();
        assert_eq!(cards, others);
    }

    #[test]
    fn check_rejects_bad_replicas() {
        let plan = plan3();
        let owner0 = plan.shards[0].card;
        // Owner card as its own replica.
        let set = ReplicaSet::with_replicas(1, vec![Replica { shard: 0, card: owner0 }]);
        assert!(set.check(&plan, 3).is_err());
        // Shard out of range.
        let set = ReplicaSet::with_replicas(1, vec![Replica { shard: 99, card: 0 }]);
        assert!(set.check(&plan, 3).is_err());
        // Card out of range.
        let set = ReplicaSet::with_replicas(1, vec![Replica { shard: 0, card: 99 }]);
        assert!(set.check(&plan, 3).is_err());
        // Duplicate (shard, card) pair.
        let other = (0..3).find(|&c| c != owner0).unwrap();
        let set = ReplicaSet::with_replicas(
            1,
            vec![
                Replica { shard: 0, card: other },
                Replica { shard: 0, card: other },
            ],
        );
        assert!(set.check(&plan, 3).is_err());
    }
}

//! Placement policies: which SM resource group serves which window.
//!
//! The paper's three experimental arms, as deployable policies:
//!
//! * [`PlacementPolicy::Naive`]        — no constraint: every group roams
//!   the whole table (Fig 1 "uniform": thrashes past 64 GB).
//! * [`PlacementPolicy::SmToChunk`]    — each *SM* is pinned to a window,
//!   groups end up straddling windows (Fig 1 "SM-to-chunk": no benefit).
//! * [`PlacementPolicy::GroupToChunk`] — each *group* is pinned to one
//!   window (Fig 6: full speed over the whole memory).  The contribution.
//!
//! A [`Placement`] also answers the inverse question the router needs:
//! which groups may serve a given window.

use crate::probe::TopologyMap;
use crate::sim::{Machine, Pattern, SmAssignment};
use crate::util::rng::Rng;

use super::chunks::WindowPlan;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    Naive,
    SmToChunk,
    GroupToChunk,
}

impl std::fmt::Display for PlacementPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            PlacementPolicy::Naive => "naive",
            PlacementPolicy::SmToChunk => "sm-to-chunk",
            PlacementPolicy::GroupToChunk => "group-to-chunk",
        };
        f.write_str(s)
    }
}

impl PlacementPolicy {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "naive" => Ok(Self::Naive),
            "sm-to-chunk" | "sm" => Ok(Self::SmToChunk),
            "group-to-chunk" | "group" => Ok(Self::GroupToChunk),
            _ => anyhow::bail!("unknown policy '{s}' (naive|sm-to-chunk|group-to-chunk)"),
        }
    }
}

/// A concrete assignment of groups to windows.
#[derive(Debug, Clone)]
pub struct Placement {
    pub policy: PlacementPolicy,
    /// window id -> group indices (into `map.groups`) serving it.
    pub groups_of_window: Vec<Vec<usize>>,
    /// group index -> window id it is pinned to (GroupToChunk only; under
    /// other policies groups serve every window).
    pub window_of_group: Vec<usize>,
}

impl Placement {
    /// Build a placement.  GroupToChunk assigns groups to windows
    /// round-robin weighted by probed solo throughput: every window gets at
    /// least one group, faster groups absorb leftover windows' load (and
    /// when windows < groups, spare groups double up on windows).
    pub fn build(
        policy: PlacementPolicy,
        map: &TopologyMap,
        plan: &WindowPlan,
        seed: u64,
    ) -> anyhow::Result<Self> {
        let g = map.groups.len();
        let w = plan.count();
        if g == 0 || w == 0 {
            anyhow::bail!("empty topology map or window plan");
        }
        match policy {
            PlacementPolicy::Naive | PlacementPolicy::SmToChunk => {
                // All groups serve all windows (the router spreads load);
                // window_of_group is a synthetic striping used only for the
                // SmToChunk *simulation* arm.
                let mut rng = Rng::seed_from_u64(seed);
                let window_of_group = (0..g).map(|_| rng.gen_index(w)).collect();
                Ok(Self {
                    policy,
                    groups_of_window: vec![(0..g).collect(); w],
                    window_of_group,
                })
            }
            PlacementPolicy::GroupToChunk => {
                if g < w {
                    anyhow::bail!("{w} windows but only {g} groups: cannot pin 1:1");
                }
                // Sort groups by probed solo throughput (desc) and deal them
                // to windows round-robin: each window's serving capacity
                // stays balanced.
                let mut order: Vec<usize> = (0..g).collect();
                order.sort_by(|&a, &b| {
                    map.solo_gbps[b]
                        .partial_cmp(&map.solo_gbps[a])
                        .unwrap()
                        .then(a.cmp(&b))
                });
                let mut groups_of_window = vec![Vec::new(); w];
                let mut window_of_group = vec![0usize; g];
                for (k, &gi) in order.iter().enumerate() {
                    let wid = k % w;
                    groups_of_window[wid].push(gi);
                    window_of_group[gi] = wid;
                }
                Ok(Self {
                    policy,
                    groups_of_window,
                    window_of_group,
                })
            }
        }
    }

    /// Serving groups for a window.
    pub fn serving_groups(&self, window: usize) -> &[usize] {
        &self.groups_of_window[window]
    }

    /// Probed capacity (GB/s) dedicated to a window.
    pub fn window_capacity_gbps(&self, map: &TopologyMap, window: usize) -> f64 {
        self.groups_of_window[window]
            .iter()
            .map(|&g| map.solo_gbps[g])
            .sum()
    }

    /// Translate the placement into per-SM simulator assignments over a
    /// device-resident table occupying `plan`'s row space from byte 0.
    /// This is what the Fig-1/Fig-6 experiments run.
    pub fn sim_assignments(
        &self,
        map: &TopologyMap,
        plan: &WindowPlan,
        machine: &Machine,
        seed: u64,
    ) -> Vec<SmAssignment> {
        let whole = crate::sim::MemRegion::new(0, plan.total_rows * plan.row_bytes);
        let mut rng = Rng::seed_from_u64(seed);
        let mut out = Vec::new();
        for (gi, group) in map.groups.iter().enumerate() {
            for &smid in group {
                if smid >= machine.topology().sm_count() {
                    continue;
                }
                let pattern = match self.policy {
                    PlacementPolicy::Naive => Pattern::Uniform(whole),
                    PlacementPolicy::SmToChunk => {
                        // Each SM independently picks a window (the paper's
                        // "pick a random half per SM").
                        let w = &plan.windows()[rng.gen_index(plan.count())];
                        Pattern::Uniform(plan.region_of(w))
                    }
                    PlacementPolicy::GroupToChunk => {
                        let w = &plan.windows()[self.window_of_group[gi]];
                        Pattern::Uniform(plan.region_of(w))
                    }
                };
                out.push(SmAssignment { smid, pattern });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;

    fn test_map() -> TopologyMap {
        TopologyMap {
            groups: vec![
                vec![0, 1, 2, 3],
                vec![4, 5, 6, 7],
                vec![8, 9],
                vec![10, 11],
            ],
            reach_bytes: 16 << 20,
            solo_gbps: vec![120.0, 118.0, 90.0, 91.0],
            independent: true,
            card_id: "test".into(),
        }
    }

    fn plan(windows: usize) -> WindowPlan {
        WindowPlan::split(1 << 20, 128, windows)
    }

    #[test]
    fn group_to_chunk_pins_every_window() {
        let p = Placement::build(PlacementPolicy::GroupToChunk, &test_map(), &plan(2), 0).unwrap();
        assert_eq!(p.groups_of_window.len(), 2);
        for w in 0..2 {
            assert!(!p.serving_groups(w).is_empty());
        }
        // All 4 groups assigned, each to exactly one window.
        let mut seen = vec![false; 4];
        for w in 0..2 {
            for &g in p.serving_groups(w) {
                assert!(!seen[g]);
                seen[g] = true;
                assert_eq!(p.window_of_group[g], w);
            }
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn group_to_chunk_balances_capacity() {
        let p = Placement::build(PlacementPolicy::GroupToChunk, &test_map(), &plan(2), 0).unwrap();
        let m = test_map();
        let c0 = p.window_capacity_gbps(&m, 0);
        let c1 = p.window_capacity_gbps(&m, 1);
        // Weighted dealing: both windows get one fast + one slow group.
        assert!((c0 - c1).abs() / c0.max(c1) < 0.1, "c0={c0} c1={c1}");
    }

    #[test]
    fn group_to_chunk_rejects_too_many_windows() {
        assert!(Placement::build(PlacementPolicy::GroupToChunk, &test_map(), &plan(5), 0).is_err());
    }

    #[test]
    fn naive_serves_everything() {
        let p = Placement::build(PlacementPolicy::Naive, &test_map(), &plan(3), 0).unwrap();
        for w in 0..3 {
            assert_eq!(p.serving_groups(w).len(), 4);
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [
            PlacementPolicy::Naive,
            PlacementPolicy::SmToChunk,
            PlacementPolicy::GroupToChunk,
        ] {
            assert_eq!(PlacementPolicy::parse(&p.to_string()).unwrap(), p);
        }
        assert!(PlacementPolicy::parse("bogus").is_err());
    }

    #[test]
    fn sim_assignments_respect_policy() {
        let machine = Machine::new(MachineConfig::tiny_test()).unwrap();
        // Use the real topology for the map so smids are valid.
        let topo = machine.topology();
        let map = TopologyMap {
            groups: (0..topo.group_count()).map(|g| topo.sms_in_group(g)).collect(),
            reach_bytes: machine.config().tlb.reach_bytes(),
            solo_gbps: vec![100.0; topo.group_count()],
            independent: true,
            card_id: "t".into(),
        };
        let plan = WindowPlan::split(
            machine.config().memory.total_bytes / 128,
            128,
            2,
        );
        let p = Placement::build(PlacementPolicy::GroupToChunk, &map, &plan, 1).unwrap();
        let asg = p.sim_assignments(&map, &plan, &machine, 2);
        assert_eq!(asg.len(), topo.sm_count());
        // All SMs of one group read the same region.
        for (gi, group) in map.groups.iter().enumerate() {
            let want = plan.region_of(&plan.windows()[p.window_of_group[gi]]);
            for &smid in group {
                let a = asg.iter().find(|a| a.smid == smid).unwrap();
                assert_eq!(a.pattern.region(), &want);
            }
        }
    }
}

//! Placement policies: which SM resource group serves which window.
//!
//! The paper's three experimental arms, as deployable policies:
//!
//! * [`PlacementPolicy::Naive`]        — no constraint: every group roams
//!   the whole table (Fig 1 "uniform": thrashes past 64 GB).
//! * [`PlacementPolicy::SmToChunk`]    — each *SM* is pinned to a window,
//!   groups end up straddling windows (Fig 1 "SM-to-chunk": no benefit).
//! * [`PlacementPolicy::GroupToChunk`] — each *group* is pinned to one
//!   window (Fig 6: full speed over the whole memory).  The contribution.
//!
//! A [`Placement`] also answers the inverse question the router needs:
//! which groups may serve a given window.
//!
//! Placement is a *live* layer, not a boot-time literal: the [`Placer`]
//! trait produces placements (the three static arms via [`StaticPlacer`],
//! skew-aware rebalancing via
//! [`AdaptivePlacer`](super::adaptive::AdaptivePlacer)), and a
//! [`PlacementCell`] publishes generation-stamped swaps to the dispatch
//! path without draining in-flight tickets.  At fleet scope the same
//! publish-by-generation discipline covers hot-shard read replicas: a
//! [`ReplicaSet`](super::replicate::ReplicaSet) stamps which cards
//! additionally serve the hot shard (`service/fleet.rs` routes over it
//! by power-of-two-choices).

use std::sync::{Arc, RwLock};

use crate::probe::TopologyMap;
use crate::sim::{Machine, Pattern, SmAssignment};
use crate::util::rng::Rng;

use super::chunks::WindowPlan;
use super::remap::RemapPlan;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    Naive,
    SmToChunk,
    GroupToChunk,
}

impl std::fmt::Display for PlacementPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            PlacementPolicy::Naive => "naive",
            PlacementPolicy::SmToChunk => "sm-to-chunk",
            PlacementPolicy::GroupToChunk => "group-to-chunk",
        };
        f.write_str(s)
    }
}

impl PlacementPolicy {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "naive" => Ok(Self::Naive),
            "sm-to-chunk" | "sm" => Ok(Self::SmToChunk),
            "group-to-chunk" | "group" => Ok(Self::GroupToChunk),
            _ => anyhow::bail!("unknown policy '{s}' (naive|sm-to-chunk|group-to-chunk)"),
        }
    }
}

/// A concrete assignment of groups to windows.
#[derive(Debug, Clone)]
pub struct Placement {
    pub policy: PlacementPolicy,
    /// Swap stamp: 0 at construction, bumped by [`PlacementCell::store`]
    /// each time a rebalanced placement goes live.
    pub generation: u64,
    /// window id -> group indices (into `map.groups`) serving it.
    pub groups_of_window: Vec<Vec<usize>>,
    /// group index -> window id it is pinned to (GroupToChunk only; under
    /// other policies groups serve every window).
    pub window_of_group: Vec<usize>,
}

impl Placement {
    /// Build a placement.  GroupToChunk assigns groups to windows
    /// round-robin weighted by probed solo throughput: every window gets at
    /// least one group, faster groups absorb leftover windows' load (and
    /// when windows < groups, spare groups double up on windows).
    pub fn build(
        policy: PlacementPolicy,
        map: &TopologyMap,
        plan: &WindowPlan,
        seed: u64,
    ) -> anyhow::Result<Self> {
        let g = map.groups.len();
        let w = plan.count();
        if g == 0 || w == 0 {
            anyhow::bail!("empty topology map or window plan");
        }
        match policy {
            PlacementPolicy::Naive | PlacementPolicy::SmToChunk => {
                // All groups serve all windows (the router spreads load);
                // window_of_group is a synthetic striping used only for the
                // SmToChunk *simulation* arm.
                let mut rng = Rng::seed_from_u64(seed);
                let window_of_group = (0..g).map(|_| rng.gen_index(w)).collect();
                Ok(Self {
                    policy,
                    generation: 0,
                    groups_of_window: vec![(0..g).collect(); w],
                    window_of_group,
                })
            }
            PlacementPolicy::GroupToChunk => {
                if g < w {
                    anyhow::bail!("{w} windows but only {g} groups: cannot pin 1:1");
                }
                // Sort groups by probed solo throughput (desc) and deal them
                // to windows round-robin: each window's serving capacity
                // stays balanced.
                let mut order: Vec<usize> = (0..g).collect();
                order.sort_by(|&a, &b| {
                    map.solo_gbps[b]
                        .partial_cmp(&map.solo_gbps[a])
                        // PANIC: probed throughputs are finite, never NaN.
                        .unwrap()
                        .then(a.cmp(&b))
                });
                let mut groups_of_window = vec![Vec::new(); w];
                let mut window_of_group = vec![0usize; g];
                for (k, &gi) in order.iter().enumerate() {
                    let wid = k % w;
                    groups_of_window[wid].push(gi);
                    window_of_group[gi] = wid;
                }
                Ok(Self {
                    policy,
                    generation: 0,
                    groups_of_window,
                    window_of_group,
                })
            }
        }
    }

    /// Serving groups for a window.
    pub fn serving_groups(&self, window: usize) -> &[usize] {
        &self.groups_of_window[window]
    }

    /// Probed capacity (GB/s) dedicated to a window.
    pub fn window_capacity_gbps(&self, map: &TopologyMap, window: usize) -> f64 {
        self.groups_of_window[window]
            .iter()
            .map(|&g| map.solo_gbps[g])
            .sum()
    }

    /// Translate the placement into per-SM simulator assignments over a
    /// device-resident table occupying `plan`'s row space from byte 0.
    /// This is what the Fig-1/Fig-6 experiments run.
    pub fn sim_assignments(
        &self,
        map: &TopologyMap,
        plan: &WindowPlan,
        machine: &Machine,
        seed: u64,
    ) -> Vec<SmAssignment> {
        let whole = crate::sim::MemRegion::new(0, plan.total_rows * plan.row_bytes);
        let mut rng = Rng::seed_from_u64(seed);
        let mut out = Vec::new();
        for (gi, group) in map.groups.iter().enumerate() {
            for &smid in group {
                if smid >= machine.topology().sm_count() {
                    continue;
                }
                let pattern = match self.policy {
                    PlacementPolicy::Naive => Pattern::Uniform(whole),
                    PlacementPolicy::SmToChunk => {
                        // Each SM independently picks a window (the paper's
                        // "pick a random half per SM").
                        let w = &plan.windows()[rng.gen_index(plan.count())];
                        Pattern::Uniform(plan.region_of(w))
                    }
                    PlacementPolicy::GroupToChunk => {
                        let w = &plan.windows()[self.window_of_group[gi]];
                        Pattern::Uniform(plan.region_of(w))
                    }
                };
                out.push(SmAssignment { smid, pattern });
            }
        }
        out
    }

    /// What every consumer of a placement structurally requires before the
    /// paper-level invariant even applies: one serving list per plan
    /// window, none empty, every group id within the map, and a
    /// window-per-group table sized to the map.  The router panics on
    /// anything less (`% 0` / index OOB), so backend startup and live-swap
    /// gates check this in release builds — the one validator behind
    /// [`check_windowed_invariant`](Self::check_windowed_invariant),
    /// `SimBackend`'s swap gate, and `EmbeddingServer::swap_placement`.
    pub fn check_servable(&self, windows: usize, groups: usize) -> Result<(), String> {
        if self.groups_of_window.len() != windows {
            return Err(format!(
                "covers {} windows but the plan has {windows}",
                self.groups_of_window.len()
            ));
        }
        if self.window_of_group.len() != groups {
            return Err(format!(
                "window_of_group covers {} groups but the map has {groups}",
                self.window_of_group.len()
            ));
        }
        for (w, serving) in self.groups_of_window.iter().enumerate() {
            if serving.is_empty() {
                return Err(format!("window {w} has no serving group"));
            }
            if let Some(&g) = serving.iter().find(|&&g| g >= groups) {
                return Err(format!(
                    "window {w} names group {g} but the map has only {groups}"
                ));
            }
        }
        Ok(())
    }

    /// The paper's serving invariant for windowed placements: structurally
    /// servable ([`check_servable`](Self::check_servable)), every group on
    /// exactly one window's serving list, and every window within the
    /// probed reach.  Returns a description of the first violation.
    pub fn check_windowed_invariant(
        &self,
        map: &TopologyMap,
        plan: &WindowPlan,
    ) -> Result<(), String> {
        self.check_servable(plan.count(), map.groups.len())?;
        let mut count = vec![0usize; map.groups.len()];
        for (w, groups) in self.groups_of_window.iter().enumerate() {
            for &g in groups {
                count[g] += 1;
                if self.window_of_group[g] != w {
                    return Err(format!("group {g} listed in window {w} but pinned elsewhere"));
                }
            }
        }
        if let Some(g) = count.iter().position(|&c| c != 1) {
            return Err(format!("group {g} serves {} windows (want exactly 1)", count[g]));
        }
        if !plan.fits_reach(map.reach_bytes) {
            return Err("a window exceeds the probed reach".into());
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// The placement seam: producers (Placer) and the live cell (PlacementCell).
// ---------------------------------------------------------------------------

/// Per-window load signals observed over one rebalance epoch (deltas since
/// the previous epoch, not lifetime totals).  Collected from
/// [`Metrics`](super::metrics::Metrics) by the serving backend.
#[derive(Debug, Clone, Default)]
pub struct WindowSignals {
    /// Rows routed to each window this epoch (index = window id) — the
    /// primary load signal every rebalancer consumes.
    pub rows: Vec<u64>,
    /// Mean request latency observed so far, µs (0 when unknown).
    /// Informational: carried for placers that target a latency SLO; the
    /// built-in [`AdaptivePlacer`](super::adaptive::AdaptivePlacer)
    /// decides on `rows` + `queued_rows`.
    pub mean_latency_us: f64,
    /// Rows queued in the batcher at observation time: queue pressure
    /// tightens the adaptive placer's rebalance hysteresis.
    pub queued_rows: u64,
}

impl WindowSignals {
    pub fn total_rows(&self) -> u64 {
        self.rows.iter().sum()
    }
}

/// A placement producer.  The three static arms are [`StaticPlacer`];
/// [`AdaptivePlacer`](super::adaptive::AdaptivePlacer) additionally
/// rebalances the group↔window assignment from observed load.
pub trait Placer: Send + Sync + std::fmt::Debug {
    fn name(&self) -> &'static str;

    /// Build the initial placement for a plan.
    fn place(&self, map: &TopologyMap, plan: &WindowPlan, seed: u64) -> anyhow::Result<Placement>;

    /// Propose a rebalanced placement from one epoch's signals; `None`
    /// keeps the current one.  Windowed implementations must preserve the
    /// paper's invariant ([`Placement::check_windowed_invariant`]): every
    /// group on exactly one ≤reach window, every window covered.
    fn rebalance(
        &self,
        current: &Placement,
        map: &TopologyMap,
        plan: &WindowPlan,
        signals: &WindowSignals,
    ) -> Option<Placement> {
        let _ = (current, map, plan, signals);
        None
    }
}

/// The static policies as a [`Placer`]: naive / sm-to-chunk /
/// group-to-chunk, computed once, never rebalanced.
#[derive(Debug, Clone, Copy)]
pub struct StaticPlacer(pub PlacementPolicy);

impl Placer for StaticPlacer {
    fn name(&self) -> &'static str {
        match self.0 {
            PlacementPolicy::Naive => "static-naive",
            PlacementPolicy::SmToChunk => "static-sm-to-chunk",
            PlacementPolicy::GroupToChunk => "static-group-to-chunk",
        }
    }

    fn place(&self, map: &TopologyMap, plan: &WindowPlan, seed: u64) -> anyhow::Result<Placement> {
        Placement::build(self.0, map, plan, seed)
    }
}

/// The live serving epoch: a generation-stamped (window plan, placement)
/// pair the dispatcher reads once per formed batch and the repartitioning
/// control plane writes between epochs.  Two write paths:
///
/// * [`store`](Self::store) — re-*deal* groups under the current window
///   boundaries (the cheapest lever),
/// * [`store_replan`](Self::store_replan) — re-*split* the boundaries
///   themselves and deal groups over the new windows in one swap, and
/// * [`store_remap`](Self::store_remap) — publish a re-*packed* per-window
///   row layout ([`RemapPlan`]) under the current plan + placement.
///
/// The cell also carries the live [`RemapPlan`] so a batch's routing state
/// is one mutually-consistent triple: a re-split resets the remap to
/// identity (the old permutations describe windows that no longer exist),
/// a re-deal keeps it (boundaries unchanged).
///
/// Swaps never drain in-flight work — splits that already loaded the old
/// `Arc`s finish under them, the next batch routes under the new triple.
#[derive(Debug)]
pub struct PlacementCell {
    inner: RwLock<CellState>,
}

#[derive(Debug)]
struct CellState {
    plan: Arc<WindowPlan>,
    placement: Arc<Placement>,
    remap: Arc<RemapPlan>,
}

impl PlacementCell {
    pub fn new(plan: Arc<WindowPlan>, placement: Placement) -> Self {
        Self {
            inner: RwLock::new(CellState {
                plan,
                placement: Arc::new(placement),
                remap: Arc::new(RemapPlan::identity()),
            }),
        }
    }

    /// The current placement (cheap: read lock + refcount bump).
    pub fn load(&self) -> Arc<Placement> {
        Arc::clone(&self.inner.read().unwrap().placement)
    }

    /// The current (plan, placement) pair under one lock acquisition — the
    /// dispatcher's per-batch read, guaranteed mutually consistent.
    pub fn load_planned(&self) -> (Arc<WindowPlan>, Arc<Placement>) {
        let st = self.inner.read().unwrap();
        (Arc::clone(&st.plan), Arc::clone(&st.placement))
    }

    /// The full routing triple (plan, placement, remap) under one lock
    /// acquisition — what the remap-aware dispatcher reads per batch.
    pub fn load_routed(&self) -> (Arc<WindowPlan>, Arc<Placement>, Arc<RemapPlan>) {
        let st = self.inner.read().unwrap();
        (
            Arc::clone(&st.plan),
            Arc::clone(&st.placement),
            Arc::clone(&st.remap),
        )
    }

    /// The current remap plan.
    pub fn remap(&self) -> Arc<RemapPlan> {
        Arc::clone(&self.inner.read().unwrap().remap)
    }

    /// The current window plan.
    pub fn plan(&self) -> Arc<WindowPlan> {
        Arc::clone(&self.inner.read().unwrap().plan)
    }

    /// Publish a re-dealt placement under the *current* window plan,
    /// stamping `generation = current + 1`.  Returns the new generation.
    pub fn store(&self, mut placement: Placement) -> u64 {
        let mut inner = self.inner.write().unwrap();
        placement.generation = inner.placement.generation + 1;
        let generation = placement.generation;
        inner.placement = Arc::new(placement);
        generation
    }

    /// Publish a re-*split* plan and its placement atomically (one write
    /// lock: no batch can observe the new plan with the old placement).
    /// The live remap resets to identity — its permutations describe
    /// window boundaries that no longer exist.  Returns the new generation.
    pub fn store_replan(&self, plan: WindowPlan, mut placement: Placement) -> u64 {
        let mut inner = self.inner.write().unwrap();
        placement.generation = inner.placement.generation + 1;
        let generation = placement.generation;
        inner.plan = Arc::new(plan);
        inner.placement = Arc::new(placement);
        if !inner.remap.is_identity() {
            inner.remap = Arc::new(RemapPlan::identity());
        }
        generation
    }

    /// Publish a re-*packed* row layout under the current plan/placement,
    /// stamping a fresh generation on both the placement and the remap (a
    /// repack is a published epoch like any other lever's).  Returns the
    /// new generation.
    pub fn store_remap(&self, mut remap: RemapPlan) -> u64 {
        let mut inner = self.inner.write().unwrap();
        let mut placement = (*inner.placement).clone();
        placement.generation += 1;
        let generation = placement.generation;
        remap.generation = generation;
        inner.placement = Arc::new(placement);
        inner.remap = Arc::new(remap);
        generation
    }

    pub fn generation(&self) -> u64 {
        self.inner.read().unwrap().placement.generation
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;

    fn test_map() -> TopologyMap {
        TopologyMap {
            groups: vec![
                vec![0, 1, 2, 3],
                vec![4, 5, 6, 7],
                vec![8, 9],
                vec![10, 11],
            ],
            reach_bytes: 16 << 20,
            solo_gbps: vec![120.0, 118.0, 90.0, 91.0],
            independent: true,
            card_id: "test".into(),
        }
    }

    fn plan(windows: usize) -> WindowPlan {
        WindowPlan::split(1 << 20, 128, windows)
    }

    #[test]
    fn group_to_chunk_pins_every_window() {
        let p = Placement::build(PlacementPolicy::GroupToChunk, &test_map(), &plan(2), 0).unwrap();
        assert_eq!(p.groups_of_window.len(), 2);
        for w in 0..2 {
            assert!(!p.serving_groups(w).is_empty());
        }
        // All 4 groups assigned, each to exactly one window.
        let mut seen = vec![false; 4];
        for w in 0..2 {
            for &g in p.serving_groups(w) {
                assert!(!seen[g]);
                seen[g] = true;
                assert_eq!(p.window_of_group[g], w);
            }
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn group_to_chunk_balances_capacity() {
        let p = Placement::build(PlacementPolicy::GroupToChunk, &test_map(), &plan(2), 0).unwrap();
        let m = test_map();
        let c0 = p.window_capacity_gbps(&m, 0);
        let c1 = p.window_capacity_gbps(&m, 1);
        // Weighted dealing: both windows get one fast + one slow group.
        assert!((c0 - c1).abs() / c0.max(c1) < 0.1, "c0={c0} c1={c1}");
    }

    #[test]
    fn group_to_chunk_rejects_too_many_windows() {
        assert!(Placement::build(PlacementPolicy::GroupToChunk, &test_map(), &plan(5), 0).is_err());
    }

    #[test]
    fn naive_serves_everything() {
        let p = Placement::build(PlacementPolicy::Naive, &test_map(), &plan(3), 0).unwrap();
        for w in 0..3 {
            assert_eq!(p.serving_groups(w).len(), 4);
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [
            PlacementPolicy::Naive,
            PlacementPolicy::SmToChunk,
            PlacementPolicy::GroupToChunk,
        ] {
            assert_eq!(PlacementPolicy::parse(&p.to_string()).unwrap(), p);
        }
        assert!(PlacementPolicy::parse("bogus").is_err());
    }

    #[test]
    fn sim_assignments_respect_policy() {
        let machine = Machine::new(MachineConfig::tiny_test()).unwrap();
        // Use the real topology for the map so smids are valid.
        let topo = machine.topology();
        let map = TopologyMap {
            groups: (0..topo.group_count()).map(|g| topo.sms_in_group(g)).collect(),
            reach_bytes: machine.config().tlb.reach_bytes(),
            solo_gbps: vec![100.0; topo.group_count()],
            independent: true,
            card_id: "t".into(),
        };
        let plan = WindowPlan::split(
            machine.config().memory.total_bytes / 128,
            128,
            2,
        );
        let p = Placement::build(PlacementPolicy::GroupToChunk, &map, &plan, 1).unwrap();
        assert_eq!(p.generation, 0);
        let asg = p.sim_assignments(&map, &plan, &machine, 2);
        assert_eq!(asg.len(), topo.sm_count());
        // All SMs of one group read the same region.
        for (gi, group) in map.groups.iter().enumerate() {
            let want = plan.region_of(&plan.windows()[p.window_of_group[gi]]);
            for &smid in group {
                let a = asg.iter().find(|a| a.smid == smid).unwrap();
                assert_eq!(a.pattern.region(), &want);
            }
        }
    }

    #[test]
    fn static_placer_matches_placement_build() {
        let map = test_map();
        let plan = plan(2);
        for policy in [
            PlacementPolicy::Naive,
            PlacementPolicy::SmToChunk,
            PlacementPolicy::GroupToChunk,
        ] {
            let a = StaticPlacer(policy).place(&map, &plan, 7).unwrap();
            let b = Placement::build(policy, &map, &plan, 7).unwrap();
            assert_eq!(a.groups_of_window, b.groups_of_window);
            assert_eq!(a.window_of_group, b.window_of_group);
            // Static placers never rebalance.
            let signals = WindowSignals {
                rows: vec![1_000_000, 1],
                ..Default::default()
            };
            assert!(StaticPlacer(policy)
                .rebalance(&a, &map, &plan, &signals)
                .is_none());
        }
    }

    #[test]
    fn windowed_invariant_accepts_group_to_chunk() {
        let map = test_map();
        let plan = plan(2);
        let p = Placement::build(PlacementPolicy::GroupToChunk, &map, &plan, 0).unwrap();
        assert_eq!(p.check_windowed_invariant(&map, &plan), Ok(()));
    }

    #[test]
    fn windowed_invariant_rejects_orphans_and_straddlers() {
        let map = test_map();
        let plan = plan(2);
        let mut p = Placement::build(PlacementPolicy::GroupToChunk, &map, &plan, 0).unwrap();
        // Orphan: strip window 0.
        let moved = std::mem::take(&mut p.groups_of_window[0]);
        assert!(p.check_windowed_invariant(&map, &plan).is_err());
        // Straddler: a group listed under both windows.
        p.groups_of_window[0] = moved;
        let g = p.groups_of_window[0][0];
        p.groups_of_window[1].push(g);
        assert!(p.check_windowed_invariant(&map, &plan).is_err());
    }

    #[test]
    fn validators_report_malformed_placements_without_panicking() {
        let map = test_map();
        let plan = plan(2);
        let good = Placement::build(PlacementPolicy::GroupToChunk, &map, &plan, 0).unwrap();
        assert_eq!(good.check_servable(2, 4), Ok(()));
        // A truncated window_of_group (shorter than the listed group ids)
        // must come back as Err from both validators, not as an index
        // panic inside them.
        let mut truncated = good.clone();
        truncated.window_of_group.clear();
        assert!(truncated.check_servable(2, 4).is_err());
        assert!(truncated.check_windowed_invariant(&map, &plan).is_err());
        // Wrong window count and out-of-map group ids are Errs too.
        assert!(good.check_servable(3, 4).is_err());
        assert!(good.check_servable(2, 2).is_err());
    }

    #[test]
    fn placement_cell_stamps_generations_without_blocking_readers() {
        let map = test_map();
        let plan = plan(2);
        let p = Placement::build(PlacementPolicy::GroupToChunk, &map, &plan, 0).unwrap();
        let cell = PlacementCell::new(Arc::new(plan), p.clone());
        assert_eq!(cell.generation(), 0);
        let old = cell.load();
        assert_eq!(cell.store(p.clone()), 1);
        assert_eq!(cell.store(p), 2);
        assert_eq!(cell.generation(), 2);
        // The reader that loaded before the swaps still holds generation 0:
        // in-flight work is never drained or invalidated.
        assert_eq!(old.generation, 0);
        assert_eq!(cell.load().generation, 2);
    }

    #[test]
    fn placement_cell_remap_rides_the_generation_stream() {
        use crate::coordinator::remap::{RemapConfig, WindowRemap};
        use crate::coordinator::table::Table;

        let map = test_map();
        let rows = 1 << 10;
        let plan2 = WindowPlan::split(rows, 32, 2);
        let table = Table::synthetic(rows, 8);
        let p = Placement::build(PlacementPolicy::GroupToChunk, &map, &plan2, 0).unwrap();
        let cell = PlacementCell::new(Arc::new(plan2.clone()), p.clone());

        // Fresh cells serve the identity remap.
        let (_, _, remap0) = cell.load_routed();
        assert!(remap0.is_identity());
        assert_eq!(remap0.generation, 0);

        // A published repack bumps the shared generation and is visible in
        // the routed triple; a pre-swap reader still holds identity.
        let cfg = RemapConfig {
            page_bytes: 32 * 8,
            ..RemapConfig::default()
        };
        let w0 = plan2.windows()[0];
        let wr = WindowRemap::pack(&table.view(), &w0, &[3, 1, 9], 0.7, &cfg).unwrap();
        let mut rp = RemapPlan::with_windows(2);
        rp.set_window(0, Some(wr));
        assert_eq!(cell.store_remap(rp), 1);
        let (_, placement1, remap1) = cell.load_routed();
        assert_eq!(placement1.generation, 1);
        assert_eq!(remap1.generation, 1);
        assert!(!remap1.is_identity());
        assert!(remap0.is_identity());

        // A re-deal keeps the remap (boundaries unchanged)...
        assert_eq!(cell.store(p), 2);
        assert!(!cell.remap().is_identity());
        // ...but a re-split resets it to identity.
        let plan4 = WindowPlan::split(rows, 32, 4);
        let p4 = Placement::build(PlacementPolicy::GroupToChunk, &map, &plan4, 0).unwrap();
        assert_eq!(cell.store_replan(plan4, p4), 3);
        let (plan_now, _, remap_now) = cell.load_routed();
        assert_eq!(plan_now.count(), 4);
        assert!(remap_now.is_identity());
        // The in-flight reader's packed slab survives untouched.
        assert!(remap1.window_remap(0).is_some());
    }

    #[test]
    fn placement_cell_replan_swaps_plan_and_placement_together() {
        let map = test_map();
        let plan2 = plan(2);
        let p2 = Placement::build(PlacementPolicy::GroupToChunk, &map, &plan2, 0).unwrap();
        let cell = PlacementCell::new(Arc::new(plan2.clone()), p2);
        let (old_plan, old_placement) = cell.load_planned();
        assert_eq!(old_plan.count(), 2);

        // Re-split to 4 windows: the pair swaps atomically, generation bumps.
        let plan4 = plan(4);
        let p4 = Placement::build(PlacementPolicy::GroupToChunk, &map, &plan4, 0).unwrap();
        assert_eq!(cell.store_replan(plan4, p4), 1);
        let (new_plan, new_placement) = cell.load_planned();
        assert_eq!(new_plan.count(), 4);
        assert_eq!(new_placement.groups_of_window.len(), 4);
        assert_eq!(new_placement.generation, 1);
        assert_eq!(cell.plan().count(), 4);
        // The pre-swap reader still holds a mutually consistent old pair.
        assert_eq!(old_plan.count(), old_placement.groups_of_window.len());
    }
}

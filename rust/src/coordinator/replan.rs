//! Window *re-splitting*: recompute the window boundaries themselves from
//! observed per-window load — the control plane's second repartitioning
//! lever, for skew hotter than group granularity can absorb.
//!
//! [`AdaptivePlacer`](super::adaptive::AdaptivePlacer) re-*deals* SM groups
//! across **fixed** window boundaries, so its best response to a window
//! carrying 95% of the load is to pin all-but-one group there — the one
//! group left covering the cold windows caps the achievable balance at
//! group granularity.  [`PlanSplitter`] moves the boundaries instead: it
//! estimates a piecewise-constant load density from the epoch's per-window
//! routed-row counts, then re-cuts the row space so each new window's load
//! share matches the capacity share the group deal will be able to give it
//! (narrow windows around hot row ranges, cold ranges merged into wide
//! windows).  Cf. TileLens (arXiv 2607.04031) on transparent re-layout
//! under large-granularity memory systems.
//!
//! Every emitted plan preserves the paper's serving constraint by
//! construction: no window exceeds the probed TLB reach, window count never
//! exceeds the group count, and the dealt placement keeps every group on
//! exactly one window ([`Placement::check_windowed_invariant`] — property
//! tested across random topologies and signals).
//!
//! Deterministic: same plan + signals + capacities → same boundaries.

use crate::probe::TopologyMap;

use super::adaptive::AdaptivePlacer;
use super::chunks::WindowPlan;
use super::placement::{Placement, PlacementPolicy, WindowSignals};

/// Tuning for [`PlanSplitter`].
#[derive(Debug, Clone)]
pub struct SplitterConfig {
    /// Hysteresis: only re-split when the **best possible re-deal** under
    /// the current boundaries would still leave some window's load share at
    /// least this far from its capacity share.  (A mismatch the cheap lever
    /// can fix never justifies the expensive one.)
    pub min_imbalance: f64,
    /// Minimum rows observed in an epoch before re-splitting (starved
    /// epochs carry no trustworthy density estimate).
    pub min_epoch_rows: u64,
    /// Floor on rows per emitted window, so degenerate densities can never
    /// produce empty or near-empty windows.
    pub min_window_rows: u64,
}

impl Default for SplitterConfig {
    fn default() -> Self {
        Self {
            min_imbalance: 0.10,
            min_epoch_rows: 256,
            min_window_rows: 64,
        }
    }
}

/// The window-boundary re-splitter (see module docs).
#[derive(Debug, Clone, Default)]
pub struct PlanSplitter {
    pub cfg: SplitterConfig,
}

impl PlanSplitter {
    pub fn new(cfg: SplitterConfig) -> Self {
        Self { cfg }
    }

    /// Propose re-split boundaries (and the group deal over them) from one
    /// epoch's per-window load.  `None` keeps the current plan: signals too
    /// thin, the mismatch is within what a re-deal can absorb, or the
    /// recomputed boundaries come out identical.
    pub fn replan(
        &self,
        plan: &WindowPlan,
        map: &TopologyMap,
        signals: &WindowSignals,
    ) -> Option<(WindowPlan, Placement)> {
        let w_now = plan.count();
        let g = map.groups.len();
        let total = signals.total_rows();
        if signals.rows.len() != w_now
            || total == 0
            || total < self.cfg.min_epoch_rows
            || g < w_now
            || g == 0
        {
            return None;
        }

        // Smoothed piecewise-constant load density over the current
        // windows (the uniform prior keeps cold regions at finite — wide,
        // not infinite — width).
        let density = LoadDensity::smoothed(
            plan.windows()
                .iter()
                .zip(&signals.rows)
                .map(|(w, &l)| (w.rows, l)),
            plan.total_rows,
        );
        let shares = density.shares();

        // Hysteresis: if the best re-deal under the *current* boundaries
        // already balances load to capacity, the cheap lever suffices.
        let total_cap: f64 = map.solo_gbps.iter().sum();
        let (best_deal, _) = AdaptivePlacer::deal(map, shares);
        let best_imbalance = (0..w_now)
            .map(|w| {
                let cap: f64 = best_deal[w].iter().map(|&q| map.solo_gbps[q]).sum();
                (shares[w] - cap / total_cap).abs()
            })
            .fold(0.0f64, f64::max);
        if best_imbalance < self.cfg.min_imbalance {
            return None;
        }

        // Geometry bounds: windows may not exceed reach, may not dip under
        // the row floor, and their count may not exceed the group count.
        let min_rows = self.cfg.min_window_rows.max(1);
        let max_window_rows = map.reach_bytes / plan.row_bytes;
        if max_window_rows < min_rows {
            return None;
        }
        let w_target = (g as u64).min(plan.total_rows / min_rows).max(1) as usize;
        if (w_target as u64) * max_window_rows < plan.total_rows {
            // Even at maximum granularity the reach cannot cover the table
            // (should be unreachable while a valid current plan exists).
            return None;
        }

        // Per-window load targets anticipate the deal's granularity: deal
        // capacities round-robin (fastest first) over `w_target` windows
        // and target each window's share of that capacity.
        let mut order: Vec<usize> = (0..g).collect();
        order.sort_by(|&a, &b| {
            map.solo_gbps[b]
                .partial_cmp(&map.solo_gbps[a])
                // PANIC: probed throughputs are finite, never NaN.
                .unwrap()
                .then(a.cmp(&b))
        });
        let mut target_cap = vec![0.0f64; w_target];
        for (k, &gi) in order.iter().enumerate() {
            target_cap[k % w_target] += map.solo_gbps[gi];
        }
        let targets: Vec<f64> = target_cap.iter().map(|c| c / total_cap).collect();

        // Cut boundaries at cumulative-load quantiles over the density,
        // clamped so every window stays within [min_rows, max_window_rows]
        // and the remainder always stays coverable by the windows still to
        // come.
        let mut starts: Vec<u64> = Vec::with_capacity(w_target);
        let mut cursor: u64 = 0;
        let mut want = 0.0f64;
        for j in 0..w_target {
            starts.push(cursor);
            if j == w_target - 1 {
                break;
            }
            want += targets[j];
            let remaining = (w_target - 1 - j) as u64;
            let lo = (cursor + min_rows)
                .max(plan.total_rows.saturating_sub(remaining * max_window_rows));
            let hi = (cursor + max_window_rows).min(plan.total_rows - remaining * min_rows);
            if lo > hi {
                return None; // defensive: infeasible geometry
            }
            cursor = density.row_at_load(want).clamp(lo, hi);
        }

        let new_plan = WindowPlan::from_boundaries(plan.total_rows, plan.row_bytes, &starts)
            // PANIC: invariant — the clamp loop above keeps every boundary
            // strictly increasing and in range by construction.
            .expect("splitter emits strictly increasing in-range boundaries");
        if new_plan.same_boundaries(plan) {
            return None;
        }

        // Load share of each *new* window under the observed density, then
        // the capacity-proportional group deal over them.
        let new_shares: Vec<f64> = new_plan
            .windows()
            .iter()
            .map(|w| density.load_between(w.start_row, w.end_row()))
            .collect();
        let (groups_of_window, window_of_group) = AdaptivePlacer::deal(map, &new_shares);
        let placement = Placement {
            policy: PlacementPolicy::GroupToChunk,
            generation: 0, // stamped by PlacementCell::store_replan
            groups_of_window,
            window_of_group,
        };
        debug_assert!(new_plan.fits_reach(map.reach_bytes));
        debug_assert_eq!(placement.check_windowed_invariant(map, &new_plan), Ok(()));
        Some((new_plan, placement))
    }
}

/// A smoothed piecewise-constant load density over contiguous row
/// segments — the quantile machinery shared by both boundary re-cutters:
/// [`PlanSplitter`] (segments = windows) and
/// [`FleetRebalancer`](crate::service::FleetRebalancer) (segments = card
/// shards).  Fixes to the interpolation apply to both levers at once.
pub(crate) struct LoadDensity {
    starts: Vec<u64>,
    rows: Vec<u64>,
    /// Smoothed load share per segment (sums to 1; every entry > 0).
    shares: Vec<f64>,
    /// `cum[i]` = load strictly before segment `i`; `cum[len]` = 1.
    cum: Vec<f64>,
    total_rows: u64,
}

impl LoadDensity {
    /// Build from `(rows, observed_load)` segments tiling `[0, total_rows)`
    /// in order, blending in a uniform prior so cold segments keep finite
    /// (wide, not infinite) width under the quantile inverse.
    pub(crate) fn smoothed(
        segments: impl Iterator<Item = (u64, u64)>,
        total_rows: u64,
    ) -> Self {
        const ALPHA: f64 = 0.05;
        let segs: Vec<(u64, u64)> = segments.collect();
        let n = segs.len().max(1);
        let total_load: u64 = segs.iter().map(|&(_, l)| l).sum();
        let mut starts = Vec::with_capacity(segs.len());
        let mut rows = Vec::with_capacity(segs.len());
        let mut shares = Vec::with_capacity(segs.len());
        let mut cum = Vec::with_capacity(segs.len() + 1);
        cum.push(0.0);
        let mut acc = 0.0;
        let mut cursor = 0u64;
        for &(r, l) in &segs {
            starts.push(cursor);
            rows.push(r);
            let share =
                (l as f64 / total_load.max(1) as f64 + ALPHA / n as f64) / (1.0 + ALPHA);
            shares.push(share);
            acc += share;
            cum.push(acc);
            cursor += r;
        }
        debug_assert_eq!(cursor, total_rows, "segments must tile the row space");
        Self {
            starts,
            rows,
            shares,
            cum,
            total_rows,
        }
    }

    /// Smoothed per-segment load shares (same order as the input).
    pub(crate) fn shares(&self) -> &[f64] {
        &self.shares
    }

    /// Row position where cumulative load reaches `want`, interpolating
    /// inside the piecewise-constant density.
    pub(crate) fn row_at_load(&self, want: f64) -> u64 {
        for i in 0..self.shares.len() {
            if want <= self.cum[i + 1] || i == self.shares.len() - 1 {
                let density = self.shares[i] / self.rows[i] as f64; // > 0 via smoothing
                let frac_rows = (((want - self.cum[i]) / density).max(0.0) as u64)
                    .min(self.rows[i]);
                return self.starts[i] + frac_rows;
            }
        }
        self.total_rows
    }

    /// Load share carried by rows `[start, end)`.
    pub(crate) fn load_between(&self, start: u64, end: u64) -> f64 {
        debug_assert!(start <= end && end <= self.total_rows);
        self.cum_at(end) - self.cum_at(start)
    }

    /// Cumulative load strictly before `row`.
    fn cum_at(&self, row: u64) -> f64 {
        if row >= self.total_rows {
            return self.cum[self.shares.len()];
        }
        // Segments are few (≤ groups per card, ≤ cards per fleet).
        let i = self.starts.partition_point(|&s| s <= row) - 1;
        self.cum[i] + self.shares[i] * (row - self.starts[i]) as f64 / self.rows[i] as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map(solo: &[f64], reach_bytes: u64) -> TopologyMap {
        TopologyMap {
            groups: (0..solo.len()).map(|q| vec![q * 2, q * 2 + 1]).collect(),
            reach_bytes,
            solo_gbps: solo.to_vec(),
            independent: true,
            card_id: "replan-test".into(),
        }
    }

    fn signals(rows: &[u64]) -> WindowSignals {
        WindowSignals {
            rows: rows.to_vec(),
            ..Default::default()
        }
    }

    #[test]
    fn hot_window_is_split_into_narrow_windows() {
        // 2 windows, 4 groups, 95% of load on window 0: a re-deal tops out
        // at 3:1 capacity (imbalance 0.2), so the splitter must act.
        let m = map(&[100.0; 4], 1 << 30);
        let plan = WindowPlan::split(8_192, 128, 2);
        let splitter = PlanSplitter::default();
        let (new_plan, placement) = splitter
            .replan(&plan, &m, &signals(&[9_500, 500]))
            .expect("group granularity cannot absorb 95/5 skew");
        assert_eq!(new_plan.count(), 4, "{:?}", new_plan.boundaries());
        assert_eq!(placement.check_windowed_invariant(&m, &new_plan), Ok(()));
        // The hot half of the row space ends up holding most of the
        // windows; the cold half is merged into wide ones.
        let hot_windows = new_plan
            .windows()
            .iter()
            .filter(|w| w.start_row < 4_096)
            .count();
        assert!(hot_windows >= 3, "{:?}", new_plan.boundaries());
        // Roughly equal load per new window: each new window's share of
        // the observed density within ~2x of the 1/4 ideal.
        let shares = [9_500.0 / 10_000.0, 500.0 / 10_000.0];
        for w in new_plan.windows() {
            let mut load = 0.0;
            for half in 0..2u64 {
                let (s, e) = (half * 4_096, (half + 1) * 4_096);
                let ov = w.end_row().min(e).saturating_sub(w.start_row.max(s));
                load += shares[half as usize] * ov as f64 / 4_096.0;
            }
            assert!(load > 0.10 && load < 0.45, "window {w:?} carries {load}");
        }
    }

    #[test]
    fn redeal_absorbable_skew_keeps_boundaries() {
        // 70/30 over 2 windows with 4 equal groups: a 3:1 deal gives
        // 75/25 capacity — within min_imbalance of the load, so the cheap
        // lever suffices and the splitter stays quiet.
        let m = map(&[100.0; 4], 1 << 30);
        let plan = WindowPlan::split(8_192, 128, 2);
        assert!(PlanSplitter::default()
            .replan(&plan, &m, &signals(&[7_000, 3_000]))
            .is_none());
    }

    #[test]
    fn starved_epoch_never_replans() {
        let m = map(&[100.0; 4], 1 << 30);
        let plan = WindowPlan::split(8_192, 128, 2);
        let s = PlanSplitter::default();
        assert!(s.replan(&plan, &m, &signals(&[10, 0])).is_none());
        assert!(s.replan(&plan, &m, &signals(&[0, 0])).is_none());
        assert!(s.replan(&plan, &m, &signals(&[10_000])).is_none()); // wrong arity
    }

    #[test]
    fn reach_bounds_every_emitted_window() {
        // Tight reach: even cold ranges may not be merged past it.
        let rows = 8_192u64;
        let row_bytes = 128u64;
        let reach = 3_000 * row_bytes;
        let m = map(&[100.0; 4], reach);
        let plan = WindowPlan::split(rows, row_bytes, 3);
        let (new_plan, placement) = PlanSplitter::default()
            .replan(&plan, &m, &signals(&[9_000, 600, 400]))
            .expect("hot front third must trigger a re-split");
        assert!(new_plan.fits_reach(reach));
        assert_eq!(placement.check_windowed_invariant(&m, &new_plan), Ok(()));
    }

    #[test]
    fn unequal_capacities_get_matching_load_targets() {
        // Fastest group should end up alone on the heaviest new window.
        let m = map(&[130.0, 90.0, 90.0, 90.0], 1 << 30);
        let plan = WindowPlan::split(8_192, 128, 2);
        let (new_plan, placement) = PlanSplitter::default()
            .replan(&plan, &m, &signals(&[9_600, 400]))
            .expect("skew beyond deal granularity");
        assert_eq!(placement.check_windowed_invariant(&m, &new_plan), Ok(()));
        // Every window got exactly one group (4 windows, 4 groups).
        for w in 0..new_plan.count() {
            assert_eq!(placement.serving_groups(w).len(), 1);
        }
    }

    #[test]
    fn replan_is_deterministic() {
        let m = map(&[100.0, 99.0, 98.0, 97.0], 1 << 30);
        let plan = WindowPlan::split(8_192, 128, 2);
        let s = PlanSplitter::default();
        let sig = signals(&[9_300, 700]);
        let (pa, la) = s.replan(&plan, &m, &sig).unwrap();
        let (pb, lb) = s.replan(&plan, &m, &sig).unwrap();
        assert!(pa.same_boundaries(&pb));
        assert_eq!(la.groups_of_window, lb.groups_of_window);
    }
}

#[cfg(test)]
mod prop_tests {
    use super::*;
    use crate::util::prop;

    /// The ISSUE's acceptance property: any splitter output preserves the
    /// one-group-one-≤reach-window invariant across random signals and
    /// topologies (and tiles the row space exactly).
    #[test]
    fn property_replan_keeps_invariant() {
        prop::check("replan-invariant", 80, |g| {
            let n_windows = g.usize(1, 6);
            let n_groups = g.usize(n_windows, 12);
            let row_bytes = 128u64;
            let total_rows = g.u64(4_096, 200_000);
            // Reach somewhere between "tight" and "roomy", but always
            // feasible for the group count.
            let min_reach_rows = total_rows.div_ceil(n_groups as u64).max(512);
            let reach_rows = g.u64(min_reach_rows, total_rows.max(min_reach_rows + 1));
            let map = TopologyMap {
                groups: (0..n_groups).map(|q| vec![q * 2, q * 2 + 1]).collect(),
                reach_bytes: reach_rows * row_bytes,
                solo_gbps: (0..n_groups).map(|_| g.f64(60.0, 140.0)).collect(),
                independent: true,
                card_id: "prop".into(),
            };
            let Ok(mut plan) = WindowPlan::for_reach(
                total_rows,
                row_bytes,
                map.reach_bytes,
                n_windows.max(total_rows.div_ceil(reach_rows) as usize),
            ) else {
                return;
            };
            if plan.count() > n_groups {
                return; // not servable at all; splitter precondition fails
            }

            let splitter = PlanSplitter::default();
            for _ in 0..g.usize(1, 6) {
                let rows: Vec<u64> = (0..plan.count()).map(|_| g.u64(0, 50_000)).collect();
                let sig = WindowSignals {
                    rows,
                    ..Default::default()
                };
                if let Some((new_plan, placement)) = splitter.replan(&plan, &map, &sig) {
                    // Tiles the row space.
                    assert_eq!(new_plan.total_rows, total_rows);
                    assert_eq!(new_plan.windows()[0].start_row, 0);
                    assert_eq!(new_plan.windows().last().unwrap().end_row(), total_rows);
                    for w in new_plan.windows().windows(2) {
                        assert_eq!(w[0].end_row(), w[1].start_row);
                    }
                    // The paper's invariant, every time.
                    assert!(new_plan.fits_reach(map.reach_bytes), "window exceeds reach");
                    assert!(new_plan.count() <= n_groups);
                    assert_eq!(
                        placement.check_windowed_invariant(&map, &new_plan),
                        Ok(()),
                        "signals {sig:?}"
                    );
                    plan = new_plan;
                }
            }
        });
    }
}

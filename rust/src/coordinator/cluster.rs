//! Multi-card coordination: shard a table larger than one device across
//! several probed cards, each with its own (card-specific!) topology map.
//!
//! The paper stresses that the smid->group mapping "may vary card to card"
//! — so a fleet deployment probes every card once at install time and the
//! coordinator composes the per-card maps.  Routing becomes two-level:
//!
//! ```text
//! global row ──► card (device-level shard) ──► window ──► SM group
//! ```
//!
//! Each card independently applies group-to-chunk placement inside its
//! shard; the fleet-level router only needs shard boundaries.  Capacity-
//! aware sharding sizes each card's shard by its probed aggregate
//! throughput (cards may differ: a 40 GB card takes a smaller shard).

use anyhow::{anyhow, Context};

use crate::probe::TopologyMap;

use super::chunks::WindowPlan;
use super::placement::{Placement, PlacementPolicy};

/// One card in the fleet: its probe result and memory budget.
#[derive(Debug, Clone)]
pub struct CardSpec {
    pub map: TopologyMap,
    /// Device memory usable for the table, bytes.
    pub memory_bytes: u64,
}

impl CardSpec {
    /// Probed aggregate capacity, GB/s.
    pub fn capacity_gbps(&self) -> f64 {
        self.map.solo_gbps.iter().sum()
    }
}

/// A card's slice of the global row space, with its internal plan.
#[derive(Debug, Clone)]
pub struct CardShard {
    pub card: usize,
    pub start_row: u64,
    pub rows: u64,
    pub plan: WindowPlan,
    pub placement: Placement,
}

impl CardShard {
    pub fn end_row(&self) -> u64 {
        self.start_row + self.rows
    }

    pub fn contains(&self, row: u64) -> bool {
        row >= self.start_row && row < self.end_row()
    }
}

/// The fleet-level plan.
#[derive(Debug, Clone)]
pub struct FleetPlan {
    pub shards: Vec<CardShard>,
    pub total_rows: u64,
    pub row_bytes: u64,
    /// Migration stamp: 0 at [`build`](Self::build), bumped per published
    /// re-sharding ([`with_ranges`](Self::with_ranges)).  In-flight fleet
    /// tickets split under generation N merge under N even after N+1 goes
    /// live.
    pub generation: u64,
}

impl FleetPlan {
    /// Shard `total_rows` across `cards`, proportionally to probed
    /// capacity, honoring per-card memory and reach limits; inside each
    /// card, build a `GroupToChunk` placement over reach-sized windows.
    pub fn build(
        cards: &[CardSpec],
        total_rows: u64,
        row_bytes: u64,
        seed: u64,
    ) -> anyhow::Result<Self> {
        if cards.is_empty() {
            return Err(anyhow!("no cards"));
        }
        let total_bytes = total_rows * row_bytes;
        let fleet_mem: u64 = cards.iter().map(|c| c.memory_bytes).sum();
        if total_bytes > fleet_mem {
            return Err(anyhow!(
                "table needs {total_bytes} bytes but the fleet only has {fleet_mem}"
            ));
        }
        let fleet_cap: f64 = cards.iter().map(|c| c.capacity_gbps()).sum();

        // Capacity-proportional split, clamped to per-card memory, with the
        // remainder spilled to cards that still have room.
        let mut rows_of: Vec<u64> = cards
            .iter()
            .map(|c| {
                let ideal = (total_rows as f64 * c.capacity_gbps() / fleet_cap) as u64;
                ideal.min(c.memory_bytes / row_bytes)
            })
            .collect();
        let mut assigned: u64 = rows_of.iter().sum();
        // Distribute the rounding/clamping remainder.
        'outer: while assigned < total_rows {
            let mut progressed = false;
            for (i, c) in cards.iter().enumerate() {
                let room = c.memory_bytes / row_bytes - rows_of[i];
                if room > 0 {
                    let take = room.min(total_rows - assigned);
                    rows_of[i] += take;
                    assigned += take;
                    progressed = true;
                    if assigned == total_rows {
                        break 'outer;
                    }
                }
            }
            if !progressed {
                return Err(anyhow!("could not place all rows"));
            }
        }
        // Trim over-assignment (possible only from the ideal rounding up).
        while assigned > total_rows {
            for r in rows_of.iter_mut() {
                if *r > 0 && assigned > total_rows {
                    let give = (*r).min(assigned - total_rows);
                    *r -= give;
                    assigned -= give;
                }
            }
        }

        let mut shards = Vec::new();
        let mut start = 0u64;
        for (i, c) in cards.iter().enumerate() {
            let rows = rows_of[i];
            if rows == 0 {
                continue;
            }
            let plan = WindowPlan::for_reach(rows, row_bytes, c.map.reach_bytes, c.map.groups.len())
                .with_context(|| format!("card {i}"))?;
            let placement = Placement::build(PlacementPolicy::GroupToChunk, &c.map, &plan, seed)
                .with_context(|| format!("card {i}"))?;
            shards.push(CardShard {
                card: i,
                start_row: start,
                rows,
                plan,
                placement,
            });
            start += rows;
        }
        debug_assert_eq!(start, total_rows);
        Ok(Self {
            shards,
            total_rows,
            row_bytes,
            generation: 0,
        })
    }

    /// Build a plan from explicit per-card row counts (`rows_of[i]` rows
    /// for card `i`, in card order; zero skips the card) — the fleet
    /// rebalancer's constructor for migrated shard boundaries.  Validates
    /// memory and reach per card exactly like [`build`](Self::build) and
    /// stamps `generation`.
    pub fn with_ranges(
        cards: &[CardSpec],
        rows_of: &[u64],
        total_rows: u64,
        row_bytes: u64,
        seed: u64,
        generation: u64,
    ) -> anyhow::Result<Self> {
        if cards.len() != rows_of.len() {
            return Err(anyhow!(
                "{} cards but {} row counts",
                cards.len(),
                rows_of.len()
            ));
        }
        if rows_of.iter().sum::<u64>() != total_rows {
            return Err(anyhow!("row counts do not tile the table"));
        }
        let mut shards = Vec::new();
        let mut start = 0u64;
        for (i, c) in cards.iter().enumerate() {
            let rows = rows_of[i];
            if rows == 0 {
                continue;
            }
            if rows * row_bytes > c.memory_bytes {
                return Err(anyhow!(
                    "card {i} assigned {rows} rows but only fits {}",
                    c.memory_bytes / row_bytes
                ));
            }
            let plan =
                WindowPlan::for_reach(rows, row_bytes, c.map.reach_bytes, c.map.groups.len())
                    .with_context(|| format!("card {i}"))?;
            let placement = Placement::build(PlacementPolicy::GroupToChunk, &c.map, &plan, seed)
                .with_context(|| format!("card {i}"))?;
            shards.push(CardShard {
                card: i,
                start_row: start,
                rows,
                plan,
                placement,
            });
            start += rows;
        }
        if shards.is_empty() {
            return Err(anyhow!("no card received any rows"));
        }
        Ok(Self {
            shards,
            total_rows,
            row_bytes,
            generation,
        })
    }

    /// Rows per card under this plan (indexed by card id, zero when a card
    /// holds no shard) — the rebalancer's geometry input.
    pub fn rows_per_card(&self, cards: usize) -> Vec<u64> {
        let mut out = vec![0u64; cards];
        for s in &self.shards {
            out[s.card] = s.rows;
        }
        out
    }

    /// Rows whose owning card differs between two plans over the same row
    /// space — the migration volume a re-sharding implies (view re-slices,
    /// never data copies).
    pub fn rows_moved(&self, next: &FleetPlan) -> u64 {
        debug_assert_eq!(self.total_rows, next.total_rows);
        let mut kept = 0u64;
        for a in &self.shards {
            for b in &next.shards {
                if a.card == b.card {
                    let lo = a.start_row.max(b.start_row);
                    let hi = a.end_row().min(b.end_row());
                    kept += hi.saturating_sub(lo);
                }
            }
        }
        self.total_rows - kept
    }

    /// Two-level route: global row -> (shard index, card-local row).
    pub fn route(&self, row: u64) -> anyhow::Result<(usize, u64)> {
        if row >= self.total_rows {
            return Err(anyhow!("row {row} out of table"));
        }
        // Shards are few (fleet-sized); linear scan beats binary search at
        // n <= ~16 and is branch-predictable.
        for (si, s) in self.shards.iter().enumerate() {
            if s.contains(row) {
                return Ok((si, row - s.start_row));
            }
        }
        unreachable!("shards tile the row space");
    }

    /// Split a request batch by card: returns per-shard (local rows,
    /// original positions).
    pub fn split(&self, rows: &[u64]) -> anyhow::Result<Vec<(Vec<u64>, Vec<u32>)>> {
        let mut out: Vec<(Vec<u64>, Vec<u32>)> =
            (0..self.shards.len()).map(|_| Default::default()).collect();
        for (pos, &row) in rows.iter().enumerate() {
            let (si, local) = self.route(row)?;
            out[si].0.push(local);
            out[si].1.push(pos as u32);
        }
        Ok(out)
    }

    /// The paper's invariant across the whole fleet.
    pub fn fits_reach(&self, cards: &[CardSpec]) -> bool {
        self.shards
            .iter()
            .all(|s| s.plan.fits_reach(cards[s.card].map.reach_bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GIB;
    use crate::util::prop;

    fn card(groups: usize, sms_per_group: usize, gbps: f64, mem_gib: u64) -> CardSpec {
        CardSpec {
            map: TopologyMap {
                groups: (0..groups)
                    .map(|g| (g * sms_per_group..(g + 1) * sms_per_group).collect())
                    .collect(),
                reach_bytes: 64 * GIB,
                solo_gbps: vec![gbps; groups],
                independent: true,
                card_id: format!("card-{groups}x{sms_per_group}"),
            },
            memory_bytes: mem_gib * GIB,
        }
    }

    #[test]
    fn two_equal_cards_split_evenly() {
        let cards = vec![card(14, 8, 120.0, 80), card(14, 8, 120.0, 80)];
        let rows = 120 * GIB / 128;
        let plan = FleetPlan::build(&cards, rows, 128, 0).unwrap();
        assert_eq!(plan.shards.len(), 2);
        let r0 = plan.shards[0].rows as f64;
        let r1 = plan.shards[1].rows as f64;
        assert!((r0 / r1 - 1.0).abs() < 0.01, "{r0} vs {r1}");
        assert!(plan.fits_reach(&cards));
    }

    #[test]
    fn capacity_weighting_favors_faster_card() {
        // Card B has 6-SM groups only (slower): gets a smaller shard.
        let cards = vec![card(14, 8, 120.0, 80), card(14, 6, 90.0, 80)];
        let rows = 100 * GIB / 128;
        let plan = FleetPlan::build(&cards, rows, 128, 0).unwrap();
        assert!(plan.shards[0].rows > plan.shards[1].rows);
        let ratio = plan.shards[0].rows as f64 / plan.shards[1].rows as f64;
        assert!((ratio - 120.0 / 90.0).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn memory_clamp_spills_to_other_cards() {
        // A fast card with tiny memory cannot take its capacity share.
        let cards = vec![card(14, 8, 200.0, 10), card(14, 8, 100.0, 80)];
        let rows = 60 * GIB / 128;
        let plan = FleetPlan::build(&cards, rows, 128, 0).unwrap();
        assert_eq!(plan.shards[0].rows, 10 * GIB / 128);
        assert_eq!(plan.shards[1].rows, 50 * GIB / 128);
    }

    #[test]
    fn oversized_table_rejected() {
        let cards = vec![card(14, 8, 120.0, 80)];
        assert!(FleetPlan::build(&cards, 100 * GIB / 128, 128, 0).is_err());
    }

    #[test]
    fn route_and_split_are_consistent() {
        let cards = vec![card(14, 8, 120.0, 80), card(14, 8, 110.0, 40)];
        let rows = 90 * GIB / 128;
        let plan = FleetPlan::build(&cards, rows, 128, 1).unwrap();
        let batch: Vec<u64> = vec![0, rows - 1, rows / 2, 17, rows / 3];
        let split = plan.split(&batch).unwrap();
        let mut covered = 0;
        for (si, (locals, positions)) in split.iter().enumerate() {
            for (k, &local) in locals.iter().enumerate() {
                let global = plan.shards[si].start_row + local;
                assert_eq!(global, batch[positions[k] as usize]);
                covered += 1;
            }
        }
        assert_eq!(covered, batch.len());
        assert!(plan.route(rows).is_err());
    }

    #[test]
    fn per_card_windows_respect_each_cards_reach() {
        // Mixed fleet: an 80 GiB card needs 2 windows, a 40 GiB fits in 1.
        let cards = vec![card(14, 8, 120.0, 80), card(14, 8, 120.0, 40)];
        let rows = 120 * GIB / 128;
        let plan = FleetPlan::build(&cards, rows, 128, 0).unwrap();
        assert!(plan.fits_reach(&cards));
        for s in &plan.shards {
            // Every window pinned to a group of ITS card.
            for w in 0..s.plan.count() {
                assert!(!s.placement.serving_groups(w).is_empty());
            }
        }
    }

    #[test]
    fn with_ranges_builds_and_validates_migrated_plans() {
        let cards = vec![card(14, 8, 120.0, 80), card(14, 8, 120.0, 80)];
        let rows = 100 * GIB / 128;
        let old = FleetPlan::build(&cards, rows, 128, 0).unwrap();
        assert_eq!(old.generation, 0);
        // Shift a quarter of the table from card 0 to card 1.
        let moved = rows / 4;
        let new_rows = vec![old.shards[0].rows - moved, old.shards[1].rows + moved];
        let next = FleetPlan::with_ranges(&cards, &new_rows, rows, 128, 0, 1).unwrap();
        assert_eq!(next.generation, 1);
        assert!(next.fits_reach(&cards));
        assert_eq!(old.rows_moved(&next), moved);
        assert_eq!(next.rows_per_card(2), new_rows);
        // Routing stays total and consistent under the new boundaries.
        let (si, local) = next.route(rows - 1).unwrap();
        assert_eq!(next.shards[si].start_row + local, rows - 1);

        // Over-memory assignments and non-tiling row counts are rejected.
        assert!(FleetPlan::with_ranges(&cards, &[rows, 0], rows, 128, 0, 1).is_err());
        assert!(FleetPlan::with_ranges(&cards, &[rows / 2, rows / 2 + 1], rows, 128, 0, 1)
            .is_err());
    }

    #[test]
    fn property_fleet_shards_tile_rows() {
        prop::check("fleet-tiling", 40, |g| {
            let n_cards = g.usize(1, 4);
            let cards: Vec<CardSpec> = (0..n_cards)
                .map(|_| {
                    card(
                        g.usize(2, 14),
                        *g.pick(&[6, 8]),
                        g.f64(80.0, 130.0),
                        g.u64(8, 80),
                    )
                })
                .collect();
            let fleet_rows: u64 = cards.iter().map(|c| c.memory_bytes / 128).sum();
            let rows = g.u64(1 << 16, fleet_rows);
            let Ok(plan) = FleetPlan::build(&cards, rows, 128, g.u64(0, 99)) else {
                return; // reach constraints can legitimately fail
            };
            // Shards tile [0, rows).
            let mut cursor = 0;
            for s in &plan.shards {
                assert_eq!(s.start_row, cursor);
                cursor = s.end_row();
            }
            assert_eq!(cursor, rows);
            // Random routes agree with containment.
            for _ in 0..20 {
                let row = g.u64(0, rows - 1);
                let (si, local) = plan.route(row).unwrap();
                assert!(plan.shards[si].contains(row));
                assert_eq!(plan.shards[si].start_row + local, row);
            }
        });
    }
}

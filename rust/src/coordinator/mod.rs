//! L3 coordinator: the serving *mechanics* under the facade.
//!
//! The paper shows that full-speed random access to all 80 GB requires
//! confining each SM resource group to a window smaller than its 64 GB TLB
//! reach.  This module holds the machinery that enforces that for the
//! workload the paper motivates (random cache-line lookups over a huge
//! table).  **The public entry point is [`crate::service::Service`]** — the
//! async ticketed facade documented in `service/`; what lives here are its
//! moving parts:
//!
//! * [`table`]     — zero-copy storage: one shared `Arc<[f32]>` behind
//!                   [`TableView`]s; sharding never copies row data.
//! * [`chunks`]    — slice the table into windows <= probed reach.
//! * [`placement`] — pin groups to windows: the [`Placer`] trait (the
//!                   paper's three arms as [`StaticPlacer`]) and the
//!                   generation-stamped live [`PlacementCell`].
//! * [`adaptive`]  — skew-aware [`AdaptivePlacer`]: rebalance the
//!                   group↔window deal from per-window load signals.
//! * [`replan`]    — [`PlanSplitter`]: re-split the window *boundaries*
//!                   themselves when skew is hotter than the deal's group
//!                   granularity can absorb.
//! * [`controlplane`] — the repartitioning [`ControlPlane`]: one
//!                   escalation policy (deal → re-split → migrate →
//!                   repack → replicate, cheapest data movement first,
//!                   hysteresis per level) with an audited decision trace;
//!                   driven per card by [`crate::service::SimBackend`] and
//!                   fleet-wide by [`crate::service::FleetService`].
//! * [`remap`]     — TLB-aware hot-row packing: per-window logical→physical
//!                   row permutations ([`RemapPlan`]) densifying learned
//!                   hot sets into page-aligned prefixes, published live
//!                   through the [`PlacementCell`] like re-splits.
//! * [`replicate`] — hot-shard read replication: the generation-stamped
//!                   [`ReplicaSet`] giving a saturated shard zero-copy
//!                   replicas on additional cards, routed by
//!                   power-of-two-choices over live queue depth (fifth
//!                   control-plane lever, fleet scope).
//! * [`router`]    — split requests by owning window (under the current
//!                   plan + placement generation), merge in order.
//! * [`batcher`]   — dynamic batching with deadline + backpressure.
//! * [`server`]    — the PJRT [`crate::service::Backend`]: per-group
//!                   worker threads executing AOT gather kernels via
//!                   [`crate::runtime`] (the hermetic sibling is
//!                   [`crate::service::SimBackend`]).
//! * [`state`]     — assignment epochs, group health, failure rebalancing.
//! * [`cluster`]   — fleet-level sharding across several probed cards
//!                   (maps vary card to card, per the paper); served
//!                   through [`crate::service::FleetService`].
//! * [`metrics`]   — counters + latency histogram + per-window load,
//!                   shared by backends, sessions, and tickets.

pub mod adaptive;
pub mod batcher;
pub mod chunks;
pub mod cluster;
pub mod controlplane;
pub mod metrics;
pub mod placement;
pub mod remap;
pub mod replan;
pub mod replicate;
pub mod router;
pub mod server;
pub mod state;
pub mod table;

pub use adaptive::{AdaptiveConfig, AdaptivePlacer};
pub use batcher::{Batcher, BatcherConfig};
pub use chunks::{Window, WindowPlan};
pub use cluster::{CardSpec, CardShard, FleetPlan};
pub use controlplane::{capacity_imbalance, ControlPlane, ControlPlaneConfig, Decision, Lever};
pub use metrics::{Metrics, MetricsSnapshot, RowFreqSketch};
pub use placement::{
    Placement, PlacementCell, PlacementPolicy, Placer, StaticPlacer, WindowSignals,
};
pub use remap::{RemapConfig, RemapPlan, WindowRemap};
pub use replan::{PlanSplitter, SplitterConfig};
pub use replicate::{Replica, ReplicaSet, ReplicateConfig};
pub use router::{merge_rows, pad_indices, Router};
pub use server::{EmbeddingServer, ServerConfig};
pub use state::{CoordinatorState, GroupHealth};
pub use table::{Table, TableView};

//! Model-checked proofs for the serving path's lock-free primitives.
//!
//! Compiled only under `--features model`, where the `util::sync` shim
//! routes every atomic, lock, cell access, and park/unpark of the ported
//! modules through the in-tree `interleave` checker (see its crate docs).
//! Each test here drives the *real* crate primitive — not a replica —
//! through every interleaving the bounded DFS reaches (default: all
//! schedules with at most 2 preemptive context switches, plus stale-read
//! choices for `Relaxed` visibility), so a pass is a proof over that
//! bounded space, not a lucky run.  `model_random` supplements the
//! exhaustive passes with seeded unbounded-preemption schedules for depth.
//!
//! The seven modeled protocols (EXPERIMENTS.md §Verify):
//!
//! 1. SPSC ring send/recv handshake, including the Dekker sleeping-flag
//!    park/unpark with its `PARK_BACKSTOP` removed (the model's `park`
//!    never times out — correctness cannot lean on the backstop).
//! 2. The ring's close/drop-drain race (`pushing` bracket): no queued item
//!    is ever leaked or double-freed, under any interleaving.
//! 3. `Completion` one-shot + the request countdown (`RequestAcc`):
//!    N workers' `finish_part` vs. a parked waiter.
//! 4. `ScatterBuf`'s claim bitmap under duplicate writes (the PR-6 hedging
//!    race): token-guarded duplicates are clean; unguarded duplicates are
//!    *detected* in every schedule (the alias assertion fires before the
//!    data race can execute).
//! 5. `GlobalAdmission`'s lock-free CAS admission and its parked-waiter
//!    wakeup (whose `wait_timeout` backstop is likewise disabled).
//! 6. The striped `SlabPool`'s steal path: concurrent gets over a
//!    two-stripe pool hand the lone pooled slab to exactly one caller
//!    (conservation — never duplicated, never stranded), and a get racing
//!    a put never loses the slab, in every schedule.
//! 7. The replication router (PR 9): the queue-depth gauge discipline
//!    (`DepthGuard`'s paired inc/dec never underflows, P2C samples are
//!    bounded by the true in-flight count) and the replica-set generation
//!    swap — a routed reader's pinned snapshot stays coherent and its
//!    depth unit balances through the *shared* gauge even when the
//!    publisher retires that generation mid-request.
//!
//! Plus the ordering regression behind the PR's audit:
//! [`tests::dekker_handshake_requires_seqcst`] re-derives *why* the ring's
//! four Dekker accesses are `SeqCst` — the same protocol with the
//! plausible-looking `Release`/`Acquire` orderings loses the wakeup
//! (store-buffering) and the checker reports the deadlock.

#[cfg(test)]
mod tests {
    use std::sync::{Arc, OnceLock};
    use std::time::Instant;

    use interleave::{explore, Config, FailureKind};

    use crate::coordinator::metrics::Metrics;
    use crate::service::backend::RequestAcc;
    use crate::service::ring::{spsc, Completion, EpochGate};
    use crate::service::scatter::{ScatterBuf, SlabPool};
    use crate::service::session::GlobalAdmission;
    use crate::util::sync::thread::{self, Thread};
    use crate::util::sync::{AtomicBool, AtomicU64, AtomicUsize, CellSlot, Mutex, Ordering};

    /// Assert an exhaustive clean pass: no failure AND the bounded state
    /// space was fully explored (a capped-out run is not a proof).
    fn assert_exhaustive_clean(what: &str, f: impl Fn()) {
        assert_exhaustive_clean_with(what, Config::default(), f);
    }

    /// As [`assert_exhaustive_clean`] with an explicit config — the larger
    /// models raise `max_executions` so the DFS can actually exhaust their
    /// bounded space instead of tripping the default cap.
    fn assert_exhaustive_clean_with(what: &str, cfg: Config, f: impl Fn()) {
        let r = explore(cfg, f);
        if let Some(fl) = r.failure {
            panic!(
                "{what}: {:?} after {} executions: {} (schedule {:?})",
                fl.kind, r.executions, fl.message, fl.schedule
            );
        }
        assert!(
            r.complete,
            "{what}: state space not exhausted in {} executions",
            r.executions
        );
    }

    // -----------------------------------------------------------------
    // T0: the Dekker-orderings regression (ring audit, PR 7).
    // -----------------------------------------------------------------

    /// A minimal replica of the ring's sleep handshake, parameterized by
    /// memory ordering.  Consumer side: set own sleeping flag, re-check
    /// the peer-owned counter, park.  Producer side: bump the counter,
    /// check the flag, unpark.  Exactly the four accesses the ring audit
    /// covers (`service::ring` module docs, "Ordering audit").
    fn dekker(store_ord: Ordering, load_ord: Ordering) -> impl Fn() {
        move || {
            let item = Arc::new(AtomicUsize::new(0));
            let sleeping = Arc::new(AtomicBool::new(false));
            let me: Arc<OnceLock<Thread>> = Arc::new(OnceLock::new());
            let consumer = thread::spawn({
                let item = Arc::clone(&item);
                let sleeping = Arc::clone(&sleeping);
                let me = Arc::clone(&me);
                move || loop {
                    if item.load(load_ord) != 0 {
                        return;
                    }
                    let _ = me.set(thread::current());
                    sleeping.store(true, store_ord);
                    // Dekker re-check after publishing the flag.
                    if item.load(load_ord) != 0 {
                        sleeping.store(false, store_ord);
                        return;
                    }
                    // No timeout backstop: the handshake must be correct.
                    thread::park();
                    sleeping.store(false, store_ord);
                }
            });
            item.store(1, store_ord);
            if sleeping.load(load_ord) {
                if let Some(t) = me.get() {
                    t.unpark();
                }
            }
            consumer.join().unwrap();
        }
    }

    /// The PR's ordering audit, as a machine-checked fact: the handshake
    /// is wakeup-correct under `SeqCst` (exhaustively), and the
    /// plausible-looking `Release`/`Acquire` version — which a Dekker
    /// protocol must NOT use — loses the wakeup via store-buffering and
    /// deadlocks.  If someone "optimizes" the ring's orderings back down,
    /// the clean half of this test is the spec they break (and the ring
    /// models below fail outright).
    #[test]
    fn dekker_handshake_requires_seqcst() {
        assert_exhaustive_clean(
            "SeqCst Dekker handshake",
            dekker(Ordering::SeqCst, Ordering::SeqCst),
        );

        let r = explore(
            Config::default(),
            dekker(Ordering::Release, Ordering::Acquire),
        );
        match r.failure {
            Some(f) => assert_eq!(
                f.kind,
                FailureKind::Deadlock,
                "Release/Acquire Dekker must fail as a lost-wakeup deadlock, got {f:?}"
            ),
            None => panic!(
                "Release/Acquire Dekker explored {} executions without finding \
                 the store-buffering lost wakeup — checker regression",
                r.executions
            ),
        }
    }

    // -----------------------------------------------------------------
    // T1: the SPSC ring.
    // -----------------------------------------------------------------

    /// Producer pushes a stream longer than the ring through blocking
    /// `send` (parks on full), then closes; consumer drains with blocking
    /// `recv` (parks on empty).  FIFO with nothing lost, under every
    /// bounded schedule — including the ones where both sides sleep and
    /// wake each other through the Dekker flags.
    #[test]
    fn spsc_blocking_handshake_exhaustive() {
        let cfg = Config {
            max_executions: 400_000,
            max_ops: 400_000,
            ..Config::default()
        };
        assert_exhaustive_clean_with("SPSC send/recv handshake", cfg, || {
            let (tx, rx) = spsc::<u64>(2);
            let producer = thread::spawn(move || {
                for i in 0..3u64 {
                    tx.send(i).unwrap();
                }
                tx.close();
            });
            let mut expect = 0u64;
            while let Some(v) = rx.recv() {
                assert_eq!(v, expect, "out of order or lost");
                expect += 1;
            }
            assert_eq!(expect, 3, "stream ended early");
            producer.join().unwrap();
        });
    }

    /// Depth supplement: a longer stream under seeded unbounded-preemption
    /// schedules (too deep to exhaust; EXPERIMENTS.md §Verify lists the
    /// seed so a failure reproduces).
    #[test]
    fn spsc_blocking_handshake_randomized() {
        interleave::model_random(0xA100, 150, || {
            let (tx, rx) = spsc::<u64>(2);
            let producer = thread::spawn(move || {
                for i in 0..6u64 {
                    tx.send(i).unwrap();
                }
                tx.close();
            });
            let mut expect = 0u64;
            while let Some(v) = rx.recv() {
                assert_eq!(v, expect);
                expect += 1;
            }
            assert_eq!(expect, 6);
            producer.join().unwrap();
        });
    }

    /// T2: the close/drop-drain race.  A consumer dropped while a push is
    /// mid-flight (the `pushing` bracket) must account for the item in
    /// every interleaving: delivered-and-dropped by the drain, or refused
    /// as `Closed` and dropped by the producer — never leaked into a slot
    /// both sides have abandoned, never dropped twice (the `RaceCell`
    /// slots would flag the double access).
    #[test]
    fn spsc_consumer_drop_drain_never_strands_items() {
        assert_exhaustive_clean("SPSC drop-drain", || {
            let item = Arc::new(());
            let (tx, rx) = spsc::<Arc<()>>(2);
            let probe = Arc::clone(&item);
            let producer = thread::spawn(move || {
                let _ = tx.try_send(probe);
                // tx drops here: the ring closes from the producer side.
            });
            drop(rx); // races the push: close + spin-out `pushing` + drain
            producer.join().unwrap();
            assert_eq!(
                Arc::strong_count(&item),
                1,
                "queued item leaked (or freed twice and we'd have raced)"
            );
        });
    }

    // -----------------------------------------------------------------
    // T3: Completion one-shot + the request countdown.
    // -----------------------------------------------------------------

    /// Two workers scatter disjoint rows and count the request down
    /// (`finish_part`) while the waiter parks on the `Completion`; the
    /// last worker must publish exactly once and wake the waiter in every
    /// schedule.  This is the whole default-path completion protocol —
    /// claim CAS, result cell, WAITING/READY state machine, park/unpark —
    /// driven end to end through `RequestAcc`.
    #[test]
    fn completion_countdown_exhaustive() {
        let cfg = Config {
            max_executions: 400_000,
            max_ops: 400_000,
            ..Config::default()
        };
        assert_exhaustive_clean_with("Completion + countdown", cfg, || {
            let metrics = Arc::new(Metrics::new());
            let pool = SlabPool::new();
            let acc = Arc::new(RequestAcc::new_slab(&pool, 2, 1, false));
            acc.arm(2, Instant::now());
            let done = acc.completion();
            for i in 0..2u32 {
                let acc = Arc::clone(&acc);
                let m = Arc::clone(&metrics);
                thread::spawn(move || {
                    acc.write_row(i, &[(i + 1) as f32]);
                    acc.finish_part(&m);
                });
            }
            let out = done
                .wait(None)
                .expect("no deadline set")
                .expect("both parts succeeded");
            assert_eq!(out, vec![1.0, 2.0]);
        });
    }

    /// A completer racing a defensive double-complete (the accumulator
    /// Drop backstop does this) must publish the first result exactly once
    /// — the loser's result is silently dropped, the waiter never sees two.
    #[test]
    fn completion_double_complete_is_idempotent() {
        assert_exhaustive_clean("Completion double-complete", || {
            let done = Arc::new(Completion::new());
            let racer = {
                let done = Arc::clone(&done);
                thread::spawn(move || done.complete(Ok(vec![1.0])))
            };
            done.complete(Ok(vec![2.0]));
            racer.join().unwrap();
            let got = done.try_take().expect("claimed cell must publish");
            let v = got.unwrap();
            assert!(v == vec![1.0] || v == vec![2.0]);
            assert!(done.try_take().is_none(), "one-shot: second take empty");
        });
    }

    // -----------------------------------------------------------------
    // T4: ScatterBuf claim bitmap under duplicate writes (hedging race).
    // -----------------------------------------------------------------

    /// The PR-6 hedging protocol: two copies of one sub-batch race, a
    /// claim token (here the same CAS shape as `resilience::PartToken`)
    /// elects the writer, the loser stays silent.  Clean in every
    /// schedule — exactly one row lands, `take` sees it.
    #[test]
    fn scatter_hedged_duplicate_with_token_is_clean() {
        assert_exhaustive_clean("ScatterBuf hedged duplicate (token)", || {
            let pool = SlabPool::with_claims(true);
            let buf = Arc::new(ScatterBuf::new(&pool, 1, 1));
            let token = Arc::new(AtomicBool::new(false));
            let hedge = {
                let buf = Arc::clone(&buf);
                let token = Arc::clone(&token);
                thread::spawn(move || {
                    if token
                        .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                    {
                        buf.write_row(0, &[2.0]);
                    }
                })
            };
            if token
                .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                buf.write_row(0, &[1.0]);
            }
            hedge.join().unwrap();
            let out = buf.take();
            assert!(out == vec![1.0] || out == vec![2.0], "one copy must win");
        });
    }

    /// The same race *without* the token — the bug hedging would have
    /// without PR 6's claim protocol.  The claim bitmap must catch the
    /// alias in **every** schedule: the checker finds a Panic (the
    /// "written twice" assertion), never a DataRace — i.e. the bitmap's
    /// swap fires before the aliased data write can execute.
    #[test]
    fn scatter_unguarded_duplicate_is_always_detected() {
        let r = explore(Config::default(), || {
            let pool = SlabPool::with_claims(true);
            let buf = Arc::new(ScatterBuf::new(&pool, 1, 1));
            let rogue = {
                let buf = Arc::clone(&buf);
                thread::spawn(move || buf.write_row(0, &[2.0]))
            };
            buf.write_row(0, &[1.0]);
            rogue.join().unwrap();
        });
        match r.failure {
            Some(f) => {
                assert_eq!(
                    f.kind,
                    FailureKind::Panic,
                    "the claim bitmap must fire before any racy write, got {f:?}"
                );
                assert!(
                    f.message.contains("written twice"),
                    "wrong panic: {}",
                    f.message
                );
            }
            None => panic!(
                "unguarded duplicate write went undetected in {} executions",
                r.executions
            ),
        }
    }

    // -----------------------------------------------------------------
    // T5: EpochGate + GlobalAdmission.
    // -----------------------------------------------------------------

    /// Mutual exclusion of the CAS gate, proven on a `RaceCell`: the
    /// unsynchronized counter inside the critical section would be flagged
    /// as a data race by the checker in any schedule where both threads
    /// got through the gate together.
    #[test]
    fn epoch_gate_excludes_exhaustively() {
        assert_exhaustive_clean("EpochGate mutual exclusion", || {
            let gate = Arc::new(EpochGate::new());
            let cell = Arc::new(CellSlot::new(0usize));
            let t = {
                let gate = Arc::clone(&gate);
                let cell = Arc::clone(&cell);
                thread::spawn(move || {
                    let _g = gate.lock();
                    // SAFETY: the gate serializes access; the RaceCell
                    // aborts the model if it ever fails to.
                    unsafe { *cell.get() += 1 };
                })
            };
            {
                let _g = gate.lock();
                // SAFETY: as above.
                unsafe { *cell.get() += 1 };
            }
            t.join().unwrap();
            // SAFETY: the spawned thread was joined, so this read is
            // ordered after both increments; no access is concurrent.
            assert_eq!(unsafe { *cell.get() }, 2);
        });
    }

    /// The lock-free admission core: two tenants (capacity 2, weights 1:1
    /// so each is guaranteed one slot) acquire and release concurrently.
    /// In every schedule both within-guarantee grants succeed, the budget
    /// never overshoots, and everything drains to zero.
    #[test]
    fn admission_cas_invariants_exhaustive() {
        assert_exhaustive_clean("GlobalAdmission CAS invariants", || {
            let ga = GlobalAdmission::new(2);
            let a = ga.register("a", 1.0);
            let b = ga.register("b", 1.0);
            let t = {
                let ga = Arc::clone(&ga);
                thread::spawn(move || {
                    let g = GlobalAdmission::try_acquire(&ga, b)
                        .expect("within guarantee: must admit");
                    assert!(ga.used_total() <= 2, "budget overshot");
                    drop(g);
                })
            };
            let g = GlobalAdmission::try_acquire(&ga, a).expect("within guarantee: must admit");
            assert!(ga.used_total() <= 2, "budget overshot");
            drop(g);
            t.join().unwrap();
            assert_eq!(ga.used_total(), 0, "slots leaked");
        });
    }

    // -----------------------------------------------------------------
    // T6: the striped SlabPool's steal path (PR 8).
    // -----------------------------------------------------------------

    /// Two concurrent `get`s race for one pooled slab over a two-stripe
    /// pool.  Whatever stripe the round-robin cursor lands on, exactly
    /// one caller receives the retained capacity (its home hit or a steal
    /// from the sibling stripe) and the other allocates fresh — the slab
    /// is never handed out twice and never stranded.  The exhaustive pass
    /// is also the deadlock-freedom proof for the steal scan's
    /// stripe-at-a-time locking.
    #[test]
    fn slab_pool_steal_hands_the_slab_to_exactly_one_getter() {
        assert_exhaustive_clean("SlabPool steal conservation", || {
            let pool = SlabPool::with_stripes(2);
            pool.put(Vec::with_capacity(128));
            let racer = {
                let pool = Arc::clone(&pool);
                thread::spawn(move || pool.get(16).capacity())
            };
            let mine = pool.get(16).capacity();
            let theirs = racer.join().unwrap();
            let winners =
                usize::from(mine >= 128) + usize::from(theirs >= 128);
            assert_eq!(winners, 1, "pooled slab duplicated or stranded");
            assert_eq!(pool.pooled(), 0, "both stripes must be drained");
        });
    }

    /// A `get` racing a `put`: in every interleaving the slab ends up in
    /// exactly one place — stolen by the getter, or retained in a stripe
    /// for the next caller.  Never dropped, never double-pooled.
    #[test]
    fn slab_pool_concurrent_put_get_never_loses_the_slab() {
        assert_exhaustive_clean("SlabPool put/get conservation", || {
            let pool = SlabPool::with_stripes(2);
            let putter = {
                let pool = Arc::clone(&pool);
                thread::spawn(move || pool.put(Vec::with_capacity(256)))
            };
            let got = pool.get(16).capacity() >= 256;
            putter.join().unwrap();
            assert_eq!(
                usize::from(got) + pool.pooled(),
                1,
                "slab lost or duplicated across the put/get race"
            );
        });
    }

    // -----------------------------------------------------------------
    // T7: the replication router (PR 9).
    // -----------------------------------------------------------------

    /// The depth-gauge discipline behind power-of-two-choices routing, as
    /// a minimal replica of `fleet::DepthGuard` (the fleet itself runs on
    /// std atomics; like T0, the protocol *shape* is what is proven).
    /// Two routed requests race: each samples both gauges (`Relaxed`, so
    /// the checker also explores stale snapshots), routes to the
    /// shallower, increments before submission, and decrements on drop.
    /// In every schedule no sample ever exceeds the true in-flight count,
    /// no decrement underflows, and both gauges drain to zero.
    #[test]
    fn depth_gauge_p2c_routing_never_underflows() {
        assert_exhaustive_clean("depth gauge P2C discipline", || {
            let gauges = Arc::new([AtomicU64::new(0), AtomicU64::new(0)]);
            let route = |gauges: &Arc<[AtomicU64; 2]>| {
                // RELAXED-equivalent snapshot: stale is allowed, the pick
                // is only a heuristic — the guard pairing is the proof
                // obligation.
                let da = gauges[0].load(Ordering::Relaxed);
                let db = gauges[1].load(Ordering::Relaxed);
                assert!(da <= 2 && db <= 2, "sample exceeds in-flight count");
                let pick = usize::from(db < da);
                gauges[pick].fetch_add(1, Ordering::Relaxed);
                let prev = gauges[pick].fetch_sub(1, Ordering::Relaxed);
                assert!(prev >= 1, "depth gauge underflow");
            };
            let racer = {
                let gauges = Arc::clone(&gauges);
                thread::spawn(move || route(&gauges))
            };
            route(&gauges);
            racer.join().unwrap();
            assert_eq!(gauges[0].load(Ordering::Relaxed), 0, "gauge 0 leaked");
            assert_eq!(gauges[1].load(Ordering::Relaxed), 0, "gauge 1 leaked");
        });
    }

    /// The replica-set generation swap under a concurrently routed read:
    /// the publisher builds the next generation *completely* (stamp and
    /// unit list together, `publish_replicas`' shape) and swaps it behind
    /// the state lock, while a reader pins the old snapshot, acquires a
    /// depth unit through it, and releases after the swap.  In every
    /// schedule the reader's snapshot is internally coherent (stamp
    /// matches units — never torn), the retired generation stays alive
    /// for the pinned reader, and the *shared* gauge balances across the
    /// swap (the guard's decrement lands on the same gauge the new
    /// generation routes by).
    #[test]
    fn replica_generation_swap_keeps_pinned_readers_coherent() {
        struct Gen {
            stamp: u64,
            units: Vec<usize>,
            gauge: Arc<AtomicU64>,
        }
        assert_exhaustive_clean("replica generation swap", || {
            let gauge = Arc::new(AtomicU64::new(0));
            let state = Arc::new(Mutex::new(Arc::new(Gen {
                stamp: 0,
                units: Vec::new(),
                gauge: Arc::clone(&gauge),
            })));
            let reader = {
                let state = Arc::clone(&state);
                thread::spawn(move || {
                    let snap = Arc::clone(&state.lock().unwrap());
                    assert_eq!(
                        snap.units.len() as u64,
                        snap.stamp,
                        "torn replica publish"
                    );
                    // DepthGuard::acquire under the pinned generation...
                    snap.gauge.fetch_add(1, Ordering::Relaxed);
                    // ...raced by the publisher's swap; the release must
                    // still balance through the shared gauge.
                    let prev = snap.gauge.fetch_sub(1, Ordering::Relaxed);
                    assert!(prev >= 1, "depth gauge underflow across the swap");
                })
            };
            // Publisher: retire generation 0 with a fully built successor
            // sharing the same gauge (exactly `publish_replicas`).
            let next = Arc::new(Gen {
                stamp: 1,
                units: vec![7],
                gauge: Arc::clone(&gauge),
            });
            *state.lock().unwrap() = next;
            reader.join().unwrap();
            let live = Arc::clone(&state.lock().unwrap());
            assert_eq!(live.stamp, 1);
            assert_eq!(live.units, vec![7]);
            assert_eq!(live.gauge.load(Ordering::Relaxed), 0, "gauge leaked");
        });
    }

    /// The parked-waiter handshake under a full budget, with the
    /// `wait_timeout` backstop disabled by the model: a blocked acquirer
    /// must be woken by the release in every schedule, or the checker
    /// reports the lost wakeup as a deadlock.
    #[test]
    fn admission_blocking_wakeup_exhaustive() {
        assert_exhaustive_clean("GlobalAdmission blocking wakeup", || {
            let ga = GlobalAdmission::new(1);
            let a = ga.register("a", 1.0);
            let held = GlobalAdmission::try_acquire(&ga, a).expect("empty budget");
            let waiter = {
                let ga = Arc::clone(&ga);
                thread::spawn(move || {
                    let (g, _blocked) = GlobalAdmission::acquire_blocking(&ga, a);
                    drop(g);
                })
            };
            drop(held); // must wake the (possibly parked) waiter
            waiter.join().unwrap();
            assert_eq!(ga.used_total(), 0);
        });
    }
}

//! Per-group page-walker pool with walk coalescing (MSHR-style merge).
//!
//! A TLB miss queues a page walk on the group's k-server walker pool.  If a
//! walk for the same page is already in flight, the new miss *merges* onto
//! it (no extra walker occupancy) and completes at the same time — exactly
//! what hardware miss-status-holding registers do.  Without merging, a
//! burst of warps touching one new page would count as dozens of walks.
//!
//! The walker pool's service rate (k / walk_ns) is the ceiling that the
//! paper's Fig-1 cliff collapses onto once the working set exceeds reach.

use std::collections::HashMap;

use crate::sim::queue::{MultiServer, Ps};

#[derive(Debug, Clone)]
pub struct WalkerPool {
    pool: MultiServer,
    walk_svc: Ps,
    /// page -> completion time of the in-flight walk for that page.
    pending: HashMap<u64, Ps>,
    walks: u64,
    merged: u64,
    /// Lazy cleanup watermark: drop stale `pending` entries when it grows.
    sweep_len: usize,
}

impl WalkerPool {
    pub fn new(walkers: usize, walk_svc: Ps) -> Self {
        Self {
            pool: MultiServer::new(walkers),
            walk_svc,
            pending: HashMap::new(),
            walks: 0,
            merged: 0,
            sweep_len: 64,
        }
    }

    /// A miss for `page` arrives at `t`; returns when its translation is
    /// available.  Either merges onto an in-flight walk or starts a new one.
    #[inline]
    pub fn walk(&mut self, t: Ps, page: u64) -> Ps {
        if let Some(&done) = self.pending.get(&page) {
            if done > t {
                self.merged += 1;
                return done;
            }
            // Stale entry (walk finished in the past): fall through.
        }
        let done = self.pool.serve(t, self.walk_svc);
        self.pending.insert(page, done);
        self.walks += 1;
        if self.pending.len() > self.sweep_len {
            self.pending.retain(|_, &mut d| d > t);
            self.sweep_len = (self.pending.len() * 2).max(64);
        }
        done
    }

    /// Completion time of an in-flight walk for `page`, if any is pending
    /// at or after time 0 (caller checks recency).  Used for hit-under-miss:
    /// a TLB hit on a just-installed entry must still wait for the walk.
    #[inline]
    pub fn pending_completion(&self, page: u64) -> Option<Ps> {
        self.pending.get(&page).copied()
    }

    /// Completed + in-flight real walks (merges excluded).
    pub fn walks(&self) -> u64 {
        self.walks
    }

    /// Misses that merged onto an in-flight walk.
    pub fn merged(&self) -> u64 {
        self.merged
    }

    pub fn busy_ps(&self) -> Ps {
        self.pool.busy_ps()
    }

    pub fn walkers(&self) -> usize {
        self.pool.servers()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct_pages_use_walkers_in_parallel() {
        let mut w = WalkerPool::new(4, 500_000); // 500 ns walks
        for p in 0..4 {
            assert_eq!(w.walk(0, p), 500_000);
        }
        // Fifth distinct page queues.
        assert_eq!(w.walk(0, 99), 1_000_000);
        assert_eq!(w.walks(), 5);
        assert_eq!(w.merged(), 0);
    }

    #[test]
    fn same_page_merges() {
        let mut w = WalkerPool::new(4, 500_000);
        let d = w.walk(0, 7);
        // Ten more misses on the same page while the walk is in flight: all
        // complete at the same time, consuming no walkers.
        for _ in 0..10 {
            assert_eq!(w.walk(100, 7), d);
        }
        assert_eq!(w.walks(), 1);
        assert_eq!(w.merged(), 10);
        // Another distinct page still finds 3 idle walkers.
        assert_eq!(w.walk(0, 8), 500_000);
    }

    #[test]
    fn stale_pending_entry_triggers_new_walk() {
        let mut w = WalkerPool::new(2, 1000);
        let d1 = w.walk(0, 7);
        assert_eq!(d1, 1000);
        // Long after the first walk completed (entry is stale; the page was
        // evicted from the TLB again): a new real walk must start.
        let d2 = w.walk(10_000, 7);
        assert_eq!(d2, 11_000);
        assert_eq!(w.walks(), 2);
        assert_eq!(w.merged(), 0);
    }

    #[test]
    fn throughput_is_k_over_walk_time() {
        let k = 8;
        let svc = 500_000;
        let mut w = WalkerPool::new(k, svc);
        let n = 8000u64;
        let mut last = 0;
        for p in 0..n {
            last = last.max(w.walk(0, p));
        }
        // n distinct pages, k walkers: makespan = n/k * svc.
        assert_eq!(last, n / k as u64 * svc);
    }

    #[test]
    fn pending_map_is_swept() {
        let mut w = WalkerPool::new(2, 10);
        for p in 0..10_000u64 {
            w.walk(p * 1000, p);
        }
        // All walks complete long before the last arrival; sweep must have
        // kept the map bounded.
        assert!(w.pending.len() < 1000, "pending = {}", w.pending.len());
    }
}

//! Per-group page-walker pool with walk coalescing (MSHR-style merge).
//!
//! A TLB miss queues a page walk on the group's k-server walker pool.  If a
//! walk for the same page is already in flight, the new miss *merges* onto
//! it (no extra walker occupancy) and completes at the same time — exactly
//! what hardware miss-status-holding registers do.  Without merging, a
//! burst of warps touching one new page would count as dozens of walks.
//!
//! The walker pool's service rate (k / walk_ns) is the ceiling that the
//! paper's Fig-1 cliff collapses onto once the working set exceeds reach.
//!
//! The pending table is an open-addressed (linear-probe, fibonacci-hashed)
//! map rather than `std::collections::HashMap`: in thrash mode every
//! access walks, so this lookup sits on the engine's innermost path and
//! SipHash + bucket-chasing dominated it (EXPERIMENTS.md §Perf L3).  The
//! table replicates the `HashMap` semantics *exactly* — including the lazy
//! sweep schedule — so the engine's bit-identical-measurement contract
//! holds (see the reference-engine equivalence tests in
//! [`crate::sim::engine`]).

use crate::sim::queue::{MultiServer, Ps};

/// Sentinel for an empty slot; device pages are far below `u64::MAX`.
const EMPTY_KEY: u64 = u64::MAX;

/// Fibonacci multiplier (2^64 / phi) — one multiply diffuses page numbers
/// whose low bits are correlated (contiguous regions).
const FIB: u64 = 0x9E37_79B9_7F4A_7C15;

/// Open-addressed `page -> completion` map for in-flight walks.
///
/// Linear probing, power-of-two capacity, load factor <= 7/8.  Removal
/// only ever happens wholesale via [`PendingTable::retain_after`] (the
/// sweep), which rebuilds in place — so no tombstones are needed.
#[derive(Debug, Clone)]
struct PendingTable {
    keys: Vec<u64>,
    vals: Vec<Ps>,
    /// `capacity - 1` (capacity is a power of two).
    mask: usize,
    /// `64 - log2(capacity)`: index = high bits of the hash product.
    hash_shift: u32,
    len: usize,
}

impl PendingTable {
    fn new() -> Self {
        Self::with_pow2_capacity(128)
    }

    fn with_pow2_capacity(cap: usize) -> Self {
        debug_assert!(cap.is_power_of_two() && cap >= 2);
        Self {
            keys: vec![EMPTY_KEY; cap],
            vals: vec![0; cap],
            mask: cap - 1,
            hash_shift: 64 - cap.trailing_zeros(),
            len: 0,
        }
    }

    /// Slot holding `page`, or the empty slot where it would be inserted.
    #[inline]
    fn probe(&self, page: u64) -> usize {
        let mut i = (page.wrapping_mul(FIB) >> self.hash_shift) as usize;
        loop {
            let k = self.keys[i];
            if k == page || k == EMPTY_KEY {
                return i;
            }
            i = (i + 1) & self.mask;
        }
    }

    #[inline]
    fn get(&self, page: u64) -> Option<Ps> {
        let i = self.probe(page);
        if self.keys[i] == page {
            Some(self.vals[i])
        } else {
            None
        }
    }

    #[inline]
    fn insert(&mut self, page: u64, done: Ps) {
        if (self.len + 1) * 8 > self.keys.len() * 7 {
            self.grow();
        }
        let i = self.probe(page);
        if self.keys[i] != page {
            self.keys[i] = page;
            self.len += 1;
        }
        self.vals[i] = done;
    }

    fn grow(&mut self) {
        let bigger = Self::with_pow2_capacity(self.keys.len() * 2);
        let old = std::mem::replace(self, bigger);
        for (k, v) in old.keys.into_iter().zip(old.vals) {
            if k != EMPTY_KEY {
                self.insert(k, v);
            }
        }
    }

    /// Keep only entries whose completion is strictly after `t` (the
    /// sweep's predicate), rebuilding in place.
    fn retain_after(&mut self, t: Ps) {
        let cap = self.keys.len();
        let old_keys = std::mem::replace(&mut self.keys, vec![EMPTY_KEY; cap]);
        let old_vals = std::mem::take(&mut self.vals);
        self.vals = vec![0; cap];
        self.len = 0;
        for (k, v) in old_keys.into_iter().zip(old_vals) {
            if k != EMPTY_KEY && v > t {
                self.insert(k, v);
            }
        }
    }

    fn len(&self) -> usize {
        self.len
    }
}

#[derive(Debug, Clone)]
pub struct WalkerPool {
    pool: MultiServer,
    walk_svc: Ps,
    /// page -> completion time of the in-flight walk for that page.
    pending: PendingTable,
    walks: u64,
    merged: u64,
    /// Lazy cleanup watermark: drop stale `pending` entries when it grows.
    sweep_len: usize,
}

impl WalkerPool {
    pub fn new(walkers: usize, walk_svc: Ps) -> Self {
        Self {
            pool: MultiServer::new(walkers),
            walk_svc,
            pending: PendingTable::new(),
            walks: 0,
            merged: 0,
            sweep_len: 64,
        }
    }

    /// A miss for `page` arrives at `t`; returns when its translation is
    /// available.  Either merges onto an in-flight walk or starts a new one.
    #[inline]
    pub fn walk(&mut self, t: Ps, page: u64) -> Ps {
        if let Some(done) = self.pending.get(page) {
            if done > t {
                self.merged += 1;
                return done;
            }
            // Stale entry (walk finished in the past): fall through.
        }
        let done = self.pool.serve(t, self.walk_svc);
        self.pending.insert(page, done);
        self.walks += 1;
        if self.pending.len() > self.sweep_len {
            self.pending.retain_after(t);
            self.sweep_len = (self.pending.len() * 2).max(64);
        }
        done
    }

    /// Completion time of an in-flight walk for `page`, if any is pending
    /// at or after time 0 (caller checks recency).  Used for hit-under-miss:
    /// a TLB hit on a just-installed entry must still wait for the walk.
    #[inline]
    pub fn pending_completion(&self, page: u64) -> Option<Ps> {
        self.pending.get(page)
    }

    /// Completed + in-flight real walks (merges excluded).
    pub fn walks(&self) -> u64 {
        self.walks
    }

    /// Misses that merged onto an in-flight walk.
    pub fn merged(&self) -> u64 {
        self.merged
    }

    pub fn busy_ps(&self) -> Ps {
        self.pool.busy_ps()
    }

    pub fn walkers(&self) -> usize {
        self.pool.servers()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct_pages_use_walkers_in_parallel() {
        let mut w = WalkerPool::new(4, 500_000); // 500 ns walks
        for p in 0..4 {
            assert_eq!(w.walk(0, p), 500_000);
        }
        // Fifth distinct page queues.
        assert_eq!(w.walk(0, 99), 1_000_000);
        assert_eq!(w.walks(), 5);
        assert_eq!(w.merged(), 0);
    }

    #[test]
    fn same_page_merges() {
        let mut w = WalkerPool::new(4, 500_000);
        let d = w.walk(0, 7);
        // Ten more misses on the same page while the walk is in flight: all
        // complete at the same time, consuming no walkers.
        for _ in 0..10 {
            assert_eq!(w.walk(100, 7), d);
        }
        assert_eq!(w.walks(), 1);
        assert_eq!(w.merged(), 10);
        // Another distinct page still finds 3 idle walkers.
        assert_eq!(w.walk(0, 8), 500_000);
    }

    #[test]
    fn stale_pending_entry_triggers_new_walk() {
        let mut w = WalkerPool::new(2, 1000);
        let d1 = w.walk(0, 7);
        assert_eq!(d1, 1000);
        // Long after the first walk completed (entry is stale; the page was
        // evicted from the TLB again): a new real walk must start.
        let d2 = w.walk(10_000, 7);
        assert_eq!(d2, 11_000);
        assert_eq!(w.walks(), 2);
        assert_eq!(w.merged(), 0);
    }

    #[test]
    fn throughput_is_k_over_walk_time() {
        let k = 8;
        let svc = 500_000;
        let mut w = WalkerPool::new(k, svc);
        let n = 8000u64;
        let mut last = 0;
        for p in 0..n {
            last = last.max(w.walk(0, p));
        }
        // n distinct pages, k walkers: makespan = n/k * svc.
        assert_eq!(last, n / k as u64 * svc);
    }

    #[test]
    fn pending_map_is_swept() {
        let mut w = WalkerPool::new(2, 10);
        for p in 0..10_000u64 {
            w.walk(p * 1000, p);
        }
        // All walks complete long before the last arrival; sweep must have
        // kept the map bounded.
        assert!(w.pending.len() < 1000, "pending = {}", w.pending.len());
    }

    #[test]
    fn pending_table_matches_hashmap_reference() {
        // Drive the table and a std HashMap through an identical random
        // insert/overwrite/sweep schedule; state must agree at every step.
        use crate::util::rng::Rng;
        use std::collections::HashMap;
        let mut t = PendingTable::new();
        let mut h: HashMap<u64, Ps> = HashMap::new();
        let mut rng = Rng::seed_from_u64(11);
        for step in 0..20_000u64 {
            let page = rng.gen_range(512);
            match rng.gen_range(10) {
                0..=5 => {
                    let v = step + 1;
                    t.insert(page, v);
                    h.insert(page, v);
                }
                6..=8 => {
                    assert_eq!(t.get(page), h.get(&page).copied(), "step {step}");
                }
                _ => {
                    let cut = step / 2;
                    t.retain_after(cut);
                    h.retain(|_, v| *v > cut);
                }
            }
            assert_eq!(t.len(), h.len(), "step {step}");
        }
        for (k, v) in &h {
            assert_eq!(t.get(*k), Some(*v));
        }
    }

    #[test]
    fn pending_table_grows_past_initial_capacity() {
        let mut t = PendingTable::new();
        for p in 0..10_000u64 {
            t.insert(p, p + 1);
        }
        assert_eq!(t.len(), 10_000);
        for p in 0..10_000u64 {
            assert_eq!(t.get(p), Some(p + 1));
        }
        assert_eq!(t.get(10_001), None);
    }
}
